"""Substrate tests: data pipeline determinism, checkpoint atomicity +
resharding restore, serving engine correctness."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.checkpoint import ckpt
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, SyntheticLM, prefetch
from repro.models import build_model
from repro.serving.engine import Request, ServeConfig, ServingEngine


# ------------------------------------------------------------------ #
# data
# ------------------------------------------------------------------ #
def test_data_deterministic_resume():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for step in (0, 5, 1000):
        ba, bb = a.batch(step), b.batch(step)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    assert not np.array_equal(a.batch(1)["tokens"], a.batch(2)["tokens"])


def test_data_sharding_partitions_batch():
    whole = SyntheticLM(DataConfig(128, 16, 8))
    sh0 = SyntheticLM(DataConfig(128, 16, 8, shard=0, num_shards=2))
    sh1 = SyntheticLM(DataConfig(128, 16, 8, shard=1, num_shards=2))
    assert sh0.local_batch == sh1.local_batch == 4
    # shards draw from distinct streams
    assert not np.array_equal(sh0.batch(3)["tokens"], sh1.batch(3)["tokens"])


def test_data_labels_shifted_and_learnable():
    d = SyntheticLM(DataConfig(64, 32, 4))
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # structure: each row follows one latent mode => its token deltas are
    # dominated by a single value (modulo 5% noise)
    diffs = (b["labels"].astype(int) - b["tokens"].astype(int)) % 64
    for row in diffs:
        _, counts = np.unique(row, return_counts=True)
        assert counts.max() > 0.6 * row.size


def test_prefetch_preserves_order():
    d = SyntheticLM(DataConfig(64, 8, 2))
    direct = [d.batch(i)["tokens"] for i in range(5)]
    fetched = []
    for i, b in enumerate(prefetch(d.iterate(0))):
        fetched.append(b["tokens"])
        if i == 4:
            break
    for x, y in zip(direct, fetched):
        np.testing.assert_array_equal(x, y)


# ------------------------------------------------------------------ #
# checkpoint
# ------------------------------------------------------------------ #
def tree_example(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "scale": jnp.float32(2.5)}}


def test_checkpoint_roundtrip(tmp_path):
    t = tree_example()
    ckpt.save(str(tmp_path), 10, t, meta={"data_step": 40}, shards=2)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    got, meta = ckpt.restore(str(tmp_path), 10, like=like)
    assert meta == {"data_step": 40}
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_checkpoint_latest_and_retention(tmp_path):
    t = tree_example()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert ckpt.available_steps(str(tmp_path)) == [3, 4, 5]


def test_checkpoint_atomic_no_partial_commit(tmp_path, monkeypatch):
    t = tree_example()
    ckpt.save(str(tmp_path), 1, t)

    # make the second save fail mid-write; step_1 must stay intact and no
    # committed step_2 may appear
    import numpy as _np
    orig = _np.savez

    def boom(*a, **kw):
        raise RuntimeError("disk full")

    monkeypatch.setattr(_np, "savez", boom)
    with pytest.raises(RuntimeError):
        ckpt.save(str(tmp_path), 2, t)
    monkeypatch.setattr(_np, "savez", orig)
    assert ckpt.available_steps(str(tmp_path)) == [1]
    got, _ = ckpt.restore(str(tmp_path), 1, like=t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_checkpoint_restore_with_shardings(tmp_path):
    t = tree_example()
    ckpt.save(str(tmp_path), 7, t)
    sh = jax.tree.map(lambda x: jax.devices()[0], t)
    got, _ = ckpt.restore(str(tmp_path), 7, like=t, shardings=sh)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_checkpoint_model_state_roundtrip(tmp_path):
    cfg = replace(ARCHS["yi-6b"].smoke(), compute_dtype="float32",
                  param_dtype="float32")
    model = build_model(cfg, remat="none")
    params, _ = model.init(jax.random.PRNGKey(0))
    from repro.training.optimizer import init_opt_state
    state = {"params": params, "opt": init_opt_state(params)._asdict()}
    ckpt.save(str(tmp_path), 3, state, meta={"arch": cfg.name})
    got, meta = ckpt.restore(str(tmp_path), 3, like=state)
    assert meta["arch"] == cfg.name
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state, got)


# ------------------------------------------------------------------ #
# serving
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def served_model():
    cfg = replace(ARCHS["yi-6b"].smoke(), compute_dtype="float32",
                  param_dtype="float32")
    model = build_model(cfg, remat="none")
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ref_greedy(model, params, prompt, n, max_len=64):
    last, caches = model.prefill(
        params, np.asarray(prompt)[None].astype(np.int32), pad_to=max_len)
    out = [int(jnp.argmax(last, -1)[0])]
    pos = len(prompt)
    for _ in range(n - 1):
        logits, caches = model.decode_step(
            params, jnp.asarray([out[-1]], dtype=jnp.int32), caches, pos)
        out.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    return out


def test_serving_continuous_batching_matches_reference(served_model):
    cfg, model, params = served_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
               for l in (5, 9, 7, 3, 11, 6)]
    refs = [_ref_greedy(model, params, p, 8) for p in prompts]
    eng = ServingEngine(model, params, ServeConfig(batch=3, max_len=64))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, ref in zip(reqs, refs):
        assert r.done and r.out_tokens == ref, (r.rid, r.out_tokens, ref)


def test_serving_batches_share_decode_ticks(served_model):
    """3 slots x 6 requests of 8 tokens should take far fewer ticks than
    serial decoding (continuous batching actually batches)."""
    cfg, model, params = served_model
    rng = np.random.default_rng(1)
    eng = ServingEngine(model, params, ServeConfig(batch=3, max_len=64))
    for i in range(6):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, size=6
                                               ).astype(np.int32),
                           max_new_tokens=8))
    eng.run()
    assert eng.ticks <= 6 * 7 / 2, eng.ticks  # well under serial 42


@pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="the subprocess snippet builds its meshes with "
           "jax.sharding.AxisType (explicit-sharding API, jax >= 0.5.x); "
           "the pinned jax in this environment predates it, so the "
           "snippet can only fail on import — skipped, not broken")
def test_checkpoint_cross_mesh_reshard_subprocess(tmp_path):
    """FT at fleet scale: params saved under one mesh topology restore
    under a different one (the manifest is topology-free; shardings are
    re-applied at load)."""
    import subprocess, sys, textwrap
    snippet = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import ckpt
        d = {str(tmp_path)!r}
        auto = (jax.sharding.AxisType.Auto,) * 2
        m1 = jax.make_mesh((2, 4), ("data", "model"), axis_types=auto)
        tree = {{"w": jnp.arange(64 * 32, dtype=jnp.float32
                                 ).reshape(64, 32)}}
        tree = jax.device_put(tree, NamedSharding(m1, P("data", "model")))
        ckpt.save(d, 1, tree)
        m2 = jax.make_mesh((4, 2), ("data", "model"), axis_types=auto)
        sh2 = {{"w": NamedSharding(m2, P("model", "data"))}}
        like = {{"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}}
        got, _ = ckpt.restore(d, 1, like=like, shardings=sh2)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(64 * 32).reshape(64, 32))
        assert got["w"].sharding.spec == P("model", "data")
        print("RESHARD_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "RESHARD_OK" in out.stdout, out.stdout + out.stderr
