"""Device-sharded engine: byte-identity with the windowed engine at
every device count, the ISSUE acceptance matrix (N ∈ {64, 256} on
1/2/4 host devices, churn/crash/gating scenarios included), overflow
and horizon parity, the api front door, and per-device-aware engine
auto-selection.

Single-device runs execute in-process (the default test environment has
one CPU device); multi-device runs spawn child interpreters because
``--xla_force_host_platform_device_count`` must precede jax
initialization (same pattern as ``tests/test_engine.py``).
"""

import numpy as np
import pytest

from repro.core.vecsim import (WindowOverflowError, execute_windowed,
                               link_add_scenario, sustained_scenario)
from repro.core.vecsim.shard import execute_sharded, pad_rows
from vecsim_cases import build, run_shard_matrix_subprocess


def _assert_matches(win, sh):
    np.testing.assert_array_equal(win.delivered, sh.delivered)
    np.testing.assert_array_equal(win.series, sh.series)
    assert win.stats == sh.stats
    assert win.deliv_count.tolist() == sh.deliv_count.tolist()
    assert win.bcast_done.tolist() == sh.bcast_done.tolist()
    assert win.expired.tolist() == sh.expired.tolist()
    assert win.peak_live == sh.peak_live
    assert (win.lat_sum, win.lat_cnt) == (sh.lat_sum, sh.lat_cnt)
    for key in win.state:
        np.testing.assert_array_equal(win.state[key], sh.state[key],
                                      err_msg=key)


@pytest.mark.parametrize("builder,seed", [
    ("static", 3), ("link_add", 5), ("churn", 7), ("crash", 9),
    ("partition", 11), ("sustained_kreg", 13),
])
def test_sharded_single_device_byte_identical(builder, seed):
    """D=1: the mesh program with no cross-shard traffic still matches
    the windowed reference bit for bit — delivered matrix, series,
    NetStats, aggregates, peak."""
    scn = build(builder, seed, 64)
    win = execute_windowed(scn, scn.m_total, backend="numpy",
                           collect="full", seg_len=16)
    sh = execute_sharded(scn, scn.m_total, n_devices=1, collect="full",
                         seg_len=16)
    assert sh.n_devices == 1
    _assert_matches(win, sh)


def test_sharded_small_window_and_overflow_parity():
    """Retirement actually recycles columns (window below m_total) and
    an impossible window refuses identically on both engines."""
    scn = build("churn", 21, 48)
    w = max(4, scn.m_total // 2)
    try:
        win = execute_windowed(scn, w, backend="numpy", collect="full",
                               seg_len=8)
    except WindowOverflowError:
        with pytest.raises(WindowOverflowError):
            execute_sharded(scn, w, n_devices=1, collect="full", seg_len=8)
        return
    sh = execute_sharded(scn, w, n_devices=1, collect="full", seg_len=8)
    _assert_matches(win, sh)
    with pytest.raises(WindowOverflowError):
        execute_sharded(scn, 2, n_devices=1, collect="full", seg_len=8)


def test_sharded_horizon_expiry_parity():
    """Opt-in horizon force-retirement (including the hung-gate escape
    hatch on a gated scenario) stays byte-identical."""
    scn = link_add_scenario(seed=6, n=40)
    win = execute_windowed(scn, scn.m_total, backend="numpy",
                           collect="full", seg_len=4, horizon=4)
    sh = execute_sharded(scn, scn.m_total, n_devices=1, collect="full",
                         seg_len=4, horizon=4)
    assert win.expired.any()          # the horizon actually bit
    _assert_matches(win, sh)


def test_sharded_aggregate_collect_matches_windowed_aggregates():
    scn = sustained_scenario(seed=4, n=32, k=5, rate=2.0, messages=30,
                             max_delay=2)
    win = execute_windowed(scn, 24, backend="numpy", collect="aggregate",
                           seg_len=8)
    sh = execute_sharded(scn, 24, n_devices=1, collect="aggregate",
                         seg_len=8)
    assert sh.delivered is None
    np.testing.assert_array_equal(win.series, sh.series)
    assert win.stats == sh.stats
    assert win.deliv_count.tolist() == sh.deliv_count.tolist()
    assert win.delivered_frac() == sh.delivered_frac()
    assert win.mean_latency() == sh.mean_latency()


def test_pad_rows():
    assert pad_rows(64, 4) == 64
    assert pad_rows(50, 4) == 52
    assert pad_rows(1, 3) == 3


def test_sharded_runs_via_api_front_door():
    """engine="sharded" through repro.api.run: report fields, extras,
    and exact-engine cross-validation."""
    from repro.api import MetricsSpec, RunSpec, TrafficSpec, WindowSpec, run
    rep = run(RunSpec(protocol="pc", engine="sharded", n=64, seed=11,
                      traffic=TrafficSpec(kind="poisson", rate=2.0,
                                          messages=24),
                      window=WindowSpec(window=24, seg_len=4,
                                        collect="full"),
                      metrics=MetricsSpec(oracle=True, crossval=True)))
    assert rep.engine == "sharded" and rep.backend == "jax"
    assert rep.window == 24
    assert rep.delivered_frac == 1.0
    assert rep.oracle.ok and rep.crossval_ok
    assert rep.extras["devices"] >= 1


def test_sharded_spec_validation():
    from repro.api import RunSpec, ShardSpec, SpecError
    with pytest.raises(SpecError, match="jax device-mesh"):
        RunSpec(engine="sharded", backend="numpy").validate()
    with pytest.raises(SpecError, match="shard.devices"):
        RunSpec(engine="vec", shard=ShardSpec(devices=2)).validate()
    with pytest.raises(SpecError, match="must be an int >= 1"):
        RunSpec(engine="sharded", shard=ShardSpec(devices=0)).validate()
    with pytest.raises(SpecError, match="no windowed engine"):
        RunSpec(protocol="vc", engine="sharded").validate()
    RunSpec(engine="sharded", shard=ShardSpec(devices=1)).validate()


def test_sharded_rejects_more_devices_than_visible():
    import jax
    from repro.core.vecsim.shard import resolve_devices
    avail = jax.device_count()
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        resolve_devices(avail + 1)


# --------------------------------------------------------------------- #
# The acceptance matrix: 2 and 4 host devices in child interpreters
# --------------------------------------------------------------------- #
def test_sharded_two_devices_matrix_subprocess():
    run_shard_matrix_subprocess(
        [("churn", 7, 64, 1.0, 8),
         ("crash", 9, 64, 1.0, 16),
         ("link_add", 5, 256, 1.0, 16),    # gating at the larger N
         ("churn", 3, 64, 0.5, 8)],        # retirement recycling
        shards=2)


_AUTO_SELECT_SNIPPET = """
from repro.api import (RunSpec, TrafficSpec, MetricsSpec, build_scenario,
                       run, select_engine)
spec = RunSpec(n=2000, memory_budget_mb=1,
               traffic=TrafficSpec(kind="poisson", rate=3.0,
                                   messages=500)).validate()
eng, wdw = select_engine(spec, build_scenario(spec))
assert eng == "sharded", eng
assert wdw == 4 * (1 << 20) // (8 * 2000), wdw
rep = run(RunSpec(n=256, memory_budget_mb=1, seed=5,
                  traffic=TrafficSpec(kind="poisson", rate=4.0,
                                      messages=600),
                  metrics=MetricsSpec(crossval=False)))
assert rep.engine == "sharded", rep.engine
assert rep.extras["devices"] == 4
assert rep.delivered_frac == 1.0, rep.delivered_frac
print("AUTO_OK")
"""


def test_sharded_four_devices_matrix_and_auto_selection_subprocess():
    """4 devices: churn/crash at N=64 and N=256 (odd N exercises the
    padding path), plus the per-device-aware auto-selection rule picking
    the sharded engine with the D-scaled window on a real mesh."""
    out = run_shard_matrix_subprocess(
        [("churn", 8, 256, 1.0, 16),
         ("crash", 2, 256, 1.0, 16),
         ("waves", 4, 50, 1.0, 8)],       # 50 % 4 != 0: padding rows
        shards=4, extra=_AUTO_SELECT_SNIPPET)
    assert "AUTO_OK" in out
