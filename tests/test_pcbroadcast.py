"""PC-broadcast (Algorithm 2): the Fig. 3 scenario is fixed; ping phases
flush buffers in order; link removal is harmless (Lemma 1)."""

import pytest

from repro.core import (Network, PCBroadcast, check_trace, msg_id,
                        ring_plus_random)
from tests.test_rbroadcast import fig3_topology


@pytest.mark.parametrize("ping_mode", ["flood", "route"])
def test_fig3_fixed_by_ping_phase(ping_mode):
    net, (A, B, D) = fig3_topology(PCBroadcast, ping_mode=ping_mode)
    net.procs[A].broadcast("a")
    net.run(until=1.0)
    net.connect(A, D, delay=0.1)          # gated: unsafe until pong
    assert D not in net.procs[A].Q
    assert D in net.procs[A].B
    net.procs[A].broadcast("a'")          # buffered for D, sent to B
    net.run()
    rep = check_trace(net.trace, all_pids={A, B, D})
    assert rep.ok, rep.summary()
    # Link became safe after the phase:
    assert D in net.procs[A].Q and D not in net.procs[A].B
    # D delivered a before a':
    order = [m.payload for m in net.procs[D].delivered_log]
    assert order.index("a") < order.index("a'")


@pytest.mark.parametrize("ping_mode", ["flood", "route"])
def test_buffered_messages_flushed_in_order(ping_mode):
    """Messages delivered during the phase arrive over the new link in
    delivery order (Lemma 3's FIFO flush).  always_gate=True exercises the
    paper's unconditional gating (nothing delivered yet)."""
    net, (A, B, D) = fig3_topology(PCBroadcast, ping_mode=ping_mode,
                                   always_gate=True)
    net.connect(A, D, delay=0.05)
    assert D in net.procs[A].B
    for i in range(5):
        net.procs[A].broadcast(f"m{i}")   # all delivered during the phase
    assert len(net.procs[A].B[D][1]) == 5
    net.run()
    rep = check_trace(net.trace, all_pids={A, B, D})
    assert rep.ok, rep.summary()
    payloads = [m.payload for m in net.procs[D].delivered_log]
    assert payloads == [f"m{i}" for i in range(5)]


def test_sole_link_is_immediately_safe():
    """|Q| <= 1 at open(q): no alternate path exists, no gating (Alg. 2)."""
    net = Network(seed=0)
    net.add_process(PCBroadcast(0))
    net.add_process(PCBroadcast(1))
    net.connect(0, 1)
    assert 1 in net.procs[0].Q and not net.procs[0].B


def test_link_removals_preserve_causality():
    """Lemma 1: removals neither reorder nor (absent partition) lose."""
    net = Network(seed=4, default_delay=2.0)
    n = 10
    for pid in range(n):
        net.add_process(PCBroadcast(pid))
    ring_plus_random(net, range(n), k=4)
    net.run()  # let bootstrap ping phases settle
    net.procs[0].broadcast("before")
    net.run(until=net.time + 1.0)
    # Remove a batch of links (keeping the ring => still connected).
    removed = 0
    for (a, b), lk in list(net.links.items()):
        if lk.alive and (b != (a + 1) % n) and removed < 8:
            net.disconnect(a, b)
            removed += 1
    net.procs[3].broadcast("after")
    net.run()
    rep = check_trace(net.trace, all_pids=set(range(n)))
    assert rep.ok, rep.summary()


@pytest.mark.parametrize("ping_mode", ["flood", "route"])
def test_churn_storm_stays_causal(ping_mode):
    """Random adds/removes interleaved with broadcasts: never a violation."""
    import random
    rng = random.Random(7)
    net = Network(seed=7, default_delay=lambda t, r: r.uniform(0.5, 3.0),
                  oob_delay=0.2)
    n = 16
    for pid in range(n):
        net.add_process(PCBroadcast(pid, ping_mode=ping_mode))
    ring_plus_random(net, range(n), k=3)
    for step in range(30):
        horizon = net.time + rng.uniform(0.5, 2.0)
        net.run(until=horizon)
        op = rng.random()
        if op < 0.4:
            net.procs[rng.randrange(n)].broadcast(("msg", step))
        elif op < 0.7:
            a, b = rng.randrange(n), rng.randrange(n)
            if a != b and not net.has_link(a, b):
                net.connect(a, b)
        else:
            cands = [(a, b) for (a, b), lk in net.links.items()
                     if lk.alive and b != (a + 1) % n]
            if cands:
                net.disconnect(*rng.choice(cands))
    net.run()
    rep = check_trace(net.trace, all_pids=set(range(n)))
    # Causality + integrity must hold unconditionally:
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()
    # The ring survived, so agreement holds too:
    assert rep.ok, rep.summary()
