"""Vectorized lockstep simulator: cross-validation against the exact
event simulator (same scenario, byte-identical delivered-message
multisets, oracle-clean traces), numpy/jax backend parity, Fig. 3 at the
round level, crash semantics, and NetStats schema sanity."""

import numpy as np
import pytest

from repro.core import NetStats, check_trace
from repro.core.vecsim import (VecScenario, WindowOverflowError, build_trace,
                               churn_scenario, churn_wave_scenario,
                               crash_scenario, cross_validate,
                               delivered_multiset, full_out_mask,
                               kregular_topology, link_add_scenario,
                               mean_shortest_path_vec,
                               partition_heal_scenario, poisson_traffic,
                               run_vec, safe_out_mask, smallworld_topology,
                               static_scenario, sustained_scenario,
                               unsafe_link_stats_vec, vc_overhead_model)

SCENARIOS = {
    "static": static_scenario,
    "link_add": link_add_scenario,
    "churn": churn_scenario,
}


# --------------------------------------------------------------------- #
# Cross-validation: one scenario, two engines, same deliveries
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("n", [64, 256])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_vec_matches_exact_engine(name, n):
    scn = SCENARIOS[name](seed=n + 17, n=n)
    out = cross_validate(scn)
    # byte-identical delivered-message multisets across the two engines
    assert out["vec_multiset"] == out["exact_multiset"]
    # every correct process delivered every message (connected overlay)
    assert len(out["vec_multiset"]) == n * scn.m_app
    # zero causal violations (and full broadcast spec) on both traces
    assert out["vec_report"].ok, out["vec_report"].summary()
    assert out["exact_report"].ok, out["exact_report"].summary()


@pytest.mark.parametrize("name", ["link_add", "churn"])
def test_gating_scenarios_exercise_ping_phases(name):
    """The equivalence above must not be vacuous: the dynamic scenarios
    really do put links through unsafe (gated) phases."""
    scn = SCENARIOS[name](seed=5, n=64)
    res = run_vec(scn, backend="numpy")
    assert int(res.series[:, 5].sum()) > 0          # gated link-rounds
    assert res.stats.oob_messages > 0               # pongs flowed
    assert res.stats.sent_control > 0               # pings flowed


def test_crossval_catches_a_lost_delivery():
    """Sanity of the harness itself: corrupting one delivery breaks
    multiset equality."""
    scn = static_scenario(seed=0, n=64)
    out = cross_validate(scn)
    res = out["vec"]
    pid = 7
    res.delivered[pid, 0] = -1
    assert delivered_multiset(res) != out["exact_multiset"]


# --------------------------------------------------------------------- #
# Backend parity: numpy reference vs jitted jax scan
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(SCENARIOS) + ["crash"])
def test_numpy_jax_backend_parity(name):
    builder = SCENARIOS.get(name, crash_scenario)
    scn = builder(seed=3, n=48)
    r_np = run_vec(scn, backend="numpy")
    r_jx = run_vec(scn, backend="jax")
    np.testing.assert_array_equal(r_np.delivered, r_jx.delivered)
    np.testing.assert_array_equal(r_np.series, r_jx.series)
    assert r_np.stats == r_jx.stats


def test_snapshot_round_matches_between_backends():
    scn = churn_scenario(seed=9, n=48)
    snap = int(scn.add_round[-1])
    r_np = run_vec(scn, backend="numpy", snapshot_round=snap)
    r_jx = run_vec(scn, backend="jax", snapshot_round=snap)
    for key in r_np.snapshot:
        np.testing.assert_array_equal(r_np.snapshot[key],
                                      r_jx.snapshot[key], err_msg=key)


# --------------------------------------------------------------------- #
# Fig. 3 at the round level (mirrors tests/test_engine.py)
# --------------------------------------------------------------------- #
def fig3_scenario(mode):
    """A(0) -> B(1) -> D(2) slow chain; fast link A->D added mid-flight."""
    n, k = 3, 3
    adj0 = np.full((n, k), -1, np.int32)
    delay0 = np.ones((n, k), np.int32) * 5
    adj0[0, 0] = 1   # A -> B slow
    adj0[1, 0] = 2   # B -> D slow
    adj0[1, 1] = 0   # B -> A
    adj0[2, 0] = 1   # D -> B
    i32 = lambda *a: np.asarray(a, np.int32)  # noqa: E731
    return VecScenario(
        n=n, k=k, rounds=40, adj0=adj0, delay0=delay0,
        bcast_round=i32(0, 3), bcast_origin=i32(0, 0),
        add_round=i32(2), add_p=i32(0), add_k=i32(2), add_q=i32(2),
        add_delay=i32(1), mode=mode).validate()


def test_fig3_r_mode_violates_causal_order():
    res = run_vec(fig3_scenario("r"), backend="numpy")
    rep = check_trace(build_trace(res), all_pids={0, 1, 2})
    assert rep.causal_violations
    assert res.delivered[2, 1] < res.delivered[2, 0]   # a' before a at D


def test_fig3_pc_mode_gates_the_shortcut():
    res = run_vec(fig3_scenario("pc"), backend="numpy")
    rep = check_trace(build_trace(res), all_pids={0, 1, 2})
    assert rep.ok, rep.summary()
    assert res.delivered[2, 0] < res.delivered[2, 1]


# --------------------------------------------------------------------- #
# Crashes (Fig. 5b silent departures)
# --------------------------------------------------------------------- #
def test_crash_freezes_process_and_spares_the_rest():
    scn = crash_scenario(seed=5, n=64)
    res = run_vec(scn, backend="numpy")
    crashed = np.nonzero(res.state["crashed"])[0]
    assert crashed.size == len(scn.crash_pid)
    t_crash = int(scn.crash_round[0])
    # crashed processes deliver nothing at or after their crash round
    assert (res.delivered[crashed] < t_crash).all()
    # correct processes still deliver everything that was broadcast
    assert res.delivered_frac() == 1.0
    rep = check_trace(build_trace(res), crashed=set(crashed.tolist()),
                      all_pids=set(range(scn.n)))
    assert rep.ok, rep.summary()


# --------------------------------------------------------------------- #
# NetStats schema + metrics
# --------------------------------------------------------------------- #
def test_netstats_schema_on_static_run():
    n, m_app = 64, 8
    scn = static_scenario(seed=1, n=n, m_app=m_app)
    res = run_vec(scn, backend="numpy")
    s = res.stats
    assert isinstance(s, NetStats)
    assert s.deliveries == n * m_app
    # static pc run: no gating -> no pings/pongs, O(1) overhead exactly
    assert s.sent_control == 0 and s.oob_messages == 0
    assert s.control_bytes == 16 * s.sent_messages
    # flooding sends one copy per (delivery, out-link); receipts can't
    # exceed sends
    assert s.sent_messages >= s.deliveries - m_app
    assert s.duplicate_receipts < s.sent_messages


def test_static_metrics_safe_equals_full_graph():
    scn = static_scenario(seed=2, n=128, k=5)
    res = run_vec(scn, backend="numpy", snapshot_round=scn.rounds - 1)
    snap = res.snapshot
    srcs = list(range(0, 128, 16))
    sp_safe = mean_shortest_path_vec(snap["adj"], safe_out_mask(snap), srcs)
    sp_all = mean_shortest_path_vec(snap["adj"], full_out_mask(snap), srcs)
    assert sp_safe == sp_all > 1.0
    unsafe, buffered, mx = unsafe_link_stats_vec(snap, scn.rounds - 1,
                                                 scn.m_app)
    assert unsafe == buffered == mx == 0


def test_vc_overhead_model_grows_with_broadcasters():
    small = run_vec(static_scenario(seed=3, n=64, m_app=4), backend="numpy")
    large = run_vec(static_scenario(seed=3, n=64, m_app=24), backend="numpy")
    b_small, _ = vc_overhead_model(small)
    b_large, _ = vc_overhead_model(large)
    assert b_large > b_small >= 16.0
    # PC-broadcast's overhead is O(1) regardless
    for res in (small, large):
        assert res.stats.control_bytes / res.stats.sent_messages == 16.0


def test_msg_counters_are_per_origin_sequential():
    scn = churn_scenario(seed=11, n=32)
    counters = scn.msg_counters()
    seen = {}
    for origin, c in zip(scn.bcast_origin.tolist(), counters.tolist()):
        seen[origin] = seen.get(origin, 0) + 1
        assert c == seen[origin]


# --------------------------------------------------------------------- #
# Streaming windowed engine (vecsim.stream)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("name", sorted(SCENARIOS) + ["crash"])
def test_windowed_byte_identical_to_monolithic(name, backend):
    """The windowed acceptance property on every scenario family: same
    delivered matrix, same per-round series, same NetStats."""
    builder = SCENARIOS.get(name, crash_scenario)
    scn = builder(seed=21, n=40)
    mono = run_vec(scn, backend="numpy")
    win = run_vec(scn, backend=backend, window=scn.m_total,
                  seg_len=8, collect="full")
    np.testing.assert_array_equal(mono.delivered, win.delivered)
    np.testing.assert_array_equal(mono.series, win.series)
    assert mono.stats == win.stats
    assert not win.expired.any()
    assert win.delivered_frac() == mono.delivered_frac()
    assert win.mean_latency() == pytest.approx(mono.mean_latency())


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_windowed_sub_mtotal_window_on_sustained_traffic(backend):
    """Sustained traffic is where the window buys memory: messages
    retire as the stream flows, so a buffer far below M_total carries
    the whole run without loss of fidelity."""
    scn = sustained_scenario(seed=11, n=64, k=6, rate=2.0, messages=30,
                             max_delay=2)
    mono = run_vec(scn, backend="numpy")
    win = run_vec(scn, backend=backend, window=20, seg_len=4,
                  collect="full")
    assert win.peak_live <= 20 < scn.m_total
    np.testing.assert_array_equal(mono.delivered, win.delivered)
    np.testing.assert_array_equal(mono.series, win.series)
    assert mono.stats == win.stats


def test_windowed_overflow_raises_not_diverges():
    scn = static_scenario(seed=1, n=48, m_app=12)
    with pytest.raises(WindowOverflowError):
        run_vec(scn, backend="numpy", window=2, seg_len=4)


def test_windowed_horizon_expires_and_flags():
    """A horizon shorter than the flood time force-retires columns and
    says so in ``expired`` — partial delivery is reported, not hidden."""
    scn = static_scenario(seed=5, n=64, k=4, m_app=10)
    win = run_vec(scn, backend="numpy", window=6, seg_len=2, horizon=4,
                  collect="full")
    assert win.expired.any()
    assert win.delivered_frac() < 1.0


def test_windowed_horizon_unpins_hung_gates():
    """A gate whose ping can never be answered (its target crashed) pins
    the ping column; the horizon must clear the hung gate and recycle
    the column instead of letting it occupy the window forever."""
    i32 = lambda *a: np.asarray(a, np.int32)  # noqa: E731
    n, k = 4, 3
    adj0 = np.full((n, k), -1, np.int32)
    adj0[:, 0] = (np.arange(n) + 1) % n       # ring
    delay0 = np.ones((n, k), np.int32)
    scn = VecScenario(
        n=n, k=k, rounds=40, adj0=adj0, delay0=delay0,
        bcast_round=i32(0, 1, 20), bcast_origin=i32(0, 1, 2),
        # process 3 crashes silently, then 0 gains a link to it: the
        # gate's ping floods but 3 never delivers it -> no pong, ever
        add_round=i32(10), add_p=i32(0), add_k=i32(2), add_q=i32(3),
        add_delay=i32(1),
        crash_round=i32(5), crash_pid=i32(3)).validate()
    mono = run_vec(scn, backend="numpy")
    assert (mono.state["gate"] >= 0).any()        # the gate really hangs
    win = run_vec(scn, backend="numpy", window=scn.m_total, seg_len=4,
                  horizon=8, collect="full")
    assert (win.state["gate"] < 0).all()          # horizon cleared it
    assert win.expired.any()
    # app deliveries among the survivors are unaffected by the expiry
    alive = ~win.state["crashed"]
    np.testing.assert_array_equal(mono.delivered[alive][:, : scn.m_app],
                                  win.delivered[alive][:, : scn.m_app])


def test_windowed_aggregate_mode_matches_full_counts():
    scn = churn_scenario(seed=13, n=40)
    full = run_vec(scn, backend="numpy", window=scn.m_total, collect="full")
    agg = run_vec(scn, backend="numpy", window=scn.m_total,
                  collect="aggregate")
    assert agg.delivered is None
    np.testing.assert_array_equal(
        agg.deliv_count, (full.delivered >= 0).sum(axis=0))
    assert agg.stats == full.stats
    assert agg.mean_latency() == pytest.approx(full.mean_latency())
    assert agg.bcast_done.all()


def test_windowed_snapshot_metrics_match_monolithic():
    scn = churn_scenario(seed=9, n=48)
    snap_t = int(scn.add_round[-1])
    mono = run_vec(scn, backend="numpy", snapshot_round=snap_t)
    win = run_vec(scn, backend="numpy", window=scn.m_total, seg_len=8,
                  snapshot_round=snap_t)
    assert win.snapshot is not None and "is_app" in win.snapshot
    assert (unsafe_link_stats_vec(win.snapshot, snap_t, scn.m_app)
            == unsafe_link_stats_vec(mono.snapshot, snap_t, scn.m_app))
    srcs = list(range(0, scn.n, 8))
    for mask_fn in (safe_out_mask, full_out_mask):
        assert (mean_shortest_path_vec(win.snapshot["adj"],
                                       mask_fn(win.snapshot), srcs)
                == mean_shortest_path_vec(mono.snapshot["adj"],
                                          mask_fn(mono.snapshot), srcs))


# --------------------------------------------------------------------- #
# New topology / traffic / dynamic-scenario builders
# --------------------------------------------------------------------- #
def test_kregular_topology_is_regular_in_and_out():
    n, k = 120, 6
    adj, _ = kregular_topology(seed=2, n=n, k=k, free_slots=1)
    used = adj[:, : k - 1]
    assert (used >= 0).all()
    assert (used != np.arange(n)[:, None]).all()          # no self-links
    indeg = np.bincount(used.ravel(), minlength=n)
    assert indeg.min() == indeg.max() == k - 1            # in-regular too


def test_smallworld_topology_keeps_ring_and_rewires():
    n, k = 120, 6
    lattice, _ = smallworld_topology(seed=2, n=n, k=k, beta=0.0)
    rewired, _ = smallworld_topology(seed=2, n=n, k=k, beta=0.5)
    np.testing.assert_array_equal(lattice[:, 0], (np.arange(n) + 1) % n)
    np.testing.assert_array_equal(rewired[:, 0], (np.arange(n) + 1) % n)
    assert (lattice[:, 1:] != rewired[:, 1:]).any()       # something moved
    mask = rewired >= 0
    srcs = list(range(0, n, 16))
    assert (mean_shortest_path_vec(rewired, mask, srcs)
            < mean_shortest_path_vec(lattice, lattice >= 0, srcs))


def test_poisson_traffic_unique_origin_round_pairs():
    r, o = poisson_traffic(seed=3, n=50, rate=4.0, t0=0, t1=40)
    assert (np.diff(r) >= 0).all()
    pairs = set(zip(o.tolist(), r.tolist()))
    assert len(pairs) == len(r)


@pytest.mark.parametrize("name,builder", [
    ("sustained", lambda: sustained_scenario(seed=11, n=64, k=6, rate=2.0,
                                             messages=30, max_delay=2)),
    ("waves", lambda: churn_wave_scenario(seed=11, n=64, waves=3)),
    ("partition", lambda: partition_heal_scenario(
        seed=11, n=64, traffic_during_partition=True)),
])
def test_new_builders_cross_validate_against_exact_engine(name, builder):
    scn = builder()
    out = cross_validate(scn)
    assert out["vec_multiset"] == out["exact_multiset"]
    assert out["vec_report"].ok, out["vec_report"].summary()
    assert out["exact_report"].ok, out["exact_report"].summary()
    assert out["vec"].delivered_frac() == 1.0


def test_partition_heal_exercises_ping_phase_and_resolves():
    scn = partition_heal_scenario(seed=4, n=64)
    res = run_vec(scn, backend="numpy")
    assert int(res.series[:, 5].sum()) > 0        # heal links were gated
    assert res.stats.oob_messages > 0             # pongs flowed
    assert (res.state["gate"] < 0).all()          # every gate resolved


# --------------------------------------------------------------------- #
# VecScenario.validate() failure paths: informative errors, not asserts
# --------------------------------------------------------------------- #
def _valid_parts(n=8, k=3):
    i32 = lambda *a: np.asarray(a, np.int32)  # noqa: E731
    adj0 = np.full((n, k), -1, np.int32)
    adj0[:, 0] = (np.arange(n) + 1) % n
    delay0 = np.ones((n, k), np.int32)
    return dict(n=n, k=k, rounds=30, adj0=adj0, delay0=delay0,
                bcast_round=i32(0, 2), bcast_origin=i32(0, 1))


def test_validate_accepts_a_minimal_scenario():
    VecScenario(**_valid_parts()).validate()


@pytest.mark.parametrize("mutate,match", [
    # ragged schedules: parallel arrays of different lengths
    (lambda p: p.update(bcast_origin=p["bcast_origin"][:1]),
     "ragged bcast schedule"),
    (lambda p: p.update(add_round=np.asarray([3], np.int32)),
     "ragged add schedule"),
    (lambda p: p.update(rm_round=np.asarray([3, 4], np.int32),
                        rm_p=np.asarray([1], np.int32),
                        rm_k=np.asarray([1], np.int32)),
     "ragged rm schedule"),
    (lambda p: p.update(crash_round=np.asarray([3], np.int32)),
     "ragged crash schedule"),
    # out-of-range ids
    (lambda p: p.update(bcast_origin=np.asarray([0, 99], np.int32)),
     "bcast_origin out of range"),
    (lambda p: p.update(crash_round=np.asarray([3], np.int32),
                        crash_pid=np.asarray([-2], np.int32)),
     "crash_pid out of range"),
    (lambda p: p.update(add_round=np.asarray([3], np.int32),
                        add_p=np.asarray([1], np.int32),
                        add_k=np.asarray([7], np.int32),
                        add_q=np.asarray([4], np.int32),
                        add_delay=np.asarray([1], np.int32)),
     "add_k out of range"),
    # bad slot tables
    (lambda p: p["adj0"].__setitem__((0, 1), 99),
     "adj0 targets"),
    (lambda p: p["adj0"].__setitem__((0, 1), 0),
     "self-link at process 0"),
    (lambda p: (p["adj0"].__setitem__((0, 1), 1)),
     "duplicate out-target at process 0"),
    (lambda p: (p["adj0"].__setitem__((0, 1), 2),
                p["delay0"].__setitem__((0, 1), 0)),
     "delay0 >= 1"),
    # schedule semantics
    (lambda p: p.update(bcast_round=np.asarray([2, 0], np.int32)),
     "not sorted"),
    (lambda p: p.update(bcast_round=np.asarray([2, 2], np.int32),
                        bcast_origin=np.asarray([1, 1], np.int32)),
     "duplicate \\(origin, round\\) broadcast"),
    (lambda p: p.update(rm_round=np.asarray([3], np.int32),
                        rm_p=np.asarray([1], np.int32),
                        rm_k=np.asarray([0], np.int32)),
     "slot 0 .* connectivity ring"),
    (lambda p: p.update(mode="tcp"), "mode='tcp'"),
])
def test_validate_failure_paths_raise_informative_errors(mutate, match):
    parts = _valid_parts()
    mutate(parts)
    with pytest.raises(ValueError, match=match):
        VecScenario(**parts).validate()


def test_validate_rejects_same_round_adds_on_one_process():
    parts = _valid_parts()
    parts.update(add_round=np.asarray([3, 3], np.int32),
                 add_p=np.asarray([1, 1], np.int32),
                 add_k=np.asarray([1, 2], np.int32),
                 add_q=np.asarray([4, 5], np.int32),
                 add_delay=np.asarray([1, 1], np.int32))
    with pytest.raises(ValueError, match="share a process"):
        VecScenario(**parts).validate()
