"""Vectorized lockstep simulator: cross-validation against the exact
event simulator (same scenario, byte-identical delivered-message
multisets, oracle-clean traces), numpy/jax backend parity, Fig. 3 at the
round level, crash semantics, and NetStats schema sanity."""

import numpy as np
import pytest

from repro.core import NetStats, check_trace
from repro.core.vecsim import (VecScenario, build_trace, churn_scenario,
                               crash_scenario, cross_validate,
                               delivered_multiset, full_out_mask,
                               link_add_scenario, mean_shortest_path_vec,
                               run_vec, safe_out_mask, static_scenario,
                               unsafe_link_stats_vec, vc_overhead_model)

SCENARIOS = {
    "static": static_scenario,
    "link_add": link_add_scenario,
    "churn": churn_scenario,
}


# --------------------------------------------------------------------- #
# Cross-validation: one scenario, two engines, same deliveries
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("n", [64, 256])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_vec_matches_exact_engine(name, n):
    scn = SCENARIOS[name](seed=n + 17, n=n)
    out = cross_validate(scn)
    # byte-identical delivered-message multisets across the two engines
    assert out["vec_multiset"] == out["exact_multiset"]
    # every correct process delivered every message (connected overlay)
    assert len(out["vec_multiset"]) == n * scn.m_app
    # zero causal violations (and full broadcast spec) on both traces
    assert out["vec_report"].ok, out["vec_report"].summary()
    assert out["exact_report"].ok, out["exact_report"].summary()


@pytest.mark.parametrize("name", ["link_add", "churn"])
def test_gating_scenarios_exercise_ping_phases(name):
    """The equivalence above must not be vacuous: the dynamic scenarios
    really do put links through unsafe (gated) phases."""
    scn = SCENARIOS[name](seed=5, n=64)
    res = run_vec(scn, backend="numpy")
    assert int(res.series[:, 5].sum()) > 0          # gated link-rounds
    assert res.stats.oob_messages > 0               # pongs flowed
    assert res.stats.sent_control > 0               # pings flowed


def test_crossval_catches_a_lost_delivery():
    """Sanity of the harness itself: corrupting one delivery breaks
    multiset equality."""
    scn = static_scenario(seed=0, n=64)
    out = cross_validate(scn)
    res = out["vec"]
    pid = 7
    res.delivered[pid, 0] = -1
    assert delivered_multiset(res) != out["exact_multiset"]


# --------------------------------------------------------------------- #
# Backend parity: numpy reference vs jitted jax scan
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(SCENARIOS) + ["crash"])
def test_numpy_jax_backend_parity(name):
    builder = SCENARIOS.get(name, crash_scenario)
    scn = builder(seed=3, n=48)
    r_np = run_vec(scn, backend="numpy")
    r_jx = run_vec(scn, backend="jax")
    np.testing.assert_array_equal(r_np.delivered, r_jx.delivered)
    np.testing.assert_array_equal(r_np.series, r_jx.series)
    assert r_np.stats == r_jx.stats


def test_snapshot_round_matches_between_backends():
    scn = churn_scenario(seed=9, n=48)
    snap = int(scn.add_round[-1])
    r_np = run_vec(scn, backend="numpy", snapshot_round=snap)
    r_jx = run_vec(scn, backend="jax", snapshot_round=snap)
    for key in r_np.snapshot:
        np.testing.assert_array_equal(r_np.snapshot[key],
                                      r_jx.snapshot[key], err_msg=key)


# --------------------------------------------------------------------- #
# Fig. 3 at the round level (mirrors tests/test_engine.py)
# --------------------------------------------------------------------- #
def fig3_scenario(mode):
    """A(0) -> B(1) -> D(2) slow chain; fast link A->D added mid-flight."""
    n, k = 3, 3
    adj0 = np.full((n, k), -1, np.int32)
    delay0 = np.ones((n, k), np.int32) * 5
    adj0[0, 0] = 1   # A -> B slow
    adj0[1, 0] = 2   # B -> D slow
    adj0[1, 1] = 0   # B -> A
    adj0[2, 0] = 1   # D -> B
    i32 = lambda *a: np.asarray(a, np.int32)  # noqa: E731
    return VecScenario(
        n=n, k=k, rounds=40, adj0=adj0, delay0=delay0,
        bcast_round=i32(0, 3), bcast_origin=i32(0, 0),
        add_round=i32(2), add_p=i32(0), add_k=i32(2), add_q=i32(2),
        add_delay=i32(1), mode=mode).validate()


def test_fig3_r_mode_violates_causal_order():
    res = run_vec(fig3_scenario("r"), backend="numpy")
    rep = check_trace(build_trace(res), all_pids={0, 1, 2})
    assert rep.causal_violations
    assert res.delivered[2, 1] < res.delivered[2, 0]   # a' before a at D


def test_fig3_pc_mode_gates_the_shortcut():
    res = run_vec(fig3_scenario("pc"), backend="numpy")
    rep = check_trace(build_trace(res), all_pids={0, 1, 2})
    assert rep.ok, rep.summary()
    assert res.delivered[2, 0] < res.delivered[2, 1]


# --------------------------------------------------------------------- #
# Crashes (Fig. 5b silent departures)
# --------------------------------------------------------------------- #
def test_crash_freezes_process_and_spares_the_rest():
    scn = crash_scenario(seed=5, n=64)
    res = run_vec(scn, backend="numpy")
    crashed = np.nonzero(res.state["crashed"])[0]
    assert crashed.size == len(scn.crash_pid)
    t_crash = int(scn.crash_round[0])
    # crashed processes deliver nothing at or after their crash round
    assert (res.delivered[crashed] < t_crash).all()
    # correct processes still deliver everything that was broadcast
    assert res.delivered_frac() == 1.0
    rep = check_trace(build_trace(res), crashed=set(crashed.tolist()),
                      all_pids=set(range(scn.n)))
    assert rep.ok, rep.summary()


# --------------------------------------------------------------------- #
# NetStats schema + metrics
# --------------------------------------------------------------------- #
def test_netstats_schema_on_static_run():
    n, m_app = 64, 8
    scn = static_scenario(seed=1, n=n, m_app=m_app)
    res = run_vec(scn, backend="numpy")
    s = res.stats
    assert isinstance(s, NetStats)
    assert s.deliveries == n * m_app
    # static pc run: no gating -> no pings/pongs, O(1) overhead exactly
    assert s.sent_control == 0 and s.oob_messages == 0
    assert s.control_bytes == 16 * s.sent_messages
    # flooding sends one copy per (delivery, out-link); receipts can't
    # exceed sends
    assert s.sent_messages >= s.deliveries - m_app
    assert s.duplicate_receipts < s.sent_messages


def test_static_metrics_safe_equals_full_graph():
    scn = static_scenario(seed=2, n=128, k=5)
    res = run_vec(scn, backend="numpy", snapshot_round=scn.rounds - 1)
    snap = res.snapshot
    srcs = list(range(0, 128, 16))
    sp_safe = mean_shortest_path_vec(snap["adj"], safe_out_mask(snap), srcs)
    sp_all = mean_shortest_path_vec(snap["adj"], full_out_mask(snap), srcs)
    assert sp_safe == sp_all > 1.0
    unsafe, buffered, mx = unsafe_link_stats_vec(snap, scn.rounds - 1,
                                                 scn.m_app)
    assert unsafe == buffered == mx == 0


def test_vc_overhead_model_grows_with_broadcasters():
    small = run_vec(static_scenario(seed=3, n=64, m_app=4), backend="numpy")
    large = run_vec(static_scenario(seed=3, n=64, m_app=24), backend="numpy")
    b_small, _ = vc_overhead_model(small)
    b_large, _ = vc_overhead_model(large)
    assert b_large > b_small >= 16.0
    # PC-broadcast's overhead is O(1) regardless
    for res in (small, large):
        assert res.stats.control_bytes / res.stats.sent_messages == 16.0


def test_msg_counters_are_per_origin_sequential():
    scn = churn_scenario(seed=11, n=32)
    counters = scn.msg_counters()
    seen = {}
    for origin, c in zip(scn.bcast_origin.tolist(), counters.tolist()):
        seen[origin] = seen.get(origin, 0) + 1
        assert c == seen[origin]
