"""Spray-like overlay dynamics driving the protocol's open/close path."""

import pytest

from repro.core import (BoundedPCBroadcast, Network, PCBroadcast,
                        SprayOverlay, check_trace, ring_plus_random)
from repro.obs import (full_graph, mean_shortest_path, safe_graph,
                       unsafe_link_stats)


def spray_net(n=40, seed=5, delay=0.5, period=20.0):
    net = Network(seed=seed, default_delay=delay, oob_delay=delay / 2)
    for pid in range(n):
        net.add_process(BoundedPCBroadcast(
            pid, ping_mode="route", max_size=64, max_retry=10,
            ping_timeout=60.0))
    ring_plus_random(net, range(n), k=4)
    overlay = SprayOverlay(net, range(n), period=period)
    return net, overlay


def test_spray_exchanges_churn_links_and_stay_causal():
    net, overlay = spray_net()
    overlay.start()
    # Broadcast while the overlay churns.
    for i, t in enumerate(range(5, 65, 5)):
        net.run(until=float(t))
        net.procs[i % 40].broadcast(("m", i))
    overlay.stop()
    net.run(until=net.time + 500.0)
    assert overlay.exchanges > 20
    assert overlay.links_added > 0 and overlay.links_removed > 0
    rep = check_trace(net.trace, all_pids=set(range(40)), check_agreement=False)
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()


def test_safe_graph_path_length_close_to_full_graph():
    """Fig. 7's core observation: excluding unsafe links barely stretches
    paths on random-graph overlays.  Unreachable pairs are charged a large
    penalty so the subgraph relation sp_safe >= sp_full is preserved."""
    net, overlay = spray_net(n=60, delay=0.2, period=30.0)
    for p in net.procs.values():
        p.ping_timeout = 10.0  # recover quickly from dropped routed pings
    overlay.start()
    net.run(until=100.0)
    g_safe = safe_graph(net)
    g_full = full_graph(net)
    sources = list(range(0, 60, 6))
    penalty = 60.0
    sp_safe = mean_shortest_path(g_safe, sources, unreachable_penalty=penalty)
    sp_full = mean_shortest_path(g_full, sources, unreachable_penalty=penalty)
    assert sp_full <= sp_safe < sp_full + 2.0, (sp_safe, sp_full)
    mean_unsafe, mean_buf, mx = unsafe_link_stats(net)
    assert mean_unsafe < 8.0


def test_unsafe_links_drain_when_churn_stops():
    net, overlay = spray_net(n=30, delay=0.3, period=15.0)
    overlay.start()
    net.run(until=40.0)
    overlay.stop()
    net.run(until=net.time + 300.0)
    mean_unsafe, _, _ = unsafe_link_stats(net)
    assert mean_unsafe == 0.0, "all ping phases must settle once churn stops"
