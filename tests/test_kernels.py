"""Pallas kernel sweeps (interpret mode): shapes x dtypes against the
pure-jnp oracles, plus the model-level use_pallas path equivalence."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd_scan.ops import ssd_chunk_scan
from repro.kernels.ssd_scan.ref import ssd_chunk_scan_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d,causal", [
    (1, 4, 4, 128, 64, True),     # MHA, aligned
    (2, 4, 2, 200, 64, True),     # GQA, padded seq
    (1, 8, 1, 256, 128, True),    # MQA
    (2, 4, 2, 160, 96, False),    # full attention, odd head_dim tile
    (1, 2, 2, 64, 32, True),      # smaller than one block
])
def test_flash_attention_sweep(b, h, kv, s, d, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * s + d), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, causal, 128, 128, True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_flash_attention_block_shape_invariance():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    a = flash_attention(q, k, v, True, 128, 128, True)
    b = flash_attention(q, k, v, True, 64, 256, True)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_flash_attention_backward_matches_ref():
    """custom-vjp backward (oracle recompute) must equal pure-ref grads."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))

    def f_kernel(q, k, v):
        return (flash_attention(q, k, v, True, 128, 128, True) ** 2).sum()

    def f_ref(q, k, v):
        return (attention_ref(q, k, v, causal=True) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ #
# SSD scan
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 96, 3, 16, 32, 32),
    (1, 128, 2, 64, 128, 128),    # production-like tile
    (2, 100, 2, 16, 32, 32),      # needs padding
    (1, 64, 1, 8, 16, 16),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + p), 4)
    xb = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
    al = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = (jax.random.normal(ks[2], (b, s, n)) * 0.3).astype(dtype)
    cm = (jax.random.normal(ks[3], (b, s, n)) * 0.3).astype(dtype)
    y, hf = ssd_chunk_scan(xb, al, bm, cm, chunk=chunk, interpret=True)
    yr, hr = ssd_chunk_scan_ref(xb, al, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(hf, np.float32),
                               np.asarray(hr, np.float32), **TOL[dtype])


def test_ssd_scan_state_continuity():
    """Final state equals a sequential single-chunk run's final state."""
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    b, s, h, p, n = 1, 64, 2, 8, 16
    xb = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    al = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    _, h_16 = ssd_chunk_scan(xb, al, bm, cm, chunk=16, interpret=True)
    _, h_64 = ssd_chunk_scan(xb, al, bm, cm, chunk=64, interpret=True)
    np.testing.assert_allclose(h_16, h_64, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# RG-LRU scan
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,w,h0", [
    (2, 256, 128, False),
    (2, 300, 96, True),     # padding both axes
    (1, 512, 256, True),
    (3, 64, 64, False),
])
def test_rglru_scan_sweep(b, s, w, h0, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + w), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, w))).astype(dtype)
    bx = (jax.random.normal(ks[1], (b, s, w)) * 0.2).astype(dtype)
    h0v = (jax.random.normal(ks[2], (b, w)) * 0.1) if h0 else None
    h, hl = rglru_scan(a, bx, h0v, interpret=True)
    hr, hlr = rglru_scan_ref(a.astype(jnp.float32),
                             bx.astype(jnp.float32), h0v)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr), **TOL[dtype])


# ------------------------------------------------------------------ #
# model-level: use_pallas path equals the jnp path
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", ["mamba2-2.7b", "recurrentgemma-9b"])
def test_model_use_pallas_matches_ref(arch):
    from repro.configs import ARCHS
    from repro.models import build_model
    cfg = replace(ARCHS[arch].smoke(), compute_dtype="float32",
                  param_dtype="float32")
    m_ref = build_model(cfg, use_pallas=False, remat="none")
    m_pal = build_model(cfg, use_pallas=True, remat="none")
    params, _ = m_ref.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    lr, _, _, _ = m_ref.forward(params, tokens)
    lp, _, _, _ = m_pal.forward(params, tokens)
    np.testing.assert_allclose(lr, lp, rtol=5e-5, atol=5e-5)
