"""Algorithm 3: bounded buffers, stale-pong discard, retries, timeouts,
silent crashes (Fig. 5 failure scenarios, Fig. 6 walkthrough)."""

import pytest

from repro.core import BoundedPCBroadcast, Network, check_trace


def chain_net(oob_loss=0.0, **kw):
    """A -> B -> D slow chain (delay 5), plus reverse links; oob pongs."""
    net = Network(seed=11, default_delay=5.0, oob_delay=0.1, oob_loss=oob_loss)
    for pid in range(3):
        net.add_process(BoundedPCBroadcast(pid, **kw))
    A, B, D = 0, 1, 2
    for (a, b) in [(A, B), (B, D), (B, A), (D, B)]:
        net.connect(a, b)
    return net, (A, B, D)


def test_fig6_buffer_bound_resets_phase_and_discards_stale_pong():
    net, (A, B, D) = chain_net(max_size=2, max_retry=10)
    net.procs[A].broadcast("a")
    net.run(until=1.0)
    net.connect(A, D, delay=0.1)               # phase 1: ping pi_1
    first_ctr = net.procs[A].B[D][0]
    # Deliver 3 messages at A during the phase -> exceeds maxSize=2.
    for i in range(3):
        net.procs[A].broadcast(f"m{i}")
    assert net.procs[A].B[D][0] > first_ctr, "buffer must reset w/ new counter"
    assert len(net.procs[A].B[D][1]) == 0, "reset buffer starts empty"
    net.run()
    rep = check_trace(net.trace, all_pids={A, B, D})
    assert rep.ok, rep.summary()
    assert D in net.procs[A].Q                  # eventually safe
    assert net.procs[A].R.get(D) is None        # retry state cleared


def test_lost_pong_timeout_retry_recovers():
    """Fig. 5c: the pong is lost; the timeout retries and succeeds once
    the oob channel recovers."""
    net, (A, B, D) = chain_net(oob_loss=1.0, max_retry=50, ping_timeout=30.0)
    net.procs[A].broadcast("a")
    net.run(until=1.0)
    net.connect(A, D, delay=0.1)
    net.run(until=40.0)                          # first pong lost; timeout hit
    assert D not in net.procs[A].Q
    assert net.procs[A].R[D] >= 1                # at least one retry
    net.oob_loss = 0.0                           # channel recovers
    net.run()
    rep = check_trace(net.trace, all_pids={A, B, D})
    assert rep.ok, rep.summary()
    assert D in net.procs[A].Q


def test_silent_crash_exhausts_retries_and_closes_link():
    """Fig. 5b: the target departs silently; maxRetry bounds the buffer's
    lifetime and the link is abandoned."""
    net, (A, B, D) = chain_net(max_retry=2, ping_timeout=20.0)
    net.procs[A].broadcast("a")
    net.run(until=1.0)
    net.crash(D)                                 # silent: no close() events
    net.connect(A, D, delay=0.1)
    net.run(until=500.0)
    assert D not in net.procs[A].Q
    assert D not in net.procs[A].B               # buffer reclaimed
    assert D in net.procs[A].gave_up
    rep = check_trace(net.trace, crashed={D}, all_pids={A, B, D})
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()


def test_buffer_never_exceeds_bound():
    net, (A, B, D) = chain_net(max_size=4, max_retry=100)
    net.procs[A].broadcast("a")
    net.run(until=1.0)
    net.connect(A, D, delay=0.1)
    worst = 0
    for i in range(20):
        net.procs[A].broadcast(f"m{i}")
        if D in net.procs[A].B:
            worst = max(worst, len(net.procs[A].B[D][1]))
    assert worst <= 4 + 1  # checked after insertion (paper: > maxSize)
    net.run()
    rep = check_trace(net.trace, all_pids={A, B, D})
    assert rep.ok, rep.summary()


def test_defaults_degenerate_to_plain_pc():
    """With infinite bounds Algorithm 3 == Algorithm 2 (no retries)."""
    net, (A, B, D) = chain_net()
    net.procs[A].broadcast("a")
    net.run(until=1.0)
    net.connect(A, D, delay=0.1)
    for i in range(10):
        net.procs[A].broadcast(f"m{i}")
    net.run()
    assert net.procs[A].R == {} and net.procs[A].I == {}
    rep = check_trace(net.trace, all_pids={A, B, D})
    assert rep.ok, rep.summary()
