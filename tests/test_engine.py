"""Tensorized engine: exact equivalence with the numpy oracle, Fig. 3 at
the round level, delivery = shortest paths on static nets, scale smoke,
and the sharded (multi-device) runner."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.engine import (EngineConfig, Schedule, analyze,
                               random_instance, run_engine, run_ref)


@pytest.mark.parametrize("seed", range(10))
def test_engine_matches_numpy_oracle(seed):
    cfg, sched, adj0, delay0 = random_instance(
        seed, n=16, k=4, m_app=8, n_adds=5, n_rms=4, rounds=48,
        mode="pc", always_gate=bool(seed % 2), pong_delay=1 + seed % 3)
    d_ref = run_ref(cfg, sched, adj0.copy(), delay0.copy())
    d_jax = run_engine(cfg, sched, adj0, delay0)
    np.testing.assert_array_equal(d_ref, d_jax)


@pytest.mark.parametrize("seed", range(5))
def test_engine_matches_oracle_r_mode(seed):
    cfg, sched, adj0, delay0 = random_instance(
        seed + 100, n=12, k=3, m_app=6, n_adds=4, n_rms=2, rounds=40,
        mode="r")
    d_ref = run_ref(cfg, sched, adj0.copy(), delay0.copy())
    d_jax = run_engine(cfg, sched, adj0, delay0)
    np.testing.assert_array_equal(d_ref, d_jax)


def fig3_instance(mode):
    """A(0) -> B(1) -> D(2) slow chain; fast link A->D added mid-flight."""
    n, k = 3, 3
    adj0 = np.full((n, k), -1, np.int64)
    delay0 = np.ones((n, k), np.int64)
    adj0[0, 0], delay0[0, 0] = 1, 5   # A -> B slow
    adj0[1, 0], delay0[1, 0] = 2, 5   # B -> D slow
    adj0[1, 1], delay0[1, 1] = 0, 5   # B -> A
    adj0[2, 0], delay0[2, 0] = 1, 5   # D -> B
    sched = Schedule(
        bcast_round=np.array([0, 3], np.int32),
        bcast_origin=np.array([0, 0], np.int32),   # A broadcasts a, a'
        add_round=np.array([2], np.int32),
        add_p=np.array([0], np.int32),
        add_k=np.array([2], np.int32),
        add_q=np.array([2], np.int32),             # new fast link A -> D
        add_delay=np.array([1], np.int32),
        rm_round=np.zeros(0, np.int32),
        rm_p=np.zeros(0, np.int32),
        rm_k=np.zeros(0, np.int32),
    )
    cfg = EngineConfig(n=n, k=k, rounds=40, mode=mode, pong_delay=1)
    return cfg, sched, adj0, delay0


def test_fig3_r_mode_violates():
    cfg, sched, adj0, delay0 = fig3_instance("r")
    d = run_engine(cfg, sched, adj0, delay0)
    rep = analyze(d, sched)
    assert rep["violations"] > 0
    # D receives a' (slot 1) before a (slot 0)
    assert d[2, 1] < d[2, 0]


def test_fig3_pc_mode_safe():
    cfg, sched, adj0, delay0 = fig3_instance("pc")
    d = run_engine(cfg, sched, adj0, delay0)
    rep = analyze(d, sched)
    assert rep["violations"] == 0 and rep["missing"] == 0
    assert rep["delivered_frac"] == 1.0
    assert d[2, 0] < d[2, 1]


def test_static_delivery_equals_bfs_distance():
    """With unit delays and no churn, delivery round == hop distance."""
    rng = np.random.default_rng(0)
    n, k = 32, 4
    adj0 = np.full((n, k), -1, np.int64)
    adj0[:, 0] = (np.arange(n) + 1) % n
    for i in range(n):
        adj0[i, 1:] = rng.choice(n, size=k - 1, replace=False)
    delay0 = np.ones((n, k), np.int64)
    sched = Schedule.empty_churn([0], [0])
    cfg = EngineConfig(n=n, k=k, rounds=n + 2, mode="pc")
    d = run_engine(cfg, sched, adj0, delay0)

    # BFS over the same digraph (self-loops possible via rng; harmless)
    from collections import deque
    dist = {0: 0}
    dq = deque([0])
    while dq:
        u = dq.popleft()
        for v in adj0[u]:
            v = int(v)
            if v >= 0 and v not in dist:
                dist[v] = dist[u] + 1
                dq.append(v)
    for q in range(n):
        assert d[q, 0] == dist[q]


def test_pc_no_violations_at_scale():
    """2k processes, heavy churn: PC mode stays violation-free."""
    cfg, sched, adj0, delay0 = random_instance(
        7, n=2000, k=6, m_app=32, n_adds=24, n_rms=24, rounds=64,
        mode="pc")
    d = run_engine(cfg, sched, adj0, delay0)
    rep = analyze(d, sched)
    assert rep["violations"] == 0 and rep["missing"] == 0, rep
    assert rep["delivered_frac"] == 1.0


_SHARDED_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.core.engine import random_instance, run_ref
    from repro.core.engine.sharded import run_engine_sharded

    cfg, sched, adj0, delay0 = random_instance(
        3, n=50, k=4, m_app=8, n_adds=5, n_rms=3, rounds=40, mode="pc")
    d_ref = run_ref(cfg, sched, adj0.copy(), delay0.copy())
    d_sh = run_engine_sharded(cfg, sched, adj0, delay0)
    np.testing.assert_array_equal(d_ref, d_sh[:50])
    # padded rows never deliver anything
    assert (d_sh[50:] < 0).all()
    print("SHARDED_OK")
""")


def test_sharded_engine_matches_oracle_subprocess():
    """8 forced host devices in a subprocess (flag must precede jax init)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SHARDED_SNIPPET],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SHARDED_OK" in out.stdout, out.stdout + out.stderr
