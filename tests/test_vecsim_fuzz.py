"""Differential fuzzing of the vecsim engines (hypothesis).

Random small :class:`VecScenario`\\ s — drawn across every topology
builder (ring / k-regular / small-world), traffic model (batch /
Poisson / bursty), churn shape (link-add, churn, churn waves,
partition-heal) and crash schedule — are executed four ways and the
results compared byte-for-byte:

  * NumPy backend  == JAX backend (delivered matrix + stats series);
  * windowed streaming == monolithic (delivered + series + NetStats),
    at several window sizes down to the overflow boundary, with the
    backend drawn from {numpy, jax, pallas} — pallas runs the fused
    delivery-sweep kernels (``vecsim.kernels``) in interpret mode;
  * vec delivered multiset == exact event-engine multiset (crossval);
  * oracle-clean traces (causal order, integrity, validity, agreement
    among correct processes) on crash and churn runs.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="fuzz tests need the optional 'hypothesis' "
    "extra (pip install -r requirements.txt)")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import check_trace  # noqa: E402
from repro.core.vecsim import (WindowOverflowError, build_trace,  # noqa: E402
                               churn_scenario, cross_validate,
                               delivered_multiset, run_vec,
                               static_scenario)
from vecsim_cases import BUILDERS, run_shard_matrix_subprocess  # noqa: E402

BASE = dict(
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
    derandomize=True,
)

scenario_strategy = st.tuples(
    st.sampled_from(sorted(BUILDERS)),
    st.integers(min_value=0, max_value=10 ** 6),
    st.integers(min_value=10, max_value=40),
)


def _build(spec):
    name, seed, n = spec
    return BUILDERS[name](seed, n)


@settings(max_examples=15, **BASE)
@given(spec=scenario_strategy)
def test_fuzz_numpy_jax_backends_byte_identical(spec):
    scn = _build(spec)
    r_np = run_vec(scn, backend="numpy")
    r_jx = run_vec(scn, backend="jax")
    np.testing.assert_array_equal(r_np.delivered, r_jx.delivered)
    np.testing.assert_array_equal(r_np.series, r_jx.series)
    assert r_np.stats == r_jx.stats


@settings(max_examples=15, **BASE)
@given(spec=scenario_strategy,
       frac=st.sampled_from([1.0, 0.6, 0.3]),
       seg_len=st.sampled_from([4, 16, 64]),
       backend=st.sampled_from(["numpy", "jax", "pallas"]))
def test_fuzz_windowed_equals_monolithic(spec, frac, seg_len, backend):
    """The acceptance-criterion property: wherever both runs fit, the
    windowed delivered matrix is byte-identical to the monolithic one.
    Windows below the live-message high-water mark must refuse loudly
    (WindowOverflowError), never silently diverge."""
    scn = _build(spec)
    mono = run_vec(scn, backend="numpy")
    w = max(2, int(scn.m_total * frac))
    try:
        win = run_vec(scn, backend=backend, window=w, seg_len=seg_len,
                      collect="full")
    except WindowOverflowError:
        assert w < scn.m_total  # a full-width window can never overflow
        return
    np.testing.assert_array_equal(mono.delivered, win.delivered)
    np.testing.assert_array_equal(mono.series, win.series)
    assert mono.stats == win.stats
    assert not win.expired.any()
    assert win.peak_live <= w


@settings(max_examples=10, **BASE)
@given(spec=scenario_strategy,
       window=st.sampled_from([None, -1]))
def test_fuzz_vec_matches_exact_engine(spec, window):
    """Delivered-message multisets agree byte-for-byte with the exact
    discrete-event simulator, monolithic and windowed alike."""
    scn = _build(spec)
    if window == -1:
        window = scn.m_total
    out = cross_validate(scn, window=window)
    assert out["vec_multiset"] == out["exact_multiset"]
    assert out["vec_report"].ok, out["vec_report"].summary()
    assert out["exact_report"].ok, out["exact_report"].summary()


@settings(max_examples=10, **BASE)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       n=st.integers(min_value=16, max_value=48),
       builder=st.sampled_from(["crash", "churn", "waves"]))
def test_fuzz_oracle_clean_on_crash_and_churn(seed, n, builder):
    """Oracle coverage on faulty/dynamic runs: traces rebuilt from the
    delivery matrix show zero causal or agreement violations among the
    correct processes."""
    scn = BUILDERS[builder](seed, n)
    res = run_vec(scn, backend="numpy")
    crashed = set(np.nonzero(res.state["crashed"])[0].tolist())
    rep = check_trace(build_trace(res), crashed=crashed,
                      all_pids=set(range(scn.n)))
    assert not rep.causal_violations, rep.summary()
    assert not rep.agreement_violations, rep.summary()
    assert not rep.double_deliveries and not rep.validity_violations


@settings(max_examples=8, **BASE)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_fuzz_windowed_multiset_stable_under_window_choice(seed):
    """Any two overflow-free window/segment choices give the same
    deliveries — the stream driver's bookkeeping cannot depend on how
    the message axis happens to be chunked."""
    scn = churn_scenario(seed=seed, n=24)
    base = None
    for w, seg in ((scn.m_total, 8), (scn.m_total, 64),
                   (max(4, scn.m_total // 2), 16)):
        try:
            res = run_vec(scn, backend="numpy", window=w, seg_len=seg,
                          collect="full")
        except WindowOverflowError:
            continue
        ms = delivered_multiset(res)
        if base is None:
            base = ms
        assert ms == base
    assert base is not None


@settings(max_examples=6, **BASE)
@given(spec=st.tuples(
           st.sampled_from(["static", "link_add", "churn", "crash",
                            "sustained_kreg"]),
           st.integers(min_value=0, max_value=10 ** 6),
           st.integers(min_value=12, max_value=32)),
       shards=st.sampled_from([1, 2, 4]),
       frac=st.sampled_from([1.0, 0.5]),
       seg_len=st.sampled_from([8, 32]),
       backend=st.sampled_from(["jax", "pallas"]),
       scan=st.sampled_from(["on", "off"]))
def test_fuzz_sharded_equals_windowed(spec, shards, frac, seg_len, backend,
                                      scan):
    """The sharded acceptance property, differentially: at every drawn
    shard count, round-body backend (plain lax or per-shard Pallas
    kernel launches) and segment stepping (whole-segment ``lax.scan``
    vs per-round dispatch) the device-sharded engine is byte-identical
    to the windowed engine (or both refuse with WindowOverflowError).
    One shard runs in-process; multi-shard draws spawn a child
    interpreter because the forced host-device flag must precede jax
    init."""
    name, seed, n = spec
    if shards > 1:
        run_shard_matrix_subprocess([(name, seed, n, frac, seg_len)],
                                    shards=shards, backend=backend,
                                    scan=scan)
        return
    from repro.core.vecsim.shard import execute_sharded
    scn = _build(spec)
    w = max(4, int(scn.m_total * frac))
    try:
        mono = run_vec(scn, backend="numpy", window=w, seg_len=seg_len,
                       collect="full")
    except WindowOverflowError:
        with pytest.raises(WindowOverflowError):
            execute_sharded(scn, w, n_devices=1, collect="full",
                            seg_len=seg_len, backend=backend, scan=scan)
        return
    sh = execute_sharded(scn, w, n_devices=1, collect="full",
                         seg_len=seg_len, backend=backend, scan=scan)
    assert sh.scan == scan
    np.testing.assert_array_equal(mono.delivered, sh.delivered)
    np.testing.assert_array_equal(mono.series, sh.series)
    assert mono.stats == sh.stats
    assert mono.peak_live == sh.peak_live


@settings(max_examples=6, **BASE)
@given(spec=st.tuples(
           st.sampled_from(["static", "link_add", "churn", "crash",
                            "sustained_kreg"]),
           st.integers(min_value=0, max_value=10 ** 6),
           st.integers(min_value=12, max_value=32)),
       frac=st.sampled_from([1.0, 0.5]),
       horizon=st.sampled_from([None, 6, 12]),
       seg_a=st.sampled_from([1, 5, 16]),
       seg_b=st.sampled_from([3, 8, 64]))
def test_fuzz_scan_results_independent_of_segment_length(spec, frac,
                                                         horizon,
                                                         seg_a, seg_b):
    """Segment length is an execution detail of the scanned path, never
    a semantic one — *including* failure: any two seg_len choices give
    byte-identical deliveries, series, stats and final state, and when
    a draw overflows its window, every seg_len overflows at the same
    round (``activate`` stops segments just before a blocked event and
    caps them at horizon-expiry rounds, so retirement opportunities do
    not depend on where the boundaries fall).  This is the property
    that licenses the driver's per-segment fast-body selection — a
    segment boundary can move without moving any delivery."""
    from repro.core.vecsim.shard import execute_sharded
    scn = _build(spec)
    w = max(4, int(scn.m_total * frac))
    results = []
    for seg in (seg_a, seg_b):
        try:
            results.append(execute_sharded(scn, w, n_devices=1,
                                           collect="full", seg_len=seg,
                                           backend="jax", scan="on",
                                           horizon=horizon))
        except WindowOverflowError as e:
            results.append(e.round)
    a, b = results
    if isinstance(a, int) or isinstance(b, int):
        assert frac < 1.0, "a full-width window can never overflow"
        assert a == b, f"overflow round depends on seg_len: {a} != {b}"
        return
    np.testing.assert_array_equal(a.delivered, b.delivered)
    np.testing.assert_array_equal(a.series, b.series)
    assert a.stats == b.stats
    assert a.expired.tolist() == b.expired.tolist()
    assert (a.lat_sum, a.lat_cnt) == (b.lat_sum, b.lat_cnt)
    for key in a.state:
        np.testing.assert_array_equal(a.state[key], b.state[key],
                                      err_msg=key)


@settings(max_examples=25, **BASE)
@given(topology=st.sampled_from(["ring", "kregular", "smallworld"]),
       seed=st.integers(min_value=0, max_value=10 ** 6),
       n=st.integers(min_value=12, max_value=96),
       k=st.integers(min_value=3, max_value=6),
       max_delay=st.integers(min_value=1, max_value=4),
       m_app=st.integers(min_value=1, max_value=12),
       beta=st.sampled_from([0.0, 0.2, 0.8]))
def test_fuzz_settle_rounds_is_a_sound_delivery_bound(
        topology, seed, n, k, max_delay, m_app, beta):
    """``settle_rounds`` with the computed ``diameter_bound`` really is a
    sound bound: on every topology builder — including low-beta
    small-world lattices, whose diameter is nowhere near log N — every
    broadcast is delivered everywhere within the settle window of its
    broadcast round."""
    from repro.core.vecsim import (diameter_bound, execute_vec,
                                  settle_rounds)
    scn = static_scenario(seed, n, k=k, m_app=m_app, max_delay=max_delay,
                          topology=topology, beta=beta)
    res = execute_vec(scn, backend="numpy")
    d = res.delivered_app
    assert (d >= 0).all(), "a broadcast never finished flooding"
    settle = settle_rounds(n, k, max_delay, scn.pong_delay,
                           diam=diameter_bound(scn.adj0))
    worst = int((d - scn.bcast_round[None, :]).max())
    assert worst <= settle, (worst, settle)
