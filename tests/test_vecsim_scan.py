"""The scanned-segment differential suite (DESIGN.md §2.7).

``scan="on"`` moves the sharded engine's segment loop device-side — one
``lax.scan`` over rounds inside ``shard_map``, stacked schedules,
donated buffers, a double-buffered frontier exchange, and a bit-packed
int16 fast body for topology-quiescent segments.  Every test here is a
byte-identity proof obligation for that rewrite:

  * scan="on" == scan="off" == windowed numpy reference, at N ∈
    {64, 256} over 1/2/4 (forced host) devices, across churn, crash,
    partition, gating, horizon-expiry and overflow scenarios (the
    multi-device children *also* re-run every case with scan="off" and
    compare the two sharded results directly);
  * segment-tail edges: ragged final segments, zero-traffic tail
    segments, a boundary that retires every live column at once;
  * seg_len is an execution detail, never a semantic one;
  * the donated state tuple really aliases (lowered/compiled HLO +
    ``memory_analysis``) — the peak (N, W) footprint must not double;
  * the spec layer rejects the combinations that cannot work.

Multi-device runs spawn child interpreters because
``--xla_force_host_platform_device_count`` must precede jax
initialization (same pattern as ``tests/test_vecsim_shard.py``).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.vecsim import (WindowOverflowError, execute_windowed,
                               link_add_scenario, static_scenario)
from repro.core.vecsim.shard import execute_sharded
from repro.core.vecsim.shard.spanner import resolve_scan
from vecsim_cases import build, run_shard_matrix_subprocess


def _assert_matches(ref, sh):
    np.testing.assert_array_equal(ref.delivered, sh.delivered)
    np.testing.assert_array_equal(ref.series, sh.series)
    assert ref.stats == sh.stats
    assert ref.deliv_count.tolist() == sh.deliv_count.tolist()
    assert ref.bcast_done.tolist() == sh.bcast_done.tolist()
    assert ref.expired.tolist() == sh.expired.tolist()
    assert ref.peak_live == sh.peak_live
    assert (ref.lat_sum, ref.lat_cnt) == (sh.lat_sum, sh.lat_cnt)
    for key in ref.state:
        np.testing.assert_array_equal(ref.state[key], sh.state[key],
                                      err_msg=key)


def _run_pair(scn, w, seg_len, **kw):
    """The tightest differential: same mesh, same backend, same window —
    only the segment stepping differs."""
    on = execute_sharded(scn, w, n_devices=1, collect="full",
                         seg_len=seg_len, scan="on", **kw)
    off = execute_sharded(scn, w, n_devices=1, collect="full",
                          seg_len=seg_len, scan="off", **kw)
    assert on.scan == "on" and off.scan == "off"
    _assert_matches(off, on)
    return on, off


# --------------------------------------------------------------------- #
# Single-device byte-identity: scan on == scan off == windowed numpy
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("builder,seed", [
    ("static", 3), ("link_add", 5), ("churn", 7), ("crash", 9),
    ("partition", 11), ("sustained_kreg", 13), ("waves", 15),
])
def test_scan_single_device_byte_identical(builder, seed):
    scn = build(builder, seed, 64)
    win = execute_windowed(scn, scn.m_total, backend="numpy",
                           collect="full", seg_len=8)
    on, _ = _run_pair(scn, scn.m_total, 8)
    _assert_matches(win, on)


@pytest.mark.parametrize("builder,seed", [("churn", 2), ("crash", 6)])
def test_scan_single_device_byte_identical_n256(builder, seed):
    scn = build(builder, seed, 256)
    win = execute_windowed(scn, scn.m_total, backend="numpy",
                           collect="full", seg_len=16)
    on, _ = _run_pair(scn, scn.m_total, 16)
    _assert_matches(win, on)


def test_scan_retirement_recycling_and_overflow_parity():
    """A window below m_total forces live column recycling through the
    scanned path; an impossible window refuses identically."""
    scn = build("churn", 21, 48)
    w = max(4, scn.m_total // 2)
    try:
        win = execute_windowed(scn, w, backend="numpy", collect="full",
                               seg_len=8)
    except WindowOverflowError:
        win = None
    if win is None:
        with pytest.raises(WindowOverflowError):
            execute_sharded(scn, w, n_devices=1, collect="full",
                            seg_len=8, scan="on")
    else:
        on, _ = _run_pair(scn, w, 8)
        _assert_matches(win, on)
    with pytest.raises(WindowOverflowError):
        execute_sharded(scn, 2, n_devices=1, collect="full", seg_len=8,
                        scan="on")


def test_scan_horizon_expiry_parity():
    """Horizon force-retirement (and its hung-gate escape hatch, on a
    gated scenario) through the scanned segment body."""
    scn = link_add_scenario(seed=6, n=40)
    win = execute_windowed(scn, scn.m_total, backend="numpy",
                           collect="full", seg_len=4, horizon=4)
    on, _ = _run_pair(scn, scn.m_total, 4, horizon=4)
    assert win.expired.any()
    _assert_matches(win, on)


# --------------------------------------------------------------------- #
# Segment-tail edges
# --------------------------------------------------------------------- #
def test_scan_ragged_final_segment():
    """A final segment shorter than seg_len runs with sentinel padding
    rounds; the padding must be inert (results byte-identical to the
    per-round path, which never pads)."""
    scn = build("sustained_kreg", 17, 64)
    seg = next(s for s in (7, 9, 11, 13) if scn.rounds % s)
    assert scn.rounds % seg != 0
    win = execute_windowed(scn, scn.m_total, backend="numpy",
                           collect="full", seg_len=seg)
    on, _ = _run_pair(scn, scn.m_total, seg)
    _assert_matches(win, on)


def test_scan_zero_traffic_tail_and_retire_everything_boundary():
    """A static flood quiesces well before its settle-bound round count:
    at the first segment boundary after quiescence *every* live column
    retires at once, and the remaining segments run zero-traffic on an
    empty window.  Both edges must be inert in the scanned body (the
    fast body's packed frontier is all-zero there) and byte-identical."""
    scn = static_scenario(2, 64)
    win = execute_windowed(scn, scn.m_total, backend="numpy",
                           collect="full", seg_len=4)
    on, _ = _run_pair(scn, scn.m_total, 4)
    _assert_matches(win, on)
    # the settle bound really did overshoot: trailing rounds saw no
    # deliveries, sends, flushes, pongs or gates — all-zero series rows
    # produced by scanned segments over a fully-retired window
    assert (on.series[-4:] == 0).all()
    assert on.delivered_frac() == 1.0


@pytest.mark.parametrize("seg_len", [1, 5, 64])
def test_scan_seg_len_invariance(seg_len):
    """Any seg_len gives the same run as the seg_len=16 base: segment
    boundaries are pure execution structure."""
    scn = build("churn", 31, 64)
    base = execute_sharded(scn, scn.m_total, n_devices=1, collect="full",
                           seg_len=16, scan="on")
    other = execute_sharded(scn, scn.m_total, n_devices=1, collect="full",
                            seg_len=seg_len, scan="on")
    np.testing.assert_array_equal(base.delivered, other.delivered)
    np.testing.assert_array_equal(base.series, other.series)
    assert base.stats == other.stats
    for key in base.state:
        np.testing.assert_array_equal(base.state[key], other.state[key],
                                      err_msg=key)


@pytest.mark.parametrize("seg_len", [1, 3, 16, 64])
def test_scan_seg_len_invariance_narrow_window_horizon(seg_len):
    """seg_len-invariance where it used to break: a narrow window under
    a horizon.  ``activate`` now caps every segment at the earliest
    expiry-due round, so force-expiries (and the columns they free)
    land at the same round for every seg_len — results match the
    windowed numpy reference byte-for-byte."""
    scn = build("crash", 9, 64)
    w, h = 6, 9
    win = execute_windowed(scn, w, backend="numpy", collect="full",
                           horizon=h, seg_len=seg_len)
    sh = execute_sharded(scn, w, n_devices=1, collect="full",
                         seg_len=seg_len, scan="on", horizon=h)
    _assert_matches(win, sh)


def test_scan_on_never_dispatches_standalone_reduce(monkeypatch):
    """The fused segment aggregates fully replace the standalone
    retirement reduce on the scanned path: across a run whose final
    boundary retires every live column at once, zero ``reduce_run``
    dispatches happen — the boundary sweeps consume the fused 8-tuple
    and the drain skips its reduce because nothing is live (the old
    drain ran a full (N, W) reduction just to learn there was nothing
    to record).  scan="off" keeps the standalone reduce as reference."""
    from repro.core.vecsim.shard import driver as drv
    calls = {"reduce": 0}
    orig = drv.shard_retire_kernels

    def counting(d):
        reduce_run, apply_run = orig(d)

        def reduce_counted(*a, **kw):
            calls["reduce"] += 1
            return reduce_run(*a, **kw)
        return reduce_counted, apply_run

    monkeypatch.setattr(drv, "shard_retire_kernels", counting)
    scn = static_scenario(2, 64)
    res = drv.execute_sharded(scn, scn.m_total, n_devices=1,
                              collect="full", seg_len=4, scan="on")
    assert res.delivered_frac() == 1.0
    assert calls["reduce"] == 0
    calls["reduce"] = 0
    drv.execute_sharded(scn, scn.m_total, n_devices=1, collect="full",
                        seg_len=4, scan="off")
    assert calls["reduce"] > 0


def test_overflow_round_invariant_across_seg_len():
    """The S-curve of the old bug: overflow used to fire at whatever
    segment boundary happened to follow the fatal round, so its timing
    depended on seg_len.  It must now raise at the same round — and the
    same :attr:`WindowOverflowError.round` — for every seg_len, with
    and without a horizon, in both engines."""
    scn = build("sustained_kreg", 13, 64)
    for h in (None, 5):
        rounds = set()
        for seg_len in (1, 2, 7, 16, 64):
            for run in (
                lambda: execute_windowed(scn, 2, backend="numpy",
                                         horizon=h, seg_len=seg_len),
                lambda: execute_sharded(scn, 2, n_devices=1, horizon=h,
                                        seg_len=seg_len, scan="on"),
            ):
                with pytest.raises(WindowOverflowError) as ei:
                    run()
                rounds.add(ei.value.round)
        assert len(rounds) == 1, (h, rounds)


# --------------------------------------------------------------------- #
# The acceptance matrix: 2 and 4 forced host devices, children compare
# scan="on" against both the windowed reference and scan="off"
# --------------------------------------------------------------------- #
def test_scan_two_devices_matrix_subprocess():
    run_shard_matrix_subprocess(
        [("churn", 7, 64, 1.0, 8),
         ("link_add", 5, 256, 1.0, 16),   # gating at the larger N
         ("crash", 9, 64, 0.5, 8)],       # retirement recycling
        shards=2, scan="on")


def test_scan_four_devices_matrix_subprocess():
    run_shard_matrix_subprocess(
        [("churn", 8, 256, 1.0, 16),
         ("crash", 2, 256, 1.0, 16),
         ("waves", 4, 50, 1.0, 8),        # 50 % 4 != 0: padding rows
         ("static", 3, 64, 1.0, 7)],      # ragged final segment
        shards=4, scan="on")


def test_scan_pallas_backend_matrix_subprocess():
    """The scanned generic body hosting per-shard Pallas kernel
    launches (deliver sweep, slot frontier, ring scatter)."""
    run_shard_matrix_subprocess(
        [("churn", 7, 64, 1.0, 8),
         ("crash", 9, 64, 1.0, 16)],
        shards=2, backend="pallas", scan="on")


# --------------------------------------------------------------------- #
# Buffer donation: the scanned state tuple must update in place
# --------------------------------------------------------------------- #
def _scan_lowering(n_devices, scn, w, seg_len):
    """Lower the scanned span runner exactly as the driver invokes it."""
    from jax.experimental import enable_x64

    from repro.core.vecsim.shard.spanner import (STATE_KEYS,
                                                 shard_span_runner)
    from repro.core.vecsim.sim import init_topo_state
    from repro.core.vecsim.stream import ColumnWindow

    cw = ColumnWindow(scn, w)
    st0 = init_topo_state(scn, w)
    state = tuple(st0[key] for key in STATE_KEYS)
    sst = cw.stacked_schedule(0, min(seg_len, scn.rounds),
                              cw.round_caps(scn.rounds), seg_len)
    ts = np.full(seg_len, -3, np.int32)
    ts[: min(seg_len, scn.rounds)] = np.arange(
        min(seg_len, scn.rounds), dtype=np.int32)
    runner = shard_span_runner(n_devices, scn.k, scn.mode == "pc",
                               scn.always_gate, scn.pong_delay,
                               gating=scn.n_adds > 0, backend="jax",
                               scan=True)
    origins = np.full(w, -1, np.int32)
    with enable_x64():
        return runner.jitted.lower(state, sst, ts, origins,
                                   np.int32(scn.rounds)), state


def test_scan_donation_aliases_live_planes():
    """donate_argnums really landed: the lowered and compiled programs
    alias the donated state into the outputs, and the compiler's own
    memory accounting shows at least a full (N, W) plane aliased — the
    regression this guards is a silent donation drop (shape mismatch,
    dtype change) doubling the peak footprint."""
    scn = build("sustained_kreg", 13, 64)
    lowered, state = _scan_lowering(1, scn, scn.m_total, 8)
    txt = lowered.as_text()
    assert "tf.aliasing_output" in txt or "input_output_alias" in txt
    compiled = lowered.compile()
    hlo = compiled.as_text()
    assert "input_output_alias" in hlo
    ma = compiled.memory_analysis()
    if ma is not None:  # backend-dependent; present on CPU
        arr_bytes = state[0].nbytes
        assert ma.alias_size_in_bytes >= arr_bytes, \
            (ma.alias_size_in_bytes, arr_bytes)
        # no hidden full-state temp copy either
        assert ma.temp_size_in_bytes < ma.argument_size_in_bytes + \
            ma.output_size_in_bytes


_DONATION_4DEV_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {tests_dir!r})
import numpy as np
from vecsim_cases import build
from test_vecsim_scan import _scan_lowering

scn = build("sustained_kreg", 13, 256)
lowered, state = _scan_lowering(4, scn, scn.m_total, 16)
# multi-device lowerings carry donation as buffer-donor annotations
# (aliasing is resolved at compile time); single-device ones alias
# directly in the stablehlo
txt = lowered.as_text()
assert ("jax.buffer_donor" in txt or "tf.aliasing_output" in txt
        or "input_output_alias" in txt), \\
    "donation dropped from the 4-device lowering"
compiled = lowered.compile()
assert "input_output_alias" in compiled.as_text()
ma = compiled.memory_analysis()
if ma is not None:
    per_dev = state[0].nbytes // 4
    assert ma.alias_size_in_bytes >= per_dev, \\
        (ma.alias_size_in_bytes, per_dev)
print("DONATION_OK")
"""


def test_scan_donation_four_devices_subprocess():
    """Same donation regression on a real 4-device mesh at N=256 (the
    forced-host-device flag must precede jax init, hence the child)."""
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    repo_root = os.path.dirname(tests_dir)
    out = subprocess.run(
        [sys.executable, "-c",
         _DONATION_4DEV_SNIPPET.format(tests_dir=tests_dir)],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH="src"), cwd=repo_root)
    assert out.returncode == 0 and "DONATION_OK" in out.stdout, \
        out.stdout + out.stderr


# --------------------------------------------------------------------- #
# Knob plumbing and refusal paths
# --------------------------------------------------------------------- #
def test_resolve_scan():
    assert resolve_scan("auto") == "on"
    assert resolve_scan("on") == "on"
    assert resolve_scan("off") == "off"
    with pytest.raises(ValueError, match="unknown scan mode"):
        resolve_scan("fast")


def test_scan_spec_validation():
    from repro.api import RunSpec, ShardSpec, SpecError
    with pytest.raises(SpecError, match="shard.scan"):
        RunSpec(shard=ShardSpec(scan="fast")).validate()
    with pytest.raises(SpecError, match="only applies"):
        RunSpec(engine="windowed", shard=ShardSpec(scan="on")).validate()
    with pytest.raises(SpecError, match="numpy reference engine"):
        RunSpec(backend="numpy", shard=ShardSpec(scan="on")).validate()
    # scan="off" is meaningful wherever the sharded engine could run,
    # numpy backend included (auto-selection may still pick another
    # engine; the knob is then unused, which "off" permits and "on"
    # does not)
    RunSpec(backend="numpy", shard=ShardSpec(scan="off")).validate()
    RunSpec(engine="sharded", shard=ShardSpec(scan="on")).validate()
    # JSON round-trip carries the knob
    spec = RunSpec(engine="sharded", shard=ShardSpec(scan="off")).validate()
    assert RunSpec.from_dict(spec.to_dict()) == spec
    # profile: sharded/auto engines only, and round-trips like scan
    with pytest.raises(SpecError, match="shard.profile"):
        RunSpec(engine="windowed", shard=ShardSpec(profile=True)).validate()
    spec = RunSpec(engine="sharded",
                   shard=ShardSpec(profile=True)).validate()
    assert RunSpec.from_dict(spec.to_dict()) == spec


def test_profile_through_api_front_door():
    """shard.profile=True yields per-segment timings on the raw result
    and scalar totals in extras, without changing any result."""
    from repro.api import RunSpec, ShardSpec, run

    def go(profile):
        return run(RunSpec(engine="sharded", n=64, seed=3,
                           shard=ShardSpec(devices=1, profile=profile)))

    on, off = go(True), go(False)
    prof = on.result.seg_profile
    assert off.result.seg_profile is None
    assert len(prof) == on.extras["profile_segments"] > 0
    assert all(set(p) == {"lo", "hi", "fast", "stage_s", "dispatch_s",
                          "block_s", "retire_s"} for p in prof)
    assert [(p["lo"], p["hi"]) for p in prof] == \
        sorted((p["lo"], p["hi"]) for p in prof)
    assert on.extras["profile_dispatch_s"] == sum(
        p["dispatch_s"] for p in prof)
    assert on.stats == off.stats
    assert on.delivered_frac == off.delivered_frac
    assert on.mean_latency == off.mean_latency


def test_scan_through_api_front_door():
    """extras report the resolved mode, and the two modes agree through
    the whole api stack."""
    from repro.api import RunSpec, ShardSpec, TrafficSpec, WindowSpec, run

    def go(scan):
        return run(RunSpec(protocol="pc", engine="sharded", n=64, seed=11,
                           shard=ShardSpec(scan=scan),
                           traffic=TrafficSpec(kind="poisson", rate=2.0,
                                               messages=24),
                           window=WindowSpec(window=24, seg_len=4,
                                             collect="full")))
    rep_on, rep_off = go("auto"), go("off")
    assert rep_on.extras["scan"] == "on"
    assert rep_off.extras["scan"] == "off"
    assert rep_on.stats == rep_off.stats
    assert rep_on.delivered_frac == rep_off.delivered_frac == 1.0
    np.testing.assert_array_equal(rep_on.result.delivered,
                                  rep_off.result.delivered)
