"""End-to-end behaviour tests: the full stack from paper protocol to the
causal-gossip training runtime (the framework's flagship path).

The protocol-level end-to-end (Fig. 7 style) runs here; training-runtime
end-to-end tests live in tests/test_gossip.py once the runtime stack is
imported on top.
"""

import pytest

from repro.core import (BoundedPCBroadcast, Network, SprayOverlay,
                        check_trace, ring_plus_random)
from repro.obs import (overhead_per_message, safe_graph,
                       mean_shortest_path, unsafe_link_stats)


def test_end_to_end_protocol_under_realistic_conditions():
    """A 100-process dynamic overlay with variable delays, churn, lossy
    pongs and silent crashes: PC-broadcast keeps every safety property
    while control overhead stays O(1) per message."""
    import random
    rng = random.Random(42)
    net = Network(seed=42,
                  default_delay=lambda t, r: r.uniform(0.05, 1.5),
                  oob_delay=lambda t, r: r.uniform(0.05, 0.5),
                  oob_loss=0.05)
    n = 100
    for pid in range(n):
        net.add_process(BoundedPCBroadcast(
            pid, ping_mode="route", max_size=32, max_retry=6,
            ping_timeout=20.0))
    ring_plus_random(net, range(n), k=5)
    overlay = SprayOverlay(net, range(n), period=40.0)
    overlay.start()

    crashed = set()
    for step in range(60):
        net.run(until=net.time + rng.uniform(0.3, 1.2))
        r = rng.random()
        if r < 0.6:
            pid = rng.randrange(n)
            if pid not in crashed:
                net.procs[pid].broadcast(("payload", step))
        elif r < 0.65 and len(crashed) < 5:
            victim = rng.randrange(n)
            # never crash ring members' predecessor chain entirely; ring
            # keeps the overlay unpartitioned for the remaining processes
            if victim not in crashed and victim % 10 != 0:
                net.crash(victim)
                crashed.add(victim)
    overlay.stop()
    net.run(until=net.time + 2000.0)

    rep = check_trace(net.trace, crashed=crashed, check_agreement=False)
    assert rep.causal_ok, rep.summary()
    assert not rep.double_deliveries, rep.summary()
    assert rep.n_broadcasts >= 30
    # O(1) overhead: a handful of id bytes per FIFO message, far below one
    # vector-clock entry per process (8 bytes x 100).
    assert overhead_per_message(net) < 40.0
    # Network stays usable: safe graph reaches most correct processes
    # (crash holes are only repaired while the overlay churns, so demand
    # high-but-not-total reachability after it stops).
    from repro.obs.graphs import _bfs_depths
    g = safe_graph(net)
    alive = [p for p in range(n) if p not in crashed]
    reach = [len(_bfs_depths(g, s)) / len(alive) for s in alive[:5]]
    assert sum(reach) / len(reach) > 0.8, reach
