"""Pallas delivery-sweep kernels (vecsim.kernels, DESIGN.md §2.6).

Three layers of coverage:

  * kernel-vs-ref — every kernel against its plain-lax ``ref.py`` twin
    on random inputs, including odd window widths with forced ragged
    column tiling, the single-column window, and all-retired (empty)
    segments;
  * backend="pallas" byte-identity — the ISSUE acceptance matrix: the
    monolithic, windowed and sharded engines running the fused kernels
    (interpret mode) produce bit-equal delivered matrices, per-round
    series, NetStats, aggregates and final state against the jax
    backend across every scenario builder at N ∈ {64, 256}, including
    multi-device meshes via the subprocess harness;
  * the api front door — spec validation, select_engine's eager
    SpecError when Pallas cannot initialize, and a full
    ``backend="pallas"`` report equal to the jax report.
"""

import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="the pallas backend needs jax (pip install -r "
    "requirements.txt)")
import jax.numpy as jnp  # noqa: E402

from repro.core.vecsim import WindowOverflowError, execute_windowed  # noqa: E402
from repro.core.vecsim import kernels as kx  # noqa: E402
from repro.core.vecsim.kernels import ref as kref  # noqa: E402
from repro.core.vecsim.shard import execute_sharded  # noqa: E402
from repro.core.vecsim.sim import execute_vec, resolve_backend  # noqa: E402
from vecsim_cases import BUILDERS, run_shard_matrix_subprocess  # noqa: E402

INF = np.int32(2 ** 30)


# --------------------------------------------------------------------- #
# random kernel inputs
# --------------------------------------------------------------------- #
def _inputs(rng, n, w, k):
    return dict(
        t=np.int32(rng.integers(1, 20)),
        arr=np.where(rng.random((n, w)) < 0.4,
                     rng.integers(0, 25, (n, w)), INF).astype(np.int32),
        delivered=np.where(rng.random((n, w)) < 0.4,
                           rng.integers(0, 20, (n, w)), -1).astype(np.int32),
        crashed=rng.random(n) < 0.2,
        is_app=rng.random(w) < 0.7,
        adj=rng.integers(0, n, (n, k)).astype(np.int32),
        delay=rng.integers(1, 4, (n, k)).astype(np.int32),
        gate=np.where(rng.random((n, k)) < 0.3,
                      rng.integers(0, 15, (n, k)), -1).astype(np.int32),
        do=rng.random((n, k)) < 0.3,
        fwd=rng.random((n, k)) < 0.6,
        min_gate=np.where(rng.random(n) < 0.3,
                          rng.integers(0, 15, n), INF).astype(np.int32),
    )


def _eq(got, want):
    got = got if isinstance(got, tuple) else (got,)
    want = want if isinstance(want, tuple) else (want,)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# (n, w, k, block_w): odd window, forced ragged tiling, single column
SHAPES = [(16, 9, 3, None),    # odd window, one tile
          (24, 7, 4, 4),       # odd window, ragged 2-tile grid
          (8, 1, 2, None),     # single-column window
          (12, 11, 3, 3)]      # ragged 4-tile grid


@pytest.mark.parametrize("n,w,k,bw", SHAPES)
def test_kernels_match_refs(n, w, k, bw):
    """Every kernel == its lax ref, bit for bit, across tilings."""
    rng = np.random.default_rng(n * 1000 + w)
    iv = _inputs(rng, n, w, k)
    t = iv["t"]
    _eq(kx.deliver_sweep(iv["arr"], iv["delivered"], iv["crashed"],
                         iv["is_app"], t, block_w=bw),
        kref.deliver_sweep_ref(iv["arr"], iv["delivered"], iv["crashed"],
                               iv["is_app"], t))
    _eq(kx.fused_sweep(iv["arr"], iv["delivered"], iv["crashed"], iv["adj"],
                       iv["delay"], iv["fwd"], iv["is_app"], t, block_w=bw),
        kref.fused_sweep_ref(iv["arr"], iv["delivered"], iv["crashed"],
                             iv["adj"], iv["delay"], iv["fwd"],
                             iv["is_app"], t))
    _eq(kx.frontier_sweep(iv["arr"], iv["delivered"], iv["adj"], iv["delay"],
                          iv["gate"], iv["do"], iv["fwd"], iv["is_app"], t,
                          block_w=bw),
        kref.frontier_sweep_ref(iv["arr"], iv["delivered"], iv["adj"],
                                iv["delay"], iv["gate"], iv["do"],
                                iv["fwd"], iv["is_app"], t))
    _eq(kx.retire_scan(iv["delivered"], iv["crashed"], iv["min_gate"],
                       block_w=bw),
        kref.retire_scan_ref(iv["delivered"], iv["crashed"],
                             iv["min_gate"]))
    rounds = np.int32(22)
    _eq(kx.retire_reduce(iv["arr"], iv["delivered"], iv["crashed"],
                         iv["min_gate"], rounds, block_w=bw),
        kref.retire_reduce_ref(iv["arr"], iv["delivered"], iv["crashed"],
                               iv["min_gate"], rounds))
    # the record-side outputs against plain numpy, not just the lax ref
    _, _, _, arrcnt, sumdel = (np.asarray(x) for x in kx.retire_reduce(
        iv["arr"], iv["delivered"], iv["crashed"], iv["min_gate"], rounds,
        block_w=bw))
    np.testing.assert_array_equal(arrcnt, (iv["arr"] < rounds).sum(axis=0))
    np.testing.assert_array_equal(
        sumdel, np.where(iv["delivered"] >= 0, iv["delivered"], 0)
        .sum(axis=0))
    for gating in (True, False):
        _eq(kx.slot_frontier(iv["delivered"], iv["gate"][:, 0],
                             iv["delay"][:, 0], iv["do"][:, 0],
                             iv["fwd"][:, 0], iv["is_app"], t,
                             gating=gating, block_w=bw),
            kref.slot_frontier_ref(iv["delivered"], iv["gate"][:, 0],
                                   iv["delay"][:, 0], iv["do"][:, 0],
                                   iv["fwd"][:, 0], iv["is_app"], t,
                                   gating=gating))
    vals = np.where(rng.random((n, w)) < 0.4,
                    rng.integers(1, 30, (n, w)), INF).astype(np.int32)
    tgt = rng.integers(0, 2 * n, n).astype(np.int32)
    off = np.int32(n // 2)
    _eq(kx.ring_apply(iv["arr"], vals, tgt, off, block_w=bw),
        kref.ring_apply_ref(jnp.asarray(iv["arr"]), jnp.asarray(vals),
                            jnp.asarray(tgt), off))


def test_kernels_on_all_retired_segment():
    """An all-retired segment (every column recycled: arr=INF,
    delivered=-1) sweeps to a no-op with zero counts."""
    n, w, k = 10, 6, 3
    arr = np.full((n, w), INF, np.int32)
    delivered = np.full((n, w), -1, np.int32)
    crashed = np.zeros(n, bool)
    is_app = np.ones(w, bool)
    adj = np.zeros((n, k), np.int32)
    delay = np.ones((n, k), np.int32)
    fwd = np.ones((n, k), bool)
    t = np.int32(5)
    a2, d2, napp, nping = (np.asarray(x) for x in kx.fused_sweep(
        arr, delivered, crashed, adj, delay, fwd, is_app, t))
    np.testing.assert_array_equal(a2, arr)
    np.testing.assert_array_equal(d2, delivered)
    assert napp.sum() == 0 and nping.sum() == 0
    cnt, alivedel, blocked = (np.asarray(x) for x in kx.retire_scan(
        delivered, crashed, np.full(n, INF, np.int32)))
    assert cnt.sum() == 0 and alivedel.sum() == 0 and blocked.sum() == 0
    red = tuple(np.asarray(x) for x in kx.retire_reduce(
        arr, delivered, crashed, np.full(n, INF, np.int32), np.int32(9)))
    assert all(x.sum() == 0 for x in red)


# --------------------------------------------------------------------- #
# backend="pallas" == backend="jax": the acceptance matrix
# --------------------------------------------------------------------- #
def _assert_windowed_matches(a, b):
    np.testing.assert_array_equal(a.delivered, b.delivered)
    np.testing.assert_array_equal(a.series, b.series)
    assert a.stats == b.stats
    assert a.deliv_count.tolist() == b.deliv_count.tolist()
    assert a.bcast_done.tolist() == b.bcast_done.tolist()
    assert a.expired.tolist() == b.expired.tolist()
    assert a.peak_live == b.peak_live
    assert (a.lat_sum, a.lat_cnt) == (b.lat_sum, b.lat_cnt)
    for key in a.state:
        np.testing.assert_array_equal(a.state[key], b.state[key],
                                      err_msg=key)


@pytest.mark.parametrize("builder", sorted(BUILDERS))
@pytest.mark.parametrize("n", [64, 256])
def test_pallas_monolithic_byte_identical(builder, n):
    """Monolithic engine: the fused-kernel backend reproduces the jax
    backend bit for bit on every scenario builder."""
    scn = BUILDERS[builder](3, n)
    rj = execute_vec(scn, backend="jax")
    rp = execute_vec(scn, backend="pallas")
    assert rp.backend == "pallas"
    np.testing.assert_array_equal(rj.delivered, rp.delivered)
    np.testing.assert_array_equal(rj.series, rp.series)
    assert rj.stats == rp.stats
    for key in rj.state:
        np.testing.assert_array_equal(rj.state[key], rp.state[key],
                                      err_msg=key)


@pytest.mark.parametrize("builder", sorted(BUILDERS))
@pytest.mark.parametrize("n", [64, 256])
def test_pallas_windowed_byte_identical(builder, n):
    """Windowed engine: span kernels + the retirement-scan kernel give
    byte-identical results (delivered, series, NetStats, aggregates,
    peak, state) on every builder, full-width and fractional windows."""
    scn = BUILDERS[builder](5, n)
    for frac, seg in ((1.0, 16), (0.5, 8)):
        w = max(4, int(scn.m_total * frac))
        try:
            rj = execute_windowed(scn, w, backend="jax", collect="full",
                                  seg_len=seg)
        except WindowOverflowError:
            with pytest.raises(WindowOverflowError):
                execute_windowed(scn, w, backend="pallas", collect="full",
                                 seg_len=seg)
            continue
        rp = execute_windowed(scn, w, backend="pallas", collect="full",
                              seg_len=seg)
        _assert_windowed_matches(rj, rp)


def test_pallas_windowed_horizon_and_aggregate_parity():
    """Horizon expiry (the forced-retire escape hatch) and aggregate
    collection go through the same kernel path byte-identically."""
    scn = BUILDERS["churn"](13, 64)
    kw = dict(horizon=24, seg_len=8, collect="full")
    rj = execute_windowed(scn, scn.m_total, backend="jax", **kw)
    rp = execute_windowed(scn, scn.m_total, backend="pallas", **kw)
    _assert_windowed_matches(rj, rp)
    kw = dict(seg_len=8, collect="aggregate")
    aj = execute_windowed(scn, scn.m_total, backend="jax", **kw)
    ap = execute_windowed(scn, scn.m_total, backend="pallas", **kw)
    assert aj.stats == ap.stats
    assert aj.deliv_count.tolist() == ap.deliv_count.tolist()
    np.testing.assert_array_equal(aj.series, ap.series)


@pytest.mark.parametrize("builder", sorted(BUILDERS))
def test_pallas_sharded_single_device_byte_identical(builder):
    """Sharded engine, D=1: per-shard kernel launches inside shard_map
    reproduce the windowed jax reference bit for bit."""
    scn = BUILDERS[builder](7, 64)
    win = execute_windowed(scn, scn.m_total, backend="numpy",
                           collect="full", seg_len=16)
    sh = execute_sharded(scn, scn.m_total, n_devices=1, collect="full",
                         seg_len=16, backend="pallas")
    assert sh.backend == "pallas"
    assert sh.n_devices == 1
    _assert_windowed_matches(win, sh)


@pytest.mark.parametrize("shards,cases", [
    (2, [("churn", 9, 64, 1.0, 8), ("crash", 9, 64, 1.0, 8),
         ("sustained_kreg", 9, 64, 0.5, 8)]),
    (4, [("link_add", 9, 256, 1.0, 16), ("partition", 9, 64, 1.0, 8)]),
])
def test_pallas_sharded_multi_device_matrix(shards, cases):
    """Sharded engine across real multi-device meshes (subprocess: the
    forced host-device flag must precede jax init): the pallas round
    body — per-shard kernels between the ppermute rings — matches the
    windowed numpy reference on gating/churn/crash/partition scenarios
    at N ∈ {64, 256}."""
    run_shard_matrix_subprocess(cases, shards=shards, backend="pallas")


# --------------------------------------------------------------------- #
# api front door + availability surface
# --------------------------------------------------------------------- #
def test_resolve_backend_accepts_pallas():
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("auto") in ("numpy", "jax", "pallas")
    with pytest.raises(ValueError):
        resolve_backend("cuda")


def test_pallas_available_probe_shape():
    ok, note = kx.pallas_available()
    assert isinstance(ok, bool) and isinstance(note, str) and note
    assert ok, "jax is importable here, so the probe must succeed"


def test_api_run_pallas_report_matches_jax():
    from repro.api import RunSpec, TrafficSpec, WindowSpec, run
    kw = dict(protocol="pc", engine="windowed", n=64, seed=2,
              traffic=TrafficSpec(kind="poisson", rate=2.0, messages=24),
              window=WindowSpec(window=24, seg_len=4, collect="full"))
    rj = run(RunSpec(backend="jax", **kw))
    rp = run(RunSpec(backend="pallas", **kw))
    assert rp.backend == "pallas"
    assert rp.stats == rj.stats
    assert rp.delivered_frac == rj.delivered_frac
    assert rp.mean_latency == rj.mean_latency


def test_api_spec_validates_pallas_backend():
    from repro.api import RunSpec, SpecError
    RunSpec(backend="pallas").validate()
    with pytest.raises(SpecError, match="backend='cuda'"):
        RunSpec(backend="cuda").validate()
    with pytest.raises(SpecError, match="numpy-only"):
        RunSpec(protocol="vc", backend="pallas").validate()


def test_select_engine_spec_error_when_pallas_unavailable(monkeypatch):
    """An explicit backend='pallas' fails eagerly — with a SpecError
    naming the probe's reason — when Pallas cannot initialize."""
    from repro.api import (BACKENDS, BackendEntry, RunSpec, SpecError,
                           build_scenario, select_engine)
    broken = BackendEntry("pallas", "broken for this test",
                          lambda: (False, "no pallas in this build"))
    monkeypatch.setitem(BACKENDS._items, "pallas", broken)
    spec = RunSpec(backend="pallas").validate()
    with pytest.raises(SpecError, match="no pallas in this build"):
        select_engine(spec, build_scenario(spec))


def test_cli_list_has_backends_section(capsys):
    from repro.api.__main__ import main
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "backends:" in out
    for key in ("numpy", "jax", "pallas"):
        assert key in out
    assert "available" in out
