"""Shared scenario case builders for the vecsim differential suites.

One ``(name, seed, n) -> VecScenario`` dispatch used by the hypothesis
fuzz suite (``test_vecsim_fuzz.py``), the sharded-engine matrix tests
(``test_vecsim_shard.py``) and — crucially — the *subprocess* snippets
those tests spawn to get multi-device meshes
(``XLA_FLAGS=--xla_force_host_platform_device_count`` must precede jax
initialization, so multi-shard runs happen in child interpreters that
rebuild the identical scenario from ``(name, seed, n)``).  Keeping the
builders here, hypothesis-free, is what lets a child import them
without the fuzz suite's optional dependency.
"""

from repro.core.vecsim import (churn_scenario, churn_wave_scenario,
                               crash_scenario, link_add_scenario,
                               partition_heal_scenario, static_scenario,
                               sustained_scenario)

BUILDERS = {
    "static": lambda seed, n: static_scenario(seed, n),
    "link_add": lambda seed, n: link_add_scenario(seed, n),
    "churn": lambda seed, n: churn_scenario(seed, n),
    "crash": lambda seed, n: crash_scenario(seed, n),
    "waves": lambda seed, n: churn_wave_scenario(seed, n, waves=2),
    "partition": lambda seed, n: partition_heal_scenario(
        seed, max(n, 12), traffic_during_partition=bool(seed % 2)),
    "sustained_kreg": lambda seed, n: sustained_scenario(
        seed, n, k=5, rate=1.0 + (seed % 3), messages=24,
        topology="kregular", max_delay=2),
    "sustained_sw": lambda seed, n: sustained_scenario(
        seed, n, k=5, rate=2.0, messages=24, topology="smallworld",
        traffic="bursty", max_delay=2),
}


def build(name: str, seed: int, n: int):
    return BUILDERS[name](seed, n)


# --------------------------------------------------------------------- #
# Multi-device subprocess harness
# --------------------------------------------------------------------- #
_SNIPPET = """
import os, sys
os.environ["XLA_FLAGS"] = \
    "--xla_force_host_platform_device_count={shards}"
sys.path.insert(0, {tests_dir!r})
import numpy as np
from vecsim_cases import build
from repro.core.vecsim import WindowOverflowError, execute_windowed
from repro.core.vecsim.shard import execute_sharded

for name, seed, n, frac, seg in {cases!r}:
    scn = build(name, seed, n)
    w = max(4, int(scn.m_total * frac))
    try:
        win = execute_windowed(scn, w, backend="numpy", collect="full",
                               seg_len=seg)
    except WindowOverflowError:
        win = None
    try:
        sh = execute_sharded(scn, w, n_devices={shards}, collect="full",
                             seg_len=seg, backend={backend!r},
                             scan={scan!r})
    except WindowOverflowError:
        sh = None
    assert (win is None) == (sh is None), (name, "overflow parity")
    if win is not None:
        np.testing.assert_array_equal(win.delivered, sh.delivered)
        np.testing.assert_array_equal(win.series, sh.series)
        assert win.stats == sh.stats, name
        assert win.deliv_count.tolist() == sh.deliv_count.tolist()
        assert win.bcast_done.tolist() == sh.bcast_done.tolist()
        assert win.peak_live == sh.peak_live
        assert (win.lat_sum, win.lat_cnt) == (sh.lat_sum, sh.lat_cnt)
        for key in win.state:
            np.testing.assert_array_equal(win.state[key], sh.state[key],
                                          err_msg=name + "/" + key)
    if sh is not None and {scan!r} == "on":
        # the scanned segment body must be byte-identical to the
        # per-round sharded path it replaced, not just to the windowed
        # reference — compare against scan="off" in the same mesh
        off = execute_sharded(scn, w, n_devices={shards}, collect="full",
                              seg_len=seg, backend={backend!r},
                              scan="off")
        assert sh.scan == "on" and off.scan == "off"
        np.testing.assert_array_equal(off.delivered, sh.delivered)
        np.testing.assert_array_equal(off.series, sh.series)
        assert off.stats == sh.stats, (name, "scan on vs off")
        for key in off.state:
            np.testing.assert_array_equal(off.state[key], sh.state[key],
                                          err_msg=name + "/scan/" + key)
    print("CASE_OK", name, n)
{extra}
print("ALL_OK")
"""


def run_shard_matrix_subprocess(cases, shards, extra: str = "",
                                backend: str = "jax", scan: str = "auto"):
    """Run ``cases`` — ``(builder, seed, n, window_frac, seg_len)``
    tuples — in a child interpreter with ``shards`` forced host devices,
    asserting the sharded engine is byte-identical to the windowed
    reference on each (or that both overflow).  ``extra`` appends
    arbitrary assertion code to the child (used for the auto-selection
    check, which also needs the multi-device mesh).  ``backend`` picks
    the sharded round body — ``"jax"`` or ``"pallas"`` (interpret-mode
    kernel launches inside the child's shard_map).  ``scan`` picks the
    segment stepping; with the scanned path in play the child *also*
    re-runs each case with ``scan="off"`` and asserts the two sharded
    results match byte for byte (the tightest differential: same mesh,
    same backend, only the stepping strategy differs)."""
    import os
    import subprocess
    import sys

    tests_dir = os.path.dirname(os.path.abspath(__file__))
    repo_root = os.path.dirname(tests_dir)
    snippet = _SNIPPET.format(shards=shards, tests_dir=tests_dir,
                              cases=list(cases), extra=extra,
                              backend=backend, scan=scan)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, env=env,
                         cwd=repo_root)
    assert out.returncode == 0 and "ALL_OK" in out.stdout, \
        out.stdout + out.stderr
    return out.stdout
