"""Per-architecture smoke tests (deliverable f): reduced same-family
configs run a forward pass, one gradient step, and a prefill->decode
consistency check on CPU.  Full-size configs are exercised only via the
dry-run (ShapeDtypeStructs, no allocation)."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, runnable_shapes
from repro.models import build_model

ALL = sorted(ARCHS)


def smoke_cfg(name):
    cfg = ARCHS[name].smoke()
    kw = dict(compute_dtype="float32", param_dtype="float32")
    if cfg.is_moe:  # drop-free capacity so decode == forward exactly
        kw.update(capacity_factor=float(cfg.n_experts / cfg.top_k),
                  capacity_factor_eval=float(cfg.n_experts / cfg.top_k))
    return replace(cfg, **kw)


def inputs_for(cfg, key, b=2, s=24):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encdec:
        kw["enc_embeds"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1
    return tokens, kw


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finiteness(name):
    cfg = smoke_cfg(name)
    model = build_model(cfg, remat="none")
    params, axes = model.init(jax.random.PRNGKey(0))
    # axes pytree mirrors params exactly
    assert (jax.tree.structure(params) ==
            jax.tree.structure(axes, is_leaf=lambda t: isinstance(t, tuple)))
    tokens, kw = inputs_for(cfg, jax.random.PRNGKey(1))
    logits, aux, _, _ = model.forward(params, tokens, **kw)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())
    if cfg.is_moe:
        assert bool(jnp.isfinite(aux)) and float(aux) > 0.0


@pytest.mark.parametrize("name", ALL)
def test_one_train_step_grads_finite(name):
    cfg = smoke_cfg(name)
    model = build_model(cfg, remat="dots")
    params, _ = model.init(jax.random.PRNGKey(0))
    tokens, kw = inputs_for(cfg, jax.random.PRNGKey(1))
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux, _, _ = model.forward(p, tokens, **kw)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return (lse - ll).mean() + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # every parameter receives gradient signal somewhere
    nonzero = sum(float(jnp.abs(g).sum()) > 0 for g in flat)
    assert nonzero / len(flat) > 0.9, f"{nonzero}/{len(flat)} grads nonzero"


@pytest.mark.parametrize("name", ALL)
def test_decode_matches_forward(name):
    cfg = smoke_cfg(name)
    model = build_model(cfg, remat="none")
    params, _ = model.init(jax.random.PRNGKey(0))
    b, s, s0 = 2, 24, 16
    tokens, kw = inputs_for(cfg, jax.random.PRNGKey(1), b, s)
    full_logits, _, _, _ = model.forward(params, tokens, **kw)
    last, caches = model.prefill(params, tokens[:, :s0], pad_to=s, **kw)
    np.testing.assert_allclose(last, full_logits[:, s0 - 1],
                               rtol=2e-4, atol=2e-4)
    step = jax.jit(model.decode_step)
    for t in range(s0, s):
        logits, caches = step(params, tokens[:, t], caches, t)
        np.testing.assert_allclose(logits, full_logits[:, t],
                                   rtol=2e-4, atol=2e-4)


def test_runnable_shapes_registry():
    """long_500k only for sub-quadratic archs; 32 runnable cells total."""
    cells = sum(len(runnable_shapes(ARCHS[a])) for a in ALL)
    assert cells == 8 * 3 + 2 * 4
    assert [s.name for s in runnable_shapes(ARCHS["mamba2-2.7b"])] == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert "long_500k" not in [
        s.name for s in runnable_shapes(ARCHS["qwen3-8b"])]


def test_param_counts_match_published():
    expect = {
        "qwen3-8b": 8.2e9, "yi-6b": 6.1e9, "granite-8b": 8.1e9,
        "phi3-mini-3.8b": 3.8e9, "recurrentgemma-9b": 8.5e9,
        "qwen3-moe-235b-a22b": 235e9, "grok-1-314b": 316e9,
        "mamba2-2.7b": 2.7e9, "qwen2-vl-72b": 72.7e9,
    }
    for name, target in expect.items():
        got = ARCHS[name].param_count()
        assert abs(got - target) / target < 0.05, (name, got, target)
    assert abs(ARCHS["qwen3-moe-235b-a22b"].active_param_count() - 22.2e9
               ) / 22.2e9 < 0.05
