"""Device-side retirement decision units (DESIGN.md §2.8).

The sharded engine decides column retirement from an 8-tuple of
per-column aggregates reduced on device (``spanner._column_partials``,
psum'd across the mesh) — consumed either from the standalone
``shard_retire_kernels`` reduce (scan="off", drain) or fused into the
tail of the scanned span runners (scan="on").  These tests pin that
reduction and the decisions derived from it:

  * the device reduce against an independent host numpy reference, on
    random states and on handcrafted single-rule states;
  * each retirement rule — full-delivery (alive rows only), dead
    column, blocked-app gating, ping refcounts, horizon expiry and the
    hung-gate escape hatch — producing exactly the expected decision
    mask;
  * fused-vs-standalone: the aggregates at the tail of a scanned
    segment equal a standalone reduce of the post-segment state, at
    segment boundaries and mid-segment (ragged segments), on real
    scenario runs;
  * all of the above across 1/2/4 devices (multi-device in child
    interpreters — the forced host-device flag must precede jax init).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="the sharded engine needs jax (pip install -r "
    "requirements.txt)")

from repro.core.vecsim.scenario import INF  # noqa: E402
from repro.core.vecsim.shard.mesh import pad_rows, shard_mesh  # noqa: E402
from repro.core.vecsim.shard.spanner import (STATE_KEYS,  # noqa: E402
                                             shard_retire_kernels,
                                             shard_span_runner)
from vecsim_cases import build  # noqa: E402


# --------------------------------------------------------------------- #
# host numpy reference of the 8 per-column aggregates
# --------------------------------------------------------------------- #
def reduce_reference(st, origins, rounds):
    """(cnt, arrcnt, sumdel, alive, alivedel, blocked, ref, bdone) from
    plain numpy over the full (padded) host state — independently
    written against the retirement rules, not the device code."""
    arr, delivered = st["arr"], st["delivered"]
    crashed, gate, active, ping = (st["crashed"], st["gate"],
                                   st["active"], st["ping"])
    w = arr.shape[1]
    got = delivered >= 0
    cnt = got.sum(axis=0).astype(np.int64)
    arrcnt = (arr < rounds).sum(axis=0).astype(np.int64)
    sumdel = np.where(got, delivered, 0).sum(axis=0).astype(np.int64)
    alive = np.int64((~crashed).sum())
    alivedel = (got & ~crashed[:, None]).sum(axis=0).astype(np.int64)
    gated = (gate >= 0) & active & ~crashed[:, None]
    min_gate = np.where(gated, gate, INF).min(axis=1)
    blocked = ((got & (delivered >= min_gate[:, None]))
               .sum(axis=0).astype(np.int64))
    ref = np.zeros(w, np.int64)
    pv = ping[(ping >= 0) & ~crashed[:, None]]
    np.add.at(ref, pv, 1)
    bdone = np.zeros(w, np.int64)
    ok = origins >= 0
    bdone[ok] = got[origins[ok], np.nonzero(ok)[0]]
    return (cnt, arrcnt, sumdel, alive, alivedel, blocked, ref, bdone)


def decide(red, slot_app, slot_birth, t_now, horizon=None):
    """The driver's retirement decision formula, verbatim, from the
    reduced aggregates: returns (done, by_exp, hung) masks."""
    cnt, _, _, alive, alivedel, blockcnt, refcnt, _ = red
    w = len(cnt)
    live = slot_birth >= 0  # tests encode "free" as birth -1
    full_del = alivedel == int(alive)
    blocked = (blockcnt > 0) & slot_app
    ref = refcnt > 0
    dead = (cnt == 0) & (slot_birth < t_now)
    done = live & ~ref & ((full_del & ~blocked) | dead)
    by_exp = np.zeros(w, bool)
    hung = np.zeros(w, bool)
    if horizon is not None:
        by_exp = live & ~done & (t_now - slot_birth > horizon)
        hung = by_exp & ref
        done = done | by_exp
    return done, by_exp, hung


def _random_state(rng, n, w, k):
    return dict(
        arr=np.where(rng.random((n, w)) < 0.4,
                     rng.integers(0, 25, (n, w)), INF).astype(np.int32),
        delivered=np.where(rng.random((n, w)) < 0.4,
                           rng.integers(0, 20, (n, w)), -1).astype(np.int32),
        adj=rng.integers(0, n, (n, k)).astype(np.int32),
        delay=rng.integers(1, 4, (n, k)).astype(np.int32),
        active=rng.random((n, k)) < 0.8,
        gate=np.where(rng.random((n, k)) < 0.3,
                      rng.integers(0, 15, (n, k)), -1).astype(np.int32),
        flush=np.full((n, k), INF, np.int32),
        ping=np.where(rng.random((n, k)) < 0.25,
                      rng.integers(0, w, (n, k)), -1).astype(np.int32),
        crashed=rng.random(n) < 0.2,
        ever_del=np.zeros(n, bool),
    )


def _device_state(st, d):
    from jax.sharding import NamedSharding, PartitionSpec as P
    row = NamedSharding(shard_mesh(d), P("shard"))
    return tuple(jax.device_put(st[key], row) for key in STATE_KEYS)


def run_reduce_matches_reference(n_devices, seeds=(0, 1, 2)):
    """Standalone device reduce == numpy reference on random states."""
    reduce_run, _ = shard_retire_kernels(n_devices)
    for seed in seeds:
        rng = np.random.default_rng(seed)
        n, w, k = 8 * n_devices, 7, 3
        st = _random_state(rng, n, w, k)
        origins = np.where(rng.random(w) < 0.6,
                           rng.integers(0, n, w), -1).astype(np.int32)
        rounds = np.int32(25)
        got = tuple(np.asarray(x) for x in
                    reduce_run(_device_state(st, n_devices), origins,
                               rounds))
        want = reduce_reference(st, origins, rounds)
        for g, wnt, name in zip(got, want,
                                ("cnt", "arrcnt", "sumdel", "alive",
                                 "alivedel", "blocked", "ref", "bdone")):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(wnt),
                                          err_msg=f"seed={seed} {name}")


def run_fused_vs_standalone(n_devices, case=("crash", 5, 32),
                            segments=((0, 3), (3, 11), (11, 16))):
    """Real-scenario segments through the scanned runner: the fused
    aggregates at the segment tail must equal a standalone reduce of
    the post-segment state AND the numpy reference on the fetched host
    state.  Segment spans include a mid-segment stop (shorter than
    seg_len, so the tail rounds are padding) and on-grid boundaries."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.vecsim.shard.driver import _padded_state
    from repro.core.vecsim.stream import ColumnWindow

    name, seed, n = case
    scn = build(name, seed, n)
    w = scn.m_total
    seg_len = 8
    cw = ColumnWindow(scn, w)
    st0 = _padded_state(scn, w, pad_rows(scn.n, n_devices))
    mesh = shard_mesh(n_devices)
    row = NamedSharding(mesh, P("shard"))
    rep = NamedSharding(mesh, P())
    state = tuple(jax.device_put(st0[key], row) for key in STATE_KEYS)
    runner = shard_span_runner(n_devices, scn.k, scn.mode == "pc",
                               scn.always_gate, scn.pong_delay,
                               gating=scn.n_adds > 0, backend="jax",
                               scan=True)
    reduce_run, _ = shard_retire_kernels(n_devices)
    caps = cw.round_caps(scn.rounds)
    rounds = np.int32(scn.rounds)
    for lo, hi in segments:
        hi = min(hi, scn.rounds)
        assert cw.activate(lo, hi) == hi, "case must not shorten segments"
        sst = cw.stacked_schedule(lo, hi, caps, seg_len)
        ts = np.full(seg_len, -3, np.int32)
        ts[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
        origins = np.full(w, -1, np.int32)
        app = cw.slot_app & (cw.slot_msg >= 0)
        origins[app] = scn.bcast_origin[cw.slot_msg[app]]
        state, _, red = runner(
            state, {key: jax.device_put(v, rep) for key, v in sst.items()},
            jax.device_put(ts, rep), jax.device_put(origins, rep), rounds)
        fused = tuple(np.asarray(x) for x in red)
        standalone = tuple(np.asarray(x)
                           for x in reduce_run(state, origins, rounds))
        host = {key: np.asarray(v) for key, v in zip(STATE_KEYS, state)}
        ref = reduce_reference(host, origins, rounds)
        for f, s, r in zip(fused, standalone, ref):
            np.testing.assert_array_equal(f, s, err_msg=f"seg [{lo},{hi})")
            np.testing.assert_array_equal(f, np.asarray(r),
                                          err_msg=f"seg [{lo},{hi})")


def test_reduce_matches_reference_one_device():
    run_reduce_matches_reference(1)


def test_fused_reduce_matches_standalone_one_device():
    run_fused_vs_standalone(1)
    run_fused_vs_standalone(1, case=("link_add", 3, 24))


def test_retirement_decision_rules():
    """One handcrafted column per rule; the decisions derived from the
    device reduce must match the expectations exactly.

    col0 full-delivery: delivered on every alive row -> retires
    col1 dead: no deliveries, born before t_now -> retires
    col2 blocked app: fully delivered but a delivery lands at-or-after
         an open gate on an active link -> held
    col3 ping-referenced: an alive row's ping slot points here -> held,
         and under a horizon it force-expires as *hung*
    col4 full-delivery modulo crashes: only crashed rows undelivered ->
         retires (the alive-rows-only rule)
    col5 straggler: partial delivery, old birth -> held without a
         horizon, force-expired (not hung) with one
    """
    n, w, k = 8, 6, 2
    st = dict(
        arr=np.full((n, w), INF, np.int32),
        delivered=np.full((n, w), -1, np.int32),
        adj=np.zeros((n, k), np.int32),
        delay=np.ones((n, k), np.int32),
        active=np.ones((n, k), bool),
        gate=np.full((n, k), -1, np.int32),
        flush=np.full((n, k), INF, np.int32),
        ping=np.full((n, k), -1, np.int32),
        crashed=np.zeros(n, bool),
        ever_del=np.zeros(n, bool),
    )
    st["crashed"][6:] = True
    st["delivered"][:, 0] = 3            # col0: everywhere (crashed too)
    st["delivered"][:, 2] = 4            # col2: everywhere, but gated:
    st["gate"][1, 0] = 4                 #   row1 delivery at gate round
    st["delivered"][:, 3] = 3            # (below the gate: not blocked)
    st["delivered"][0, 3] = -1           # col3: one miss + a ping ref
    st["ping"][2, 1] = 3
    st["delivered"][:6, 4] = 3           # col4: all *alive* rows
    st["delivered"][:2, 5] = 2           # col5: partial
    st["arr"][:, (0, 2, 3, 4)] = 3
    st["arr"][:2, 5] = 2

    slot_app = np.array([True, True, True, True, True, True])
    slot_birth = np.array([2, 1, 2, 2, 2, 1], np.int64)
    origins = np.array([0, -1, 1, 2, 3, 4], np.int32)
    t_now, rounds = 12, np.int32(20)

    reduce_run, _ = shard_retire_kernels(1)
    red = tuple(np.asarray(x) for x in
                reduce_run(_device_state(st, 1), origins, rounds))
    for g, wnt in zip(red, reduce_reference(st, origins, rounds)):
        np.testing.assert_array_equal(g, np.asarray(wnt))

    done, by_exp, hung = decide(red, slot_app, slot_birth, t_now)
    assert done.tolist() == [True, True, False, False, True, False]
    assert not by_exp.any() and not hung.any()

    done_h, by_exp_h, hung_h = decide(red, slot_app, slot_birth, t_now,
                                      horizon=8)
    assert done_h.tolist() == [True, True, True, True, True, True]
    assert by_exp_h.tolist() == [False, False, True, True, False, True]
    assert hung_h.tolist() == [False, False, False, True, False, False]

    # bdone: the origin row of each retiring app column delivered it
    assert red[7].tolist() == [1, 0, 1, 1, 1, 0]


_MULTIDEV_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={d}"
import sys
sys.path.insert(0, {tests_dir!r})
from test_vecsim_retire import (run_fused_vs_standalone,
                                run_reduce_matches_reference)
run_reduce_matches_reference({d})
run_fused_vs_standalone({d})
print("RETIRE_OK")
"""


@pytest.mark.parametrize("d", [2, 4])
def test_retire_reduce_multidevice_subprocess(d):
    """Reference match + fused-vs-standalone on real 2- and 4-device
    meshes (psum across shards, padded rows in play)."""
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    repo_root = os.path.dirname(tests_dir)
    out = subprocess.run(
        [sys.executable, "-c",
         _MULTIDEV_SNIPPET.format(tests_dir=tests_dir, d=d)],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH="src"), cwd=repo_root)
    assert out.returncode == 0 and "RETIRE_OK" in out.stdout, \
        out.stdout + out.stderr
