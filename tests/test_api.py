"""The experiment front door: RunSpec validation, registry dispatch,
engine auto-selection, cross-engine agreement through ``repro.api.run``,
the measured vector-clock baseline, legacy-shim warnings, and the CLI."""

import json
import warnings

import numpy as np
import pytest

from repro.api import (DynamicsSpec, MetricsSpec, RunReport, RunSpec,
                       SpecError, TopologySpec, TrafficSpec, WindowSpec,
                       build_scenario, run, select_engine)
from repro.api import ENGINES, PROTOCOLS, SCENARIOS, TOPOLOGIES, TRAFFIC
from repro.core.types import LegacyEntryPointWarning
from repro.core.vecsim import (VecScenario, run_vec, run_vec_windowed,
                               static_scenario)


# --------------------------------------------------------------------- #
# Spec validation
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("bad,match", [
    (dict(protocol="zab"), "protocol='zab'"),
    (dict(engine="vex"), "engine='vex'"),
    (dict(backend="torch"), "backend='torch'"),
    (dict(n=1), "n=1"),
    (dict(topology=TopologySpec(kind="torus")), "topology.kind='torus'"),
    (dict(traffic=TrafficSpec(kind="pareto")), "traffic.kind='pareto'"),
    (dict(dynamics=DynamicsSpec(kind="meteor")), "dynamics.kind='meteor'"),
    (dict(protocol="vc", engine="windowed"), "no windowed engine"),
    (dict(protocol="vc", backend="jax"), "numpy-only"),
    (dict(dynamics=DynamicsSpec(kind="churn"),
          traffic=TrafficSpec(kind="poisson")), "only .* traffic"),
    (dict(dynamics=DynamicsSpec(kind="partition_heal"),
          topology=TopologySpec(kind="smallworld")), "only .* topologies"),
    (dict(window=WindowSpec(window=0)), "window.window"),
    (dict(window=WindowSpec(collect="some")), "window.collect"),
    (dict(metrics=MetricsSpec(snapshot="first_churn")), "last_churn"),
])
def test_spec_validation_rejects_with_informative_errors(bad, match):
    with pytest.raises(SpecError, match=match):
        RunSpec(**bad).validate()


def test_spec_json_round_trip_and_unknown_keys():
    spec = RunSpec(protocol="vc", n=96, seed=7,
                   topology=TopologySpec(kind="kregular", k=6),
                   traffic=TrafficSpec(kind="poisson", rate=2.5,
                                       messages=40))
    assert RunSpec.from_dict(spec.to_dict()) == spec
    assert RunSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) == spec
    with pytest.raises(SpecError, match="unknown RunSpec field"):
        RunSpec.from_dict({"protcol": "pc"})
    with pytest.raises(SpecError, match="unknown topology field"):
        RunSpec.from_dict({"topology": {"kid": "ring"}})


def test_registered_topology_and_traffic_are_actually_buildable():
    """The register-to-extend contract end to end: a topology or traffic
    model registered on the api registries must be dispatched by the
    scenario builders, not just pass key validation."""
    from repro.api import TOPOLOGIES, TRAFFIC, TrafficModel
    from repro.core.vecsim import poisson_traffic, ring_topology

    if "test_star" not in TOPOLOGIES:
        def star(seed, n, k, max_delay, free_slots, beta):
            # a ring on slot 0 plus spokes into process 0 (skipping the
            # last process, whose ring slot already points at 0)
            adj0, delay0 = ring_topology(seed, n, k, max_delay,
                                         free_slots=k - 2)
            adj0[2:n - 1, 1] = 0
            return adj0, delay0
        TOPOLOGIES.register("test_star", star)
    rep = run(RunSpec(engine="vec", backend="numpy", n=24,
                      topology=TopologySpec(kind="test_star", k=3),
                      traffic=TrafficSpec(messages=4)))
    assert rep.delivered_frac == 1.0
    snap = rep.result.state
    assert (snap["adj"][2:23, 1] == 0).all()   # the custom shape ran

    if "test_halfrate" not in TRAFFIC:
        TRAFFIC.register("test_halfrate", TrafficModel(
            build=lambda seed, n, t0, t1, mm, p:
                poisson_traffic(seed, n, p["rate"] / 2, t0, t1, mm),
            mean_rate=lambda p: p["rate"] / 2))
    rep = run(RunSpec(engine="vec", backend="numpy", n=24,
                      traffic=TrafficSpec(kind="test_halfrate", rate=4.0,
                                          messages=10)))
    assert rep.m_app == 10 and rep.delivered_frac == 1.0


def test_registries_expose_expected_keys():
    assert {"pc", "r", "vc"} <= set(PROTOCOLS.keys())
    assert {"exact", "vec", "windowed", "sharded"} == set(ENGINES.keys())
    assert {"ring", "kregular", "smallworld"} <= set(TOPOLOGIES.keys())
    assert {"uniform", "poisson", "bursty"} <= set(TRAFFIC.keys())
    assert {"none", "link_add", "churn", "crash", "partition_heal",
            "churn_wave"} <= set(SCENARIOS.keys())
    with pytest.raises(KeyError, match="registered"):
        PROTOCOLS.get("zab")
    with pytest.raises(KeyError, match="already registered"):
        PROTOCOLS.register("pc", PROTOCOLS.get("pc"))


# --------------------------------------------------------------------- #
# Engine auto-selection (the DESIGN.md §3 budget rule)
# --------------------------------------------------------------------- #
def test_auto_selects_monolithic_when_budget_fits():
    spec = RunSpec(n=64).validate()
    assert select_engine(spec, build_scenario(spec)) == ("vec", None)


def test_auto_selects_windowed_with_budget_sized_window():
    from repro.api import ShardSpec
    # devices pinned to 1 so the assertion holds on multi-device hosts
    # (there the per-device rule would pick the sharded engine instead)
    spec = RunSpec(n=2000, memory_budget_mb=1,
                   shard=ShardSpec(devices=1),
                   traffic=TrafficSpec(kind="poisson", rate=3.0,
                                       messages=500)).validate()
    engine, window = select_engine(spec, build_scenario(spec))
    assert engine == "windowed"
    assert window == (1 << 20) // (8 * 2000)


def test_auto_never_windowed_for_vc():
    spec = RunSpec(protocol="vc", n=2000, memory_budget_mb=1,
                   traffic=TrafficSpec(kind="poisson", rate=3.0,
                                       messages=500)).validate()
    assert select_engine(spec, build_scenario(spec)) == ("vec", None)


def test_explicit_window_selects_windowed():
    spec = RunSpec(n=64, window=WindowSpec(window=128)).validate()
    assert select_engine(spec, build_scenario(spec)) == ("windowed", 128)


def test_auto_selection_exact_budget_boundaries():
    """The thresholds bit for bit: 8·N·M_total == budget stays
    monolithic, one more message tips to a streaming engine, and the
    budget-derived window sits on its 64-column floor there."""
    from repro.api import ShardSpec

    def spec_for(messages, **kw):
        # devices pinned to 1 on auto-engine specs: the boundary under
        # test is the budget rule, not the device count of the host
        # running the suite (validate() rejects the pin on explicit
        # single-host engines)
        if "engine" not in kw:
            kw.setdefault("shard", ShardSpec(devices=1))
        return RunSpec(n=2048, memory_budget_mb=1,
                       traffic=TrafficSpec(kind="poisson", rate=2.0,
                                           messages=messages),
                       **kw).validate()
    # 8 * 2048 * 64 == 1 MiB exactly (no adds, so m_total == messages)
    at = spec_for(64)
    scn = build_scenario(at)
    assert scn.m_total == 64
    assert select_engine(at, scn) == ("vec", None)
    over = spec_for(65)
    engine, window = select_engine(over, build_scenario(over))
    assert engine == "windowed"
    assert window == 64          # clamp floor: budget // (8*2048) == 64
    # the window never exceeds the message axis (explicit engine path)
    from repro.api.run import _auto_window
    tiny = spec_for(65, engine="windowed")
    assert _auto_window(tiny, build_scenario(tiny), devices=64) == 65


def test_auto_selection_is_per_device_aware():
    """shard.devices (or a visible mesh) scales the budget-derived
    window D-fold and routes the run to the sharded engine; a single
    device or a numpy backend keeps the single-host windowed engine; a
    budget the monolithic planes fit is never sharded."""
    from repro.api import ShardSpec
    tr = TrafficSpec(kind="poisson", rate=3.0, messages=500)

    spec4 = RunSpec(n=2000, memory_budget_mb=1, traffic=tr,
                    shard=ShardSpec(devices=4)).validate()
    engine, window = select_engine(spec4, build_scenario(spec4))
    assert engine == "sharded"
    assert window == 4 * (1 << 20) // (8 * 2000)

    spec1 = RunSpec(n=2000, memory_budget_mb=1, traffic=tr,
                    shard=ShardSpec(devices=1)).validate()
    assert select_engine(spec1, build_scenario(spec1)) == (
        "windowed", (1 << 20) // (8 * 2000))

    # the numpy backend can never shard: asking for a mesh with it is a
    # spec error, not a silent single-host fallback
    with pytest.raises(SpecError, match="needs the jax backend"):
        RunSpec(n=2000, memory_budget_mb=1, backend="numpy", traffic=tr,
                shard=ShardSpec(devices=4)).validate()
    # ...and without an explicit mesh, numpy auto-selection stays
    # windowed without ever initializing jax
    numpy1 = RunSpec(n=2000, memory_budget_mb=1, backend="numpy",
                     traffic=tr).validate()
    assert select_engine(numpy1, build_scenario(numpy1))[0] == "windowed"

    fits4 = RunSpec(n=64, shard=ShardSpec(devices=4)).validate()
    assert select_engine(fits4, build_scenario(fits4)) == ("vec", None)

    # an explicit window plus an explicit mesh keeps the mesh
    win4 = RunSpec(n=64, shard=ShardSpec(devices=4),
                   window=WindowSpec(window=128)).validate()
    assert select_engine(win4, build_scenario(win4)) == ("sharded", 128)


def test_spec_shard_section_round_trips():
    from repro.api import ShardSpec
    spec = RunSpec(engine="sharded", shard=ShardSpec(devices=2))
    assert RunSpec.from_dict(spec.to_dict()) == spec
    assert RunSpec.from_dict({"shard": {"devices": 2}}).shard.devices == 2


# --------------------------------------------------------------------- #
# run(): one spec, every engine, agreeing results
# --------------------------------------------------------------------- #
def _base(engine, **kw):
    kw.setdefault("metrics", MetricsSpec(oracle=True))
    return RunSpec(protocol="pc", engine=engine, backend="numpy", n=48,
                   seed=11, traffic=TrafficSpec(messages=8),
                   dynamics=DynamicsSpec(kind="link_add", n_adds=4), **kw)


def test_run_dispatches_every_engine_and_engines_agree():
    reports = {
        eng: run(_base(eng, window=WindowSpec(window=None if eng != "windowed"
                                              else 16, collect="full")))
        for eng in ("exact", "vec", "windowed")}
    for eng, rep in reports.items():
        assert isinstance(rep, RunReport), eng
        assert rep.engine == eng
        assert rep.delivered_frac == 1.0, eng
        assert rep.oracle.ok, (eng, rep.oracle.summary())
    # the two vec engines are byte-identical; exact agrees on volume
    np.testing.assert_array_equal(reports["vec"].result.delivered,
                                  reports["windowed"].result.delivered)
    assert reports["vec"].stats == reports["windowed"].stats
    assert (reports["exact"].stats.deliveries
            == reports["vec"].stats.deliveries)


def test_run_crossval_flag_checks_engine_agreement():
    rep = run(_base("vec", metrics=MetricsSpec(crossval=True)))
    assert rep.crossval_ok is True


def test_run_report_to_dict_is_json_safe():
    rep = run(_base("vec"))
    d = rep.to_dict()
    json.dumps(d)                       # must not raise
    assert d["engine"] == "vec" and d["oracle_ok"] is True
    assert d["stats"]["deliveries"] == rep.stats.deliveries


def test_prebuilt_scenario_escape_hatch():
    scn = static_scenario(seed=3, n=40, m_app=6)
    rep = run(RunSpec(engine="vec", backend="numpy", scenario=scn))
    assert rep.m_app == 6 and rep.delivered_frac == 1.0


def test_protocol_r_runs_ungated():
    rep = run(RunSpec(protocol="r", engine="vec", backend="numpy", n=48,
                      dynamics=DynamicsSpec(kind="link_add", n_adds=4)))
    assert rep.extras["gated_link_rounds"] == 0
    assert rep.stats.oob_messages == 0


# --------------------------------------------------------------------- #
# The measured vector-clock baseline
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("n", [64, 256])
def test_vc_vec_cross_validates_byte_identical(n):
    """The acceptance bar: delivered multisets AND final clock values
    byte-identical between vecsim.vc and core.vector_clock on the exact
    engine."""
    from repro.core.vecsim.crossval import cross_validate
    scn = static_scenario(seed=n + 5, n=n, m_app=16)
    out = cross_validate(scn, protocol="vc")
    assert out["vec_multiset"] == out["exact_multiset"]
    assert out["vec_clocks"] == out["exact_clocks"]
    assert out["vec_report"].ok, out["vec_report"].summary()
    assert out["exact_report"].ok, out["exact_report"].summary()


def test_vc_vec_cross_validates_under_churn_and_crashes():
    from repro.core.vecsim import churn_scenario, crash_scenario
    from repro.core.vecsim.crossval import cross_validate
    for scn in (churn_scenario(seed=31, n=64),
                crash_scenario(seed=7, n=64)):
        out = cross_validate(scn, protocol="vc")
        assert out["vec_multiset"] == out["exact_multiset"]
        assert out["vec_clocks"] == out["exact_clocks"]


def test_vc_overhead_grows_with_broadcasters_pc_does_not():
    """Table 1's separation, measured end to end through the API."""
    def extras(protocol, m_app):
        return run(RunSpec(protocol=protocol, engine="vec",
                           backend="numpy", n=64, seed=2,
                           traffic=TrafficSpec(messages=m_app))).extras
    vc_small = extras("vc", 4)["overhead_bytes_per_msg"]
    vc_large = extras("vc", 32)["overhead_bytes_per_msg"]
    assert vc_large > vc_small >= 24.0   # id + at least one clock entry
    pc_small = extras("pc", 4)["overhead_bytes_per_msg"]
    pc_large = extras("pc", 32)["overhead_bytes_per_msg"]
    assert pc_small == pc_large == 16.0  # the paper's O(1)
    cmp = extras("vc", 32)["comparisons_per_delivery"]
    assert cmp >= 1.0                    # every delivery rescans a clock


def test_vc_comparisons_measure_pending_rescans():
    """A deliberately out-of-order arrival (a fast link added after the
    first message already passed) must park the dependent message in
    pending and charge extra readiness scans — the Fig. 3 situation VC
    resolves by buffering instead of link gating."""
    from repro.core.vecsim.crossval import cross_validate
    from repro.core.vecsim.vc import run_vec_vc
    i32 = lambda *a: np.asarray(a, np.int32)  # noqa: E731
    n, k = 3, 3
    adj0 = np.full((n, k), -1, np.int32)
    delay0 = np.ones((n, k), np.int32)
    adj0[0, 0] = 1                        # 0 -> 1 fast
    adj0[0, 1], delay0[0, 1] = 2, 9       # 0 -> 2 slow: m1 takes 9 rounds
    adj0[1, 0] = 0
    adj0[2, 0] = 0
    scn = VecScenario(
        n=n, k=k, rounds=30, adj0=adj0, delay0=delay0,
        # m2 (causally after m1) is broadcast once the fresh 1 -> 2 link
        # exists, so it overtakes m1 on the way to process 2
        bcast_round=i32(0, 6), bcast_origin=i32(0, 1),
        add_round=i32(5), add_p=i32(1), add_k=i32(2), add_q=i32(2),
        add_delay=i32(1)).validate()
    res = run_vec_vc(scn)
    assert res.delivered_frac() == 1.0
    assert res.max_pending >= 2            # m2 waited for m1 at process 2
    assert res.comparisons > res.stats.deliveries  # rescans happened
    # m2 overtook m1 on the wire (earlier receipt) yet was parked until
    # m1's arrival unblocked it in the same drain fixpoint
    assert res.rcv[2, 1] < res.rcv[2, 0]
    assert res.rcv[2, 1] < res.delivered[2, 1]
    assert res.delivered[2, 0] <= res.delivered[2, 1]
    out = cross_validate(scn, protocol="vc")
    assert out["vec_multiset"] == out["exact_multiset"]
    assert out["vec_clocks"] == out["exact_clocks"]


# --------------------------------------------------------------------- #
# Legacy entry points: same behavior, loud warning
# --------------------------------------------------------------------- #
def test_legacy_run_vec_warns_and_matches_front_door():
    scn = static_scenario(seed=4, n=40, m_app=6)
    with pytest.warns(LegacyEntryPointWarning):
        legacy = run_vec(scn, backend="numpy")
    front = run(RunSpec(engine="vec", backend="numpy", scenario=scn))
    np.testing.assert_array_equal(legacy.delivered, front.result.delivered)
    assert legacy.stats == front.stats


def test_legacy_run_vec_windowed_warns_and_matches_front_door():
    scn = static_scenario(seed=4, n=40, m_app=6)
    with pytest.warns(LegacyEntryPointWarning):
        legacy = run_vec_windowed(scn, scn.m_total, backend="numpy",
                                  collect="full")
    front = run(RunSpec(engine="windowed", backend="numpy", scenario=scn,
                        window=WindowSpec(window=scn.m_total,
                                          collect="full")))
    np.testing.assert_array_equal(legacy.delivered, front.result.delivered)
    assert legacy.stats == front.stats


def test_front_door_emits_no_legacy_warnings():
    with warnings.catch_warnings():
        warnings.simplefilter("error", LegacyEntryPointWarning)
        run(_base("vec"))
        run(_base("windowed",
                  window=WindowSpec(window=16, collect="full"),
                  metrics=MetricsSpec(oracle=True, crossval=True)))


# --------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------- #
def test_cli_runs_a_tiny_spec(capsys):
    from repro.api.__main__ import main
    rc = main(["--protocol", "pc", "--engine", "vec", "--backend", "numpy",
               "--n", "32", "--messages", "4", "--oracle"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert out["engine"] == "vec" and out["oracle_ok"] is True
    assert out["delivered_frac"] == 1.0


def test_cli_spec_json_and_dump(tmp_path, capsys):
    spec_file = tmp_path / "spec.json"
    spec_file.write_text(json.dumps(
        {"protocol": "vc", "engine": "vec", "n": 32,
         "traffic": {"messages": 4}}))
    from repro.api.__main__ import main
    assert main(["--spec", str(spec_file), "--dump-spec"]) == 0
    dumped = json.loads(capsys.readouterr().out)
    assert dumped["protocol"] == "vc" and dumped["n"] == 32
    assert main(["--spec", str(spec_file)]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["extras"]["comparisons_per_delivery"] > 0.0


def test_cli_rejects_bad_spec(capsys):
    from repro.api.__main__ import main
    assert main(["--protocol", "pc", "--n", "1"]) == 2
    assert "n=1" in capsys.readouterr().err


def test_cli_list_is_a_discovery_surface(capsys):
    """--list names every registered key on every axis WITH its
    description, so a new user can discover the experiment space
    without reading source."""
    from repro.api.__main__ import main
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    for key in ("pc", "r", "vc",                          # protocols
                "exact", "vec", "windowed", "sharded",    # engines
                "ring", "kregular", "smallworld",         # topologies
                "uniform", "poisson", "bursty",           # traffic
                "churn", "crash", "link_add", "none",
                "partition_heal", "churn_wave",           # scenarios
                "hash", "all",                            # samplers
                "log", "fail",                            # audit modes
                "prometheus", "jsonl"):                   # ops sinks
        assert key in out, key
    # descriptions, not bare keys
    assert "shard_map frontier exchange" in out
    assert "Algorithm 2" in out
    assert "Watts-Strogatz" in out
    # flight-recorder axes (S10) are discoverable with descriptions
    assert "samplers" in out and "audit" in out and "ops sinks" in out
    assert "splitmix64" in out
    assert "CausalityViolationError" in out
    assert "append-only JSONL stream" in out
