"""Telemetry subsystem suite (repro.obs; DESIGN.md §2.10).

The unified-telemetry claims, each tested directly:

  * the log-bucket function is a single integer-comparison contract:
    the numpy reference, the jax reduction tail and the brute-force
    layout spec all agree on every boundary value;
  * hist-derived percentiles are *exact* nearest-rank percentiles for
    latencies < 16 rounds and bucket-lower-bound approximations above;
  * the on-device delivery-latency histogram cross-validates against
    the exact event simulator's per-delivery latencies, at N ∈
    {64, 256}, windowed (numpy/jax/pallas) and sharded scan="on" — and
    telemetry on vs off leaves every engine result byte-identical;
  * a live run's histogram equals the host-side rebucketing of its own
    delivered matrix (queueing delay included), and the report's
    percentiles are the histogram's;
  * the span recorder is leak-checked (depth returns to 0), bounded
    (overflow counts into ``dropped``), and its null twin is free;
  * backpressure events are well-formed: one ``backpressure`` instant
    per caught ``WindowOverflowError``, carrying the blocking round;
  * the segment stager's upload-skip accounting matches its content
    cache semantics (satellite: stager coverage);
  * both export sinks round-trip: schema-versioned JSONL metrics
    reject foreign files, Chrome trace JSON is Perfetto-loadable
    (``traceEvents`` with X/i/C/M phases);
  * every committed ``BENCH_*.json`` loads through the shared
    versioned report reader with the kind its filename claims;
  * ``repro.core.metrics`` still works but warns
    ``LegacyEntryPointWarning`` on import (satellite: shim).
"""

import importlib
import json
import math
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.api import ObsSpec, RunSpec, SpecError, TrafficSpec, WindowSpec
from repro.api import run as api_run
from repro.core.vecsim import crossval as _crossval
from repro.core.vecsim import execute_windowed, static_scenario
from repro.core.vecsim.live import LiveLoop
from repro.core.vecsim.shard import execute_sharded
from repro.obs.hist import (NB, bucket_index_jnp, bucket_index_np,
                            bucket_lower_bounds, hist_np, merge_hists,
                            percentiles_from_hist)
from repro.obs.report import (BENCH_SCHEMA_VERSION, load_bench_report,
                              write_bench_report)
from repro.obs.sinks import (SINKS, load_metrics_jsonl, write_chrome_trace,
                             write_metrics_chrome, write_metrics_jsonl)
from repro.obs.spans import NULL_RECORDER, EngineObs, SpanRecorder

REPO_ROOT = Path(__file__).resolve().parents[1]


# --------------------------------------------------------------------- #
# Bucket layout: the integer contract
# --------------------------------------------------------------------- #
def _ref_bucket(v: int) -> int:
    """Brute-force transcription of the DESIGN §2.10 layout table."""
    if v < 16:
        return max(v, 0)
    for j in range(15):
        if (1 << (4 + j)) <= v < (1 << (5 + j)):
            return min(16 + j, NB - 1)
    return NB - 1


_EDGES = sorted({0, 1, 15, 16, 17, 31, 32, 33, 63, 64, 1023, 1024,
                 (1 << 19) - 1, 1 << 19, (1 << 20) + 7}
                | {(1 << k) + d for k in range(4, 20) for d in (-1, 0, 1)})


def test_bucket_layout_matches_spec_table():
    got = bucket_index_np(_EDGES)
    want = [_ref_bucket(v) for v in _EDGES]
    assert got.tolist() == want
    # negative sentinels clamp to bucket 0 (callers mask them out)
    assert bucket_index_np([-1, -7]).tolist() == [0, 0]


def test_bucket_index_jnp_matches_numpy():
    import jax.numpy as jnp
    values = np.array(_EDGES + list(range(0, 200)), np.int64)
    np.testing.assert_array_equal(
        np.asarray(bucket_index_jnp(jnp.asarray(values))),
        bucket_index_np(values))


def test_bucket_lower_bounds_are_bucket_minima():
    lo = bucket_lower_bounds()
    assert lo.shape == (NB,)
    for i, b in enumerate(lo):
        assert bucket_index_np([int(b)])[0] == i
        if i:  # one below the bound lands in an earlier bucket
            assert bucket_index_np([int(b) - 1])[0] == i - 1


def test_hist_np_and_merge():
    a = np.array([0, 3, 3, 15, 16, 40, -1])   # -1 masked out
    b = np.array([3, 1 << 10])
    ha, hb = hist_np(a), hist_np(b)
    assert int(ha.sum()) == 6 and int(hb.sum()) == 2
    np.testing.assert_array_equal(merge_hists([ha, hb]),
                                  hist_np(np.concatenate([a, b])))


def _nearest_rank(values, q):
    v = np.sort(np.asarray(values))
    return v[max(1, math.ceil(q / 100.0 * len(v))) - 1]


def test_percentiles_exact_below_16_and_bucketed_above():
    rng = np.random.default_rng(0)
    small = rng.integers(0, 16, size=500)      # every steady-state run
    p = percentiles_from_hist(hist_np(small), (50.0, 99.0, 99.9))
    assert p == [float(_nearest_rank(small, q)) for q in (50.0, 99.0, 99.9)]
    # above 16 the read-out is the lower bound of the nearest-rank
    # value's bucket — bucketing is monotone, so it commutes with ranks
    big = rng.integers(0, 5000, size=500)
    lo = bucket_lower_bounds()
    for q, hp in zip((50.0, 99.0, 99.9),
                     percentiles_from_hist(hist_np(big), (50.0, 99.0, 99.9))):
        assert hp == float(lo[bucket_index_np([_nearest_rank(big, q)])[0]])


def test_percentiles_empty_hist_is_nan():
    out = percentiles_from_hist(np.zeros(NB, np.int64), (50.0, 99.0))
    assert len(out) == 2 and all(math.isnan(x) for x in out)


def test_shard_hist_runner_matches_host_fold():
    """The on-device histogram (cumulative threshold counts, psum'd)
    is byte-identical to hist_np over the same gathered latencies —
    the parity contract that lets the sharded driver fold host-side on
    CPU meshes and on-device on accelerator meshes interchangeably."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.core.vecsim.shard.spanner import shard_hist_runner, \
        shard_mesh

    rng = np.random.default_rng(7)
    n, w = 96, 12
    delivered = rng.integers(-1, 1 << 12, size=(n, w)).astype(np.int32)
    cols = np.array([0, 3, 3, 7, 11, 2, 0, 5], np.int32)
    # a padded slot (base -1), a sentinel-high base, and normal bases
    base = np.array([0, 5, -1, 40, 1, 9000, -1, 2], np.int32)
    mesh = shard_mesh(1)
    dev = jax.device_put(delivered, NamedSharding(mesh, PartitionSpec("shard")))
    got = np.asarray(shard_hist_runner(1)(dev, cols, base))
    da = delivered[:, cols].astype(np.int64)
    valid = (da >= 0) & (base >= 0)[None, :]
    want = hist_np((da - base[None, :].astype(np.int64))[valid])
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------- #
# Span recorder: bounded, leak-checked, null twin free
# --------------------------------------------------------------------- #
def test_span_recorder_events_and_depth():
    rec = SpanRecorder(capacity=16)
    outer, inner = rec.name("outer"), rec.name("inner")
    assert rec.name("outer") == outer      # interning is idempotent
    rec.begin(outer)
    rec.begin(inner)
    assert rec.depth == 2
    rec.end()
    rec.instant(rec.name("mark"), 7.0)
    rec.counter(rec.name("gauge"), 3.5)
    rec.end()
    assert rec.depth == 0 and rec.dropped == 0
    evs = rec.events()
    assert [e["kind"] for e in evs] == ["span", "instant", "counter",
                                       "span"]
    assert [e["name"] for e in evs] == ["inner", "mark", "gauge", "outer"]
    assert all(e["dur_ns"] >= 0 for e in evs if e["kind"] == "span")
    # inner span closed first, but outer opened first
    assert evs[3]["t0_ns"] <= evs[0]["t0_ns"]
    assert evs[1]["value"] == 7.0 and evs[2]["value"] == 3.5


def test_span_recorder_overflow_counts_dropped():
    rec = SpanRecorder(capacity=2)
    mark = rec.name("m")
    for _ in range(5):
        rec.instant(mark)
    assert rec.n == 2 and rec.dropped == 3
    assert len(rec.events()) == 2


def test_null_recorder_is_inert():
    assert not NULL_RECORDER.enabled
    NULL_RECORDER.begin(NULL_RECORDER.name("x"))
    NULL_RECORDER.end()
    NULL_RECORDER.instant(0, 1.0)
    assert NULL_RECORDER.depth == 0 and NULL_RECORDER.events() == []


def test_engine_obs_accumulators():
    obs = EngineObs(histograms=True, spans=True, span_capacity=8)
    obs.add_hist(hist_np([1, 2]))
    obs.gauge("g", 4)
    obs.count("c")
    obs.count("c", 2)
    assert int(obs.latency_hist.sum()) == 2
    assert obs.gauges == {"g": [4]} and obs.counters == {"c": 3}
    assert obs.spans.enabled
    off = EngineObs(histograms=False)
    off.add_hist(hist_np([1]))
    assert int(off.latency_hist.sum()) == 0   # disabled: fold is a no-op
    assert off.spans is NULL_RECORDER


# --------------------------------------------------------------------- #
# Histogram cross-validation against the exact event simulator
# --------------------------------------------------------------------- #
_EXACT_CACHE: dict = {}


def _scn(n):
    return static_scenario(1, n, k=4, m_app=8)


def _exact_latencies(n) -> np.ndarray:
    """Per-delivery latency multiset from the exact replay: delivery
    time minus the same message's broadcast time, rounded (exact sim
    times carry float epsilon; latencies are integral rounds)."""
    if n not in _EXACT_CACHE:
        net = _crossval.run_exact(_scn(n))
        t_bcast, lat = {}, []
        for t, kind, pid, msg in net.trace:
            if kind == "broadcast":
                t_bcast[(pid, msg.counter)] = t
            elif kind == "deliver":
                lat.append(t - t_bcast[(msg.origin, msg.counter)])
        _EXACT_CACHE[n] = np.rint(np.asarray(lat)).astype(np.int64)
    return _EXACT_CACHE[n]


def _run_engine(engine, backend, scn, obs):
    if engine == "windowed":
        return execute_windowed(scn, 32, backend=backend, collect="full",
                                obs=obs)
    return execute_sharded(scn, 32, n_devices=1, seg_len=8, scan="on",
                           collect="full", backend=backend, obs=obs)


# pallas kept to N=64: the fused-kernel bucketing is identical code at
# any N, and the interpret-mode run dominates suite wall-time otherwise
_MATRIX = [("windowed", "numpy", 64), ("windowed", "jax", 64),
           ("windowed", "pallas", 64), ("sharded", "jax", 64),
           ("windowed", "numpy", 256), ("windowed", "jax", 256),
           ("sharded", "jax", 256)]


@pytest.mark.parametrize("engine,backend,n", _MATRIX)
def test_latency_hist_crossvalidates_exact_engine(engine, backend, n):
    scn = _scn(n)
    obs = EngineObs(histograms=True, spans=True)
    on = _run_engine(engine, backend, scn, obs)
    off = _run_engine(engine, backend, scn, None)

    # telemetry on vs off: byte-identical results
    np.testing.assert_array_equal(on.delivered, off.delivered)
    np.testing.assert_array_equal(on.series, off.series)
    assert on.deliv_count.tolist() == off.deliv_count.tolist()
    assert on.stats == off.stats

    # the on-device histogram is the exact engine's latency multiset
    exact = _exact_latencies(n)
    np.testing.assert_array_equal(obs.latency_hist, hist_np(exact))
    assert int(obs.latency_hist.sum()) == len(exact)

    # hist-derived percentiles == bucketed exact nearest-rank
    lo = bucket_lower_bounds()
    qs = (50.0, 99.0, 99.9)
    for q, hp in zip(qs, percentiles_from_hist(obs.latency_hist, qs)):
        assert hp == float(lo[bucket_index_np([_nearest_rank(exact, q)])[0]])

    # piggyback/occupancy gauges rode along; no span leaked
    assert len(obs.gauges["piggyback_bytes"]) > 0
    assert len(obs.gauges["window_occupancy"]) > 0
    assert obs.spans.depth == 0


# --------------------------------------------------------------------- #
# Live mode: histogram == rebucketed delivered matrix, report percentiles
# --------------------------------------------------------------------- #
def _live_run(obs, **kw):
    scn = static_scenario(5, 48, k=4, m_app=0)
    loop = LiveLoop(scn, 64, engine="windowed", backend="numpy",
                    collect="full", arrivals="poisson", rate=4.0,
                    messages=192, seed=3, obs=obs, **kw)
    return loop, loop.run()


def test_live_hist_matches_delivered_matrix():
    obs = EngineObs(histograms=True, spans=True)
    loop, rep = _live_run(obs)
    _, rep_off = _live_run(EngineObs(histograms=False))

    # telemetry on vs off: identical serving outcome
    assert rep.admitted == rep_off.admitted
    assert rep.delivered_messages == rep_off.delivered_messages
    np.testing.assert_array_equal(rep.result.series, rep_off.result.series)
    np.testing.assert_array_equal(rep.result.deliv_count,
                                  rep_off.result.deliv_count)

    # live latency base is the submission round: the histogram must be
    # the host-side rebucketing of the delivered matrix itself
    m_bc = len(rep.submit_round)
    d = rep.result.delivered[:, :m_bc]
    lat = (d - rep.submit_round[None, :])[d >= 0]
    np.testing.assert_array_equal(obs.latency_hist, hist_np(lat))

    # the report's percentiles are the histogram's
    p50, p99, p999 = percentiles_from_hist(obs.latency_hist,
                                           (50.0, 99.0, 99.9))
    assert (rep.p50, rep.p99, rep.p999) == (p50, p99, p999)

    # tick spans recorded, nothing leaked
    names = {e["name"] for e in obs.spans.events()}
    assert {"tick", "tick.ingest", "tick.admit", "tick.advance"} <= names
    assert obs.spans.depth == 0 and obs.spans.dropped == 0


# --------------------------------------------------------------------- #
# Satellite: backpressure events are well-formed, no span leaks
# --------------------------------------------------------------------- #
def test_backpressure_events_well_formed():
    obs = EngineObs(histograms=True, spans=True)
    scn = static_scenario(3, 32, k=3, m_app=0)
    loop = LiveLoop(scn, 8, engine="windowed", backend="numpy",
                    seg_len=4, admission="admit", rate=16.0,
                    messages=256, seed=2, obs=obs)
    rep = loop.run()
    assert rep.overflow_catches > 0, "admit policy should hit overflow"
    bp = [e for e in obs.spans.events() if e["name"] == "backpressure"]
    assert all(e["kind"] == "instant" for e in bp)
    # one instant per caught overflow, mirrored by the counter
    assert len(bp) == rep.overflow_catches
    assert obs.counters["backpressure_events"] == rep.overflow_catches
    # each carries the blocking round: an integer inside the run bound
    for e in bp:
        assert e["value"] == int(e["value"])
        assert 0 <= e["value"] <= rep.bound
    # the exception path closed every span it opened
    assert obs.spans.depth == 0
    # ingest accounting stays consistent under sustained backpressure
    assert (rep.admitted + rep.unserved + rep.shed_queue
            + rep.shed_policy == rep.offered)


# --------------------------------------------------------------------- #
# Satellite: segment stager upload-skip accounting
# --------------------------------------------------------------------- #
def test_stager_content_cache_accounting():
    from repro.core.vecsim.shard.driver import _SegmentStager
    st = _SegmentStager(None, None, seg_len=4, rounds=16,
                        put=lambda a: np.asarray(a))
    a = np.arange(6, dtype=np.int32)
    st._stage("x", a.copy())
    assert (st.uploads, st.skips) == (1, 0)
    st._stage("x", a.copy())               # identical content: skip
    assert (st.uploads, st.skips) == (1, 1)
    b = a.copy()
    b[0] = 99
    st._stage("x", b)                      # mutated content: re-upload
    assert (st.uploads, st.skips) == (2, 1)
    # the cache stores a *copy*: mutating the staged source afterwards
    # must not poison the comparison for the next identical segment
    c = np.arange(6, dtype=np.int32)
    st._stage("y", c)
    c[:] = 7
    st._stage("y", np.arange(6, dtype=np.int32))
    assert (st.uploads, st.skips) == (3, 2)


def test_stager_counters_surface_through_obs():
    obs = EngineObs(histograms=True)
    execute_sharded(_scn(64), 32, n_devices=1, seg_len=8, scan="on",
                    obs=obs)
    # a static run has quiescent segments: the sentinel planes re-use
    assert obs.counters["stager_uploads"] > 0
    assert obs.counters["stager_skips"] > 0


# --------------------------------------------------------------------- #
# Sinks: JSONL metrics round-trip + Chrome trace JSON validity
# --------------------------------------------------------------------- #
def _sample_doc():
    return dict(run={"engine": "windowed", "n": 64},
                summary={"latency_p50": 4.0, "wall_seconds": 0.25},
                latency_hist=hist_np([1, 2, 2, 40]),
                gauges={"window_occupancy": [3.0, 5.0]},
                counters={"stager_uploads": 7})


def test_metrics_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    write_metrics_jsonl(path, _sample_doc())
    doc = load_metrics_jsonl(path)
    assert doc["run"]["engine"] == "windowed"
    assert doc["summary"]["latency_p50"] == 4.0
    np.testing.assert_array_equal(doc["latency_hist"],
                                  hist_np([1, 2, 2, 40]))
    assert doc["gauges"]["window_occupancy"] == [3.0, 5.0]
    assert doc["counters"]["stager_uploads"] == 7


def test_metrics_jsonl_rejects_foreign_files(tmp_path):
    alien = tmp_path / "alien.jsonl"
    alien.write_text('{"schema": "someone.else", "version": 1}\n')
    with pytest.raises(ValueError, match="not a repro.obs.metrics"):
        load_metrics_jsonl(str(alien))
    stale = tmp_path / "stale.jsonl"
    stale.write_text('{"schema": "repro.obs.metrics", "version": 99}\n')
    with pytest.raises(ValueError, match="version"):
        load_metrics_jsonl(str(stale))
    (tmp_path / "empty.jsonl").write_text("")
    with pytest.raises(ValueError, match="empty"):
        load_metrics_jsonl(str(tmp_path / "empty.jsonl"))


def test_chrome_trace_json_is_loadable(tmp_path):
    rec = SpanRecorder(capacity=16)
    rec.begin(rec.name("segment.dispatch"))
    rec.end()
    rec.instant(rec.name("backpressure"), 12.0)
    rec.counter(rec.name("queue"), 3.0)
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, rec, run_args={"engine": "windowed"})
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    phases = [e["ph"] for e in evs]
    assert phases.count("M") >= 2 and "X" in phases and "i" in phases
    assert "C" in phases
    span = next(e for e in evs if e["ph"] == "X")
    assert span["name"] == "segment.dispatch" and span["dur"] >= 0
    assert all(e["ts"] >= 0 for e in evs if "ts" in e)   # rebased to t0
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t" and inst["args"]["value"] == 12.0
    # satellite (S10): span-name families land on *named* thread tracks
    # so the trace reads without the code open
    threads = {e["tid"]: e["args"]["name"] for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert threads[span["tid"]] == "segment pipeline"
    assert threads[inst["tid"]] == "serving loop"


def test_chrome_metrics_sink(tmp_path):
    path = str(tmp_path / "metrics.json")
    SINKS["chrome-trace"].write(path, _sample_doc())
    with open(path) as fh:
        doc = json.load(fh)
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    # satellite (S10): counter tracks are engine-prefixed so gauge
    # series from different engines never collide into one track
    assert {e["name"] for e in counters} == {"windowed/window_occupancy",
                                             "windowed/stager_uploads"}
    # a sharded doc additionally carries the device count in the prefix
    sharded = _sample_doc()
    sharded["run"] = {"engine": "sharded", "n": 64, "devices": 4}
    SINKS["chrome-trace"].write(path, sharded)
    with open(path) as fh:
        doc = json.load(fh)
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "C"}
    assert names == {"sharded[d4]/window_occupancy",
                     "sharded[d4]/stager_uploads"}


def test_sinks_registry_exposed_by_api():
    from repro.api import SINKS as api_sinks
    assert set(SINKS) == {"jsonl", "chrome-trace"}
    for key in SINKS:
        assert api_sinks.get(key).write is SINKS[key].write


# --------------------------------------------------------------------- #
# Spec layer + API end-to-end export
# --------------------------------------------------------------------- #
def test_obs_spec_validates_eagerly():
    with pytest.raises(SpecError, match="obs.sink"):
        RunSpec(n=16, obs=ObsSpec(sink="nope")).validate()
    with pytest.raises(SpecError, match="span_capacity"):
        RunSpec(n=16, obs=ObsSpec(span_capacity=0)).validate()
    with pytest.raises(SpecError, match="histograms"):
        RunSpec(n=16, obs=ObsSpec(histograms="yes")).validate()


def test_obs_spec_round_trips_through_dict():
    spec = RunSpec(n=64, obs=ObsSpec(histograms=True, spans=True,
                                     sink="chrome-trace"))
    assert RunSpec.from_dict(spec.to_dict()) == spec
    assert RunSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) == spec


def _api_spec(obs):
    return RunSpec(engine="windowed", backend="numpy", n=48,
                   traffic=TrafficSpec(messages=16),
                   window=WindowSpec(window=48), obs=obs)


def test_api_run_exports_trace_and_metrics(tmp_path):
    trace = str(tmp_path / "t.json")
    metrics = str(tmp_path / "m.jsonl")
    rep = api_run(_api_spec(ObsSpec(trace_out=trace, metrics_out=metrics)))
    assert rep.obs is not None and rep.obs.spans.depth == 0
    # extras carry the histogram-derived percentiles
    total = int(rep.obs.latency_hist.sum())
    assert rep.extras["latency_hist_total"] == total > 0
    p50 = percentiles_from_hist(rep.obs.latency_hist, (50.0,))[0]
    assert rep.extras["latency_p50"] == p50
    # the metrics file round-trips and matches the in-memory histogram
    doc = load_metrics_jsonl(metrics)
    np.testing.assert_array_equal(doc["latency_hist"], rep.obs.latency_hist)
    assert doc["summary"]["latency_p50"] == p50
    # the trace file is Chrome-trace JSON with the segment span taxonomy
    with open(trace) as fh:
        tdoc = json.load(fh)
    names = {e["name"] for e in tdoc["traceEvents"] if e["ph"] == "X"}
    assert {"segment.dispatch", "segment.retire"} <= names


def test_api_obs_disabled_is_none_and_identical():
    on = api_run(_api_spec(ObsSpec(histograms=True)))
    off = api_run(_api_spec(ObsSpec(histograms=False)))
    assert off.obs is None and "latency_p50" not in off.extras
    assert on.extras["latency_p50"] > 0
    np.testing.assert_array_equal(on.result.series, off.result.series)
    np.testing.assert_array_equal(on.result.deliv_count,
                                  off.result.deliv_count)
    assert on.stats == off.stats


# --------------------------------------------------------------------- #
# Satellite: shared bench-report schema
# --------------------------------------------------------------------- #
def test_bench_report_round_trip(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    out = write_bench_report(path, "scale", {"n": 64, "kind": "ignored"})
    assert out["schema_version"] == BENCH_SCHEMA_VERSION
    assert out["kind"] == "scale"          # writer owns the stamp
    doc = load_bench_report(path, kind="scale")
    assert doc == out and doc["n"] == 64
    with pytest.raises(ValueError, match="kind"):
        load_bench_report(path, kind="serve")
    with pytest.raises(ValueError, match="unknown bench kind"):
        write_bench_report(path, "nope", {})


def test_bench_report_version_policy(tmp_path):
    legacy = tmp_path / "legacy.json"
    legacy.write_text('{"n": 8}')          # pre-schema snapshots load
    assert load_bench_report(str(legacy), kind="scale")["n"] == 8
    future = tmp_path / "future.json"
    future.write_text('{"schema_version": 99, "kind": "scale"}')
    with pytest.raises(ValueError, match="schema_version"):
        load_bench_report(str(future))


def test_every_committed_bench_snapshot_loads():
    paths = sorted(REPO_ROOT.glob("BENCH_*.json"))
    assert paths, "expected committed BENCH_*.json snapshots"
    for path in paths:
        kind = path.stem[len("BENCH_"):]
        doc = load_bench_report(str(path), kind=kind)
        assert doc["schema_version"] == BENCH_SCHEMA_VERSION, path.name


# --------------------------------------------------------------------- #
# Satellite: the repro.core.metrics legacy shim warns
# --------------------------------------------------------------------- #
def test_legacy_metrics_entry_point_warns():
    from repro.core.types import LegacyEntryPointWarning
    sys.modules.pop("repro.core.metrics", None)
    with pytest.warns(LegacyEntryPointWarning):
        mod = importlib.import_module("repro.core.metrics")
    import repro.obs.graphs as graphs
    # the shim re-exports the real implementations, not copies
    assert mod.mean_shortest_path is graphs.mean_shortest_path
    assert mod.safe_graph is graphs.safe_graph
    assert mod.overhead_per_message is graphs.overhead_per_message
