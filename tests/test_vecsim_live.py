"""Live serving front-door suite (DESIGN.md §2.9).

The serving loop's correctness claims, each tested directly:

  * the overflow raise is *state-clean*: ``ColumnWindow.activate``
    detects the blocking round before any assignment, so a caught
    ``WindowOverflowError`` leaves the window byte-identical and
    re-enterable (the catch-and-defer backpressure path relies on it);
  * a live run is a *scheduler*, not a new engine: the finally-admitted
    schedule replayed pre-scripted through the same engine reproduces
    the live run's delivered matrix, series and stats byte-for-byte
    (windowed and sharded, churn included);
  * the capacity-blind ``admit`` policy drives the engine into overflow
    and the loop serves every message anyway (catch, withdraw, requeue,
    retry — zero loss);
  * rounds-to-delivery latency (queueing delay included) cross-validates
    against the exact event simulator on the admitted schedule, at N ∈
    {64, 256} and under churn: the mean over per-message mean delivery
    rounds, and the p50/p99 as per-delivery histogram read-outs
    (repro.obs, DESIGN.md §2.10);
  * the ingest accounting identity holds under shedding;
  * the spec/registry surface validates eagerly and the discovery
    listing describes every arrivals/admission entry.
"""

import io
import numpy as np
import pytest

from repro.api import LiveSpec, MetricsSpec, RunSpec, SpecError
from repro.api import run as api_run
from repro.core.vecsim import crossval as _crossval
from repro.core.vecsim.live import (LiveColumnWindow, LiveLoop,
                                    build_arrivals)
from repro.core.vecsim.scenario import churn_scenario, static_scenario
from repro.core.vecsim.stream import (ColumnWindow, WindowOverflowError,
                                      execute_windowed)
from repro.obs.hist import hist_np, percentiles_from_hist


def _base(seed, n, **kw):
    return static_scenario(seed, n, k=4, m_app=0, **kw)


# --------------------------------------------------------------------- #
# Satellite 1: the overflow raise leaves the window untouched
# --------------------------------------------------------------------- #
def _window_snapshot(cw):
    return dict(
        slot_msg=cw.slot_msg.copy(), slot_birth=cw.slot_birth.copy(),
        slot_app=cw.slot_app.copy(), bc_live_slot=cw.bc_live_slot.copy(),
        add_live_slot=cw.add_live_slot.copy(),
        bc_round=cw.bc_round.copy(), bc_origin=cw.bc_origin.copy(),
        next_bc=cw.next_bc, next_add=cw.next_add, m_bc=cw.m_bc,
        peak_live=cw.peak_live)


def test_overflow_raise_is_state_clean():
    scn = static_scenario(3, 32, k=4, m_app=64)
    cw = ColumnWindow(scn, 4)
    t, err, snap = 0, None, None
    for _ in range(scn.rounds * 2):
        snap = _window_snapshot(cw)
        try:
            t = cw.activate(t, min(t + 8, scn.rounds))
        except WindowOverflowError as exc:
            err = exc
            break
    else:
        pytest.fail("expected the 4-column window to overflow")
    after = _window_snapshot(cw)
    for key, before in snap.items():
        if isinstance(before, np.ndarray):
            np.testing.assert_array_equal(
                before, after[key], err_msg=f"{key} mutated by the raise")
        else:
            assert before == after[key], f"{key} mutated by the raise"
    # re-enterable: the same call raises the same way, and with room
    # freed the window proceeds (nothing was half-assigned)
    with pytest.raises(WindowOverflowError) as again:
        cw.activate(t, min(t + 8, scn.rounds))
    assert again.value.round == err.round
    assert err.round <= t


def test_overflow_seg_len_invariant_after_catch():
    # the blocking round reported must not depend on how the caller
    # segments time (the live loop retries with the same seg boundaries)
    scn = static_scenario(5, 32, k=4, m_app=48)
    rounds = []
    for seg in (4, 8, 16):
        cw = ColumnWindow(scn, 4)
        t = 0
        try:
            for _ in range(scn.rounds * 2):
                t = cw.activate(t, min(t + seg, scn.rounds))
        except WindowOverflowError as exc:
            rounds.append(exc.round)
    assert len(set(rounds)) == 1, rounds


# --------------------------------------------------------------------- #
# Live window: append / withdraw mechanics
# --------------------------------------------------------------------- #
def test_live_window_append_and_withdraw():
    scn = _base(1, 16)
    cw = LiveColumnWindow(scn, 8, capacity=10, per_round_cap=2)
    ids = cw.append_broadcasts(np.array([1, 1, 2], np.int32),
                               np.array([3, 4, 5], np.int32))
    assert ids.tolist() == [0, 1, 2] and cw.m_bc == 3
    with pytest.raises(ValueError):   # unsorted batch
        cw.append_broadcasts(np.array([5, 4], np.int32),
                             np.array([0, 1], np.int32))
    with pytest.raises(ValueError):   # behind the admitted stream
        cw.append_broadcasts(np.array([1], np.int32),
                             np.array([9], np.int32))
    rounds, origins = cw.withdraw_unactivated()
    assert rounds.tolist() == [1, 1, 2] and origins.tolist() == [3, 4, 5]
    assert cw.m_bc == 0
    # positions recycle
    ids = cw.append_broadcasts(np.array([4], np.int32),
                               np.array([7], np.int32))
    assert ids.tolist() == [0]
    with pytest.raises(ValueError):   # capacity
        cw.append_broadcasts(np.full(10, 9, np.int32),
                             np.arange(10, dtype=np.int32))
    with pytest.raises(ValueError):   # live base must be broadcast-free
        LiveColumnWindow(static_scenario(1, 16, m_app=2), 8,
                         capacity=4, per_round_cap=1)


def test_arrival_processes():
    for kind in ("poisson", "bursty", "diurnal"):
        rounds, origins = build_arrivals(kind, 3, 32, 4.0, 500)
        assert len(rounds) == len(origins) == 500
        assert (np.diff(rounds) >= 0).all(), kind
        assert origins.min() >= 0 and origins.max() < 32
    with pytest.raises(KeyError):
        build_arrivals("nope", 0, 8, 1.0, 10)


# --------------------------------------------------------------------- #
# Tentpole: live == pre-scripted replay, byte for byte
# --------------------------------------------------------------------- #
def _assert_replay_identical(rep, res2):
    r1 = rep.result
    np.testing.assert_array_equal(r1.series, res2.series)
    np.testing.assert_array_equal(r1.deliv_count, res2.deliv_count)
    np.testing.assert_array_equal(r1.deliv_round_sum,
                                  res2.deliv_round_sum)
    np.testing.assert_array_equal(r1.expired, res2.expired)
    np.testing.assert_array_equal(r1.bcast_done, res2.bcast_done)
    if r1.delivered is not None and res2.delivered is not None:
        np.testing.assert_array_equal(r1.delivered, res2.delivered)
    assert r1.stats == res2.stats


@pytest.mark.parametrize("arrivals,admission", [
    ("poisson", "defer"), ("bursty", "admit"), ("diurnal", "defer"),
])
def test_live_byte_identity_windowed(arrivals, admission):
    scn = _base(3, 64)
    loop = LiveLoop(scn, 16, engine="windowed", backend="numpy",
                    arrivals=arrivals, admission=admission,
                    rate=4.0, messages=200, queue_cap=4096, seed=7,
                    arrival_params=dict(period=64, duty=0.5))
    rep = loop.run()
    assert rep.admitted == 200 and rep.delivered_messages == 200
    res2 = execute_windowed(rep.scenario, 16, backend="numpy", seg_len=32)
    _assert_replay_identical(rep, res2)


def test_live_byte_identity_sharded_scan():
    from repro.core.vecsim.shard import execute_sharded
    scn = _base(5, 64)
    loop = LiveLoop(scn, 16, engine="sharded", devices=1, scan="on",
                    arrivals="poisson", admission="defer",
                    rate=4.0, messages=120, queue_cap=512, seed=7)
    rep = loop.run()
    assert rep.admitted == 120 and rep.delivered_messages == 120
    res2 = execute_sharded(rep.scenario, 16, n_devices=1, scan="on",
                           seg_len=32)
    _assert_replay_identical(rep, res2)


def test_admit_policy_catches_overflow_and_loses_nothing():
    scn = _base(3, 64)
    loop = LiveLoop(scn, 12, engine="windowed", backend="numpy",
                    arrivals="bursty", admission="admit",
                    rate=8.0, messages=300, queue_cap=4096, seed=11,
                    arrival_params=dict(period=64, duty=0.5))
    rep = loop.run()
    assert rep.overflow_catches > 0, \
        "capacity-blind admission never hit the window"
    assert rep.admitted == 300 and rep.delivered_messages == 300
    assert rep.shed_queue == 0 and rep.shed_policy == 0
    # the overflow-driven trajectory is still a pure schedule
    res2 = execute_windowed(rep.scenario, 12, backend="numpy", seg_len=32)
    _assert_replay_identical(rep, res2)


def test_shed_accounting_identity():
    scn = _base(9, 32)
    loop = LiveLoop(scn, 8, engine="windowed", backend="numpy",
                    arrivals="bursty", admission="shed",
                    rate=16.0, messages=400, queue_cap=32, seed=3,
                    arrival_params=dict(period=32, duty=0.5))
    rep = loop.run()
    assert rep.shed_queue + rep.shed_policy > 0
    assert (rep.admitted + rep.shed_queue + rep.shed_policy
            + rep.unserved == rep.offered)
    assert rep.delivered_messages == rep.admitted
    res2 = execute_windowed(rep.scenario, 8, backend="numpy", seg_len=32)
    _assert_replay_identical(rep, res2)


# --------------------------------------------------------------------- #
# Satellite 3: latency accounting vs the exact event simulator
# --------------------------------------------------------------------- #
def _exact_mean_delivery_rounds(adm, seed):
    """Per-admitted-message mean delivery round from the exact engine's
    trace (its delivery times are whole rounds on these scenarios)."""
    net = _crossval.run_exact(adm, seed=seed, protocol="pc")
    sums = {}
    counts = {}
    for t, kind, _pid, m in net.trace:
        if kind != "deliver":
            continue
        key = (m.origin, m.counter)
        sums[key] = sums.get(key, 0.0) + t
        counts[key] = counts.get(key, 0) + 1
    # message j -> (origin, counter): counters increment per origin in
    # round order, and (origin, round) pairs are unique
    order = np.argsort(adm.bcast_round, kind="stable")
    seen = {}
    mean = np.full(adm.m_app, np.nan)
    for j in order:
        o = int(adm.bcast_origin[j])
        seen[o] = seen.get(o, 0) + 1
        key = (o, seen[o])
        if key in counts:
            mean[j] = sums[key] / counts[key]
    return mean


def _exact_delivery_latencies(adm, submit, seed):
    """Per-*delivery* latency multiset (delivery round minus submission
    round) from the exact engine's trace — the quantity the on-device
    histogram buckets, and since PR 9 the source of the report's
    p50/p99/p99.9 (exact times carry float epsilon, hence the rint)."""
    net = _crossval.run_exact(adm, seed=seed, protocol="pc")
    order = np.argsort(adm.bcast_round, kind="stable")
    seen, sub = {}, {}
    for j in order:
        o = int(adm.bcast_origin[j])
        seen[o] = seen.get(o, 0) + 1
        sub[(o, seen[o])] = int(submit[j])
    lat = [t - sub[(m.origin, m.counter)]
           for t, kind, _pid, m in net.trace if kind == "deliver"]
    return np.rint(np.asarray(lat)).astype(np.int64)


@pytest.mark.parametrize("n,messages", [(64, 150), (256, 300)])
def test_latency_crossval_vs_exact(n, messages):
    scn = _base(21, n)
    loop = LiveLoop(scn, max(16, n // 4), engine="windowed",
                    backend="numpy", arrivals="poisson",
                    admission="defer", rate=4.0, messages=messages,
                    queue_cap=1 << 14, seed=5)
    rep = loop.run()
    assert rep.delivered_messages == messages
    mean = _exact_mean_delivery_rounds(rep.scenario, seed=5)
    assert not np.isnan(mean).any()
    lat = mean - rep.submit_round
    assert rep.mean_latency_rounds == pytest.approx(float(lat.mean()))
    # p50/p99 are per-delivery histogram read-outs (repro.obs): they
    # must equal the same read-out over the exact engine's latencies
    lat_del = _exact_delivery_latencies(rep.scenario, rep.submit_round,
                                        seed=5)
    p50, p99 = percentiles_from_hist(hist_np(lat_del), (50.0, 99.0))
    assert (rep.p50, rep.p99) == (p50, p99)


def test_latency_crossval_churn_during_serving():
    base = churn_scenario(17, 64, k=5, m_app=6, n_adds=5, n_rms=4)
    from dataclasses import replace
    scn = replace(base, bcast_round=np.empty(0, np.int32),
                  bcast_origin=np.empty(0, np.int32)).validate()
    loop = LiveLoop(scn, 24, engine="windowed", backend="numpy",
                    arrivals="poisson", admission="defer", rate=3.0,
                    messages=120, queue_cap=1 << 12, seed=29)
    rep = loop.run()
    assert rep.delivered_messages == 120
    mean = _exact_mean_delivery_rounds(rep.scenario, seed=29)
    assert not np.isnan(mean).any()
    lat_del = _exact_delivery_latencies(rep.scenario, rep.submit_round,
                                        seed=29)
    p50, p99 = percentiles_from_hist(hist_np(lat_del), (50.0, 99.0))
    assert (rep.p50, rep.p99) == (p50, p99)
    # and the delivered multiset itself matches the exact engine
    res2 = execute_windowed(rep.scenario, 24, backend="numpy", seg_len=32)
    _assert_replay_identical(rep, res2)


# --------------------------------------------------------------------- #
# API surface: mode="live" through the front door
# --------------------------------------------------------------------- #
def test_api_live_mode_end_to_end():
    spec = RunSpec(
        mode="live", engine="windowed", backend="numpy", n=64, seed=2,
        live=LiveSpec(arrivals="poisson", rate=4.0, messages=100,
                      queue_cap=1024, slo_p99=1e9),
        metrics=MetricsSpec(oracle=True, crossval=True))
    rep = api_run(spec)
    assert rep.live is not None and rep.live.slo_ok is True
    assert rep.oracle.ok and rep.crossval_ok
    assert rep.m_app == 100 and rep.delivered_frac == 1.0
    assert rep.extras["serve_admitted"] == 100
    d = rep.to_dict()
    assert d["live"]["p99"] == rep.live.p99


def test_live_spec_validation():
    with pytest.raises(SpecError):
        RunSpec(mode="serve").validate()
    with pytest.raises(SpecError, match="live.arrivals"):
        RunSpec(mode="live", live=LiveSpec(arrivals="nope")).validate()
    with pytest.raises(SpecError, match="admission"):
        RunSpec(mode="live", live=LiveSpec(admission="nope")).validate()
    with pytest.raises(SpecError, match="engine"):
        RunSpec(mode="live", engine="exact").validate()
    with pytest.raises(SpecError, match="messages"):
        RunSpec(mode="live", live=LiveSpec(messages=0)).validate()
    with pytest.raises(SpecError, match="per_round_cap"):
        RunSpec(mode="live", n=8,
                live=LiveSpec(per_round_cap=9)).validate()
    with pytest.raises(SpecError, match="snapshot"):
        RunSpec(mode="live",
                metrics=MetricsSpec(snapshot=4)).validate()
    # JSON round-trip carries the live section
    spec = RunSpec.from_dict({"mode": "live",
                              "live": {"arrivals": "bursty",
                                       "rate": 2.5}}).validate()
    assert spec.live.arrivals == "bursty" and spec.live.rate == 2.5
    assert RunSpec.from_dict(spec.to_dict()) == spec


def test_discovery_lists_live_registries():
    from contextlib import redirect_stdout

    from repro.api.__main__ import print_registries
    buf = io.StringIO()
    with redirect_stdout(buf):
        print_registries()
    out = buf.getvalue()
    assert "arrivals (live mode):" in out
    assert "admission (live mode):" in out
    for line in out.splitlines():
        if line.startswith("  "):
            key_desc = line.strip().split(None, 1)
            if key_desc[0].startswith("test_"):
                # other suites register description-less throwaway
                # entries (e.g. test_api's register-to-extend checks)
                continue
            assert len(key_desc) == 2 and key_desc[1], \
                f"registry entry missing description: {line!r}"
