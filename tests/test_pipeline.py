"""Pipeline parallelism: GPipe schedule == sequential reference, forward
and gradients, on a forced multi-device mesh (subprocess)."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.sharding.pipeline import pipeline

    S, M, B, D = 4, 6, 8, 16
    mesh = jax.make_mesh((S,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, D, D)) * (D ** -0.5)
    bs = jax.random.normal(jax.random.PRNGKey(1), (S, D)) * 0.1
    params = {"w": ws, "b": bs}
    mb = jax.random.normal(jax.random.PRNGKey(2), (M, B, D))

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    def reference(params, mb):
        def apply_all(x):
            for s in range(S):
                x = stage_fn(jax.tree.map(lambda t: t[s], params), x)
            return x
        return jax.vmap(apply_all)(mb)

    piped = pipeline(stage_fn, mesh, "stage")

    with mesh:
        out_p = jax.jit(piped)(params, mb)
    out_r = reference(params, mb)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)

    # gradients flow through the ppermute pipeline (backward pipeline)
    def loss_p(params):
        with mesh:
            return (jax.jit(piped)(params, mb) ** 2).mean()

    def loss_r(params):
        return (reference(params, mb) ** 2).mean()

    gp = jax.grad(loss_p)(params)
    gr = jax.grad(loss_r)(params)
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(gr[k]),
                                   rtol=1e-4, atol=1e-5)
    print("PIPELINE_OK")
""")


@pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="the subprocess snippet builds its mesh with "
           "jax.sharding.AxisType (explicit-sharding API, jax >= 0.5.x); "
           "the pinned jax in this environment predates it, so the "
           "snippet can only fail on import — skipped, not broken")
def test_gpipe_pipeline_matches_reference_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SNIPPET],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr[-3000:]
