"""R-broadcast (Algorithm 1): causal on static overlays (Theorem 1),
violates causal order under link addition (Fig. 3)."""

import pytest

from repro.core import (Network, RBroadcast, check_trace, ring_plus_random)


def build(n, seed=0, proto=RBroadcast, delay=1.0, **kw):
    net = Network(seed=seed, default_delay=delay)
    for pid in range(n):
        net.add_process(proto(pid, **kw))
    return net


def test_static_flood_delivers_exactly_once_everywhere():
    net = build(12, seed=1)
    ring_plus_random(net, range(12), k=3)
    for pid in (0, 5, 9):
        net.procs[pid].broadcast(("x", pid))
    net.run()
    rep = check_trace(net.trace, all_pids=set(range(12)))
    assert rep.ok, rep.summary()
    assert rep.n_deliveries == 3 * 12


def test_static_concurrent_broadcasts_are_causal():
    """Theorem 1: FIFO + forward-exactly-once + all-outgoing-links."""
    net = build(10, seed=2)
    ring_plus_random(net, range(10), k=3)
    # Interleave: several processes broadcast, then respond after delivery.
    replies = []

    def deliver_cb(pid, m):
        # First delivery of a root message triggers a causally-dependent
        # reply from process 7 (reply must be delivered after its cause).
        if m.payload == "root" and pid == 7 and not replies:
            replies.append(net.procs[7].broadcast("reply"))

    for p in net.procs.values():
        p._deliver_cb = deliver_cb
    net.procs[0].broadcast("root")
    net.procs[3].broadcast("noise")
    net.run()
    rep = check_trace(net.trace, all_pids=set(range(10)))
    assert rep.ok, rep.summary()


def fig3_topology(proto, **kw):
    """A -> B -> D chain with slow links; later a fast direct link A -> D.

    Also gives D an out-link back to B (so D forwards; keeps graph alive)
    and B -> A so the graph is strongly connected.
    """
    net = Network(seed=3, default_delay=5.0, oob_delay=0.1)
    for pid, name in enumerate("ABD"):
        net.add_process(proto(pid, **kw))
    A, B, D = 0, 1, 2
    net.connect(A, B)
    net.connect(B, D)
    net.connect(B, A)
    net.connect(D, B)
    return net, (A, B, D)


def test_received_set_pruning_static():
    """Paper §6 (future work): in static nets each process receives
    exactly in-degree copies of every message, so the received-set can be
    reclaimed — space drops from O(N) to O(in-flight) with zero double
    deliveries."""
    net = build(12, seed=9, proto=lambda pid: RBroadcast(
        pid, prune_received=True))
    ring_plus_random(net, range(12), k=3)
    for pid in range(12):
        net.procs[pid].broadcast(("m", pid))
    net.run()
    rep = check_trace(net.trace, all_pids=set(range(12)))
    assert rep.ok, rep.summary()          # exactly-once held
    for p in net.procs.values():
        assert len(p.received) == 0, (p.pid, p.received)  # fully reclaimed
        assert p.pruned == 12


def test_dynamic_violation_fig3():
    """R-broadcast: the new fast link shortcuts a' past a (Fig. 3)."""
    net, (A, B, D) = fig3_topology(RBroadcast)
    net.procs[A].broadcast("a")           # t=0, crawls at delay 5/hop
    net.run(until=1.0)
    net.connect(A, D, delay=0.1)          # fast shortcut appears
    net.procs[A].broadcast("a'")          # rides the unsafe shortcut
    net.run()
    rep = check_trace(net.trace, all_pids={A, B, D})
    assert not rep.causal_ok, "expected a causal violation (Fig. 3)"
    # D saw a' before a:
    assert any(pid == D for pid, dep, mid in rep.causal_violations)
