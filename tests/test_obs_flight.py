"""Flight recorder, causal auditor, live ops plane (DESIGN.md §2.11).

The S10 claims, each tested directly:

  * the hash sampler is a pure function of (seed, origin, key round):
    chunking- and order-invariant, seed-sensitive, rate=1 total;
  * a batch run's completed provenance records reproduce the engine's
    own delivered matrix column-for-column, and the sampled id set is
    exactly the sampler's a-priori selection over the scenario;
  * provenance export is byte-identical across windowed numpy / jax /
    pallas and the sharded engine with scan on and off — in-process on
    one device and in a forced 4-device child mesh at 1/2/4 shards;
  * the auditor stays silent on honest runs but flags a corrupted
    delivery plane in BOTH batch and live mode (mutation tests:
    ``log`` collects violations, ``fail`` raises);
  * withdrawn-then-requeued live columns record their *final*
    activation, with zero span-stack leaks across the overflow-retry
    path (satellite: flight recorder under backpressure);
  * both ops sinks round-trip (Prometheus text parses; JSONL stream is
    schema-headed and cadence-correct), the --watch dashboard degrades
    to greppable plain lines off a TTY, and the SLO burn rate is a
    sound under-count over its sliding window;
  * spec validation rejects audit-without-provenance, batch ops
    planes, and non-streaming provenance hosts;
  * the API front door exports provenance JSONL records and pid-2
    Perfetto tracks next to the existing metrics/trace outputs.
"""

import io
import json
from dataclasses import replace

import numpy as np
import pytest

from repro.api import ObsSpec, RunSpec, SpecError, TrafficSpec, WindowSpec
from repro.api import run as api_run
from repro.core.vecsim import (execute_windowed, static_scenario,
                               sustained_scenario)
from repro.core.vecsim.live import LiveLoop
from repro.core.vecsim.shard import execute_sharded
from repro.core.vecsim.stream import WindowedStepper
from repro.obs.audit import (AUDIT_MODES, CausalAuditor,
                             CausalityViolationError)
from repro.obs.flight import (SAMPLERS, FlightRecorder,
                              provenance_trace_events, sample_hash)
from repro.obs.hist import NB, bucket_index_np
from repro.obs.ops import (OPS_SINKS, OpsPlane, SloBurn, WatchDashboard,
                           load_ops_jsonl)
from repro.obs.sinks import load_metrics_jsonl
from repro.obs.spans import EngineObs

from vecsim_cases import run_shard_matrix_subprocess


def _scn():
    """Small sustained-traffic scenario: many same-origin chains."""
    return sustained_scenario(3, 48, k=5, rate=2.0, messages=24,
                              topology="kregular", max_delay=2)


def _flight_obs(rate=1, seed=0, audit=None, live=False):
    obs = EngineObs(histograms=True)
    auditor = CausalAuditor(audit) if audit else None
    obs.flight = FlightRecorder(rate=rate, seed=seed, auditor=auditor,
                                live=live)
    return obs


# --------------------------------------------------------------------- #
# Sampler determinism
# --------------------------------------------------------------------- #
def test_hash_sampler_is_a_pure_function_of_the_key():
    o = np.arange(4096) % 37
    r = np.arange(4096) // 7
    m = sample_hash(5, 8, o, r)
    # chunking-invariant: batch boundaries never change the selection
    chunks = [sample_hash(5, 8, o[i:i + 13], r[i:i + 13])
              for i in range(0, 4096, 13)]
    np.testing.assert_array_equal(m, np.concatenate(chunks))
    # order-invariant: each element keyed independently
    perm = np.random.default_rng(0).permutation(4096)
    np.testing.assert_array_equal(m[perm], sample_hash(5, 8, o[perm],
                                                       r[perm]))
    # rate=1 records everything; the seed moves a proper subset
    assert sample_hash(5, 1, o, r).all()
    assert m.any() and not m.all()
    assert (m != sample_hash(6, 8, o, r)).any()
    assert 0.04 < m.mean() < 0.30          # loosely ~1/8


def test_recorder_rejects_unknown_sampler_and_registry_is_described():
    with pytest.raises(KeyError, match="unknown sampler"):
        FlightRecorder(sampler="nope")
    for reg in (SAMPLERS, AUDIT_MODES, OPS_SINKS):
        for entry in reg.values():
            assert entry.description
    with pytest.raises(KeyError, match="auditor mode"):
        CausalAuditor("off")


# --------------------------------------------------------------------- #
# Batch provenance correctness
# --------------------------------------------------------------------- #
def test_batch_records_reproduce_the_delivered_matrix():
    scn = _scn()
    obs = _flight_obs(rate=1, audit="log")
    res = execute_windowed(scn, 32, backend="numpy", collect="full",
                           seg_len=8, obs=obs)
    fl = obs.flight
    assert fl.completed, "rate=1 must sample"
    for rec in fl.completed:
        assert rec.origin == scn.bcast_origin[rec.id]
        assert rec.bcast_round == scn.bcast_round[rec.id]
        assert rec.activate_round == rec.bcast_round
        # batch runs have no front door
        assert rec.submit_round == -1 and rec.admit_round == -1
        assert rec.retire_round >= rec.bcast_round
        np.testing.assert_array_equal(rec.deliv,
                                      res.delivered[:, rec.id])
    # honest run: edges were checked, none violated
    aud = fl.auditor
    assert aud.pairs_checked > 0 and not aud.violations


def test_sampled_id_set_is_the_a_priori_selection():
    scn = _scn()
    obs = _flight_obs(rate=3, seed=11)
    execute_windowed(scn, 32, backend="numpy", collect="full",
                     seg_len=8, obs=obs)
    fl = obs.flight
    want = np.nonzero(fl.want(scn.bcast_origin, scn.bcast_round))[0]
    got = sorted(r.id for r in fl.completed) + sorted(fl.open)
    assert sorted(got) == want.tolist()


# --------------------------------------------------------------------- #
# Cross-backend byte identity
# --------------------------------------------------------------------- #
def test_provenance_is_byte_identical_across_backends():
    scn = _scn()
    runs = {
        "win-numpy": lambda o: execute_windowed(
            scn, 32, backend="numpy", collect="full", seg_len=8, obs=o),
        "win-jax": lambda o: execute_windowed(
            scn, 32, backend="jax", collect="full", seg_len=8, obs=o),
        "win-pallas": lambda o: execute_windowed(
            scn, 32, backend="pallas", collect="full", seg_len=8, obs=o),
        "shard-scan-on": lambda o: execute_sharded(
            scn, 32, n_devices=1, collect="full", seg_len=8, scan="on",
            obs=o),
        "shard-scan-off": lambda o: execute_sharded(
            scn, 32, n_devices=1, collect="full", seg_len=8, scan="off",
            obs=o),
    }
    outs = {}
    for name, fn in runs.items():
        obs = _flight_obs(rate=2, audit="fail")   # fail = mutation canary
        fn(obs)
        outs[name] = obs.flight.export()
    ref = outs["win-numpy"]
    assert ref, "sampler picked nothing"
    for name, got in outs.items():
        assert got == ref, name


def test_provenance_byte_identity_on_multi_device_meshes():
    # 4 forced host devices in a child (XLA_FLAGS must precede jax
    # init), then 1/2/4-shard runs against the windowed reference
    extra = """
from repro.obs.spans import EngineObs
from repro.obs.flight import FlightRecorder
from repro.obs.audit import CausalAuditor
from repro.core.vecsim import sustained_scenario

scn2 = sustained_scenario(3, 24, k=5, rate=2.0, messages=24,
                          topology="kregular", max_delay=2)

def _fl():
    obs = EngineObs(histograms=True)
    obs.flight = FlightRecorder(rate=2, seed=0,
                                auditor=CausalAuditor("fail"))
    return obs

obs = _fl()
execute_windowed(scn2, 32, backend="numpy", collect="full", seg_len=8,
                 obs=obs)
ref = obs.flight.export()
assert ref, "sampler picked nothing"
for d in (1, 2, 4):
    for scan in ("on", "off"):
        obs = _fl()
        execute_sharded(scn2, 32, n_devices=d, collect="full", seg_len=8,
                        scan=scan, obs=obs)
        assert obs.flight.export() == ref, (d, scan)
print("PROV_MATRIX_OK", len(ref))
"""
    out = run_shard_matrix_subprocess([], 4, extra=extra)
    assert "PROV_MATRIX_OK" in out


# --------------------------------------------------------------------- #
# Auditor mutation tests: corrupt the plane, expect the alarm
# --------------------------------------------------------------------- #
class _CorruptingStepper(WindowedStepper):
    """Forges an out-of-order causal delivery just before every sweep:
    each in-window app column after the first gets one receiver's
    delivery round zeroed, so that receiver appears to deliver the
    successor before messages it causally follows."""

    def _retire(self, t_now):
        cw, st = self.cw, self.st
        for c in np.nonzero((cw.slot_msg > 0) & cw.slot_app)[0]:
            d = st["delivered"][:, c]
            got = np.nonzero(d >= 1)[0]
            if len(got):
                st["delivered"][got[0], c] = 0
        return super()._retire(t_now)


def _corrupted_batch(mode):
    obs = _flight_obs(rate=1, audit=mode)
    stp = _CorruptingStepper(_scn(), 32, backend="numpy",
                             collect="full", seg_len=8, obs=obs)
    while not stp.done:
        stp.advance()
    stp.finish()
    return obs.flight.auditor


def test_auditor_flags_batch_plane_corruption():
    aud = _corrupted_batch("log")
    assert aud.violations, "mutation must be caught"
    for v in aud.violations:
        assert v.edge in ("same-origin", "deliv-before-bcast")
        assert v.a_deliv > v.b_deliv >= 0     # the inversion itself
        assert v.a_id != v.b_id
    # fail mode raises out of the engine loop on the first violation
    with pytest.raises(CausalityViolationError) as ei:
        _corrupted_batch("fail")
    assert ei.value.violation.a_deliv > ei.value.violation.b_deliv


def test_auditor_flags_live_plane_corruption():
    obs = _flight_obs(rate=1, audit="log", live=True)
    scn = static_scenario(5, 48, k=4, m_app=0)
    loop = LiveLoop(scn, 64, engine="windowed", backend="numpy",
                    collect="full", arrivals="poisson", rate=4.0,
                    messages=160, seed=3, obs=obs)
    stp, orig = loop.stepper, loop.stepper._retire

    def corrupt(t_now):
        cw, st = stp.cw, stp.st
        for c in np.nonzero((cw.slot_msg > 0) & cw.slot_app)[0]:
            d = st["delivered"][:, c]
            got = np.nonzero(d >= 1)[0]
            if len(got):
                st["delivered"][got[0], c] = 0
        return orig(t_now)

    stp._retire = corrupt
    loop.run()
    aud = obs.flight.auditor
    assert aud.pairs_checked > 0
    assert aud.violations, "live mutation must be caught"


def test_auditor_is_silent_on_an_honest_live_run():
    obs = _flight_obs(rate=1, audit="fail", live=True)
    scn = static_scenario(5, 48, k=4, m_app=0)
    LiveLoop(scn, 64, engine="windowed", backend="numpy",
             collect="full", arrivals="poisson", rate=4.0,
             messages=160, seed=3, obs=obs).run()
    aud = obs.flight.auditor
    assert aud.pairs_checked > 0 and not aud.violations


# --------------------------------------------------------------------- #
# Live lifecycle: requeue records the final activation, spans balance
# --------------------------------------------------------------------- #
def test_requeued_columns_record_final_activation():
    obs = EngineObs(histograms=True, spans=True)
    obs.flight = FlightRecorder(rate=1, seed=0, live=True)
    scn = static_scenario(3, 32, k=3, m_app=0)
    loop = LiveLoop(scn, 24, engine="windowed", backend="numpy",
                    seg_len=4, admission="admit", rate=8.0,
                    messages=160, seed=2, obs=obs)
    rep = loop.run()
    assert rep.overflow_catches > 0 and loop.requeued > 0, \
        "admit policy should force withdraw/requeue"
    adm = loop.admitted_scenario()
    fl = obs.flight
    assert fl.completed
    for rec in fl.completed:
        # the record describes the FINAL placement: after any number of
        # withdraw/requeue cycles it matches the admitted schedule the
        # batch replay would use
        assert rec.bcast_round == adm.bcast_round[rec.id]
        assert rec.origin == adm.bcast_origin[rec.id]
        assert rec.activate_round == rec.bcast_round
        assert 0 <= rec.submit_round <= rec.bcast_round
        assert rec.admit_round >= rec.submit_round
    # satellite: the overflow-retry path leaks no spans with the
    # flight recorder in the loop, and backpressure instants still
    # mirror the counter one-for-one
    assert obs.spans.depth == 0
    bp = [e for e in obs.spans.events() if e["name"] == "backpressure"]
    assert len(bp) == rep.overflow_catches
    assert all(e["kind"] == "instant" for e in bp)


# --------------------------------------------------------------------- #
# Ops plane: sinks, dashboard, burn rate
# --------------------------------------------------------------------- #
def _ops_run(ops, messages=96):
    obs = _flight_obs(rate=1, audit="log", live=True)
    scn = static_scenario(5, 48, k=4, m_app=0)
    loop = LiveLoop(scn, 64, engine="windowed", backend="numpy",
                    collect="full", arrivals="poisson", rate=4.0,
                    messages=messages, seed=3, obs=obs, ops=ops)
    return loop, loop.run()


def test_prometheus_snapshot_round_trips(tmp_path):
    out = tmp_path / "ops.prom"
    ops = OpsPlane(out=str(out), sink="prometheus", slo_p99=30.0)
    _ops_run(ops)
    gauges = {}
    lines = out.read_text().splitlines()
    for line in lines:
        if not line.startswith("#"):
            name, val = line.split()
            gauges[name] = float(val)
    # text-format contract: every gauge is TYPE-declared and repro_-
    # namespaced
    assert all(line.split()[2].startswith("repro_")
               and line.split()[3] == "gauge"
               for line in lines if line.startswith("# TYPE"))
    for key in ("repro_tick", "repro_queue_depth",
                "repro_window_occupancy", "repro_admitted_total",
                "repro_delivered_total", "repro_slo_burn",
                "repro_provenance_completed",
                "repro_audit_pairs_checked", "repro_audit_violations"):
        assert key in gauges, key
    # the snapshot is the LAST tick (atomically replaced each publish)
    assert gauges["repro_tick"] == ops.ticks
    assert gauges["repro_audit_violations"] == 0
    assert gauges["repro_provenance_completed"] > 0


def test_jsonl_ops_stream_round_trips(tmp_path):
    out = tmp_path / "ops.jsonl"
    ops = OpsPlane(out=str(out), sink="jsonl", every=3)
    _ops_run(ops)
    ticks = load_ops_jsonl(str(out))
    assert ticks
    # cadence: every 3rd tick, plus close() flushing the final one
    nums = [t["tick"] for t in ticks]
    assert nums == sorted(set(nums))
    assert all(t % 3 == 0 for t in nums[:-1])
    assert nums[-1] == ops.ticks
    for t in ticks:
        assert {"t", "queue_depth", "window_occupancy", "admitted_tick",
                "admitted_total", "shed", "requeued",
                "backpressure_events"} <= set(t)
    # a foreign JSONL file is rejected by the schema header check
    bad = tmp_path / "other.jsonl"
    bad.write_text(json.dumps({"kind": "header", "schema": "nope"}) + "\n")
    with pytest.raises(ValueError, match="not an ops stream"):
        load_ops_jsonl(str(bad))


def test_watch_dashboard_degrades_to_plain_lines_off_tty():
    buf = io.StringIO()          # not a TTY
    ops = OpsPlane(watch=WatchDashboard(buf), slo_p99=30.0)
    _, rep = _ops_run(ops)
    text = buf.getvalue()
    assert "\x1b[" not in text   # no ANSI redraws into a pipe
    lines = text.splitlines()
    assert len(lines) == ops.ticks
    assert all(line.startswith("ops tick=") for line in lines)
    assert "queue_depth=" in lines[-1] and "slo_burn=" in lines[-1]


def test_slo_burn_is_a_windowed_undercount():
    sb = SloBurn(slo=16.0, window=4)
    h = np.zeros(NB, np.int64)
    assert sb.update(h) == 0.0
    # 3 fast deliveries, 1 over-SLO (lat 100 lands in a bucket whose
    # lower bound exceeds the SLO)
    h[bucket_index_np([4])[0]] += 3
    h[bucket_index_np([100])[0]] += 1
    assert sb.update(h) == pytest.approx(0.25)
    # boundary soundness: lat 20 shares the SLO's own bucket, so it is
    # NOT counted over (under-count, never a false alarm)
    h[bucket_index_np([20])[0]] += 1
    assert sb.update(h) == pytest.approx(1 / 5)
    # the window forgets: after `window` idle ticks the burn is clean
    for _ in range(4):
        frac = sb.update(h)
    assert frac == 0.0


# --------------------------------------------------------------------- #
# Spec validation and the API front door
# --------------------------------------------------------------------- #
def test_flight_spec_validation():
    with pytest.raises(SpecError, match="obs.provenance"):
        RunSpec(n=16, obs=ObsSpec(audit="log")).validate()
    with pytest.raises(SpecError, match="streaming engine"):
        RunSpec(n=16, engine="vec",
                obs=ObsSpec(provenance=4)).validate()
    with pytest.raises(SpecError, match="mode='live'"):
        RunSpec(n=16, obs=ObsSpec(ops_out="x.prom")).validate()
    with pytest.raises(SpecError, match="mode='live'"):
        RunSpec(n=16, obs=ObsSpec(watch=True)).validate()
    with pytest.raises(SpecError, match="obs.sampler"):
        RunSpec(n=16, obs=ObsSpec(provenance=4,
                                  sampler="nope")).validate()
    with pytest.raises(SpecError, match="obs.provenance"):
        RunSpec(n=16, obs=ObsSpec(provenance=True)).validate()
    # the valid shapes pass
    RunSpec(n=16, engine="windowed",
            obs=ObsSpec(provenance=4, audit="fail")).validate()


def test_api_exports_provenance_records_and_tracks(tmp_path):
    trace = str(tmp_path / "t.json")
    metrics = str(tmp_path / "m.jsonl")
    rep = api_run(RunSpec(
        engine="windowed", backend="numpy", n=48,
        traffic=TrafficSpec(messages=16), window=WindowSpec(window=48),
        obs=ObsSpec(provenance=1, audit="log", trace_out=trace,
                    metrics_out=metrics)))
    assert rep.extras["provenance_sampled"] == 16
    assert rep.extras["audit_pairs_checked"] > 0
    assert rep.extras["audit_violations"] == 0
    # the metrics doc carries one `provenance` record per sampled msg
    doc = load_metrics_jsonl(metrics)
    provs = doc["provenance"]
    ids = [p["id"] for p in provs]
    assert len(ids) == len(set(ids)) == 16
    for p in provs:
        assert len(p["deliv"]) == 48
        assert p["retire_round"] >= p["bcast_round"] >= 0
    # the trace gained per-message tracks in the provenance process
    with open(trace) as fh:
        evs = json.load(fh)["traceEvents"]
    prov = [e for e in evs if e.get("pid") == 2]
    assert any(e["ph"] == "M" and e["name"] == "process_name"
               for e in prov)
    assert sum(1 for e in prov if e["ph"] == "M"
               and e["name"] == "thread_name") == 16
    assert sum(1 for e in prov if e["ph"] == "X"
               and e["name"] == "life") == 16


def test_provenance_trace_events_are_well_formed():
    rec = dict(id=7, origin=1, bcast_round=3, submit_round=1,
               admit_round=2, activate_round=3, retire_round=9,
               expired=False, blocked_at=[4, 5], deliv=[3, 4, -1, 6])
    ev = provenance_trace_events([rec, dict(rec, id=8, expired=True)],
                                 n_devices=2)
    assert ev[0]["name"] == "process_name"
    tnames = [e["args"]["name"] for e in ev
              if e["ph"] == "M" and e["name"] == "thread_name"]
    assert tnames == ["msg 7 @o1", "msg 8 @o1"]
    life = next(e for e in ev if e["name"] == "life")
    assert (life["ts"], life["dur"]) == (1000.0, 8000.0)  # submit→retire
    q = next(e for e in ev if e["name"] == "queued")
    assert (q["ts"], q["dur"]) == (1000.0, 2000.0)        # submit→bcast
    # shard split at ceil(4/2)=2 rows, -1 sentinels masked out
    d0 = next(e for e in ev if e["name"] == "deliver shard0")
    assert d0["args"] == dict(receivers=2, first=3, last=4)
    d1 = next(e for e in ev if e["name"] == "deliver shard1")
    assert d1["args"]["receivers"] == 1 and d1["dur"] == 1.0
    assert sum(1 for e in ev if e["name"] == "blocked") == 4
    assert any(e["name"] == "life (expired)" for e in ev)
    assert all(e["ts"] >= 0 for e in ev if "ts" in e)
