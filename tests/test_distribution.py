"""Distribution layer: sharding policy rules, collective parsing, the
scan-undercount fact the roofline methodology rests on, and a reduced
production-mesh lower+compile in a forced-8-device subprocess."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import HW, dominant, model_flops, \
    parse_collectives, terms_from
from repro.configs import ARCHS, SHAPES


# ------------------------------------------------------------------ #
# collective parser
# ------------------------------------------------------------------ #
HLO_SNIPPET = """
  %all-reduce.1 = f32[128,64]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %all-gather.2 = bf16[16,512]{1,0} all-gather(%y), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}
  %reduce-scatter.3 = f32[32]{0} reduce-scatter(%z), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}
  %all-reduce-done = f32[128,64]{1,0} all-reduce-done(%all-reduce.1)
  %collective-permute.4 = s32[8]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
"""


def test_parse_collectives_ring_costs():
    out = parse_collectives(HLO_SNIPPET)
    ar = 2 * (128 * 64 * 4) * 3 / 4          # g=4
    ag = (16 * 512 * 2) * 1 / 2              # g=2
    rs = (32 * 4) * 7                        # g=8, result is the shard
    cp = 8 * 4
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["all-gather"] == pytest.approx(ag)
    assert out["reduce-scatter"] == pytest.approx(rs)
    assert out["collective-permute"] == pytest.approx(cp)
    assert out["total"] == pytest.approx(ar + ag + rs + cp)


def test_terms_and_dominance():
    t = terms_from(flops=197e12 * 256, bytes_hbm=819e9 * 256,
                   wire_per_device=50e9 / 2, chips=256)
    assert t["compute"] == pytest.approx(1.0)
    assert t["memory"] == pytest.approx(1.0)
    assert t["collective"] == pytest.approx(0.5)
    assert dominant({"compute": 3, "memory": 2, "collective": 1}) == \
        "compute"


def test_model_flops_sanity():
    cfg = ARCHS["qwen3-8b"]
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    de = model_flops(cfg, SHAPES["decode_32k"])
    # train ~ 6ND: within 2x of the attention-free floor
    assert tr > 6 * cfg.param_count() * 4096 * 256
    assert de < pf < tr
    # MoE uses active params
    moe = ARCHS["qwen3-moe-235b-a22b"]
    assert model_flops(moe, SHAPES["train_4k"]) < \
        0.25 * 6 * moe.param_count() * 4096 * 256


# ------------------------------------------------------------------ #
# scan undercount (the fact the compositional costing corrects)
# ------------------------------------------------------------------ #
def test_cost_analysis_counts_scan_body_once():
    def body(c, w):
        return jnp.tanh(c @ w), None

    def f_scan(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def f_unroll(x, ws):
        for i in range(ws.shape[0]):
            x = jnp.tanh(x @ ws[i])
        return x

    def flops(fn, *avals):
        ca = jax.jit(fn).lower(*avals).compile().cost_analysis()
        # jax < 0.6 returns a one-element list of dicts (one per device),
        # newer releases return the dict directly
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return ca["flops"]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    fs = flops(f_scan, x, ws)
    fu = flops(f_unroll, x, ws)
    assert fu == pytest.approx(8 * fs, rel=0.01)


# ------------------------------------------------------------------ #
# sharding policy
# ------------------------------------------------------------------ #
@pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="the subprocess snippet builds its mesh with "
           "jax.sharding.AxisType (explicit-sharding API, jax >= 0.5.x); "
           "the pinned jax in this environment predates it, so the "
           "snippet can only fail on import — skipped, not broken")
def test_policy_specs_respect_divisibility_subprocess():
    """grok's 8 experts don't divide model=16 -> d_ff TP fallback; qwen3-
    moe's 128 experts shard on model.  Needs a mesh => subprocess."""
    snippet = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import build_model
        from repro.launch.specs import shapes_and_axes, param_specs
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)

        for arch in ("grok-1-314b", "qwen3-moe-235b-a22b"):
            cfg = get_arch(arch)
            model = build_model(cfg)
            shapes, axes = shapes_and_axes(model)
            specs = param_specs(cfg, shapes, axes, mesh, policy="fsdp")
            sp = specs["stack0"]["b0"]["moe"]["w_gate"]   # (L, E, d, ff)
            # experts divide model=4 for both archs -> expert parallel,
            # embed dim picks up the data (fsdp) axis, layers unsharded
            assert sp[0] is None and sp[1] == "model", sp
            assert sp[2] in ("data", ("data",)), sp
            emb = specs["embed"]                           # (V, d)
            assert emb[0] == "model", emb
            assert emb[1] in ("data", ("data",)), emb
            # attention q_proj dim is TP'd under plain tp policy too
            tp = param_specs(cfg, shapes, axes, mesh, policy="tp")
            wq = tp["stack0"]["b0"]["attn"]["wq"]          # (L, d, qd)
            assert wq[2] == "model" and wq[1] is None, wq
        print("POLICY_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "POLICY_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="the subprocess snippet builds its mesh with "
           "jax.sharding.AxisType (explicit-sharding API, jax >= 0.5.x); "
           "the pinned jax in this environment predates it, so the "
           "snippet can only fail on import — skipped, not broken")
def test_reduced_production_cell_compiles_subprocess():
    """A smoke-sized train cell lowers+compiles with full shardings on a
    forced 8-device (2x4) mesh — the dry-run pipeline end to end."""
    snippet = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from dataclasses import replace
        from repro.configs import get_arch
        from repro.configs.base import ShapeSpec
        from repro.launch.dryrun import lower_compile
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = replace(get_arch("yi-6b").smoke(), num_layers=2)
        shape = ShapeSpec("tiny_train", 64, 8, "train")
        lowered, compiled = lower_compile(cfg, shape, mesh, remat="full")
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        assert ca["flops"] > 0
        assert ma.argument_size_in_bytes > 0
        txt = compiled.as_text()
        assert ("all-reduce" in txt) or ("reduce-scatter" in txt)
        print("CELL_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "CELL_OK" in out.stdout, out.stdout + out.stderr
