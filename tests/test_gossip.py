"""Causal-gossip training runtime: convergence, causal safety, elastic
membership (join/leave/crash), compression, checkpoint-restart."""

from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.runtime.gossip import CausalGossipTrainer, GossipConfig


def tiny_cfg():
    return replace(ARCHS["yi-6b"].smoke(), num_layers=2, d_model=32,
                   d_ff=64, num_heads=2, num_kv_heads=2, head_dim=16,
                   vocab_size=64, compute_dtype="float32",
                   param_dtype="float32")


def make_trainer(n_pods=4, seed=0, **gkw):
    cfg = tiny_cfg()
    dc = DataConfig(vocab_size=64, seq_len=32, global_batch=8)
    g = GossipConfig(local_steps=2, **gkw)
    return CausalGossipTrainer(lambda: build_model(cfg, remat="none"),
                               n_pods, g, dc, seed=seed)


@pytest.fixture(scope="module")
def converged_run():
    tr = make_trainer()
    first = None
    tr.run_rounds(10)
    return tr


def test_gossip_loss_decreases(converged_run):
    """Each per-round loss is the last inner step's loss on a *fresh*
    batch, so consecutive entries are noisy samples of the true loss;
    comparing single samples against a fixed margin flaked whenever the
    final batch happened to be hard (the trajectory is deterministic per
    environment but shifts with BLAS/XLA versions).  Compare 3-round
    leading/trailing means instead: the convergence *trend* every
    environment reproduces."""
    tr = converged_run
    for pod in tr.pods.values():
        head = float(np.mean(pod.losses[:3]))
        tail = float(np.mean(pod.losses[-3:]))
        assert tail < head - 0.25, pod.losses


def test_gossip_is_causally_safe(converged_run):
    rep = converged_run.causal_report()
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()
    assert rep.n_broadcasts == sum(
        len(p.losses) for p in converged_run.pods.values())


def test_gossip_updates_disseminate_to_all(converged_run):
    tr = converged_run
    n = len(tr.pods)
    for pod in tr.pods.values():
        # every pod applied (n-1) foreign updates per round (quiescent)
        assert len(pod.applied) == (n - 1) * len(pod.losses)


def test_gossip_replicas_stay_close(converged_run):
    assert converged_run.replica_drift() < 0.8


def test_gossip_elastic_join_and_leave():
    tr = make_trainer(n_pods=4)

    def churn(r, t):
        if r == 2:
            t.join()                      # pod 4 joins mid-run
        if r == 4:
            t.leave(1, graceful=True)     # pod 1 departs

    tr.run_rounds(8, churn=churn)
    rep = tr.causal_report()
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()
    joined = tr.pods[4]
    assert joined.losses and joined.losses[-1] < 4.5
    assert len(joined.applied) > 0        # received foreign updates
    assert not tr.pods[1].alive


def test_gossip_silent_crash_is_survived():
    tr = make_trainer(n_pods=4, ping_timeout=5.0, max_retry=2)

    def churn(r, t):
        if r == 3:
            t.leave(2, graceful=False)    # silent crash (Fig. 5b)

    tr.run_rounds(8, churn=churn)
    rep = tr.causal_report()
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()
    live = [p for p in tr.pods.values() if p.alive]
    assert all(p.losses[-1] < p.losses[0] for p in live)


def test_gossip_compression_converges_with_smaller_payloads():
    dense = make_trainer(n_pods=3, seed=1)
    dense.run_rounds(6)
    comp = make_trainer(n_pods=3, seed=1, compress_frac=0.1)
    comp.run_rounds(6)
    assert comp.mean_loss() < 4.3
    # top-k at 10%: values f32 + indices i32 => ~20% of dense payload
    assert comp.store.bytes_stored < 0.25 * dense.store.bytes_stored


def test_gossip_checkpoint_restart(tmp_path):
    from repro.checkpoint import ckpt
    tr = make_trainer(n_pods=3)
    tr.run_rounds(4)
    pod = tr.pods[0]
    ckpt.save(str(tmp_path), pod.round,
              {"params": pod.params, "opt": pod.opt_state._asdict()},
              meta={"data_step": pod.data_step, "round": pod.round})
    # crash pod 0 silently, then bring up a replacement from the checkpoint
    tr.leave(0, graceful=False)
    new_pid = tr.join()
    npod = tr.pods[new_pid]
    state, meta = ckpt.restore(
        str(tmp_path), meta_step := ckpt.latest_step(str(tmp_path)),
        like={"params": npod.params, "opt": npod.opt_state._asdict()})
    npod.params = state["params"]
    npod.data_step = meta["data_step"]
    tr.run_rounds(4)
    rep = tr.causal_report()
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()
    assert npod.losses[-1] < 4.3


def test_gossip_straggler_does_not_block_fleet():
    """A 3x-slow pod never blocks the others (non-blocking causal
    broadcast = straggler mitigation): fast pods complete every round,
    keep converging, and apply the straggler's (rarer) updates in causal
    order."""
    tr = make_trainer(n_pods=4)
    tr.run_rounds(9, stragglers={2: 3})
    rep = tr.causal_report()
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()
    fast = [p for p in tr.pods.values() if p.pid != 2]
    slow = tr.pods[2]
    assert all(len(p.losses) == 9 for p in fast)
    assert len(slow.losses) == 3
    assert all(p.losses[-1] < p.losses[0] for p in fast)
    # fast pods saw the straggler's updates exactly when it published
    for p in fast:
        assert sum(1 for (o, _) in p.applied if o == 2) == 3


def test_lr_schedules_shape():
    import numpy as np
    from repro.training.schedule import warmup_cosine, warmup_linear
    f = warmup_cosine(10, 100, final_frac=0.1)
    assert float(f(0)) == 0.0
    assert float(f(10)) == pytest.approx(1.0)
    assert float(f(100)) == pytest.approx(0.1, abs=1e-6)
    assert float(f(55)) < float(f(20))
    g = warmup_linear(5, 50)
    assert float(g(5)) == pytest.approx(1.0)
    assert float(g(50)) == pytest.approx(0.0, abs=1e-6)
