"""Property-based tests (hypothesis): protocol invariants under random
topologies, delays, churn schedules and broadcast interleavings.

Invariants checked (the broadcast specification, §3.1):
  * causal order   — never violated by PC-broadcast (Theorem 2);
  * integrity      — at most one delivery per message per process;
  * validity       — broadcasters deliver their own messages;
  * agreement      — on quiescent connected runs, all correct processes
                     deliver the same set;
  * R-broadcast    — same properties on *static* overlays (Theorem 1);
  * VC baseline    — causal too (sanity for the Table 1 comparison).
"""

import random

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
    "extra (pip install -r requirements.txt)")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (BoundedPCBroadcast, Network, PCBroadcast, RBroadcast,
                        VCBroadcast, check_trace, ring_plus_random)

BASE = dict(
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
    derandomize=True,
)


def run_random_schedule(proto_factory, seed, n, n_ops, churn=True,
                        keep_ring=True):
    rng = random.Random(seed)
    net = Network(seed=seed, default_delay=lambda t, r: r.uniform(0.1, 4.0),
                  oob_delay=lambda t, r: r.uniform(0.05, 1.0))
    for pid in range(n):
        net.add_process(proto_factory(pid))
    ring_plus_random(net, range(n), k=3, rng=rng)
    for step in range(n_ops):
        net.run(until=net.time + rng.uniform(0.2, 1.5))
        op = rng.random()
        if op < 0.5 or not churn:
            net.procs[rng.randrange(n)].broadcast(("m", step))
        elif op < 0.75:
            a, b = rng.randrange(n), rng.randrange(n)
            if a != b and not net.has_link(a, b):
                net.connect(a, b)
        else:
            cands = [(a, b) for (a, b), lk in net.links.items()
                     if lk.alive and (not keep_ring or b != (a + 1) % n)]
            if cands:
                net.disconnect(*rng.choice(cands))
    net.run()
    return net


@settings(max_examples=25, **BASE)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 14),
       n_ops=st.integers(5, 25), always_gate=st.booleans())
def test_pc_broadcast_invariants_under_churn(seed, n, n_ops, always_gate):
    net = run_random_schedule(
        lambda pid: PCBroadcast(pid, ping_mode="flood",
                                always_gate=always_gate), seed, n, n_ops)
    rep = check_trace(net.trace, all_pids=set(range(n)))
    assert rep.ok, rep.summary()


@settings(max_examples=15, **BASE)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 12),
       n_ops=st.integers(5, 20))
def test_pc_broadcast_route_mode_invariants(seed, n, n_ops):
    net = run_random_schedule(
        lambda pid: PCBroadcast(pid, ping_mode="route"), seed, n, n_ops)
    rep = check_trace(net.trace, all_pids=set(range(n)))
    # Routed pings can be dropped by concurrent link removal; without
    # Algorithm 3 retries some links may stay unsafe forever, which can
    # only delay *who uses which link*, never violate safety:
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()


@settings(max_examples=15, **BASE)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 12),
       n_ops=st.integers(5, 20))
def test_bounded_pc_with_retries_invariants(seed, n, n_ops):
    net = run_random_schedule(
        lambda pid: BoundedPCBroadcast(pid, ping_mode="route", max_size=3,
                                       max_retry=8, ping_timeout=25.0),
        seed, n, n_ops)
    rep = check_trace(net.trace, all_pids=set(range(n)))
    assert rep.causal_ok and not rep.double_deliveries, rep.summary()
    # Buffer bound respected everywhere (checked post-insertion => +1):
    for p in net.procs.values():
        for _, buf in p.B.values():
            assert len(buf) <= 4


@settings(max_examples=20, **BASE)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 14),
       n_ops=st.integers(5, 20))
def test_r_broadcast_static_invariants(seed, n, n_ops):
    net = run_random_schedule(RBroadcast, seed, n, n_ops, churn=False)
    rep = check_trace(net.trace, all_pids=set(range(n)))
    assert rep.ok, rep.summary()


@settings(max_examples=15, **BASE)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 10),
       n_ops=st.integers(5, 15))
def test_vector_clock_baseline_invariants_under_churn(seed, n, n_ops):
    net = run_random_schedule(VCBroadcast, seed, n, n_ops)
    rep = check_trace(net.trace, all_pids=set(range(n)))
    assert rep.ok, rep.summary()


@settings(max_examples=10, **BASE)
@given(seed=st.integers(0, 10_000), n=st.integers(5, 12))
def test_pc_overhead_is_constant_vc_overhead_grows(seed, n):
    """Table 1: PC control info is O(1)/message; VC's grows with N."""
    from repro.obs import overhead_per_message
    net_pc = run_random_schedule(
        lambda pid: PCBroadcast(pid, ping_mode="route"), seed, n, 12,
        churn=False)
    net_vc = run_random_schedule(VCBroadcast, seed, n, 12, churn=False)
    assert overhead_per_message(net_pc) <= 24.0     # id pair (+ping share)
    # VC overhead: at least id + one vector entry, grows with broadcasters.
    assert overhead_per_message(net_vc) > overhead_per_message(net_pc)
