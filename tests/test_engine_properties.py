"""Property-based engine tests: for random instances, the tensorized
engine always matches the numpy oracle, PC mode is always causally safe,
and quiescent connected runs deliver everything everywhere."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
    "extra (pip install -r requirements.txt)")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.engine import (analyze, random_instance, run_engine,
                               run_ref)

BASE = dict(deadline=None, derandomize=True,
            suppress_health_check=[HealthCheck.too_slow])


@settings(max_examples=12, **BASE)
@given(seed=st.integers(0, 10_000), n=st.integers(6, 24),
       k=st.integers(3, 5), m=st.integers(2, 8), adds=st.integers(0, 6),
       rms=st.integers(0, 4), pong=st.integers(1, 3),
       gate=st.booleans())
def test_engine_always_matches_oracle(seed, n, k, m, adds, rms, pong,
                                      gate):
    cfg, sched, adj0, delay0 = random_instance(
        seed, n=n, k=k, m_app=m, n_adds=adds, n_rms=rms, rounds=40,
        mode="pc", pong_delay=pong, always_gate=gate)
    d_ref = run_ref(cfg, sched, adj0.copy(), delay0.copy())
    d_jax = run_engine(cfg, sched, adj0, delay0)
    np.testing.assert_array_equal(d_ref, d_jax)


@settings(max_examples=12, **BASE)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 64),
       adds=st.integers(0, 10), rms=st.integers(0, 8))
def test_engine_pc_mode_always_causal_and_complete(seed, n, adds, rms):
    cfg, sched, adj0, delay0 = random_instance(
        seed, n=n, k=5, m_app=8, n_adds=adds, n_rms=rms, rounds=72,
        mode="pc")
    d = run_engine(cfg, sched, adj0, delay0)
    rep = analyze(d, sched)
    assert rep["violations"] == 0, rep
    assert rep["missing"] == 0, rep
    # ring is never removed (rm_k >= 1), so the overlay stays connected
    assert rep["delivered_frac"] == 1.0, rep
