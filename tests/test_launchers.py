"""Launcher smoke tests: train (spmd + resume, gossip) and serve CLIs."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(__file__))


def run_cli(args, timeout=600):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-m"] + args,
                          capture_output=True, text=True, env=env,
                          cwd=ROOT, timeout=timeout)


def test_train_spmd_smoke_and_resume(tmp_path):
    base = ["repro.launch.train", "--arch", "yi-6b", "--steps", "6",
            "--seq-len", "32", "--batch", "4", "--log-every", "2",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"]
    out = run_cli(base)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done: final loss" in out.stdout
    # resume: second run picks up the final checkpoint and extends
    out2 = run_cli(base[:4] + ["10"] + base[5:])
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "resuming from step" in out2.stdout


def test_train_gossip_smoke():
    out = run_cli(["repro.launch.train", "--mode", "gossip", "--pods",
                   "3", "--rounds", "3", "--seq-len", "32", "--batch",
                   "4", "--local-steps", "1"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "causal check:" in out.stdout
    assert "causal_violations=0" in out.stdout


def test_serve_cli_smoke():
    out = run_cli(["repro.launch.serve", "--arch", "yi-6b", "--requests",
                   "4", "--slots", "2", "--max-new", "6"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout
