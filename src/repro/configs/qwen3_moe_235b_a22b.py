"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B scaled per assignment]:
128 experts top-8, expert d_ff=1536, GQA kv=4."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", num_layers=94, d_model=4096,
    num_heads=64, num_kv_heads=4, head_dim=128, d_ff=1536,
    vocab_size=151936, qk_norm=True, n_experts=128, top_k=8,
    rope_theta=1e6,
)
