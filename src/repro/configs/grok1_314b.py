"""Grok-1 314B [hf:xai-org/grok-1]: 8 experts top-2, d_ff=32768."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe", num_layers=64, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=32768,
    vocab_size=131072, n_experts=8, top_k=2,
)
