from .base import SHAPES, ArchConfig, ShapeSpec, runnable_shapes
from .registry import ARCHS, get_arch

__all__ = ["SHAPES", "ArchConfig", "ShapeSpec", "runnable_shapes",
           "ARCHS", "get_arch"]
