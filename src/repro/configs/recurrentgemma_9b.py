"""RecurrentGemma-9B [arXiv:2402.19427]: RG-LRU + local attention 1:2
(pattern rec,rec,attn), MQA kv=1, window 2048."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", num_layers=38, d_model=4096,
    num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288,
    vocab_size=256000, block_pattern=("rec", "rec", "attn"),
    window=2048, lru_width=4096, tie_embeddings=True,
)
