"""Granite-8B (code) [arXiv:2405.04324]: llama-arch, GQA kv=8."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", family="dense", num_layers=36, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
    vocab_size=49152, tie_embeddings=True,
)
