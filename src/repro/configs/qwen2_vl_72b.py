"""Qwen2-VL-72B [arXiv:2409.12191]: M-RoPE backbone; vision frontend
STUBBED (input_specs feeds precomputed patch embeddings)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=29568,
    vocab_size=152064, mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1e6, frontend="vision",
)
