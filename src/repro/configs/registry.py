"""Architecture registry: --arch <id> resolution."""

from . import (granite_8b, grok1_314b, mamba2_2_7b, phi3_mini_3_8b,
               qwen2_vl_72b, qwen3_8b, qwen3_moe_235b_a22b,
               recurrentgemma_9b, whisper_small, yi_6b)
from .base import SHAPES, ArchConfig, ShapeSpec, runnable_shapes

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    qwen3_8b, yi_6b, granite_8b, phi3_mini_3_8b, whisper_small,
    recurrentgemma_9b, qwen3_moe_235b_a22b, grok1_314b, mamba2_2_7b,
    qwen2_vl_72b,
)}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
