"""Architecture configs and input-shape registry.

One ``ArchConfig`` per assigned architecture (exact numbers from the
assignment table) plus ``smoke()`` reductions for CPU tests.  Shapes are
global (pre-sharding): ``train_4k`` lowers ``train_step``; ``prefill_32k``
lowers the serving prefill; ``decode_32k``/``long_500k`` lower
``serve_step`` (one token against a seq_len KV cache).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "runnable_shapes"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25       # training dispatch capacity
    capacity_factor_eval: float = 2.0   # serving dispatch capacity

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4

    # hybrid (RecurrentGemma / Griffin): block pattern repeated over depth
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    window: int = 0                       # local-attention window
    lru_width: int = 0                    # 0 -> d_model

    # encoder-decoder (whisper): backbone sizes apply to the decoder
    encoder_layers: int = 0
    encoder_seq: int = 0                  # precomputed frame embeddings
    frontend: str = "none"                # none | audio | vision (stub)

    # VLM
    mrope: bool = False
    mrope_sections: Tuple[int, ...] = ()  # per-section head_dim/2 split

    # numerics / implementation
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attn_impl: str = "blockwise"   # blockwise (flash-style) | naive

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))

    # ---------------- derived ------------------------------------------- #
    @property
    def attn_q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def attn_kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and not self.block_pattern

    @property
    def is_hybrid(self) -> bool:
        return bool(self.block_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can serve 500k+ contexts (SSM state and/or windowed attention)."""
        return self.is_ssm or self.is_hybrid

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind over the full depth."""
        if self.is_ssm:
            return ("ssm",) * self.num_layers
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        return ("attn",) * self.num_layers

    # ---------------- parameter count (for roofline / memory) ----------- #
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        n = emb + d  # final norm
        if self.encoder_layers:
            n += self.encoder_seq * 0  # frontend embeddings are inputs
        for kind in self.layer_kinds():
            n += d  # pre-norm 1
            if kind == "attn":
                n += d * self.attn_q_dim + 2 * d * self.attn_kv_dim
                n += self.attn_q_dim * d
                if self.qk_norm:
                    n += 2 * self.head_dim
            elif kind == "rec":
                w = self.lru_width or d
                n += 2 * d * w + w * d          # in gates + out
                n += self.conv_width * w + 3 * w  # conv + lru params
            elif kind == "ssm":
                di, ns, h = self.d_inner, self.ssm_state, self.ssm_heads
                n += d * (2 * di + 2 * ns + h) + self.conv_width * (
                    di + 2 * ns) + 2 * h + di + di * d
            if kind != "ssm":
                n += d  # pre-norm 2
                if self.is_moe:
                    n += d * self.n_experts
                    n += self.n_experts * 3 * d * self.d_ff
                else:
                    n += 3 * d * self.d_ff
        if self.encoder_layers:
            de = self.d_model
            per = (2 * de  # norms
                   + de * self.attn_q_dim + 2 * de * self.attn_kv_dim
                   + self.attn_q_dim * de + 3 * de * self.d_ff)
            n += self.encoder_layers * per + de
            # decoder cross-attention adds one attention block per layer
            n += self.num_layers * (de + de * self.attn_q_dim
                                    + 2 * de * self.attn_kv_dim
                                    + self.attn_q_dim * de)
        return n

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        expert = self.num_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active = self.num_layers * self.top_k * 3 * self.d_model * self.d_ff
        return full - expert + active

    # ---------------- smoke reduction ------------------------------------ #
    def smoke(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=min(self.num_layers, 4 if not self.block_pattern
                           else 2 * max(1, len(self.block_pattern))),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.is_moe:
            kw.update(n_experts=4, top_k=min(self.top_k, 2))
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.lru_width:
            kw.update(lru_width=64)
        if self.window:
            kw.update(window=32)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=24)
        if self.mrope_sections:
            kw.update(mrope_sections=(4, 2, 2))
        if self.num_kv_heads == self.num_heads:  # MHA archs stay MHA
            kw.update(num_kv_heads=4)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def runnable_shapes(cfg: ArchConfig):
    """Shapes applicable to an arch; ``long_500k`` requires sub-quadratic
    serving (DESIGN.md §4 documents the skips)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return out
