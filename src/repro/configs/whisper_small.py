"""Whisper-small [arXiv:2212.04356]: enc-dec audio; conv frontend STUBBED
(input_specs feeds precomputed 1500-frame embeddings)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
    vocab_size=51865, encoder_layers=12, encoder_seq=1500,
    frontend="audio",
)
