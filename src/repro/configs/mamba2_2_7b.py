"""Mamba2-2.7B [arXiv:2405.21060]: SSD (state-space duality), attn-free."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm", num_layers=64, d_model=2560,
    num_heads=0, num_kv_heads=0, head_dim=1, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    tie_embeddings=True,
)
