"""Batched serving engine: prefill + decode with slot-based continuous
batching.

A fixed pool of ``batch`` slots decodes in lock-step (one jitted
decode_step per tick).  Finished sequences (EOS or max_len) free their
slot; queued requests are admitted by re-prefilling the slot's cache
entries.  Greedy or temperature sampling.  This is the single-host
serving path; the dry-run's decode cells prove the same step function
shards across the production mesh.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Request", "ServeConfig", "ServingEngine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 = greedy
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeConfig:
    batch: int = 4                # decode slots
    max_len: int = 256            # cache length
    eos_id: int = -1              # -1: never stops early
    seed: int = 0


class ServingEngine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * cfg.batch
        self.pos = np.zeros(cfg.batch, np.int32)      # next write index
        self.caches = None
        self.key = jax.random.PRNGKey(cfg.seed)
        self._decode = jax.jit(model.decode_step)
        self.ticks = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ---------------- internals --------------------------------------- #
    def _admit(self) -> None:
        """Fill free slots: prefill the prompt, merge its caches in."""
        for i in range(self.cfg.batch):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            last, caches = self.model.prefill(
                self.params, req.prompt[None, :].astype(np.int32),
                pad_to=self.cfg.max_len)
            tok = self._sample(last, req)[0]
            req.out_tokens.append(int(tok))
            if self.caches is None:
                self.caches = jax.tree.map(
                    lambda c: jnp.repeat(jnp.zeros_like(c), self.cfg.batch,
                                         axis=1), caches)
            self.caches = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), i, axis=1),
                self.caches, caches)
            self.slots[i] = req
            self.pos[i] = len(req.prompt)

    def _sample(self, logits, req: Request):
        if req.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / req.temperature, axis=-1))

    def _retire(self, i: int) -> None:
        self.slots[i] = None
        self.pos[i] = 0

    # ---------------- main loop ---------------------------------------- #
    def step(self) -> int:
        """One engine tick: admit + one decode step for all active slots.
        Each slot decodes at its own position (per-row cur_index vector).
        Returns the number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        tokens = np.zeros(self.cfg.batch, np.int32)
        for i in active:
            tokens[i] = self.slots[i].out_tokens[-1]
        logits, self.caches = self._decode(self.params,
                                           jnp.asarray(tokens),
                                           self.caches,
                                           jnp.asarray(self.pos))
        self.ticks += 1
        for i in active:
            req = self.slots[i]
            nxt = int(self._sample(logits[i:i + 1], req)[0])
            req.out_tokens.append(nxt)
            self.pos[i] += 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or nxt == self.cfg.eos_id
                    or self.pos[i] >= self.cfg.max_len - 1):
                req.done = True
                self._retire(i)
        return len(active)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        while (self.queue or any(self.slots)) and self.ticks < max_ticks:
            self.step()
            done.extend(r for r in self.slots if r is not None and r.done)
        return done
