"""Mamba-2 (SSD, state-space duality) block.

Training/prefill uses the *chunked* SSD algorithm (arXiv:2405.21060 §6):
intra-chunk attention-like term + inter-chunk recurrent state passing —
the formulation whose inner matmuls map onto the MXU (and onto the Pallas
kernel in ``repro.kernels.ssd_scan``).  Decode is the O(1) recurrence on a
``(B, H, N, P)`` state.

Layout follows the reference Mamba-2: in_proj -> [z | x | B | C | dt],
causal conv over (x,B,C), per-head scalar decay A, D skip, gated RMSNorm,
out_proj.  n_groups = 1.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_dense, rms_norm

__all__ = ["init_ssm", "ssd_forward", "ssm_block", "ssm_decode_step",
           "init_ssm_state", "ssd_chunk_scan_ref"]


def init_ssm(key, cfg, dtype):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.conv_width
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    in_dim = 2 * di + 2 * n + h
    p["in_proj"], a["in_proj"] = init_dense(ks[0], (d, in_dim),
                                            ("embed", "ssm_in"), dtype)
    p["conv_w"], a["conv_w"] = init_dense(ks[1], (w, di + 2 * n),
                                          (None, "ssm_conv"), dtype,
                                          scale=w ** -0.5)
    p["conv_b"] = jnp.zeros((di + 2 * n,), dtype)
    a["conv_b"] = ("ssm_conv",)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32)
    a["A_log"] = ("ssm_heads",)
    p["D"] = jnp.ones((h,), jnp.float32)
    a["D"] = ("ssm_heads",)
    p["dt_bias"] = jnp.zeros((h,), jnp.float32)
    a["dt_bias"] = ("ssm_heads",)
    p["norm"] = jnp.ones((di,), dtype)
    a["norm"] = ("ssm_inner",)
    p["out_proj"], a["out_proj"] = init_dense(ks[2], (di, d),
                                              ("ssm_inner", "embed"), dtype)
    return p, a


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x (B,S,C), w (W,C).  With ``state``
    (B, W-1, C) it is a streaming step (S may be 1); returns new state."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                # (B, S+W-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):, :]
    return out + b, new_state


def ssd_chunk_scan_ref(xbar, a_log, Bm, Cm, h0=None, chunk: int = 128):
    """Chunked SSD scan — the pure-jnp oracle used by both the model and
    the Pallas kernel tests.

    xbar (B,S,H,P) — dt-scaled inputs;  a_log (B,S,H) — per-step log decay;
    Bm, Cm (B,S,N) — input/output projections (shared across heads, G=1);
    h0 optional (B,H,N,P) initial state.  Returns (y (B,S,H,P),
    h_final (B,H,N,P))."""
    b, s, h, p_ = xbar.shape
    n = Bm.shape[-1]
    q = min(chunk, s) if s % chunk else chunk
    if s % q:
        # pad to a chunk multiple: a_log=0 (decay 1) and xbar=0 keep the
        # final state exact; padded outputs are sliced off below.
        pad = q - s % q
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    s_pad = xbar.shape[1]
    nc = s_pad // q
    xb = xbar.reshape(b, nc, q, h, p_)
    al = a_log.reshape(b, nc, q, h).astype(jnp.float32)
    bm = Bm.reshape(b, nc, q, n)
    cm = Cm.reshape(b, nc, q, n)
    s_out = s

    # cumulative log-decay within each chunk
    l = jnp.cumsum(al, axis=2)                                  # (B,NC,Q,H)
    # intra-chunk: y_i += C_i . B_j  * exp(l_i - l_j) * xbar_j  (j <= i)
    cb = jnp.einsum("bcqn,bckn->bcqk", cm, bm)                  # (B,NC,Q,Q)
    seg = l[:, :, :, None, :] - l[:, :, None, :, :]             # (B,NC,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: upper-triangular seg is large-positive and would
    # overflow, poisoning gradients through the where
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    att = cb[..., None] * decay                                 # (B,NC,Q,Q,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", att.astype(xb.dtype), xb)

    # chunk-level states: h_c = exp(L_c) h_{c-1} + sum_j exp(L_c - l_j) B_j xbar_j^T
    lq = l[:, :, -1, :]                                         # (B,NC,H)
    binp = jnp.einsum(
        "bcqn,bcqhp->bcnhp", bm.astype(jnp.float32),
        jnp.exp(lq[:, :, None, :] - l)[..., None]
        * xb.astype(jnp.float32))                               # (B,NC,N,H,P)

    def scan_fn(hprev, inp):
        dec, upd = inp                                          # (B,H),(B,N,H,P)
        hnew = hprev * jnp.exp(dec)[:, None, :, None] + upd
        return hnew, hprev

    if h0 is None:
        h0 = jnp.zeros((b, n, h, p_), jnp.float32)
    else:
        h0 = jnp.moveaxis(h0, 1, 2).astype(jnp.float32)         # (B,N,H,P)
    hfin, hprevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(lq, 1, 0), jnp.moveaxis(binp, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                         # (B,NC,N,H,P)

    # inter-chunk: y_i += C_i . h_prev * exp(l_i)
    y_inter = jnp.einsum("bcqn,bcnhp->bcqhp", cm.astype(jnp.float32),
                         hprevs) * jnp.exp(l)[..., None]
    y = (y_intra.astype(jnp.float32) + y_inter).astype(xb.dtype)
    y = y.reshape(b, s_pad, h, p_)[:, :s_out]
    return y, jnp.moveaxis(hfin, 1, 2)                          # (B,H,N,P)


def ssd_forward(p, cfg, x, use_pallas: bool = False):
    """Full-sequence SSD block body (training / prefill).

    Returns (y (B,S,d), (conv_state, ssm_state)) for cache handoff."""
    b, s, d = x.shape
    cd = x.dtype
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"].astype(cd)
    z, xc, bm, cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xc, bm, cm], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"].astype(cd),
                                        p["conv_b"].astype(cd))
    conv_out = jax.nn.silu(conv_out)
    xc, bm, cm = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a_log = -jnp.exp(p["A_log"])[None, None, :] * dt             # (B,S,H)
    xh = xc.reshape(b, s, h, pd)
    xbar = xh * dt.astype(cd)[..., None]

    if use_pallas:
        from repro.kernels.ssd_scan.ops import ssd_chunk_scan
        y, hfin = ssd_chunk_scan(xbar, a_log, bm, cm, chunk=cfg.ssm_chunk)
    else:
        y, hfin = ssd_chunk_scan_ref(xbar, a_log, bm, cm, chunk=cfg.ssm_chunk)
    y = y + xh * p["D"].astype(cd)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(cd), (conv_state, hfin)


def ssm_block(p, cfg, x, use_pallas: bool = False):
    y, _ = ssd_forward(p, cfg, x, use_pallas)
    return y


def init_ssm_state(cfg, batch: int, dtype):
    """(conv_state (B,W-1,di+2N), ssm_state (B,H,N,P))."""
    di, n = cfg.d_inner, cfg.ssm_state
    conv = jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype)
    ssm = jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim), dtype)
    return conv, ssm


def ssm_decode_step(p, cfg, x, state):
    """One-token recurrence.  x (B,1,d); state from init_ssm_state."""
    conv_state, hstate = state
    b, _, d = x.shape
    cd = x.dtype
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"].astype(cd)
    z, xc, bm, cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xc, bm, cm], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"].astype(cd),
                                        p["conv_b"].astype(cd), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xc, bm, cm = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,1,H)
    a = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt[:, 0])        # (B,H)
    xh = xc.reshape(b, h, pd)
    xbar = xh * dt[:, 0, :, None].astype(cd)
    # h <- a h + B (x dt)^T ; y = C h + D x
    upd = jnp.einsum("bn,bhp->bhnp", bm[:, 0].astype(cd), xbar)
    hstate = hstate * a[:, :, None, None].astype(cd) + upd
    y = jnp.einsum("bn,bhnp->bhp", cm[:, 0].astype(cd), hstate)
    y = y + xh * p["D"].astype(cd)[None, :, None]
    y = y.reshape(b, 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(cd), (conv_state, hstate)
