"""Core transformer layers: RMSNorm, RoPE/M-RoPE, GQA attention (full /
causal / local / cross), SwiGLU MLP, KV caches.

Pure-functional JAX: params are nested dicts of arrays; every init returns
``(params, axes)`` where ``axes`` mirrors the params pytree with *logical
axis name* tuples consumed by ``repro.sharding.policy``.  All functions are
shape-polymorphic over batch/seq and safe to trace with ShapeDtypeStructs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm", "init_rms_norm", "rope", "mrope", "init_attention",
    "attention", "decode_attention", "init_mlp", "mlp", "init_dense",
    "big_neg", "make_mask",
]

Params = Dict[str, Any]


def big_neg(dtype) -> jnp.ndarray:
    return jnp.asarray(-0.7 * float(jnp.finfo(dtype).max), dtype)


# --------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------- #
def init_dense(key, shape, axes, dtype, scale: Optional[float] = None):
    """He/Glorot-ish init: normal with 1/sqrt(fan_in)."""
    fan_in = shape[0] if len(shape) <= 2 else int(np.prod(shape[:-1]))
    scale = scale if scale is not None else fan_in ** -0.5
    w = jax.random.normal(key, shape, jnp.float32) * scale
    return w.astype(dtype), axes


def init_rms_norm(d: int, dtype, axis: str = "embed"):
    return jnp.ones((d,), dtype), (axis,)


def rms_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6):
    """RMSNorm with f32 statistics regardless of activation dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * g.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------- #
# rotary embeddings
# --------------------------------------------------------------------- #
def _rope_angles(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, head_dim/2), f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4):
    """Apply rotary embedding.  x: (B, S, H, D), positions: (B, S)."""
    cos, sin = _rope_angles(positions, x.shape[-1], theta)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]    # (B,S,1,D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def mrope(x: jnp.ndarray, positions: jnp.ndarray, sections: Tuple[int, ...],
          theta: float = 1e4):
    """Multimodal RoPE (Qwen2-VL): positions (B, 3, S) — one position id
    stream per section group (temporal/height/width); the head_dim/2
    frequency axis is partitioned by ``sections``."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # build per-frequency position stream: section i uses positions[:, i]
    sec_id = np.repeat(np.arange(len(sections)), sections)      # (half,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                          # (B,3,S)
        jnp.asarray(sec_id)[None, :, None] * jnp.ones(
            (positions.shape[0], half, positions.shape[-1]), jnp.int32),
        axis=1)                                                  # (B,half,S)
    ang = jnp.einsum("bfs,f->bsf", pos, freqs)                   # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
def init_attention(key, cfg, dtype):
    """QKV + output projections (+ optional per-head qk RMSNorm)."""
    d, qd, kvd = cfg.d_model, cfg.attn_q_dim, cfg.attn_kv_dim
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = init_dense(ks[0], (d, qd), ("embed", "q_proj"), dtype)
    p["wk"], a["wk"] = init_dense(ks[1], (d, kvd), ("embed", "kv_proj"), dtype)
    p["wv"], a["wv"] = init_dense(ks[2], (d, kvd), ("embed", "kv_proj"), dtype)
    p["wo"], a["wo"] = init_dense(ks[3], (qd, d), ("q_proj", "embed"), dtype)
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = init_rms_norm(cfg.head_dim, dtype, "head_dim")
        p["k_norm"], a["k_norm"] = init_rms_norm(cfg.head_dim, dtype, "head_dim")
    return p, a


def make_mask(sq: int, skv: int, kind: str, window: int = 0,
              offset: int = 0):
    """(sq, skv) boolean mask; True = attend.  ``offset`` shifts query
    positions (prefill continuation)."""
    if kind == "full":
        return jnp.ones((sq, skv), bool)
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    m = kj <= qi
    if kind == "local":
        m &= kj > qi - window
    return m


def _sdpa(q, k, v, mask, compute_dtype):
    """q (B,Sq,H,D), k/v (B,Skv,KV,D) GQA; softmax in f32."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    groups = h // kv
    qg = q.reshape(b, sq, kv, groups, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (dh ** -0.5)
    scores = jnp.where(mask, scores, big_neg(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, dh)


# Query-chunk size for blockwise (flash-style) attention.  Scores are only
# ever materialized as (B, H, CHUNK, Skv) — the whole (Sq, Skv) matrix
# never exists, which is what keeps long-sequence training inside HBM.
ATTN_CHUNK = 512


def _sdpa_blockwise(q, k, v, mask_kind: str, window: int, compute_dtype,
                    chunk: int = ATTN_CHUNK):
    """Exact chunked attention (python loop over q chunks; each chunk does
    a full softmax over Skv).  Unrolled rather than scanned so the
    roofline's cost analysis prices every chunk (DESIGN.md §6); chunks are
    chained with an optimization barrier so XLA cannot inflate peak memory
    by batching the chunk score buffers."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    kv = k.shape[2]
    groups = h // kv
    nq = sq // chunk
    outs = []
    carry = jnp.zeros((), q.dtype)
    for i in range(nq):
        qc = q[:, i * chunk:(i + 1) * chunk]
        qc = qc + carry  # sequencing dependency (numerically zero)
        qg = qc.reshape(b, chunk, kv, groups, dh)
        # causal KV slicing: chunk i only sees keys < (i+1)*chunk, and for
        # local attention nothing older than the window — static slices,
        # so masked-out blocks cost neither FLOPs nor bytes (§Perf)
        hi = (i + 1) * chunk
        lo = 0
        if mask_kind == "local" and window:
            lo = max(0, ((i * chunk - window + 1) // chunk) * chunk)
        ks, vs = k[:, lo:hi], v[:, lo:hi]
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, ks,
                            preferred_element_type=jnp.float32)
        scores = scores * (dh ** -0.5)
        qi = i * chunk + jnp.arange(chunk)[:, None]
        kj = lo + jnp.arange(hi - lo)[None, :]
        m = kj <= qi
        if mask_kind == "local":
            m &= kj > qi - window
        scores = jnp.where(m[None, None, None], scores, big_neg(jnp.float32))
        probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", probs, vs)
        o = o.reshape(b, chunk, h, dh)
        o, carry = jax.lax.optimization_barrier(
            (o, jnp.zeros((), q.dtype)))
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def attention(p: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray,
              mask_kind: str = "causal", xattn_kv: Optional[jnp.ndarray] = None,
              use_rope: bool = True, seq_shard: bool = False):
    """Self- or cross-attention over a full sequence (training / prefill).

    Returns (out, kv) where kv = (k, v) for cache construction."""
    b, s, d = x.shape
    cd = x.dtype
    q = (x @ p["wq"].astype(cd)).reshape(b, s, cfg.num_heads, cfg.head_dim)
    src = xattn_kv if xattn_kv is not None else x
    skv = src.shape[1]
    k = (src @ p["wk"].astype(cd)).reshape(b, skv, cfg.num_kv_heads,
                                           cfg.head_dim)
    v = (src @ p["wv"].astype(cd)).reshape(b, skv, cfg.num_kv_heads,
                                           cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and xattn_kv is None:
        if cfg.mrope and positions.ndim == 3:
            q = mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            pos2 = positions if positions.ndim == 2 else positions[:, 0]
            q = rope(q, pos2, cfg.rope_theta)
            k = rope(k, pos2, cfg.rope_theta)
    mk = "full" if xattn_kv is not None else mask_kind
    if (cfg.attn_impl == "blockwise" and mk in ("causal", "local")
            and s == skv and s % ATTN_CHUNK == 0 and s > ATTN_CHUNK):
        if seq_shard:
            # under a sequence-sharded residual stream, re-gather q/k/v
            # ONCE here (the Megatron-SP block boundary) rather than per
            # q-chunk slice (§Perf, cell B4: neutral, kept for intent);
            # without seq-sharding q/k/v stay head-sharded — constraining
            # them here would force replication, so this is gated
            from repro.sharding.policy import constrain
            q = constrain(q, ("pod", "data"), None, None, None)
            k = constrain(k, ("pod", "data"), None, None, None)
            v = constrain(v, ("pod", "data"), None, None, None)
        out = _sdpa_blockwise(q, k, v, mk, cfg.window, cd)
    else:
        mask = make_mask(s, skv, mk, cfg.window)[None, None, None]
        out = _sdpa(q, k, v, mask, cd)
    out = out.reshape(b, s, cfg.attn_q_dim) @ p["wo"].astype(cd)
    return out, (k, v)


def decode_attention(p: Params, cfg, x: jnp.ndarray, cache_k, cache_v,
                     cur_index, window: int = 0):
    """One-token decode against a KV cache.

    x (B, 1, d); cache_k/v (B, S, KV, D).  ``cur_index`` is a scalar
    (lock-step decode, the dry-run path) or an (B,) int vector (continuous
    batching: every slot at its own position).  For windowed layers the
    cache is a circular buffer of ``window`` slots (RoPE is applied with
    absolute positions before the write, so slot order does not matter)."""
    b, _, d = x.shape
    cd = x.dtype
    smax = cache_k.shape[1]
    per_row = hasattr(cur_index, "ndim") and cur_index.ndim == 1
    q = (x @ p["wq"].astype(cd)).reshape(b, 1, cfg.num_heads, cfg.head_dim)
    k = (x @ p["wk"].astype(cd)).reshape(b, 1, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ p["wv"].astype(cd)).reshape(b, 1, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    pos = (cur_index[:, None].astype(jnp.int32) if per_row
           else jnp.full((b, 1), cur_index, jnp.int32))
    if cfg.mrope:
        pos3 = jnp.broadcast_to(pos[:, None, :], (b, 3, 1))
        q = mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

    circular = bool(window) and smax <= window
    wpos = pos[:, 0] % smax if circular else pos[:, 0]
    if per_row or circular:
        rows = jnp.arange(b)
        cache_k = cache_k.at[rows, wpos].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, wpos].set(v[:, 0].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, cur_index, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, cur_index, 0, 0))
    kj = jnp.arange(smax)[None, :]                      # (1, S)
    cur = pos[:, :1]                                    # (B, 1)
    if circular:
        # every written slot is within the window by construction
        valid = (kj <= cur) | (cur >= smax)
    else:
        valid = kj <= cur
        if window:
            valid &= kj > cur - window
    mask = valid[:, None, None, None, :]                # (B,1,1,1,S)
    out = _sdpa(q, cache_k.astype(cd), cache_v.astype(cd), mask, cd)
    out = out.reshape(b, 1, cfg.attn_q_dim) @ p["wo"].astype(cd)
    return out, cache_k, cache_v


# --------------------------------------------------------------------- #
# MLP (SwiGLU)
# --------------------------------------------------------------------- #
def init_mlp(key, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["w_gate"], a["w_gate"] = init_dense(ks[0], (d, d_ff), ("embed", "mlp"), dtype)
    p["w_up"], a["w_up"] = init_dense(ks[1], (d, d_ff), ("embed", "mlp"), dtype)
    p["w_down"], a["w_down"] = init_dense(ks[2], (d_ff, d), ("mlp", "embed"), dtype)
    return p, a


def mlp(p: Params, x: jnp.ndarray):
    cd = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(cd)) * (x @ p["w_up"].astype(cd))
    return h @ p["w_down"].astype(cd)
