"""Decoder-LM stack (plus whisper-style encoder-decoder) for every
assigned architecture.

Depth is organized as *superblocks*: the repeating block pattern (e.g.
RecurrentGemma's (rec, rec, attn)) is stacked ``n_rep`` times and applied
with one ``lax.scan`` — compile time stays flat in depth, HLO stays small,
and roofline accounting can price one superblock and multiply (DESIGN §6).
A partial tail stack covers depths not divisible by the pattern length.

Three entry modes share the same layer code:
  * ``forward``      — full-sequence logits (training);
  * ``prefill``      — full-sequence + caches (serving prefill);
  * ``decode_step``  — one token against caches (serving decode).

Caches are pytrees stacked over the same superblock layout, so the scan
carries activations while caches stream through as xs/ys.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import (attention, decode_attention, init_attention, init_mlp,
                     init_rms_norm, mlp, rms_norm)
from .moe import init_moe, moe_ffn
from .rglru import (init_rglru, init_rglru_state, rglru_decode_step,
                    rglru_forward)
from .ssm import (init_ssm, init_ssm_state, ssd_forward, ssm_decode_step)

__all__ = ["Model", "build_model"]

Params = Dict[str, Any]


# ------------------------------------------------------------------ #
# single layer
# ------------------------------------------------------------------ #
def _has_mlp(cfg, kind: str) -> bool:
    return cfg.d_ff > 0 and kind != "ssm"


def init_layer(key, cfg, kind: str, dtype, cross: bool = False):
    p: Params = {}
    a: Params = {}
    ks = jax.random.split(key, 8)
    p["ln1"], a["ln1"] = init_rms_norm(cfg.d_model, dtype)
    if kind == "attn":
        p["attn"], a["attn"] = init_attention(ks[0], cfg, dtype)
    elif kind == "rec":
        p["rec"], a["rec"] = init_rglru(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["ssm"], a["ssm"] = init_ssm(ks[0], cfg, dtype)
    if cross:
        p["ln_x"], a["ln_x"] = init_rms_norm(cfg.d_model, dtype)
        p["xattn"], a["xattn"] = init_attention(ks[1], cfg, dtype)
    if _has_mlp(cfg, kind):
        p["ln2"], a["ln2"] = init_rms_norm(cfg.d_model, dtype)
        if cfg.is_moe:
            p["moe"], a["moe"] = init_moe(ks[2], cfg, dtype)
        else:
            p["mlp"], a["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p, a


def apply_layer(p, cfg, kind: str, x, positions, mode: str,
                cache=None, cur_index=None, enc_out=None,
                mask_kind: Optional[str] = None, use_pallas: bool = False,
                seq_shard: bool = False):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        mk = mask_kind or ("local" if cfg.window else "causal")
        if mode == "decode":
            out, ck, cv = decode_attention(p["attn"], cfg, h, cache[0],
                                           cache[1], cur_index,
                                           window=cfg.window)
            new_cache = (ck, cv)
        else:
            out, (k, v) = attention(p["attn"], cfg, h, positions,
                                    mask_kind=mk, seq_shard=seq_shard)
            new_cache = (k, v)
    elif kind == "rec":
        if mode == "decode":
            out, new_cache = rglru_decode_step(p["rec"], cfg, h, cache)
        else:
            out, new_cache = rglru_forward(p["rec"], cfg, h,
                                           use_pallas=use_pallas)
    else:  # ssm
        if mode == "decode":
            out, new_cache = ssm_decode_step(p["ssm"], cfg, h, cache)
        else:
            out, new_cache = ssd_forward(p["ssm"], cfg, h,
                                         use_pallas=use_pallas)
    x = x + out

    if "xattn" in p:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        if mode == "decode":
            # enc_out here is the per-layer cross KV cache (k, v)
            from .layers import _sdpa, big_neg
            b = h.shape[0]
            cd = h.dtype
            q = (h @ p["xattn"]["wq"].astype(cd)).reshape(
                b, 1, cfg.num_heads, cfg.head_dim)
            k, v = enc_out
            mask = jnp.ones((1, 1, 1, 1, k.shape[1]), bool)
            o = _sdpa(q, k.astype(cd), v.astype(cd), mask, cd)
            out = o.reshape(b, 1, cfg.attn_q_dim) @ p["xattn"]["wo"].astype(cd)
        else:
            out, _ = attention(p["xattn"], cfg, h, positions,
                               xattn_kv=enc_out)
        x = x + out

    if _has_mlp(cfg, kind):
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, aux = moe_ffn(p["moe"], cfg, h, train=(mode == "train"))
        else:
            y = mlp(p["mlp"], h)
        x = x + y
    return x, new_cache, aux


def init_layer_cache(cfg, kind: str, batch: int, seq: int, dtype):
    if kind == "attn":
        shape = (batch, seq, cfg.num_kv_heads, cfg.head_dim)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if kind == "rec":
        return init_rglru_state(cfg, batch, dtype)
    return init_ssm_state(cfg, batch, dtype)


def cache_seq_len(cfg, kind: str, seq: int) -> int:
    """Attention caches for windowed layers only need ``window`` slots."""
    if kind == "attn" and cfg.window:
        return min(seq, cfg.window)
    return seq


# ------------------------------------------------------------------ #
# superblock stacks
# ------------------------------------------------------------------ #
@dataclass(frozen=True)
class StackSpec:
    pattern: Tuple[str, ...]   # block kinds within one superblock
    n_rep: int                 # scan length


def stack_layout(cfg) -> List[StackSpec]:
    kinds = cfg.layer_kinds()
    pat = cfg.block_pattern or (kinds[0],)
    plen = len(pat)
    n_full, rem = divmod(len(kinds), plen)
    out = []
    if n_full:
        out.append(StackSpec(tuple(pat), n_full))
    if rem:
        out.append(StackSpec(tuple(pat[:rem]), 1))
    return out


def init_stack(key, cfg, spec: StackSpec, dtype, cross: bool = False):
    """vmap layer init over the scan axis -> stacked leaves (n_rep, ...)."""
    def one(k):
        ps, axs = {}, {}
        kk = jax.random.split(k, len(spec.pattern))
        for i, kind in enumerate(spec.pattern):
            ps[f"b{i}"], axs[f"b{i}"] = init_layer(kk[i], cfg, kind, dtype,
                                                   cross)
        return ps, axs

    keys = jax.random.split(key, spec.n_rep)
    params = jax.vmap(lambda k: one(k)[0])(keys)
    _, axes = one(keys[0])
    # prepend the scan ("layers") axis to every leaf's logical axes
    axes = jax.tree.map(lambda t: ("layers",) + tuple(t), axes,
                        is_leaf=lambda t: isinstance(t, tuple))
    return params, axes


def init_stack_cache(cfg, spec: StackSpec, batch: int, seq: int, dtype):
    def one():
        return {f"b{i}": init_layer_cache(cfg, kind, batch,
                                          cache_seq_len(cfg, kind, seq),
                                          dtype)
                for i, kind in enumerate(spec.pattern)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (spec.n_rep,) + x.shape), one())


def apply_stack(params, cfg, spec: StackSpec, x, positions, mode: str,
                cache=None, cur_index=None, enc_out=None,
                mask_kind=None, use_pallas=False, remat: str = "none",
                unroll: bool = False, seq_shard: bool = False):
    """Scan the superblock over its repeat axis.

    mode="train":   xs = params,          ys = None
    mode="prefill": xs = params,          ys = fresh caches
    mode="decode":  xs = (params, cache), ys = updated caches
                    (cross-KV entries ``b{i}_x`` pass through unchanged)
    ``unroll=True`` replaces the scan with a python loop (used by the
    roofline's per-layer cost accounting — XLA's cost analysis counts a
    while body once regardless of trip count, so costed variants must be
    unrolled; DESIGN.md §6).
    Returns (x, new_cache_stacked_or_None, aux_total)."""

    def run_layers(x, aux, p_slice, c_slice):
        new_c = {}
        for i, kind in enumerate(spec.pattern):
            c_in = c_slice[f"b{i}"] if c_slice is not None else None
            eo = enc_out
            if c_slice is not None and f"b{i}_x" in c_slice:
                eo = c_slice[f"b{i}_x"]
                new_c[f"b{i}_x"] = eo
            x, c_out, a = apply_layer(p_slice[f"b{i}"], cfg, kind, x,
                                      positions, mode, cache=c_in,
                                      cur_index=cur_index, enc_out=eo,
                                      mask_kind=mask_kind,
                                      use_pallas=use_pallas,
                                      seq_shard=seq_shard)
            if seq_shard and mode != "decode":
                # sequence-parallel residual (Megatron-SP): norms/residual
                # live seq-sharded over "model"; XLA turns each block's
                # all-reduce pair into all-gather + reduce-scatter — half
                # the activation wire (§Perf)
                from repro.sharding.policy import constrain
                x = constrain(x, ("pod", "data"), "model", None)
            new_c[f"b{i}"] = c_out
            aux = aux + a
        return x, aux, new_c

    aux0 = jnp.zeros((), jnp.float32)
    if mode == "decode":
        def body(carry, xs):
            x, aux = carry
            p_slice, c_slice = xs
            x, aux, new_c = run_layers(x, aux, p_slice, c_slice)
            return (x, aux), new_c

        if unroll:
            aux, ys = aux0, []
            for r in range(spec.n_rep):
                sl = jax.tree.map(lambda t: t[r], (params, cache))
                (x, aux), nc = body((x, aux), sl)
                ys.append(nc)
            new_cache = jax.tree.map(lambda *t: jnp.stack(t), *ys)
            return x, new_cache, aux
        (x, aux), new_cache = jax.lax.scan(body, (x, aux0), (params, cache))
        return x, new_cache, aux

    def body(carry, p_slice):
        x, aux = carry
        x, aux, new_c = run_layers(x, aux, p_slice, None)
        return (x, aux), (new_c if mode == "prefill" else None)

    if remat != "none" and mode == "train":
        policy = (jax.checkpoint_policies.dots_saveable if remat == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    if unroll:
        aux, ys = aux0, []
        for r in range(spec.n_rep):
            sl = jax.tree.map(lambda t: t[r], params)
            (x, aux), nc = body((x, aux), sl)
            ys.append(nc)
        new_cache = (jax.tree.map(lambda *t: jnp.stack(t), *ys)
                     if mode == "prefill" else None)
        return x, new_cache, aux
    (x, aux), new_cache = jax.lax.scan(body, (x, aux0), params)
    return x, new_cache, aux


# ------------------------------------------------------------------ #
# full model
# ------------------------------------------------------------------ #
@dataclass
class Model:
    cfg: Any
    use_pallas: bool = False
    remat: str = "dots"
    unroll: bool = False       # unrolled layers (roofline cost variants)
    seq_shard: bool = False    # sequence-parallel residual stream

    # ---------------- init ------------------------------------------- #
    def init(self, key) -> Tuple[Params, Params]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 8)
        p: Params = {}
        a: Params = {}
        p["embed"], a["embed"] = jax.random.normal(
            ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32
        ).astype(dtype) * 0.02, ("vocab", "embed")
        for i, spec in enumerate(stack_layout(cfg)):
            p[f"stack{i}"], a[f"stack{i}"] = init_stack(
                ks[1 + i], cfg, spec, dtype, cross=cfg.is_encdec)
        p["final_norm"], a["final_norm"] = init_rms_norm(cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            from .layers import init_dense
            p["head"], a["head"] = init_dense(
                ks[5], (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                dtype)
        if cfg.is_encdec:
            enc_spec = StackSpec(("attn",), cfg.encoder_layers)
            p["enc_stack"], a["enc_stack"] = init_stack(ks[6], cfg, enc_spec,
                                                        dtype)
            p["enc_norm"], a["enc_norm"] = init_rms_norm(cfg.d_model, dtype)
        return p, a

    # ---------------- helpers ----------------------------------------- #
    def _embed(self, params, tokens):
        cd = jnp.dtype(self.cfg.compute_dtype)
        return params["embed"][tokens].astype(cd)

    def _logits(self, params, x):
        cfg = self.cfg
        head = (params["embed"].T if cfg.tie_embeddings else params["head"])
        return (x @ head.astype(x.dtype)).astype(jnp.float32)

    def _positions(self, tokens_shape, positions):
        b, s = tokens_shape
        if positions is not None:
            return positions
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if self.cfg.mrope:
            pos = jnp.broadcast_to(pos[:, None], (b, 3, s))
        return pos

    def encode(self, params, enc_embeds):
        """Whisper-style bidirectional encoder over frontend embeddings."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        x = enc_embeds.astype(cd)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        spec = StackSpec(("attn",), cfg.encoder_layers)
        x, _, _ = apply_stack(params["enc_stack"], cfg, spec, x, pos,
                              "train", mask_kind="full",
                              use_pallas=self.use_pallas, remat=self.remat,
                              unroll=self.unroll, seq_shard=self.seq_shard)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # ---------------- entry points ------------------------------------ #
    def forward(self, params, tokens=None, positions=None, embeds=None,
                enc_embeds=None, mode: str = "train"):
        """Full-sequence logits.  ``embeds`` overrides token embedding
        (VLM stub); ``enc_embeds`` feeds the encoder (whisper stub)."""
        cfg = self.cfg
        x = self._embed(params, tokens) if embeds is None else embeds.astype(
            jnp.dtype(cfg.compute_dtype))
        b, s, _ = x.shape
        pos = self._positions((b, s), positions)
        enc_out = None
        if cfg.is_encdec:
            assert enc_embeds is not None
            enc_out = self.encode(params, enc_embeds)
        aux_total = jnp.zeros((), jnp.float32)
        caches = []
        for i, spec in enumerate(stack_layout(cfg)):
            x, cache, aux = apply_stack(
                params[f"stack{i}"], cfg, spec, x, pos, mode,
                enc_out=enc_out, use_pallas=self.use_pallas,
                remat=self.remat, unroll=self.unroll,
                seq_shard=self.seq_shard)
            caches.append(cache)
            aux_total = aux_total + aux
        if mode == "prefill":
            # serving prefill needs only the last position's logits; the
            # full (B, S, V) projection is ~T*d*V wasted FLOPs (§Perf)
            x = x[:, -1:]
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x), aux_total, caches, enc_out

    def prefill(self, params, tokens=None, positions=None, embeds=None,
                enc_embeds=None, pad_to: Optional[int] = None):
        """Run the prompt; return (last-token logits, serving caches).

        For attention layers the prompt K/V are computed by the forward
        pass; they are written into fixed-size serving caches sized
        ``pad_to`` (default: prompt length)."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        logits, _, run_caches, enc_out = self.forward(
            params, tokens, positions, embeds, enc_embeds, mode="prefill")
        b = (tokens.shape[0] if tokens is not None else embeds.shape[0])
        s = (tokens.shape[1] if tokens is not None else embeds.shape[1])
        pad_to = pad_to or s

        serving = []
        for spec, cache in zip(stack_layout(cfg), run_caches):
            def fix(path_kind, c):
                if path_kind == "attn":
                    k, v = c            # (n_rep, B, S', KV, D) prompt kv
                    target = cache_seq_len(cfg, "attn", pad_to)

                    def grow(t):
                        src = t.shape[2]
                        if src > target:
                            # windowed circular buffer: keep the tail and
                            # roll so position p sits at slot p % target
                            tail = t[:, :, -target:]
                            r = src % target
                            return jnp.roll(tail, r, axis=2) if r else tail
                        if src == target:
                            return t
                        pad = jnp.zeros(t.shape[:2] + (target - src,)
                                        + t.shape[3:], t.dtype)
                        return jnp.concatenate([t, pad], axis=2)
                    return (grow(k), grow(v))
                return c
            fixed = {}
            for i, kind in enumerate(spec.pattern):
                fixed[f"b{i}"] = fix(kind, cache[f"b{i}"])
                if cfg.is_encdec and enc_out is not None:
                    # cross-attention KV, computed once from the encoder
                    fixed[f"b{i}_x"] = self._cross_kv(params, spec, i,
                                                      enc_out)
            serving.append(fixed)
        return logits[:, -1], serving

    def _cross_kv(self, params, spec, i, enc_out):
        cfg = self.cfg
        cd = enc_out.dtype
        # per-rep cross K/V: vmap projection over the stacked layer params
        stack_idx = 0  # encdec archs have a single uniform stack
        pstack = params[f"stack{stack_idx}"]
        wk = pstack[f"b{i}"]["xattn"]["wk"]          # (n_rep, d, kvd)
        wv = pstack[f"b{i}"]["xattn"]["wv"]
        b, s, _ = enc_out.shape
        k = jnp.einsum("bsd,rde->rbse", enc_out, wk.astype(cd)).reshape(
            wk.shape[0], b, s, cfg.num_kv_heads, cfg.head_dim)
        v = jnp.einsum("bsd,rde->rbse", enc_out, wv.astype(cd)).reshape(
            wv.shape[0], b, s, cfg.num_kv_heads, cfg.head_dim)
        return (k, v)

    def decode_step(self, params, token, caches, cur_index):
        """One decode step.  token (B,) int32; returns (logits, caches)."""
        cfg = self.cfg
        x = self._embed(params, token[:, None])
        b = token.shape[0]
        new_caches = []
        aux0 = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(stack_layout(cfg)):
            x, cache, _ = apply_stack(
                params[f"stack{i}"], cfg, spec, x, None, "decode",
                cache=caches[i], cur_index=cur_index,
                use_pallas=self.use_pallas, unroll=self.unroll)
            new_caches.append(cache)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x)[:, 0], new_caches


def build_model(cfg, use_pallas: bool = False, remat: str = "dots",
                unroll: bool = False, seq_shard: bool = False) -> Model:
    return Model(cfg=cfg, use_pallas=use_pallas, remat=remat,
                 unroll=unroll, seq_shard=seq_shard)
