"""Mixture-of-Experts FFN with *batched* sort-based capacity dispatch.

TPU-native dispatch (MaxText-style "dropping" implementation), with one
crucial distribution property: sorting/bucketing happens **per batch row**
("becd" layouts), so under batch-over-data sharding every dispatch gather/
scatter is shard-local — the SPMD partitioner never sees a global-index
gather from an expert-sharded buffer (which it would lower as a
replicate + full-buffer all-reduce: hundreds of GB of wire per layer; see
EXPERIMENTS.md §Perf).  Cross-shard traffic is exactly one all-reduce of
the combined (B, S, d) output over the expert axis.

FLOPs scale with active experts x capacity factor — the honest MoE
roofline.  Load-balancing auxiliary loss (Switch-style) is returned
alongside.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import init_dense

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, cfg, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["router"], a["router"] = init_dense(ks[0], (d, e), ("embed", "expert"),
                                          jnp.float32)
    p["w_gate"], a["w_gate"] = init_dense(
        ks[1], (e, d, f), ("expert", "embed", "mlp"), dtype)
    p["w_up"], a["w_up"] = init_dense(
        ks[2], (e, d, f), ("expert", "embed", "mlp"), dtype)
    p["w_down"], a["w_down"] = init_dense(
        ks[3], (e, f, d), ("expert", "mlp", "embed"), dtype)
    return p, a


def moe_ffn(p: Dict[str, Any], cfg, x: jnp.ndarray, train: bool = True
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    ``train`` picks the dispatch capacity factor: training tolerates drops
    (cf~1.25, the TPU norm); serving uses a looser cf (bounded
    overcompute) so tiny decode batches don't starve experts.  cf >= E/k
    is drop-free.  Long sequences are processed in ``moe_chunk`` slices so
    capacity buffers stay O(chunk) (32k-prefill would otherwise allocate
    s*k*cf slots per row)."""
    b, s, d = x.shape
    chunk = 8192
    if s > chunk and s % chunk == 0:
        ys, auxs = [], []
        for i in range(s // chunk):
            yc, ac = _moe_ffn(p, cfg, x[:, i * chunk:(i + 1) * chunk], train)
            ys.append(yc)
            auxs.append(ac)
        return jnp.concatenate(ys, axis=1), jnp.stack(auxs).mean()
    return _moe_ffn(p, cfg, x, train)


def _moe_ffn(p: Dict[str, Any], cfg, x: jnp.ndarray, train: bool
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    from repro.sharding.policy import constrain

    b, s, d = x.shape
    cd = x.dtype
    e, k = cfg.n_experts, cfg.top_k
    n = s * k                                    # assignments per row
    cf = cfg.capacity_factor if train else cfg.capacity_factor_eval
    cap = max(1, min(s, int(math.ceil(s * k / e * cf))))
    dp = ("pod", "data")

    logits = (x.astype(jnp.float32) @ p["router"])          # (B, S, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                    # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                            # (E,)
    ce = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32).mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # ---- permutation-only dispatch (no scatters, no while loops) ------- #
    # Scatters with data-dependent indices and searchsorted's while loop
    # partition terribly (the SPMD partitioner replicates batch and
    # all-gathers tens of GB per layer — §Perf log).  Everything below is
    # batched sort / take_along_axis / reduction, each of which stays
    # shard-local under batch-over-data sharding.
    flat_e = idx.reshape(b, n)                              # (B, n)
    order = jnp.argsort(flat_e, axis=1)                     # per-row sort
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # segment starts by counting (vectorized; no while loop)
    seg_start = jnp.sum(sorted_e[:, :, None]
                        < jnp.arange(e)[None, None, :],
                        axis=1).astype(jnp.int32)           # (B, E)
    rank = (jnp.arange(n, dtype=jnp.int32)[None, :]
            - jnp.take_along_axis(seg_start, sorted_e, axis=1))  # (B, n)
    keep = rank < cap
    token_of = order // k                                   # (B, n)

    xs = jnp.take_along_axis(x, token_of[..., None], axis=1)  # (B, n, d)
    xs = constrain(xs.astype(cd), dp, None, None)

    # slot (e, c) holds sorted-assignment seg_start[e] + c (when valid):
    # building the buffer is one batched gather of a permutation
    slot_src = (jnp.take_along_axis(
        seg_start, jnp.repeat(jnp.arange(e, dtype=jnp.int32)[None], b, 0),
        axis=1)[:, :, None]
        + jnp.arange(cap, dtype=jnp.int32)[None, None, :])  # (B, E, C)
    counts = (jnp.concatenate([seg_start[:, 1:],
                               jnp.full((b, 1), n, jnp.int32)], axis=1)
              - seg_start)                                  # (B, E)
    valid = jnp.arange(cap, dtype=jnp.int32)[None, None, :] < \
        counts[:, :, None]                                  # (B, E, C)
    buf = jnp.take_along_axis(
        xs, jnp.clip(slot_src.reshape(b, e * cap), 0, n - 1)[..., None],
        axis=1)
    buf = buf * valid.reshape(b, e * cap, 1).astype(cd)
    buf = constrain(buf.reshape(b, e, cap, d), dp, None, None, None)

    # ---- per-expert SwiGLU (dense einsums over capacity buffers) ------ #
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf,
                               p["w_gate"].astype(cd)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(cd))
    h = constrain(h, dp, "model", None, None)
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cd))
    out_buf = constrain(out_buf, dp, "model", None, None)

    # ---- combine: gather slots back per assignment, unsort, reduce k --- #
    out_flat = out_buf.reshape(b, e * cap, d)
    buf_pos = jnp.where(keep, sorted_e * cap + rank, 0)     # (B, n)
    gathered = jnp.take_along_axis(out_flat, buf_pos[..., None], axis=1)
    gathered = gathered * keep[..., None].astype(cd)
    gathered = constrain(gathered, dp, None, None)
    unsort = jnp.argsort(order, axis=1)                    # inverse perm
    vals = jnp.take_along_axis(gathered, unsort[..., None], axis=1)
    w_tok = jnp.take_along_axis(
        (jnp.take_along_axis(gates.reshape(b, n), order, axis=1)
         * keep).astype(cd), unsort, axis=1)                # (B, n)
    y = (vals * w_tok[..., None]).reshape(b, s, k, d).sum(axis=2)
    y = constrain(y, dp, None, None)
    return y, aux.astype(jnp.float32)
