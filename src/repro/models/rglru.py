"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block: x -> [W_in -> causal conv -> RG-LRU] * GeLU(W_gate x) -> W_out.
RG-LRU recurrence (arXiv:2402.19427):

    r_t = sigmoid(w_r * u_t + b_r)          (diagonal recurrence gate)
    i_t = sigmoid(w_i * u_t + b_i)          (diagonal input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill evaluates the linear recurrence with an associative scan
(O(S log S) depth, fully parallel — the TPU-friendly form; the Pallas
kernel in ``repro.kernels.rglru_scan`` implements the blocked variant).
Decode is the O(1) step on an ``(B, W)`` state.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_dense
from .ssm import _causal_conv

__all__ = ["init_rglru", "rglru_forward", "rglru_decode_step",
           "init_rglru_state", "rglru_scan_ref"]

_C = 8.0


def init_rglru(key, cfg, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["w_in"], a["w_in"] = init_dense(ks[0], (d, w), ("embed", "lru"), dtype)
    p["w_gate"], a["w_gate"] = init_dense(ks[1], (d, w), ("embed", "lru"), dtype)
    p["w_out"], a["w_out"] = init_dense(ks[2], (w, d), ("lru", "embed"), dtype)
    p["conv_w"], a["conv_w"] = init_dense(ks[3], (cfg.conv_width, w),
                                          (None, "lru"), dtype,
                                          scale=cfg.conv_width ** -0.5)
    p["conv_b"] = jnp.zeros((w,), dtype); a["conv_b"] = ("lru",)
    # Lambda init so that a ~ U[0.9, 0.999] at r = 0.5 (paper's stable range)
    p["lam"] = jnp.linspace(0.5, 4.0, w).astype(jnp.float32)
    a["lam"] = ("lru",)
    p["w_r"] = jnp.ones((w,), jnp.float32); a["w_r"] = ("lru",)
    p["b_r"] = jnp.zeros((w,), jnp.float32); a["b_r"] = ("lru",)
    p["w_i"] = jnp.ones((w,), jnp.float32); a["w_i"] = ("lru",)
    p["b_i"] = jnp.zeros((w,), jnp.float32); a["b_i"] = ("lru",)
    return p, a


def rglru_scan_ref(a: jnp.ndarray, bx: jnp.ndarray,
                   h0: Optional[jnp.ndarray] = None):
    """Linear recurrence h_t = a_t h_{t-1} + bx_t via associative scan.
    a, bx: (B, S, W); h0 optional (B, W).  Returns (h (B,S,W), h_last)."""
    if h0 is not None:
        a = jnp.concatenate([jnp.ones_like(h0)[:, None], a], axis=1)
        bx = jnp.concatenate([h0[:, None], bx], axis=1)

    def combine(x, y):
        ax, bxx = x
        ay, byy = y
        return ax * ay, ay * bxx + byy

    ha, hb = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = hb
    if h0 is not None:
        h = h[:, 1:]
    return h, h[:, -1]


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["w_r"] + p["b_r"])
    i = jax.nn.sigmoid(uf * p["w_i"] + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    bx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, bx


def rglru_forward(p, cfg, x, use_pallas: bool = False,
                  state: Optional[Tuple] = None):
    """Full-sequence block.  x (B,S,d) -> (y (B,S,d), (conv_state, h_last))."""
    cd = x.dtype
    u = x @ p["w_in"].astype(cd)
    conv_state = state[0] if state is not None else None
    u, conv_state = _causal_conv(u, p["conv_w"].astype(cd),
                                 p["conv_b"].astype(cd), conv_state)
    a, bx = _gates(p, u)
    h0 = state[1] if state is not None else None
    if use_pallas:
        from repro.kernels.rglru_scan.ops import rglru_scan
        h, h_last = rglru_scan(a, bx, h0)
    else:
        h, h_last = rglru_scan_ref(a, bx, h0)
    y = h.astype(cd) * jax.nn.gelu(x @ p["w_gate"].astype(cd))
    return y @ p["w_out"].astype(cd), (conv_state, h_last)


def init_rglru_state(cfg, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    conv = jnp.zeros((batch, cfg.conv_width - 1, w), dtype)
    h = jnp.zeros((batch, w), jnp.float32)
    return conv, h


def rglru_decode_step(p, cfg, x, state):
    """One-token step.  x (B,1,d)."""
    conv_state, h = state
    cd = x.dtype
    u = x @ p["w_in"].astype(cd)
    u, conv_state = _causal_conv(u, p["conv_w"].astype(cd),
                                 p["conv_b"].astype(cd), conv_state)
    a, bx = _gates(p, u[:, 0])
    h = a * h + bx
    y = h[:, None].astype(cd) * jax.nn.gelu(x @ p["w_gate"].astype(cd))
    return y @ p["w_out"].astype(cd), (conv_state, h)
