"""Sharded checkpointing with atomic commit and cross-topology restore.

Layout:  <dir>/step_<n>/
            manifest.json     — pytree structure, leaf shapes/dtypes, meta
            shard_<k>.npz     — flat leaves, split round-robin by size

Properties a 1000-node fleet needs:
  * atomic    — written to ``.tmp-…`` then os.replace()'d; a crashed save
    never corrupts the latest checkpoint;
  * resumable — ``latest_step`` scans committed steps only;
  * reshard   — restore is by *leaf path*, independent of mesh/topology;
    the caller re-applies whatever sharding the new mesh wants;
  * self-describing — the manifest carries user metadata (data step,
    gossip round, pod id) for exact pipeline resume;
  * retention — ``keep`` bounds disk usage.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "available_steps"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(directory: str, step: int, tree, *, meta: Optional[Dict] = None,
         shards: int = 4, keep: int = 3) -> str:
    """Write a checkpoint; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = tempfile.mkdtemp(prefix=f".tmp-step_{step}-", dir=directory)
    try:
        leaves = _flatten_with_paths(tree)
        arrays = {}
        manifest = {"step": step, "meta": meta or {}, "leaves": [],
                    "format": 1, "shards": shards}
        # round-robin largest-first for balanced shard files
        order = sorted(range(len(leaves)),
                       key=lambda i: -np.asarray(leaves[i][1]).nbytes)
        shard_of = {}
        sizes = [0] * shards
        for i in order:
            k = int(np.argmin(sizes))
            shard_of[i] = k
            sizes[k] += np.asarray(leaves[i][1]).nbytes
        per_shard: List[Dict[str, np.ndarray]] = [{} for _ in range(shards)]
        for i, (path, leaf) in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            manifest["leaves"].append(
                {"path": path, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "shard": shard_of[i]})
            per_shard[shard_of[i]][f"leaf_{i}"] = arr
        for k in range(shards):
            np.savez(os.path.join(tmp, f"shard_{k}.npz"), **per_shard[k])
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                      # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int) -> None:
    steps = available_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def available_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like=None,
            shardings=None) -> Tuple[Any, Dict]:
    """Load a checkpoint.

    ``like`` — optional pytree template; structure and leaf shapes are
    validated against the manifest.  ``shardings`` — optional pytree of
    shardings (same structure) applied via device_put — this is the
    cross-topology reshard path.  Returns (tree, meta)."""
    base = os.path.join(directory, f"step_{step}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    shard_files = {}
    flat: List[np.ndarray] = []
    for i, ent in enumerate(manifest["leaves"]):
        k = ent["shard"]
        if k not in shard_files:
            shard_files[k] = np.load(os.path.join(base, f"shard_{k}.npz"))
        arr = shard_files[k][f"leaf_{i}"]
        assert list(arr.shape) == ent["shape"], (ent["path"], arr.shape)
        flat.append(arr)

    if like is None:
        # reconstruct as {path: array}
        return ({ent["path"]: a for ent, a in
                 zip(manifest["leaves"], flat)}, manifest["meta"])

    treedef = jax.tree_util.tree_structure(like)
    like_leaves = jax.tree_util.tree_leaves(like)
    assert len(like_leaves) == len(flat), \
        f"leaf count mismatch: ckpt {len(flat)} vs template {len(like_leaves)}"
    for tmpl, arr, ent in zip(like_leaves, flat, manifest["leaves"]):
        assert tuple(tmpl.shape) == tuple(arr.shape), \
            (ent["path"], tmpl.shape, arr.shape)
    tree = jax.tree_util.tree_unflatten(treedef, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree,
                            shardings)
    return tree, manifest["meta"]
