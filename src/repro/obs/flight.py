"""Sampled per-message provenance: the flight recorder (S10).

A deterministic hash sampler selects a subset of application broadcasts
whose full lifecycle is recorded host-side with O(sample) extra state:

    submit -> admit -> activate (broadcast) -> per-receiver delivery
           -> blocked-at rounds -> retire

The engines never branch on the recorder inside traced code: every hook
fires from the host-side orchestration layer (activation bookkeeping,
retirement sweeps), and the only device work it adds is the sampled
retiring-column gather — the same ``jnp.take`` pattern the latency
histogram already uses — so telemetry-off segment bodies stay
byte-identical (DESIGN §2.10/§2.11).

Determinism contract: the sampler keys on ``(seed, origin, key_round)``
where ``key_round`` is the broadcast round in batch mode and the submit
round in live mode (stable across withdraw/requeue), so the sampled set
is a pure function of the scenario, never of backend, shard count, or
wall clock.  Both streaming engines share one ``ColumnWindow``, so
records complete in identical order with identical payloads — the
cross-backend byte-identity the tests pin.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

__all__ = [
    "SAMPLERS", "FlightSampler", "FlightRecord", "FlightRecorder",
    "provenance_trace_events", "sample_hash", "sample_all",
]

# --------------------------------------------------------------------- #
# Deterministic samplers
# --------------------------------------------------------------------- #
_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 (wrapping mod 2^64)."""
    z = (x + _GAMMA).astype(np.uint64)
    z = (z ^ (z >> np.uint64(30))) * _M1
    z = (z ^ (z >> np.uint64(27))) * _M2
    return z ^ (z >> np.uint64(31))


def sample_hash(seed: int, rate: int, origins: np.ndarray,
                rounds: np.ndarray) -> np.ndarray:
    """1-in-``rate`` deterministic selection keyed on (seed, origin, round).

    Two mixing stages so origin and round land in independent lanes; the
    result depends only on the key tuple — not on call order, backend,
    or batch boundaries.
    """
    o = np.asarray(origins, np.uint64)
    r = np.asarray(rounds, np.uint64)
    h = _mix64(_mix64(np.uint64(seed) + o * _GAMMA) + r)
    return (h % np.uint64(max(1, int(rate)))) == 0


def sample_all(seed: int, rate: int, origins: np.ndarray,
               rounds: np.ndarray) -> np.ndarray:
    """Record every application broadcast (tests / tiny runs)."""
    return np.ones(np.asarray(origins).shape, bool)


@dataclass(frozen=True)
class FlightSampler:
    """A named deterministic sampling policy (``--list`` discoverable)."""
    key: str
    sample: Callable[[int, int, np.ndarray, np.ndarray], np.ndarray]
    description: str


SAMPLERS: Dict[str, FlightSampler] = {
    "hash": FlightSampler(
        "hash", sample_hash,
        "1-in-rate splitmix64 hash of (seed, origin, round): "
        "deterministic across backends and shard counts"),
    "all": FlightSampler(
        "all", sample_all,
        "record every application broadcast (rate ignored; "
        "tests and small runs)"),
}


# --------------------------------------------------------------------- #
# Per-message lifecycle records
# --------------------------------------------------------------------- #
@dataclass
class FlightRecord:
    """Full lifecycle of one sampled application broadcast.

    Rounds are simulation rounds; ``-1`` marks "not applicable" (batch
    runs have no submit/admit stage) or "never delivered" in ``deliv``.
    """
    id: int                 # window buffer id (batch: broadcast index)
    origin: int
    bcast_round: int        # round the broadcast enters the network
    submit_round: int = -1  # live: arrival at the front door
    admit_round: int = -1   # live: tick that admitted it into the window
    activate_round: int = -1
    retire_round: int = -1
    expired: bool = False   # horizon-expired rather than fully delivered
    blocked_at: List[int] = field(default_factory=list)
    deliv: Optional[np.ndarray] = None  # (n,) per-receiver delivery round

    def to_dict(self) -> dict:
        return dict(
            id=int(self.id), origin=int(self.origin),
            bcast_round=int(self.bcast_round),
            submit_round=int(self.submit_round),
            admit_round=int(self.admit_round),
            activate_round=int(self.activate_round),
            retire_round=int(self.retire_round),
            expired=bool(self.expired),
            blocked_at=[int(t) for t in self.blocked_at],
            deliv=[int(v) for v in self.deliv]
            if self.deliv is not None else [])


class FlightRecorder:
    """Host-side provenance buffer the engine hooks feed.

    ``open`` maps live window buffer ids to in-flight records;
    ``completed`` accumulates retired records in retirement order (a
    deterministic order: both streaming engines drive one shared
    ``ColumnWindow``).  Withdrawn (backpressure-requeued) columns drop
    their open record — the re-admission recreates it — so a completed
    record always describes the *final* activation.
    """

    def __init__(self, rate: int = 64, seed: int = 0,
                 sampler: str = "hash", auditor=None, live: bool = False):
        if sampler not in SAMPLERS:
            raise KeyError(
                f"unknown sampler {sampler!r}; "
                f"expected one of {sorted(SAMPLERS)}")
        self.rate = max(1, int(rate))
        self.seed = int(seed)
        self.sampler = sampler
        self._fn = SAMPLERS[sampler].sample
        self.auditor = auditor
        self.live = bool(live)
        self.open: Dict[int, FlightRecord] = {}
        self.completed: List[FlightRecord] = []

    # -- sampling ----------------------------------------------------- #
    def want(self, origins: np.ndarray, key_rounds: np.ndarray) -> np.ndarray:
        return self._fn(self.seed, self.rate, origins, key_rounds)

    @property
    def open_count(self) -> int:
        return len(self.open)

    @property
    def sampled(self) -> int:
        return len(self.open) + len(self.completed)

    def sampled_mask(self, ids: np.ndarray) -> np.ndarray:
        """Which of these retiring buffer ids carry an open record."""
        op = self.open
        return np.fromiter((int(i) in op for i in ids), bool, len(ids))

    # -- lifecycle hooks (host side only) ----------------------------- #
    def on_admit(self, ids, origins, submit_rounds, bcast_rounds,
                 admit_round: int) -> None:
        """Live front door: sample on (origin, submit_round)."""
        m = self.want(np.asarray(origins), np.asarray(submit_rounds))
        for j in np.nonzero(m)[0]:
            i = int(ids[j])
            self.open[i] = FlightRecord(
                id=i, origin=int(origins[j]),
                bcast_round=int(bcast_rounds[j]),
                submit_round=int(submit_rounds[j]),
                admit_round=int(admit_round))

    def on_withdraw(self, ids) -> None:
        """Backpressure un-admitted these ids; drop their open records
        so the eventual re-admission records the final placement."""
        for i in ids:
            self.open.pop(int(i), None)

    def on_activate(self, ids, origins, rounds) -> None:
        """Broadcast columns [b0, b1) just went live in the window."""
        if self.live:
            for j, i in enumerate(ids):
                rec = self.open.get(int(i))
                if rec is not None:
                    rec.activate_round = int(rounds[j])
                    rec.bcast_round = int(rounds[j])
            return
        # batch: sample on (origin, broadcast round) at activation
        o = np.asarray(origins)
        r = np.asarray(rounds)
        m = self.want(o, r)
        for j in np.nonzero(m)[0]:
            i = int(ids[j])
            self.open[i] = FlightRecord(
                id=i, origin=int(o[j]), bcast_round=int(r[j]),
                activate_round=int(r[j]))

    def on_blocked(self, ids, t_now: int) -> None:
        """These live sampled columns were gate-blocked at round t_now."""
        for i in ids:
            rec = self.open.get(int(i))
            if rec is not None:
                rec.blocked_at.append(int(t_now))

    def on_retire(self, ids, deliv, t_now: int, by_expiry) -> None:
        """Retirement sweep: ``deliv`` is (n, len(ids)) per-receiver
        delivery rounds gathered from the intact delivered plane."""
        d = np.asarray(deliv)
        for j, i in enumerate(ids):
            rec = self.open.pop(int(i), None)
            if rec is None:       # defensive: unsampled id slipped in
                continue
            rec.retire_round = int(t_now)
            rec.expired = bool(by_expiry[j])
            rec.deliv = np.array(d[:, j], np.int64, copy=True)
            self.completed.append(rec)
            if self.auditor is not None:
                self.auditor.observe(rec)

    # -- export ------------------------------------------------------- #
    def export(self) -> List[dict]:
        return [rec.to_dict() for rec in self.completed]


# --------------------------------------------------------------------- #
# Perfetto export: one track per sampled message
# --------------------------------------------------------------------- #
def provenance_trace_events(records: List[dict], n_devices: int = 1,
                            pid: int = 2,
                            us_per_round: float = 1000.0) -> List[dict]:
    """Chrome trace events on a synthetic round-based timeline.

    Each sampled message gets its own named thread track carrying its
    lifecycle: a ``life`` span submit/broadcast -> retire, a ``queued``
    span for the live front-door wait, per-shard ``deliver`` spans
    covering [min, max] delivery round on that shard's rows, and
    ``blocked`` instants.  1 round = ``us_per_round`` microseconds.
    """
    ev: List[dict] = [dict(
        ph="M", pid=pid, tid=0, name="process_name",
        args=dict(name="provenance (sampled messages)"))]

    def us(r) -> float:
        return float(r) * us_per_round

    for tno, rec in enumerate(records):
        tid = tno + 1
        start = rec["submit_round"] if rec["submit_round"] >= 0 \
            else rec["bcast_round"]
        end = max(rec["retire_round"], start)
        ev.append(dict(ph="M", pid=pid, tid=tid, name="thread_name",
                       args=dict(name=f"msg {rec['id']} "
                                      f"@o{rec['origin']}")))
        ev.append(dict(
            ph="X", pid=pid, tid=tid, ts=us(start),
            dur=max(us(end - start), 1.0),
            name=("life (expired)" if rec["expired"] else "life"),
            args=dict(id=rec["id"], origin=rec["origin"],
                      bcast_round=rec["bcast_round"],
                      retire_round=rec["retire_round"])))
        if rec["submit_round"] >= 0:
            ev.append(dict(
                ph="X", pid=pid, tid=tid, ts=us(rec["submit_round"]),
                dur=max(us(rec["bcast_round"] - rec["submit_round"]), 1.0),
                name="queued",
                args=dict(admit_round=rec["admit_round"])))
        deliv = np.asarray(rec["deliv"], np.int64)
        n = len(deliv)
        d = max(1, int(n_devices))
        rows_per = -(-n // d) if n else 0      # ceil, matches pad_rows
        for s in range(d):
            part = deliv[s * rows_per:(s + 1) * rows_per]
            part = part[part >= 0]
            if not len(part):
                continue
            lo, hi = int(part.min()), int(part.max())
            ev.append(dict(
                ph="X", pid=pid, tid=tid, ts=us(lo),
                dur=max(us(hi - lo), 1.0),
                name=f"deliver shard{s}" if d > 1 else "deliver",
                args=dict(receivers=int(len(part)), first=lo, last=hi)))
        for t in rec["blocked_at"]:
            ev.append(dict(ph="i", pid=pid, tid=tid, ts=us(t), s="t",
                           name="blocked"))
    return ev
