"""repro.obs — the unified telemetry subsystem (DESIGN.md §2.10).

Three pillars, one package:

* **Latency histograms** (``hist.py``): the log-bucket contract shared
  by every backend's retirement reduction, plus the nearest-rank
  percentile read-out that turns psum'd bucket counts into the exact
  p50/p99/p99.9 that ``RunReport``/``LiveReport`` publish.
* **Trace spans** (``spans.py``): the zero-allocation ring recorder
  (``SpanRecorder`` / ``NULL_RECORDER``) and the per-run accumulator
  (``EngineObs``) the engines and the live loop share.
* **Export sinks** (``sinks.py``): schema-versioned JSONL metrics and
  Perfetto-loadable Chrome trace JSON, behind the ``SINKS`` registry
  that ``repro.api --list`` surfaces.

Plus the flight recorder (``flight.py``: sampled per-message
provenance), the online causality auditor (``audit.py``) and the live
ops plane (``ops.py``) — DESIGN.md §2.11 — alongside the shared
benchmark-report schema (``report.py``) and the protocol graph metrics
(``graphs.py``, formerly ``repro.core.metrics``).
"""

from .audit import (AUDIT_MODES, AuditMode, CausalAuditor,
                    CausalityViolationError, Violation)
from .flight import (SAMPLERS, FlightRecord, FlightRecorder,
                     FlightSampler, provenance_trace_events)
from .graphs import (full_graph, mean_shortest_path, overhead_per_message,
                     safe_graph, unsafe_link_stats)
from .hist import (NB, bucket_index_np, bucket_lower_bounds, hist_np,
                   merge_hists, percentiles_from_hist)
from .ops import (OPS_SCHEMA, OPS_SCHEMA_VERSION, OPS_SINKS, OpsPlane,
                  OpsSink, SloBurn, WatchDashboard, load_ops_jsonl)
from .report import (BENCH_SCHEMA_VERSION, load_bench_report,
                     write_bench_report)
from .sinks import (METRICS_SCHEMA, METRICS_VERSION, SINKS, MetricsSink,
                    load_metrics_jsonl, write_chrome_trace,
                    write_metrics_jsonl)
from .spans import NULL_RECORDER, EngineObs, SpanRecorder

__all__ = [
    "NB", "bucket_index_np", "bucket_lower_bounds", "hist_np",
    "merge_hists", "percentiles_from_hist",
    "SpanRecorder", "NULL_RECORDER", "EngineObs",
    "MetricsSink", "SINKS", "METRICS_SCHEMA", "METRICS_VERSION",
    "write_metrics_jsonl", "load_metrics_jsonl", "write_chrome_trace",
    "SAMPLERS", "FlightSampler", "FlightRecord", "FlightRecorder",
    "provenance_trace_events",
    "AUDIT_MODES", "AuditMode", "CausalAuditor",
    "CausalityViolationError", "Violation",
    "OPS_SCHEMA", "OPS_SCHEMA_VERSION", "OPS_SINKS", "OpsSink",
    "OpsPlane", "SloBurn", "WatchDashboard", "load_ops_jsonl",
    "BENCH_SCHEMA_VERSION", "write_bench_report", "load_bench_report",
    "safe_graph", "full_graph", "mean_shortest_path",
    "unsafe_link_stats", "overhead_per_message",
]
