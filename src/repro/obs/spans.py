"""Structured trace spans: a preallocated monotonic-clock ring recorder.

``SpanRecorder`` is the hot-path half of the telemetry subsystem: the
live loop and the segment drivers call ``begin``/``end`` around each
phase and ``instant``/``counter`` for point events.  Design constraints
(DESIGN.md §2.10 "overhead policy"):

* zero allocation on the hot path — all event storage is preallocated
  numpy arrays, names are interned once into an id table;
* bounded memory — the ring holds ``capacity`` events and counts (not
  stores) the overflow in ``dropped``;
* a no-op twin — ``NULL_RECORDER`` has the same surface with empty
  bodies, so instrumented code never branches on "is telemetry on".

Event kinds map straight onto the Chrome trace-event phases the sink
emits: span (``"X"`` complete event), instant (``"i"``), counter
(``"C"``).

``EngineObs`` is the per-run holder the engines share: the recorder,
the merged latency histogram, gauge series, and integer counters.  It
is deliberately dumb — engines own *when* to record; this owns *where*
it all accumulates.
"""

from __future__ import annotations

import time

import numpy as np

from .hist import NB

__all__ = ["SpanRecorder", "NULL_RECORDER", "EngineObs"]

_KIND_SPAN = 0
_KIND_INSTANT = 1
_KIND_COUNTER = 2

_MAX_DEPTH = 64


class SpanRecorder:
    """Fixed-capacity span/instant/counter recorder on monotonic ns."""

    enabled = True

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self.kind = np.zeros(self.capacity, np.int8)
        self.name_id = np.zeros(self.capacity, np.int32)
        self.t0_ns = np.zeros(self.capacity, np.int64)
        self.dur_ns = np.zeros(self.capacity, np.int64)
        self.value = np.zeros(self.capacity, np.float64)
        self.n = 0
        self.dropped = 0
        self._names: list = []
        self._name_ids: dict = {}
        # begin/end stack: (name_id, t0_ns) pairs, fixed depth
        self._stack_name = np.zeros(_MAX_DEPTH, np.int32)
        self._stack_t0 = np.zeros(_MAX_DEPTH, np.int64)
        self._depth = 0

    @property
    def depth(self) -> int:
        """Open-span count — 0 between ticks unless a span leaked."""
        return self._depth

    def name(self, label: str) -> int:
        """Intern a label; call once at setup, not per event."""
        nid = self._name_ids.get(label)
        if nid is None:
            nid = len(self._names)
            self._names.append(label)
            self._name_ids[label] = nid
        return nid

    def begin(self, name_id: int) -> None:
        d = self._depth
        if d < _MAX_DEPTH:
            self._stack_name[d] = name_id
            self._stack_t0[d] = time.monotonic_ns()
        self._depth = d + 1

    def end(self) -> None:
        d = self._depth - 1
        if d < 0:
            return
        self._depth = d
        if d >= _MAX_DEPTH:
            return
        i = self.n
        if i >= self.capacity:
            self.dropped += 1
            return
        t1 = time.monotonic_ns()
        self.kind[i] = _KIND_SPAN
        self.name_id[i] = self._stack_name[d]
        self.t0_ns[i] = self._stack_t0[d]
        self.dur_ns[i] = t1 - self._stack_t0[d]
        self.n = i + 1

    def instant(self, name_id: int, value: float = 0.0) -> None:
        i = self.n
        if i >= self.capacity:
            self.dropped += 1
            return
        self.kind[i] = _KIND_INSTANT
        self.name_id[i] = name_id
        self.t0_ns[i] = time.monotonic_ns()
        self.dur_ns[i] = 0
        self.value[i] = value
        self.n = i + 1

    def counter(self, name_id: int, value: float) -> None:
        i = self.n
        if i >= self.capacity:
            self.dropped += 1
            return
        self.kind[i] = _KIND_COUNTER
        self.name_id[i] = name_id
        self.t0_ns[i] = time.monotonic_ns()
        self.dur_ns[i] = 0
        self.value[i] = value
        self.n = i + 1

    def events(self) -> list:
        """Recorded events as dicts (export-time only, allocates)."""
        kinds = ("span", "instant", "counter")
        out = []
        for i in range(self.n):
            ev = dict(kind=kinds[self.kind[i]],
                      name=self._names[self.name_id[i]],
                      t0_ns=int(self.t0_ns[i]))
            if self.kind[i] == _KIND_SPAN:
                ev["dur_ns"] = int(self.dur_ns[i])
            else:
                ev["value"] = float(self.value[i])
            out.append(ev)
        return out


class _NullRecorder(SpanRecorder):
    """Same surface, empty bodies: instrumentation costs one attribute
    lookup and a no-op call when telemetry is off."""

    enabled = False

    def __init__(self):
        super().__init__(capacity=0)

    def name(self, label: str) -> int:
        return 0

    def begin(self, name_id: int) -> None:
        pass

    def end(self) -> None:
        pass

    def instant(self, name_id: int, value: float = 0.0) -> None:
        pass

    def counter(self, name_id: int, value: float) -> None:
        pass


NULL_RECORDER = _NullRecorder()


class EngineObs:
    """Per-run telemetry accumulator shared across engine layers.

    Attributes
    ----------
    histograms : bool
        Accumulate on-device delivery-latency histograms.
    spans : SpanRecorder
        Span/counter recorder (``NULL_RECORDER`` unless tracing).
    latency_hist : (NB,) int64
        Merged delivery-latency histogram over retired app columns.
    latency_base : optional (capacity,) int64
        Per-message latency reference round.  When set (live mode:
        submission round, so queueing delay counts), columns measure
        latency from ``base[msg_id]``; otherwise from column birth.
    gauges : dict[str, list]
        Per-segment gauge series (piggyback bytes, window occupancy).
    counters : dict[str, int]
        Monotonic event counts (stager uploads/skips, backpressure...).
    flight : optional FlightRecorder
        Sampled per-message provenance buffer (S10).  ``None`` unless
        the run asked for provenance; the engines read it via
        ``getattr`` so telemetry-off paths never touch it.
    """

    def __init__(self, histograms: bool = True, spans: bool = False,
                 span_capacity: int = 65536):
        self.histograms = bool(histograms)
        self.spans = (SpanRecorder(span_capacity) if spans
                      else NULL_RECORDER)
        self.latency_hist = np.zeros(NB, np.int64)
        self.latency_base = None
        self.gauges: dict = {}
        self.counters: dict = {}
        self.flight = None

    def add_hist(self, hist) -> None:
        if self.histograms:
            self.latency_hist += np.asarray(hist, np.int64)

    def gauge(self, name: str, value) -> None:
        self.gauges.setdefault(name, []).append(value)

    def count(self, name: str, delta: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + delta
