"""Live ops plane: per-tick gauges out of the serving loop (S10).

``OpsPlane.publish`` runs once per ``LiveLoop`` tick, assembles a flat
snapshot (queue depth, window occupancy, admission split, SLO burn rate
over a sliding window, auditor verdicts, provenance counters) and fans
it out to a streaming sink and/or the ``--watch`` terminal dashboard.

Two sink front-ends ship (``--list`` discoverable):

  prometheus   text-format snapshot, atomically rewritten every publish
               (point node_exporter's textfile collector or a file
               scraper at it)
  jsonl        append-only stream, schema header + one record per tick

The dashboard degrades to plain one-line records when the stream is not
a TTY (CI pins this), so ``--watch 2>log`` stays greppable.
"""

from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np

__all__ = [
    "OPS_SCHEMA", "OPS_SCHEMA_VERSION", "OPS_SINKS", "OpsSink",
    "OpsPlane", "SloBurn", "WatchDashboard",
    "write_prometheus_snapshot", "append_ops_jsonl",
]

OPS_SCHEMA = "repro.obs.ops"
OPS_SCHEMA_VERSION = 1


# --------------------------------------------------------------------- #
# Sinks
# --------------------------------------------------------------------- #
def write_prometheus_snapshot(path: str, snap: dict,
                              first: bool) -> None:
    """Rewrite ``path`` with the snapshot in Prometheus text format.

    Written to a sibling temp file and ``os.replace``d so scrapers
    never observe a torn snapshot.
    """
    lines = []
    for key in sorted(snap):
        val = snap[key]
        if val is None or isinstance(val, str):
            continue
        name = f"repro_{key}"
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {float(val):g}")
    tmp = f"{path}.tmp"
    with open(tmp, "w") as fh:
        fh.write("\n".join(lines) + "\n")
    os.replace(tmp, path)


def append_ops_jsonl(path: str, snap: dict, first: bool) -> None:
    """Append one snapshot record; the first publish truncates and
    writes the schema header line."""
    with open(path, "w" if first else "a") as fh:
        if first:
            fh.write(json.dumps(dict(
                kind="header", schema=OPS_SCHEMA,
                version=OPS_SCHEMA_VERSION)) + "\n")
        fh.write(json.dumps(dict(kind="tick", **snap)) + "\n")


def load_ops_jsonl(path: str) -> list:
    """Read back a jsonl ops stream (tests / offline analysis)."""
    ticks = []
    with open(path) as fh:
        header = json.loads(next(fh))
        if header.get("schema") != OPS_SCHEMA:
            raise ValueError(f"not an ops stream: {header!r}")
        for line in fh:
            rec = json.loads(line)
            if rec.pop("kind", None) == "tick":
                ticks.append(rec)
    return ticks


@dataclass(frozen=True)
class OpsSink:
    """A named streaming sink for per-tick ops snapshots."""
    key: str
    write: Callable[[str, dict, bool], None]
    description: str


OPS_SINKS: Dict[str, OpsSink] = {
    "prometheus": OpsSink(
        "prometheus", write_prometheus_snapshot,
        "Prometheus text-format gauge snapshot, atomically rewritten "
        "every publish (textfile-collector friendly)"),
    "jsonl": OpsSink(
        "jsonl", append_ops_jsonl,
        "append-only JSONL stream: schema header line + one snapshot "
        "record per published tick"),
}


# --------------------------------------------------------------------- #
# SLO burn rate over a sliding window
# --------------------------------------------------------------------- #
class SloBurn:
    """Fraction of recent deliveries over the latency SLO.

    Reads the engine's log-bucket latency histogram differentially: the
    per-tick delta of buckets whose *lower bound* exceeds ``slo`` is an
    under-count of over-SLO deliveries (sound: everything in such a
    bucket is over), summed across the last ``window`` ticks.
    """

    def __init__(self, slo: float, window: int = 64):
        from .hist import NB, bucket_lower_bounds
        lo = bucket_lower_bounds()
        self.thr = int(np.searchsorted(lo, float(slo), side="right"))
        self.window = max(1, int(window))
        self._prev = np.zeros(NB, np.int64)
        self._deliv: list = []
        self._over: list = []

    def update(self, hist: np.ndarray) -> float:
        h = np.asarray(hist, np.int64)
        delta = h - self._prev
        self._prev = h.copy()
        self._deliv.append(int(delta.sum()))
        self._over.append(int(delta[self.thr:].sum()))
        if len(self._deliv) > self.window:
            self._deliv.pop(0)
            self._over.pop(0)
        total = sum(self._deliv)
        return (sum(self._over) / total) if total else 0.0


# --------------------------------------------------------------------- #
# --watch terminal dashboard
# --------------------------------------------------------------------- #
class WatchDashboard:
    """In-place terminal panel; plain line-per-tick off a TTY."""

    _ROWS = (
        (("queue depth", "queue_depth"),
         ("window occupancy", "window_occupancy")),
        (("admitted (tick)", "admitted_tick"),
         ("admitted (total)", "admitted_total")),
        (("shed", "shed"), ("requeued", "requeued")),
        (("backpressure", "backpressure_events"),
         ("provenance open", "provenance_open")),
        (("audit pairs", "audit_pairs_checked"),
         ("audit violations", "audit_violations")),
    )

    def __init__(self, stream=None):
        self.stream = stream if stream is not None else sys.stderr
        self.tty = bool(getattr(self.stream, "isatty", lambda: False)())
        self._height = 0

    def render(self, snap: dict) -> None:
        burn = snap.get("slo_burn")
        burn_s = f"{burn:.1%}" if burn is not None else "n/a"
        if not self.tty:
            fields = ["queue_depth", "window_occupancy", "admitted_tick",
                      "shed", "requeued", "backpressure_events",
                      "audit_violations"]
            line = " ".join(f"{k}={snap.get(k, 0)}" for k in fields)
            print(f"ops tick={snap['tick']} t={snap['t']} {line} "
                  f"slo_burn={burn_s}", file=self.stream, flush=True)
            return
        lines = [f"repro live ops — tick {snap['tick']}  "
                 f"t={snap['t']}  slo burn {burn_s}"]
        for row in self._ROWS:
            cells = [f"{label:<18}{snap.get(key, 0):>10}"
                     for label, key in row]
            lines.append("  " + "    ".join(cells))
        out = self.stream
        if self._height:
            out.write(f"\x1b[{self._height}F\x1b[J")
        out.write("\n".join(lines) + "\n")
        out.flush()
        self._height = len(lines)


# --------------------------------------------------------------------- #
# The plane
# --------------------------------------------------------------------- #
class OpsPlane:
    """Per-tick gauge publisher wired into ``LiveLoop``."""

    def __init__(self, out: Optional[str] = None,
                 sink: str = "prometheus", every: int = 1,
                 slo_p99: Optional[float] = None, burn_window: int = 64,
                 watch=None):
        if out is not None and sink not in OPS_SINKS:
            raise KeyError(f"unknown ops sink {sink!r}; "
                           f"expected one of {sorted(OPS_SINKS)}")
        self.out = out
        self.sink = OPS_SINKS[sink] if out is not None else None
        self.every = max(1, int(every))
        self.slo_p99 = slo_p99
        self.burn_window = burn_window
        self._burn: Optional[SloBurn] = None
        if watch is True:
            watch = WatchDashboard()
        elif watch is not None and not isinstance(watch, WatchDashboard):
            watch = WatchDashboard(watch)
        self.watch: Optional[WatchDashboard] = watch
        self.ticks = 0
        self._first = True
        self._published = 0
        self.last: Optional[dict] = None

    def publish(self, loop, info: dict) -> None:
        self.ticks += 1
        obs = loop.obs
        snap = dict(
            tick=self.ticks, t=int(info["t"]),
            queue_depth=int(info["queue"]),
            window_occupancy=int(info["live"]),
            admitted_tick=int(info["admitted"]),
            admitted_total=int(info["admitted_total"]),
            shed=int(info["shed"]),
            requeued=int(loop.requeued),
            backpressure_events=int(loop.overflow_catches),
        )
        if obs is not None and obs.histograms:
            snap["delivered_total"] = int(obs.latency_hist.sum())
        if self.slo_p99 is not None and obs is not None \
                and obs.histograms:
            if self._burn is None:
                self._burn = SloBurn(self.slo_p99, self.burn_window)
            snap["slo_burn"] = round(
                self._burn.update(obs.latency_hist), 6)
        else:
            snap["slo_burn"] = None
        fl = getattr(obs, "flight", None) if obs is not None else None
        if fl is not None:
            snap["provenance_open"] = fl.open_count
            snap["provenance_completed"] = len(fl.completed)
            aud = fl.auditor
            if aud is not None:
                snap["audit_pairs_checked"] = aud.pairs_checked
                snap["audit_violations"] = len(aud.violations)
        self.last = snap
        if self.ticks % self.every == 0:
            self._emit(snap)

    def _emit(self, snap: dict) -> None:
        if self.sink is not None:
            self.sink.write(self.out, snap, self._first)
            self._first = False
        if self.watch is not None:
            self.watch.render(snap)
        self._published = self.ticks

    def close(self) -> None:
        """Flush the final snapshot if the cadence skipped it."""
        if self.last is not None and self._published != self.ticks:
            self._emit(self.last)
