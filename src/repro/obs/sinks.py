"""Telemetry export sinks: versioned JSONL metrics and Chrome trace JSON.

Two write-side formats, both schema-versioned:

* **JSONL metrics** (``write_metrics_jsonl``): first line is a header
  ``{"schema": "repro.obs.metrics", "version": 1, ...}``; every
  following line is one metric record with ``kind`` in
  ``{"summary", "hist", "gauge", "counter", "provenance"}``.
  Grep-able, append-able, and the round-trip loader validates the
  header before parsing.

* **Chrome trace-event JSON** (``write_chrome_trace``): the
  ``{"traceEvents": [...]}`` object format loadable in Perfetto /
  ``chrome://tracing``.  Spans become ``"X"`` complete events (ts/dur
  in microseconds, rebased to the earliest event), instants ``"i"``
  with thread scope, counters ``"C"``.

``SINKS`` maps the ``ObsSpec.sink`` key to a writer; it is wrapped by
the ``repro.api`` registry for ``--list`` discovery.
"""

from __future__ import annotations

import json

import numpy as np

from .hist import NB, bucket_lower_bounds

__all__ = ["METRICS_SCHEMA", "METRICS_VERSION", "MetricsSink", "SINKS",
           "load_metrics_jsonl", "write_chrome_trace",
           "write_metrics_chrome", "write_metrics_jsonl"]

METRICS_SCHEMA = "repro.obs.metrics"
METRICS_VERSION = 1


class MetricsSink:
    """A named metrics writer: ``write(path, doc)``."""

    def __init__(self, key: str, write, description: str):
        self.key = key
        self.write = write
        self.description = description


def _metric_lines(doc: dict):
    """Flatten a telemetry doc into schema'd JSONL records."""
    yield dict(schema=METRICS_SCHEMA, version=METRICS_VERSION,
               kind="header", run=doc.get("run", {}))
    for name, value in sorted(doc.get("summary", {}).items()):
        yield dict(kind="summary", name=name, value=value)
    hist = doc.get("latency_hist")
    if hist is not None:
        yield dict(kind="hist", name="delivery_latency_rounds",
                   buckets=NB,
                   lower_bounds=[int(b) for b in bucket_lower_bounds()],
                   counts=[int(c) for c in np.asarray(hist, np.int64)])
    for name, series in sorted(doc.get("gauges", {}).items()):
        yield dict(kind="gauge", name=name,
                   values=[float(v) for v in series])
    for name, value in sorted(doc.get("counters", {}).items()):
        yield dict(kind="counter", name=name, value=int(value))
    for rec in doc.get("provenance") or []:
        yield dict(kind="provenance", **rec)


def write_metrics_jsonl(path: str, doc: dict) -> None:
    with open(path, "w") as fh:
        for rec in _metric_lines(doc):
            fh.write(json.dumps(rec) + "\n")


def load_metrics_jsonl(path: str) -> dict:
    """Load + validate a metrics JSONL file back into a doc."""
    with open(path) as fh:
        lines = [json.loads(ln) for ln in fh if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty metrics file")
    head = lines[0]
    if head.get("schema") != METRICS_SCHEMA:
        raise ValueError(f"{path}: not a {METRICS_SCHEMA} file "
                         f"(schema={head.get('schema')!r})")
    if head.get("version") != METRICS_VERSION:
        raise ValueError(f"{path}: metrics version "
                         f"{head.get('version')!r} != {METRICS_VERSION}")
    doc: dict = dict(run=head.get("run", {}), summary={}, gauges={},
                     counters={}, latency_hist=None, provenance=[])
    for rec in lines[1:]:
        kind = rec.pop("kind", None)
        if kind == "summary":
            doc["summary"][rec["name"]] = rec["value"]
        elif kind == "hist":
            doc["latency_hist"] = np.asarray(rec["counts"], np.int64)
        elif kind == "gauge":
            doc["gauges"][rec["name"]] = rec["values"]
        elif kind == "counter":
            doc["counters"][rec["name"]] = int(rec["value"])
        elif kind == "provenance":
            doc["provenance"].append(rec)
        else:
            raise ValueError(f"{path}: unknown metric kind {kind!r}")
    return doc


# Span-name families -> named thread tracks, so traces read without the
# code open.  First component of the dotted span name picks the track.
_SPAN_TRACKS = {
    "tick": (1, "serving loop"),
    "backpressure": (1, "serving loop"),
    "segment": (2, "segment pipeline"),
    "stager": (3, "schedule stager"),
}
_DEFAULT_TRACK = (4, "engine misc")


def _span_track(name: str) -> tuple:
    return _SPAN_TRACKS.get(name.split(".", 1)[0], _DEFAULT_TRACK)


def write_chrome_trace(path: str, recorder, run_args: dict | None = None,
                       pid: int = 1,
                       extra_events: list | None = None) -> None:
    """Write the recorder's events as Perfetto-loadable Chrome trace JSON.

    Spans/instants land on named thread tracks by span-name family
    (``segment.*`` -> "segment pipeline", ``tick*`` -> "serving loop",
    ``stager.*`` -> "schedule stager").  ``extra_events`` (already
    trace-event dicts, e.g. provenance tracks from
    ``repro.obs.flight.provenance_trace_events``) are appended verbatim.
    """
    events = recorder.events()
    t_base = min((ev["t0_ns"] for ev in events), default=0)
    out = []
    if run_args:
        out.append(dict(name="process_name", ph="M", pid=pid, tid=0,
                        args=dict(name="repro.run")))
        out.append(dict(name="run_args", ph="M", pid=pid, tid=0,
                        args=run_args))
    tracks: dict = {}
    for ev in events:
        ts = (ev["t0_ns"] - t_base) / 1000.0
        if ev["kind"] == "span":
            tid, label = _span_track(ev["name"])
            tracks.setdefault(tid, label)
            out.append(dict(name=ev["name"], ph="X", cat="repro",
                            ts=ts, dur=ev["dur_ns"] / 1000.0,
                            pid=pid, tid=tid))
        elif ev["kind"] == "instant":
            tid, label = _span_track(ev["name"])
            tracks.setdefault(tid, label)
            out.append(dict(name=ev["name"], ph="i", cat="repro",
                            ts=ts, s="t", pid=pid, tid=tid,
                            args=dict(value=ev["value"])))
        else:
            out.append(dict(name=ev["name"], ph="C", cat="repro",
                            ts=ts, pid=pid,
                            args={ev["name"]: ev["value"]}))
    for tid, label in sorted(tracks.items()):
        out.append(dict(name="thread_name", ph="M", pid=pid, tid=tid,
                        args=dict(name=label)))
    if extra_events:
        out.extend(extra_events)
    with open(path, "w") as fh:
        json.dump(dict(traceEvents=out, displayTimeUnit="ms"), fh)


def write_metrics_chrome(path: str, doc: dict) -> None:
    """Metrics doc as Chrome trace counter tracks (per-segment gauges
    become "C" events over a segment-index timeline, 1 ms per segment).

    Counter tracks are prefixed with the run's engine (and device
    count) so series from different runs merged into one Perfetto
    session land on distinct tracks instead of colliding by bare name.
    """
    run = doc.get("run") or {}
    eng = str(run.get("engine") or "run")
    dev = run.get("devices")
    prefix = f"{eng}[d{int(dev)}]" if dev else eng
    out = [dict(name="process_name", ph="M", pid=1, tid=0,
                args=dict(name=f"repro.metrics {prefix}"))]
    for name, series in sorted(doc.get("gauges", {}).items()):
        track = f"{prefix}/{name}"
        for i, v in enumerate(series):
            out.append(dict(name=track, ph="C", cat="repro",
                            ts=i * 1000.0, pid=1,
                            args={track: float(v)}))
    for name, value in sorted(doc.get("counters", {}).items()):
        track = f"{prefix}/{name}"
        out.append(dict(name=track, ph="C", cat="repro", ts=0.0, pid=1,
                        args={track: float(value)}))
    with open(path, "w") as fh:
        json.dump(dict(traceEvents=out, displayTimeUnit="ms"), fh)


SINKS = {
    "jsonl": MetricsSink(
        "jsonl", write_metrics_jsonl,
        "schema-versioned JSONL metrics (header line + one record per "
        "summary/hist/gauge/counter)"),
    "chrome-trace": MetricsSink(
        "chrome-trace", write_metrics_chrome,
        "per-segment gauges/counters as Chrome-trace counter tracks "
        "(Perfetto-loadable; spans always export via --trace-out)"),
}
