"""Protocol metrics: safe-graph path lengths, unsafe links, overhead.

These back the Table 1 and Fig. 7 reproductions:
  * ``mean_shortest_path`` — BFS over the *safe-link* graph (PC-broadcast
    excludes links still in their buffering phase, R-broadcast uses all);
  * ``unsafe_link_stats`` — unsafe links / buffered messages per process;
  * ``overhead_per_message`` — control bytes per app message sent.

Moved verbatim from ``repro.core.metrics`` (now a deprecation shim)
when the telemetry subsystem consolidated every measurement surface
under ``repro.obs``.
"""

from __future__ import annotations

import statistics
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.events import Network

__all__ = [
    "safe_graph",
    "full_graph",
    "mean_shortest_path",
    "unsafe_link_stats",
    "overhead_per_message",
]


def safe_graph(net: Network) -> Dict[int, List[int]]:
    """Adjacency restricted to links the protocol will actually use (Q)."""
    g: Dict[int, List[int]] = {}
    for pid, proc in net.procs.items():
        if getattr(proc, "crashed", False):
            continue
        g[pid] = [q for q in getattr(proc, "Q", ()) if not
                  getattr(net.procs.get(q), "crashed", False)]
    return g


def full_graph(net: Network) -> Dict[int, List[int]]:
    """Adjacency over all alive links regardless of safety."""
    g: Dict[int, List[int]] = {}
    for pid, proc in net.procs.items():
        if getattr(proc, "crashed", False):
            continue
        g[pid] = [q for q in net.neighbors(pid) if not
                  getattr(net.procs.get(q), "crashed", False)]
    return g


def _bfs_depths(g: Dict[int, List[int]], src: int) -> Dict[int, int]:
    depth = {src: 0}
    dq = deque([src])
    while dq:
        u = dq.popleft()
        for v in g.get(u, ()):
            if v not in depth:
                depth[v] = depth[u] + 1
                dq.append(v)
    return depth


def mean_shortest_path(g: Dict[int, List[int]], sources: Sequence[int],
                       unreachable_penalty: Optional[float] = None) -> float:
    """Mean hops from ``sources`` to every reachable process.

    This is the paper's Fig. 7 (top) metric: the expected hop count of a
    broadcast before reaching everyone; x transmission delay = expected
    delivery latency."""
    total, count = 0.0, 0
    for s in sources:
        depth = _bfs_depths(g, s)
        for pid in g:
            if pid == s:
                continue
            d = depth.get(pid)
            if d is None:
                if unreachable_penalty is not None:
                    total += unreachable_penalty
                    count += 1
                continue
            total += d
            count += 1
    return total / count if count else float("nan")


def unsafe_link_stats(net: Network) -> Tuple[float, float, int]:
    """(mean unsafe links/process, mean buffered msgs/process, max buffer)."""
    unsafe, buffered, mx = [], [], 0
    for proc in net.procs.values():
        if getattr(proc, "crashed", False) or not hasattr(proc, "B"):
            continue
        sizes = [len(ent[1]) for ent in proc.B.values()]
        unsafe.append(len(proc.B))
        buffered.append(sum(sizes))
        if sizes:
            mx = max(mx, max(sizes))
    return (
        statistics.fmean(unsafe) if unsafe else 0.0,
        statistics.fmean(buffered) if buffered else 0.0,
        mx,
    )


def overhead_per_message(net: Network) -> float:
    """Mean causality-control bytes per message sent on FIFO links."""
    sent = net.stats.sent_messages
    return net.stats.control_bytes / sent if sent else 0.0
