"""Shared benchmark-report writer: one schema for every BENCH_*.json.

Before this module each benchmark rolled its own JSON shape and none
carried a version, so loaders (the CI floor gates, the README tables)
had to guess.  Every bench now writes through
:func:`write_bench_report`, which stamps ``schema_version`` and
``kind``, and reads back through :func:`load_bench_report`, which
validates both.  Legacy version-0 snapshots (no ``schema_version``
field) still load — the floor gates must keep working against old
artifacts — but anything claiming a *different* version is rejected
loudly.
"""

from __future__ import annotations

import json

__all__ = ["BENCH_SCHEMA_VERSION", "load_bench_report",
           "write_bench_report"]

BENCH_SCHEMA_VERSION = 1

# Known bench kinds; a typo'd kind is a schema bug, not a new format.
_KINDS = ("backend", "scale", "serve", "throughput", "obs_overhead")


def write_bench_report(path: str, kind: str, doc: dict) -> dict:
    """Stamp ``schema_version`` + ``kind`` onto ``doc`` and write it."""
    if kind not in _KINDS:
        raise ValueError(f"unknown bench kind {kind!r} (have {_KINDS})")
    out = dict(schema_version=BENCH_SCHEMA_VERSION, kind=kind)
    out.update({k: v for k, v in doc.items()
                if k not in ("schema_version", "kind")})
    with open(path, "w") as fh:
        json.dump(out, fh, indent=2)
        fh.write("\n")
    return out


def load_bench_report(path: str, kind: str | None = None) -> dict:
    """Load a bench snapshot, tolerating pre-schema (version-0) files."""
    with open(path) as fh:
        doc = json.load(fh)
    if not isinstance(doc, dict):
        raise ValueError(f"{path}: bench report is not a JSON object")
    version = doc.get("schema_version", 0)
    if version not in (0, BENCH_SCHEMA_VERSION):
        raise ValueError(f"{path}: unsupported bench schema_version "
                         f"{version!r} (supported: 0 legacy, "
                         f"{BENCH_SCHEMA_VERSION})")
    if kind is not None and version >= 1 and doc.get("kind") != kind:
        raise ValueError(f"{path}: bench kind {doc.get('kind')!r} != "
                         f"expected {kind!r}")
    return doc
