"""Log-bucketed delivery-latency histograms, identical on every backend.

The bucket function is the telemetry contract shared by the numpy
reference, the jax/shard reduction tail, and the Pallas retire kernel:
latencies 0..15 rounds land in their own exact bucket, larger ones in
power-of-two decades, so the p50 of a healthy run is *exact* and the
tail percentiles are never more than 2x coarse.  Everything here is
integer comparisons only — no logs, no float rounding — which is what
makes the device and host bucketings byte-identical.

Layout (``NB = 32`` buckets):

====  ==========================
 idx  latency range (rounds)
====  ==========================
0-15  exact: latency == idx
16+j  [2**(4+j), 2**(5+j)) for j in 0..14
  31  [2**19, inf)
====  ==========================

Percentiles are nearest-rank over the bucket lower bounds: the value
reported for quantile q is the lower bound of the first bucket whose
cumulative count reaches ``ceil(q/100 * total)``.  For latencies < 16
(every steady-state run in this repo) that is the *exact* nearest-rank
percentile of the sample set.
"""

from __future__ import annotations

import numpy as np

__all__ = ["NB", "bucket_index_np", "bucket_index_jnp",
           "bucket_lower_bounds", "hist_np", "merge_hists",
           "percentiles_from_hist"]

# Number of histogram buckets: 16 exact + 16 power-of-two decades.
NB = 32


def bucket_index_np(values):
    """Bucket index for each latency value (numpy reference).

    Negative values (invalid / never-delivered sentinels) bucket to 0;
    callers mask them out before accumulating.
    """
    v = np.asarray(values, np.int64)
    extra = np.zeros(v.shape, np.int64)
    for k in range(5, 20):
        extra += (v >= (1 << k)).astype(np.int64)
    return np.where(v < 16, np.clip(v, 0, 15),
                    np.minimum(16 + extra, NB - 1))


def bucket_index_jnp(values):
    """Bucket index on jax arrays — same integer comparisons as numpy."""
    import jax.numpy as jnp
    v = values.astype(jnp.int32)
    extra = jnp.zeros(v.shape, jnp.int32)
    for k in range(5, 20):
        extra = extra + (v >= (1 << k)).astype(jnp.int32)
    return jnp.where(v < 16, jnp.clip(v, 0, 15),
                     jnp.minimum(16 + extra, NB - 1))


def bucket_lower_bounds() -> np.ndarray:
    """Lower latency bound of each bucket (the percentile read-out)."""
    lo = np.arange(NB, dtype=np.int64)
    lo[16:] = 1 << (4 + np.arange(NB - 16))
    return lo


def hist_np(values) -> np.ndarray:
    """Bucket a latency sample set into an ``(NB,)`` int64 histogram."""
    v = np.asarray(values, np.int64).reshape(-1)
    v = v[v >= 0]
    return np.bincount(bucket_index_np(v), minlength=NB).astype(np.int64)


def merge_hists(hists) -> np.ndarray:
    """Sum per-segment/per-column histograms into one distribution."""
    out = np.zeros(NB, np.int64)
    for h in hists:
        out += np.asarray(h, np.int64)
    return out


def percentiles_from_hist(hist, qs) -> list:
    """Nearest-rank percentiles from a bucket histogram.

    Returns the bucket lower bound (as float) holding the rank
    ``ceil(q/100 * total)`` for each q; NaN when the histogram is empty.
    """
    h = np.asarray(hist, np.int64)
    total = int(h.sum())
    if total <= 0:
        return [float("nan")] * len(list(qs))
    cum = np.cumsum(h)
    lo = bucket_lower_bounds()
    out = []
    for q in qs:
        rank = max(1, int(np.ceil(q / 100.0 * total)))
        idx = int(np.searchsorted(cum, rank, side="left"))
        out.append(float(lo[min(idx, NB - 1)]))
    return out
