"""Online causality auditor: a protocol-level race detector (S10).

Consumes completed flight-recorder records *during* execution (every
retirement sweep: per segment in batch mode, per tick live) and checks
that no receiver delivered two causally ordered sampled messages out of
order.  Two happens-before edge families are checked (DESIGN §2.11):

  same-origin     a, b from one origin with a.bcast < b.bcast
                  (FIFO order implies causal order at the sender)
  deliv-before-bcast
                  a delivered at b's origin strictly before b was
                  broadcast (a potentially caused b)

Both edges are *sound* — they are genuine happens-before relations, so
any flagged inversion is a real causal-delivery violation, never a
false positive.  They are not complete: transitive chains through
unsampled messages are invisible by construction (O(sample) state), so
a clean audit is strong evidence, not proof.  The exact-engine
crossval remains the completeness check.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

__all__ = [
    "AUDIT_MODES", "AuditMode", "CausalAuditor",
    "CausalityViolationError", "Violation",
]


@dataclass(frozen=True)
class AuditMode:
    """A named auditing policy (``--list`` discoverable)."""
    key: str
    fail_fast: bool
    description: str


AUDIT_MODES: Dict[str, AuditMode] = {
    "off": AuditMode(
        "off", False, "no causality auditing (default)"),
    "log": AuditMode(
        "log", False, "check sampled happens-before pairs; record "
        "violations and keep running"),
    "fail": AuditMode(
        "fail", True, "check sampled happens-before pairs; raise "
        "CausalityViolationError on the first violation"),
}


@dataclass(frozen=True)
class Violation:
    """One receiver delivered a happens-before pair out of order."""
    a_id: int          # the earlier message (a -> b)
    b_id: int
    edge: str          # "same-origin" | "deliv-before-bcast"
    receiver: int
    a_deliv: int       # receiver's delivery rounds: a_deliv > b_deliv
    b_deliv: int

    def to_dict(self) -> dict:
        return dict(a_id=self.a_id, b_id=self.b_id, edge=self.edge,
                    receiver=self.receiver, a_deliv=self.a_deliv,
                    b_deliv=self.b_deliv)


class CausalityViolationError(RuntimeError):
    """Fail-fast audit tripped: carries the first ``Violation``."""

    def __init__(self, violation: Violation):
        self.violation = violation
        super().__init__(
            f"causal delivery violated ({violation.edge}): receiver "
            f"{violation.receiver} delivered msg {violation.a_id} at "
            f"round {violation.a_deliv} but its successor msg "
            f"{violation.b_id} already at round {violation.b_deliv}")


class CausalAuditor:
    """Incremental pairwise checker over completed flight records.

    ``observe`` is O(completed) per record — fine at sampling rates the
    flight recorder is built for; the ops plane surfaces
    ``pairs_checked`` so runaway quadratic cost is visible.
    """

    def __init__(self, mode: str = "log", max_violations: int = 1024):
        if mode not in AUDIT_MODES or mode == "off":
            raise KeyError(
                f"auditor mode must be one of "
                f"{sorted(k for k in AUDIT_MODES if k != 'off')}, "
                f"got {mode!r}")
        self.mode = mode
        self.fail_fast = AUDIT_MODES[mode].fail_fast
        self.max_violations = int(max_violations)
        self.records: List = []
        self._by_origin: Dict[int, List] = {}
        self.pairs_checked = 0
        self.violations: List[Violation] = []

    def observe(self, rec) -> None:
        """Audit one newly completed record against all earlier ones."""
        mine = self._by_origin.setdefault(rec.origin, [])
        for prev in mine:
            if prev.bcast_round == rec.bcast_round:
                continue    # one broadcast per (origin, round) invariant
            a, b = ((prev, rec) if prev.bcast_round < rec.bcast_round
                    else (rec, prev))
            self._check(a, b, "same-origin")
        for prev in self.records:
            if prev.origin == rec.origin:
                continue
            # prev delivered at rec's origin before rec was broadcast:
            # prev potentially caused rec (prev -> rec)
            da = int(prev.deliv[rec.origin])
            if 0 <= da < rec.bcast_round:
                self._check(prev, rec, "deliv-before-bcast")
            db = int(rec.deliv[prev.origin])
            if 0 <= db < prev.bcast_round:
                self._check(rec, prev, "deliv-before-bcast")
        mine.append(rec)
        self.records.append(rec)

    def _check(self, a, b, edge: str) -> None:
        """a -> b: no receiver that delivered both may order them
        b-first."""
        self.pairs_checked += 1
        da, db = a.deliv, b.deliv
        bad = np.nonzero((da >= 0) & (db >= 0) & (da > db))[0]
        for p in bad:
            v = Violation(int(a.id), int(b.id), edge, int(p),
                          int(da[p]), int(db[p]))
            if len(self.violations) < self.max_violations:
                self.violations.append(v)
            if self.fail_fast:
                raise CausalityViolationError(v)

    def export(self) -> List[dict]:
        return [v.to_dict() for v in self.violations]
