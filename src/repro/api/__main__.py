"""``python -m repro.api`` — run a RunSpec from JSON or flags.

Spec sources compose left to right: section defaults, then ``--spec``
JSON (a file path or an inline JSON object), then individual flag
overrides.  The report prints as JSON on stdout (``--csv`` switches to
the benchmarks' ``name,us_per_call,derived`` row format).

    python -m repro.api --protocol pc --engine vec --n 256 \
        --dynamics churn --messages 12 --oracle
    python -m repro.api --spec experiment.json
    python -m repro.api --list            # registry keys
"""

from __future__ import annotations

import argparse
import json
import sys

from . import (ADMISSION, ARRIVALS, AUDIT, BACKENDS, ENGINES, OPS_SINKS,
               PROTOCOLS, SAMPLERS, SCENARIOS, SINKS, TOPOLOGIES, TRAFFIC,
               RunSpec, SpecError, describe_entry, run)


def _spec_dict(src: str) -> dict:
    if src.strip().startswith("{"):
        return json.loads(src)
    with open(src) as fh:
        return json.load(fh)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--spec", default=None,
                    help="spec JSON: a file path or an inline object")
    ap.add_argument("--list", action="store_true",
                    help="print every registered protocol/engine/topology/"
                         "traffic/scenario key with its description and "
                         "exit (the discovery surface)")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved spec JSON and exit (no run)")
    ap.add_argument("--csv", action="store_true",
                    help="emit name,us_per_call,derived rows instead of "
                         "the JSON report")
    top = ap.add_argument_group("spec overrides")
    top.add_argument("--protocol", choices=sorted(PROTOCOLS.keys()))
    top.add_argument("--engine",
                     choices=["auto"] + sorted(ENGINES.keys()))
    top.add_argument("--backend",
                     choices=["auto"] + sorted(BACKENDS.keys()))
    top.add_argument("--n", type=int)
    top.add_argument("--seed", type=int)
    top.add_argument("--memory-budget-mb", type=int)
    topo = ap.add_argument_group("topology")
    topo.add_argument("--topology", choices=sorted(TOPOLOGIES.keys()))
    topo.add_argument("--k", type=int)
    topo.add_argument("--max-delay", type=int)
    topo.add_argument("--beta", type=float)
    tr = ap.add_argument_group("traffic")
    tr.add_argument("--traffic", choices=sorted(TRAFFIC.keys()))
    tr.add_argument("--messages", type=int)
    tr.add_argument("--rate", type=float)
    dyn = ap.add_argument_group("dynamics")
    dyn.add_argument("--dynamics", choices=sorted(SCENARIOS.keys()))
    dyn.add_argument("--n-adds", type=int)
    dyn.add_argument("--n-rms", type=int)
    dyn.add_argument("--n-crashes", type=int)
    win = ap.add_argument_group("window")
    win.add_argument("--window", type=int)
    win.add_argument("--seg-len", type=int)
    win.add_argument("--horizon", type=int)
    win.add_argument("--collect", choices=("auto", "full", "aggregate"))
    sh = ap.add_argument_group("shard")
    sh.add_argument("--devices", type=int,
                    help="device-mesh size for engine 'sharded' (default: "
                         "all visible; force host devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=D)")
    sh.add_argument("--scan", choices=("auto", "on", "off"),
                    help="segment stepping for engine 'sharded': one "
                         "lax.scan per segment (on, the auto default) "
                         "vs per-round host dispatch (off)")
    lv = ap.add_argument_group("live serving (mode='live')")
    lv.add_argument("--serve", action="store_true",
                    help="run as an open-loop service (mode='live'): an "
                         "arrival process feeds a bounded ingest queue, "
                         "an admission policy micro-batches it into the "
                         "streaming engine each segment; --rate/"
                         "--messages then describe the offered load")
    lv.add_argument("--arrivals", choices=sorted(ARRIVALS.keys()),
                    help="open-loop arrival process (live mode)")
    lv.add_argument("--admission", choices=sorted(ADMISSION.keys()),
                    help="admission policy against the window-occupancy "
                         "backpressure signal (live mode)")
    lv.add_argument("--queue-cap", type=int,
                    help="bounded ingest queue length; overflow is "
                         "tail-dropped into the shed count (live mode)")
    lv.add_argument("--admit-cap", type=int,
                    help="max admissions per simulated round "
                         "(live.per_round_cap; default auto from --rate)")
    lv.add_argument("--slo-p99", type=float,
                    help="p99 rounds-to-delivery SLO target; the report's "
                         "serve_slo_ok says whether it was met")
    met = ap.add_argument_group("metrics")
    met.add_argument("--oracle", action="store_true", default=None,
                     help="happens-before oracle check on the trace")
    met.add_argument("--crossval", action="store_true", default=None,
                     help="replay on the exact engine and compare")
    obs = ap.add_argument_group("observability")
    obs.add_argument("--trace-out", metavar="PATH",
                     help="write structured trace spans as Perfetto-"
                          "loadable Chrome trace JSON (implies span "
                          "recording)")
    obs.add_argument("--metrics-out", metavar="PATH",
                     help="write the run's latency histogram / gauges / "
                          "counters through the --sink writer")
    obs.add_argument("--sink", choices=sorted(SINKS.keys()),
                     help="metrics sink format for --metrics-out "
                          "(default jsonl)")
    obs.add_argument("--spans", action="store_true", default=None,
                     help="record trace spans even without --trace-out "
                          "(kept on report.obs.spans)")
    fr = ap.add_argument_group("flight recorder (DESIGN.md §2.11)")
    fr.add_argument("--provenance", type=int, metavar="RATE",
                    help="sample 1-in-RATE application broadcasts and "
                         "record their full lifecycle (submit/admit/"
                         "activate/deliver/retire); exported as "
                         "provenance JSONL records and per-message "
                         "Perfetto tracks")
    fr.add_argument("--sampler", choices=sorted(SAMPLERS.keys()),
                    help="provenance sampling policy (default hash: "
                         "deterministic splitmix64 of origin+round)")
    fr.add_argument("--audit", choices=sorted(AUDIT.keys()),
                    help="online causality auditor over the sampled "
                         "records: log (count violations) or fail "
                         "(raise on the first); needs --provenance")
    fr.add_argument("--ops-out", metavar="PATH",
                    help="stream per-tick ops gauges to PATH through "
                         "--ops-sink (live mode)")
    fr.add_argument("--ops-sink", choices=sorted(OPS_SINKS.keys()),
                    help="ops stream format for --ops-out "
                         "(default prometheus)")
    fr.add_argument("--ops-every", type=int, metavar="N",
                    help="publish ops gauges every N ticks (default 1)")
    fr.add_argument("--watch", action="store_true", default=None,
                    help="live terminal dashboard on stderr (plain "
                         "line-per-tick records when not a TTY)")
    return ap


# (args attr, spec section, spec field); None section = top level
_FLAG_MAP = [
    ("protocol", None, "protocol"), ("engine", None, "engine"),
    ("backend", None, "backend"), ("n", None, "n"), ("seed", None, "seed"),
    ("memory_budget_mb", None, "memory_budget_mb"),
    ("topology", "topology", "kind"), ("k", "topology", "k"),
    ("max_delay", "topology", "max_delay"), ("beta", "topology", "beta"),
    ("traffic", "traffic", "kind"), ("messages", "traffic", "messages"),
    ("rate", "traffic", "rate"),
    ("dynamics", "dynamics", "kind"), ("n_adds", "dynamics", "n_adds"),
    ("n_rms", "dynamics", "n_rms"), ("n_crashes", "dynamics", "n_crashes"),
    ("window", "window", "window"), ("seg_len", "window", "seg_len"),
    ("horizon", "window", "horizon"), ("collect", "window", "collect"),
    ("devices", "shard", "devices"), ("scan", "shard", "scan"),
    ("arrivals", "live", "arrivals"), ("admission", "live", "admission"),
    ("queue_cap", "live", "queue_cap"),
    ("admit_cap", "live", "per_round_cap"),
    ("slo_p99", "live", "slo_p99"),
    ("oracle", "metrics", "oracle"), ("crossval", "metrics", "crossval"),
    ("trace_out", "obs", "trace_out"),
    ("metrics_out", "obs", "metrics_out"),
    ("sink", "obs", "sink"), ("spans", "obs", "spans"),
    ("provenance", "obs", "provenance"), ("sampler", "obs", "sampler"),
    ("audit", "obs", "audit"), ("ops_out", "obs", "ops_out"),
    ("ops_sink", "obs", "ops_sink"), ("ops_every", "obs", "ops_every"),
    ("watch", "obs", "watch"),
]


def spec_from_args(args: argparse.Namespace) -> RunSpec:
    d: dict = _spec_dict(args.spec) if args.spec else {}
    for attr, section, fld in _FLAG_MAP:
        value = getattr(args, attr)
        if value is None:
            continue
        if section is None:
            d[fld] = value
        else:
            d.setdefault(section, {})[fld] = value
    if args.serve:
        d["mode"] = "live"
        # under --serve, --rate/--messages describe the offered load,
        # not a pre-scripted traffic schedule
        tr = d.get("traffic", {})
        live = d.setdefault("live", {})
        for fld in ("rate", "messages"):
            if fld in tr:
                live.setdefault(fld, tr.pop(fld))
    return RunSpec.from_dict(d)


def print_registries() -> None:
    """The discovery surface: every registered key on every axis, with
    its one-line description (``python -m repro.api --list``).  The
    backends section additionally runs each entry's availability probe
    so the note says whether (and how) that backend can run *here*."""
    for name, registry in (("protocols", PROTOCOLS), ("engines", ENGINES),
                           ("topologies", TOPOLOGIES), ("traffic", TRAFFIC),
                           ("scenarios (dynamics kinds)", SCENARIOS),
                           ("arrivals (live mode)", ARRIVALS),
                           ("admission (live mode)", ADMISSION),
                           ("sinks (--metrics-out formats)", SINKS),
                           ("samplers (--provenance policies)", SAMPLERS),
                           ("audit (--audit modes)", AUDIT),
                           ("ops sinks (--ops-out formats)", OPS_SINKS)):
        print(f"{name}:")
        for key in sorted(registry.keys()):
            desc = describe_entry(registry.get(key))
            print(f"  {key:<16} {desc}" if desc else f"  {key}")
    print("backends:")
    for key in sorted(BACKENDS.keys()):
        entry = BACKENDS.get(key)
        ok, note = entry.probe()
        status = "available" if ok else "UNAVAILABLE"
        print(f"  {key:<16} {entry.description} [{status}: {note}]")


def report_csv_rows(rep) -> list:
    tag = f"proto={rep.spec.protocol},engine={rep.engine},n={rep.n}"
    us = rep.wall_seconds * 1e6
    rows = [(f"api/delivered_frac/{tag}", us, rep.delivered_frac),
            (f"api/mean_latency/{tag}", us, rep.mean_latency),
            (f"api/sent_messages/{tag}", us, float(rep.stats.sent_messages))]
    rows += [(f"api/{key}/{tag}", us, float(v))
             for key, v in sorted(rep.extras.items())
             if isinstance(v, (int, float))]
    return rows


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list:
        print_registries()
        return 0
    try:
        spec = spec_from_args(args)
        if args.dump_spec:
            print(json.dumps(spec.validate().to_dict(), indent=2))
            return 0
        on_tick = None
        if args.serve:
            tick_no = [0]

            def on_tick(info):
                tick_no[0] += 1
                if tick_no[0] % 16 == 0:
                    print(f"  serve: t={info['t']} "
                          f"admitted={info['admitted_total']} "
                          f"queue={info['queue']} live={info['live']} "
                          f"shed={info['shed']}", file=sys.stderr)
        rep = run(spec, on_tick=on_tick)
    except (SpecError, FileNotFoundError, json.JSONDecodeError,
            TypeError) as exc:
        # TypeError: a JSON spec with a wrongly-typed field value (e.g.
        # a quoted number) that the eager validation didn't cover
        print(f"error: {exc}", file=sys.stderr)
        return 2
    if args.csv:
        for name, us, derived in report_csv_rows(rep):
            print(f"{name},{us:.2f},{derived:.3f}")
    else:
        print(json.dumps(rep.to_dict(), indent=2))
    if rep.oracle is not None and not rep.oracle.ok:
        print(f"oracle FAILED: {rep.oracle.summary()}", file=sys.stderr)
        return 1
    if rep.crossval_ok is False:
        print("cross-validation FAILED: engines disagree", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
