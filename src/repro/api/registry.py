"""String-keyed registries: the pluggable axes of the experiment API.

Four axes are extensible by registration rather than by editing call
sites — protocols, engines, topologies and traffic patterns — plus the
scenario-family registry that maps a ``DynamicsSpec.kind`` to the
concrete :class:`~repro.core.vecsim.scenario.VecScenario` builder.  Each
registry is a plain :class:`Registry` of entry objects; ``repro.api.run``
resolves every axis of a :class:`~repro.api.spec.RunSpec` through these
tables, so registering a new entry makes it reachable from specs, the
CLI and every rebased benchmark at once.

    from repro.api import SCENARIOS, ScenarioEntry

    @SCENARIOS.register("my_workload")
    def _build(spec): ...

Engine entries are registered by ``repro.api.run`` at import time (they
close over the dispatch logic); everything else registers here.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Optional

from ..core.vecsim import scenario as _scn
from ..core.vecsim.live import _ADMISSION, _ARRIVALS
from ..obs.audit import AUDIT_MODES as _AUDIT_MODES
from ..obs.flight import SAMPLERS as _SAMPLERS
from ..obs.ops import OPS_SINKS as _OPS_SINKS
from ..obs.sinks import SINKS as _SINKS
from .spec import RunSpec, SpecError

__all__ = ["Registry", "ProtocolEntry", "EngineEntry", "BackendEntry",
           "ScenarioEntry", "PROTOCOLS", "ENGINES", "BACKENDS",
           "TOPOLOGIES", "TRAFFIC", "SCENARIOS", "ARRIVALS", "ADMISSION",
           "SINKS", "SAMPLERS", "AUDIT", "OPS_SINKS", "describe_entry"]


class Registry:
    """A small string-keyed table with informative lookup failures.

    ``items`` may be an existing dict to wrap *live* (no copy): the
    topology and traffic registries share the dispatch tables inside
    ``vecsim.scenario``, so registering here makes the key immediately
    buildable by every scenario builder."""

    def __init__(self, name: str, items: Optional[Dict[str, Any]] = None):
        self.name = name
        self._items: Dict[str, Any] = {} if items is None else items

    def register(self, key: str, value: Any = None):
        """Register directly (``register(key, value)``) or as a
        decorator (``@register(key)``)."""
        if value is not None:
            self._add(key, value)
            return value

        def deco(fn):
            self._add(key, fn)
            return fn
        return deco

    def _add(self, key: str, value: Any) -> None:
        if key in self._items:
            raise KeyError(f"{self.name} key {key!r} already registered")
        self._items[key] = value

    def get(self, key: str) -> Any:
        try:
            return self._items[key]
        except KeyError:
            raise KeyError(f"unknown {self.name} key {key!r}; registered: "
                           f"{sorted(self._items)}") from None

    def keys(self) -> Iterable[str]:
        return self._items.keys()

    def __contains__(self, key: str) -> bool:
        return key in self._items

    def __iter__(self):
        return iter(self._items)


PROTOCOLS = Registry("protocol")
ENGINES = Registry("engine")        # populated by repro.api.run on import
BACKENDS = Registry("backend")
# Live views of the vecsim dispatch tables: a topology registered here is
# buildable by every scenario builder (uniform signature
# (seed, n, k, max_delay, free_slots, beta) -> (adj0, delay0)); a
# TrafficModel registered here is usable by sustained_scenario.
TOPOLOGIES = Registry("topology", items=_scn._TOPOLOGIES)
TRAFFIC = Registry("traffic", items=_scn._TRAFFIC)
SCENARIOS = Registry("scenario")
# Live serving axes (mode="live"): open-loop arrival processes and
# admission policies, shared live with vecsim.live so an ArrivalProcess
# or AdmissionPolicy registered here is immediately buildable by
# LiveLoop (and vice versa).
ARRIVALS = Registry("arrivals", items=_ARRIVALS)
ADMISSION = Registry("admission", items=_ADMISSION)
# Telemetry export sinks (ObsSpec.sink), shared live with repro.obs so a
# MetricsSink registered here is immediately usable by --metrics-out.
SINKS = Registry("sink", items=_SINKS)
# Flight-recorder surface (DESIGN §2.11), wrapped live from repro.obs:
# provenance samplers (ObsSpec.sampler), causality-audit modes
# (ObsSpec.audit) and live ops-plane sinks (ObsSpec.ops_sink).
SAMPLERS = Registry("sampler", items=_SAMPLERS)
AUDIT = Registry("audit mode", items=_AUDIT_MODES)
OPS_SINKS = Registry("ops sink", items=_OPS_SINKS)


# --------------------------------------------------------------------- #
# Protocols
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class ProtocolEntry:
    """One causal-broadcast protocol, runnable on every engine that
    supports it.  ``mode`` is the :class:`VecScenario` mode the pc/r vec
    engine executes (None = the protocol has its own vec runner);
    ``windowed`` marks streaming-window support."""

    name: str
    description: str
    mode: Optional[str]        # VecScenario.mode for the shared vec engine
    windowed: bool


@dataclass(frozen=True)
class BackendEntry:
    """One compute backend of the vec engines, with a lazy availability
    probe: ``probe() -> (ok, note)`` tells the discovery surface (and
    ``select_engine``) whether the backend can run here and why/how.
    The probe never raises and never imports at registration time."""

    name: str
    description: str
    probe: Callable[[], tuple]

    @property
    def available(self) -> bool:
        return bool(self.probe()[0])

    def availability_note(self) -> str:
        return str(self.probe()[1])


@dataclass(frozen=True)
class EngineEntry:
    """One execution engine: a runner callable plus the one-line
    description the CLI discovery surface (``python -m repro.api
    --list``) prints.  Calling the entry calls the runner, so
    ``repro.api.run`` dispatches through it unchanged."""

    name: str
    description: str
    run: Callable

    def __call__(self, *args, **kwargs):
        return self.run(*args, **kwargs)


def describe_entry(value: Any) -> str:
    """Best-effort one-line description of a registry value: an explicit
    ``description`` attribute, the value itself when it *is* the
    description (the batch-traffic marker), or the first docstring line
    of a registered callable."""
    desc = getattr(value, "description", None)
    if isinstance(desc, str) and desc:
        return desc
    if isinstance(value, str):
        return value
    import inspect
    doc = inspect.getdoc(value)
    return doc.splitlines()[0].strip() if doc else ""


PROTOCOLS.register("pc", ProtocolEntry(
    "pc", "PC-broadcast: O(1) control info, link-safety ping gating "
    "(the paper's Algorithm 2)", mode="pc", windowed=True))
PROTOCOLS.register("r", ProtocolEntry(
    "r", "R-broadcast: flooding without link gating (causally unsafe "
    "on dynamic overlays — the Fig. 3 foil)", mode="r", windowed=True))
PROTOCOLS.register("vc", ProtocolEntry(
    "vc", "vector-clock causal broadcast: O(N) piggybacked clocks, "
    "O(W·N) delivery drain (Table 1 baseline, measured)", mode=None,
    windowed=False))


# --------------------------------------------------------------------- #
# Backends: how the vec engines execute a round body
# --------------------------------------------------------------------- #
def _probe_numpy():
    return True, "always available"


def _probe_jax():
    try:
        import jax
    except Exception as exc:  # pragma: no cover - environment-dependent
        return False, f"jax not importable: {exc}"
    return True, f"jax {jax.__version__} on {jax.default_backend()}"


def _probe_pallas():
    from ..core.vecsim.kernels import pallas_available
    return pallas_available()


BACKENDS.register("numpy", BackendEntry(
    "numpy", "mutating numpy reference: readable, host-speed, the "
    "semantics every other backend must match byte-for-byte",
    _probe_numpy))
BACKENDS.register("jax", BackendEntry(
    "jax", "jitted lax.scan round body (vec/windowed) and the shard_map "
    "mesh program (sharded; shard.scan='on' runs whole segments as one "
    "device-side lax.scan, DESIGN.md §2.7)", _probe_jax))
BACKENDS.register("pallas", BackendEntry(
    "pallas", "fused Pallas delivery-sweep kernels in the round body "
    "(vecsim.kernels, DESIGN.md §2.6); never auto-selected off-TPU",
    _probe_pallas))


# --------------------------------------------------------------------- #
# Traffic: the batch ("uniform") marker rides alongside the shared
# sustained TrafficModel table
# --------------------------------------------------------------------- #
TRAFFIC.register("uniform", "unique (origin, round) broadcasts spread "
                 "uniformly over the schedule window (batch scheduling; "
                 "not a sustained TrafficModel)")
# "poisson" and "bursty" arrive through the shared _TRAFFIC table as
# TrafficModel entries; register new sustained models the same way:
#   TRAFFIC.register("flashcrowd", TrafficModel(build=..., mean_rate=...))


# --------------------------------------------------------------------- #
# Scenario families: DynamicsSpec.kind -> VecScenario builder
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class ScenarioEntry:
    """Adapter from a validated :class:`RunSpec` to a scenario."""

    name: str
    build: Callable[[RunSpec], Any]
    topologies: Optional[frozenset] = None   # None = any registered
    traffic: Optional[frozenset] = frozenset({"uniform"})  # None = any
    description: str = ""                    # one line for --list

    def check(self, spec: RunSpec) -> None:
        if self.topologies is not None \
                and spec.topology.kind not in self.topologies:
            raise SpecError(
                f"dynamics kind {self.name!r} supports only "
                f"{sorted(self.topologies)} topologies (got "
                f"{spec.topology.kind!r})")
        if self.traffic is not None and spec.traffic.kind not in self.traffic:
            raise SpecError(
                f"dynamics kind {self.name!r} supports only "
                f"{sorted(self.traffic)} traffic (got "
                f"{spec.traffic.kind!r})")


def _mode(spec: RunSpec) -> str:
    entry = PROTOCOLS.get(spec.protocol)
    return entry.mode if entry.mode is not None else "pc"


def _build_none(spec: RunSpec):
    t, tr = spec.topology, spec.traffic
    if tr.kind == "uniform":
        return _scn.static_scenario(
            seed=spec.seed, n=spec.n, k=t.k, m_app=tr.messages,
            max_delay=t.max_delay, mode=_mode(spec),
            pong_delay=spec.pong_delay, topology=t.kind, beta=t.beta)
    return _scn.sustained_scenario(
        seed=spec.seed, n=spec.n, k=t.k, rate=tr.rate,
        messages=tr.messages, topology=t.kind, traffic=tr.kind,
        beta=t.beta, burst_period=tr.period, burst_duty=tr.duty,
        rate_lo=tr.rate_lo, max_delay=t.max_delay, mode=_mode(spec),
        pong_delay=spec.pong_delay)


def _build_link_add(spec: RunSpec):
    t, d = spec.topology, spec.dynamics
    return _scn.link_add_scenario(
        seed=spec.seed, n=spec.n, k=t.k, m_app=spec.traffic.messages,
        n_adds=d.n_adds, max_delay=t.max_delay,
        pong_delay=spec.pong_delay, topology=t.kind, beta=t.beta)


def _build_churn(spec: RunSpec):
    t, d = spec.topology, spec.dynamics
    return _scn.churn_scenario(
        seed=spec.seed, n=spec.n, k=t.k, m_app=spec.traffic.messages,
        n_adds=d.n_adds, n_rms=d.n_rms, max_delay=t.max_delay,
        pong_delay=spec.pong_delay, churn_window=d.churn_window,
        topology=t.kind, beta=t.beta)


def _build_crash(spec: RunSpec):
    t, d = spec.topology, spec.dynamics
    return _scn.crash_scenario(
        seed=spec.seed, n=spec.n, k=t.k, m_app=spec.traffic.messages,
        n_crashes=d.n_crashes, max_delay=t.max_delay,
        pong_delay=spec.pong_delay, topology=t.kind, beta=t.beta)


def _build_partition_heal(spec: RunSpec):
    t, d = spec.topology, spec.dynamics
    return _scn.partition_heal_scenario(
        seed=spec.seed, n=spec.n, k=t.k, m_app=spec.traffic.messages,
        n_bridge=d.n_bridge, max_delay=t.max_delay,
        pong_delay=spec.pong_delay,
        traffic_during_partition=d.traffic_during_partition)


def _build_churn_wave(spec: RunSpec):
    t, d = spec.topology, spec.dynamics
    return _scn.churn_wave_scenario(
        seed=spec.seed, n=spec.n, k=t.k, m_app=spec.traffic.messages,
        waves=d.waves, adds_per_wave=d.n_adds, rms_per_wave=d.n_rms,
        max_delay=t.max_delay, pong_delay=spec.pong_delay,
        topology=t.kind, beta=t.beta)


SCENARIOS.register("none", ScenarioEntry(
    "none", _build_none, traffic=None,   # any registered traffic model
    description="static overlay; batch or sustained traffic only"))
SCENARIOS.register("link_add", ScenarioEntry(
    "link_add", _build_link_add,
    description="batched link additions racing later broadcasts (the "
    "Fig. 3 shortcut that ping gating makes safe)"))
SCENARIOS.register("churn", ScenarioEntry(
    "churn", _build_churn,
    description="interleaved link additions and removals under traffic"))
SCENARIOS.register("crash", ScenarioEntry(
    "crash", _build_crash,
    description="silent mid-broadcast crashes (Fig. 5b)"))
SCENARIOS.register("partition_heal", ScenarioEntry(
    "partition_heal", _build_partition_heal,
    topologies=frozenset({"ring"}),
    description="brownout partition over a thin bridge, then healed "
    "cross links re-gating"))
SCENARIOS.register("churn_wave", ScenarioEntry(
    "churn_wave", _build_churn_wave,
    description="periodic waves of adds+removals with traffic "
    "throughout (diurnal / flash-crowd membership)"))
