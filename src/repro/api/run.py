"""``repro.api.run`` — one front door for every protocol experiment.

``run(spec)`` resolves every axis of a validated :class:`RunSpec`
through the registries, builds (or accepts) the scenario, picks the
engine, executes, and returns a uniform :class:`RunReport` whatever ran
underneath — the exact event simulator, the monolithic vec engine, the
streaming windowed engine, or the vectorized vector-clock baseline.

Engine auto-selection (DESIGN.md §3): with ``engine="auto"``,

  1. an explicit ``window.window`` selects the streaming engine;
  2. otherwise the monolithic vec engine runs iff its two dense
     ``(N, M_total)`` int32 planes fit the spec's memory budget
     (``8·N·M_total <= memory_budget_mb``);
  3. otherwise a streaming engine runs with the budget-derived window —
     **per-device-aware**: when more than one device is visible (or
     ``shard.devices`` asks for several), the device-sharded engine
     (``vecsim.shard``) takes the run with
     ``window = clamp(D·budget // (8·N), 64, M_total)`` — the budget is
     per device, so a mesh widens the window D-fold; on a single device
     the single-host windowed engine runs with
     ``window = clamp(budget // (8·N), 64, M_total)``.

The exact event engine is never auto-selected — it is the O(objects)
reference implementation and must be asked for by name.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..core.oracle import OracleReport, check_trace
from ..core.types import NetStats
from ..core.vecsim import crossval as _crossval
from ..core.vecsim import stream as _stream
from ..core.vecsim.live import LiveLoop, LiveReport
from ..core.vecsim.metrics import build_trace
from ..core.vecsim.scenario import VecScenario
from ..core.vecsim.sim import execute_vec, resolve_backend
from ..core.vecsim.vc import run_vec_vc
from ..obs.audit import CausalAuditor
from ..obs.flight import FlightRecorder, provenance_trace_events
from ..obs.graphs import overhead_per_message
from ..obs.hist import percentiles_from_hist
from ..obs.ops import OpsPlane
from ..obs.sinks import write_chrome_trace
from ..obs.spans import EngineObs
from .registry import ENGINES, PROTOCOLS, SCENARIOS, SINKS, EngineEntry
from .spec import RunSpec, SpecError

__all__ = ["RunReport", "run", "build_scenario", "select_engine",
           "build_live_scenario"]


@dataclass
class RunReport:
    """Uniform result of :func:`run`, whatever engine executed."""

    spec: RunSpec
    engine: str                # engine that actually ran
    backend: str               # resolved backend ("object" for exact)
    window: Optional[int]      # live columns (windowed engine only)
    wall_seconds: float
    n: int
    m_app: int
    rounds: int                # scenario rounds (0 for the exact engine)
    stats: NetStats
    delivered_frac: float
    mean_latency: float        # rounds (vec) / sim-time units (exact)
    extras: Dict[str, float] = field(default_factory=dict)
    oracle: Optional[OracleReport] = None
    crossval_ok: Optional[bool] = None
    result: Any = None         # the raw engine result object
    scenario: Any = None       # the VecScenario that ran
    live: Optional[LiveReport] = None   # serving report (mode="live")
    obs: Any = None            # EngineObs telemetry accumulator (or None)

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe summary (drops the raw result and scenario)."""
        try:
            spec_d = self.spec.to_dict()
        except SpecError:
            spec_d = {"scenario": "prebuilt"}
        return dict(
            spec=spec_d, engine=self.engine, backend=self.backend,
            window=self.window, wall_seconds=round(self.wall_seconds, 4),
            n=self.n, m_app=self.m_app, rounds=self.rounds,
            stats=vars(self.stats).copy(),
            delivered_frac=self.delivered_frac,
            mean_latency=self.mean_latency,
            extras={k: (v if isinstance(v, (int, str)) else float(v))
                    for k, v in self.extras.items()},
            oracle_ok=None if self.oracle is None else self.oracle.ok,
            crossval_ok=self.crossval_ok,
            live=None if self.live is None else self.live.to_dict(),
        )


# --------------------------------------------------------------------- #
# Scenario construction and engine selection
# --------------------------------------------------------------------- #
def build_scenario(spec: RunSpec) -> VecScenario:
    """Resolve the spec's topology/traffic/dynamics sections into a
    :class:`VecScenario` (or pass through a prebuilt one)."""
    if spec.scenario is not None:
        scn = spec.scenario
    else:
        scn = SCENARIOS.get(spec.dynamics.kind).build(spec)
    proto = PROTOCOLS.get(spec.protocol)
    want_mode = proto.mode if proto.mode is not None else scn.mode
    if scn.mode != want_mode or scn.always_gate != spec.always_gate:
        scn = replace(scn, mode=want_mode,
                      always_gate=spec.always_gate).validate()
    return scn


def _auto_window(spec: RunSpec, scn: VecScenario, devices: int = 1) -> int:
    """The budget-derived window (DESIGN.md §3.3 rule 3):
    ``clamp(D·budget // (8·N), 64, M_total)`` live columns — the memory
    budget reads per device, so a mesh scales the window with it."""
    budget = devices * spec.memory_budget_mb * 2 ** 20
    return int(min(max(64, budget // (8 * scn.n)), scn.m_total))


def _device_count(spec: RunSpec) -> int:
    """Devices the sharded engine would run on: the explicit
    ``shard.devices`` if set, else whatever jax can see (1 when jax is
    absent — auto-selection then never proposes the sharded engine)."""
    if spec.shard.devices is not None:
        return spec.shard.devices
    try:
        import jax
        return jax.device_count()
    except ImportError:
        return 1


def select_engine(spec: RunSpec, scn: VecScenario
                  ) -> Tuple[str, Optional[int]]:
    """Apply the DESIGN.md §3 auto-selection rule; explicit engines pass
    through unchanged (with the spec's window, if any — validate()
    rejects a window on the monolithic/exact engines).

    An explicit ``backend="pallas"`` fails here — eagerly, with a
    :class:`SpecError` naming the probe's reason — when the kernels
    cannot initialize; ``backend="auto"`` instead quietly resolves to
    the jax backend wherever Pallas is unavailable (or interpret-only,
    which would be byte-identical but slower)."""
    if spec.backend == "pallas":
        from .registry import BACKENDS
        ok, note = BACKENDS.get("pallas").probe()
        if not ok:
            raise SpecError(
                f"backend='pallas' requested but Pallas cannot "
                f"initialize ({note}); use backend='jax' or 'auto'")
    if spec.engine != "auto":
        return spec.engine, spec.window.window
    if spec.window.window is not None:
        # an explicit window is a streaming request; an explicit mesh
        # request must not be dropped on the floor with it (validate()
        # rejects devices>1 on the numpy backend)
        if (spec.shard.devices or 1) > 1:
            return "sharded", spec.window.window
        return "windowed", spec.window.window
    proto = PROTOCOLS.get(spec.protocol)
    budget = spec.memory_budget_mb * 2 ** 20
    mono_bytes = 8 * scn.n * max(scn.m_total, 1)
    if mono_bytes <= budget or not proto.windowed:
        return "vec", None
    if spec.backend == "numpy":
        # numpy can never shard — skip device detection (and its jax
        # runtime initialization) entirely
        return "windowed", _auto_window(spec, scn)
    devices = _device_count(spec)
    if devices > 1:
        return "sharded", _auto_window(spec, scn, devices=devices)
    return "windowed", _auto_window(spec, scn)


def _snapshot_round(spec: RunSpec, scn: VecScenario) -> Optional[int]:
    snap = spec.metrics.snapshot
    if snap == "last_churn":
        return int(scn.add_round[-1]) if scn.n_adds else None
    return snap


# --------------------------------------------------------------------- #
# Engine adapters (registered under repro.api.ENGINES)
# --------------------------------------------------------------------- #
def _latency_from_trace(trace) -> float:
    t_bcast: Dict[Tuple[int, int], float] = {}
    lat_sum, lat_cnt = 0.0, 0
    for t, kind, pid, m in trace:
        if kind not in ("broadcast", "deliver"):
            continue                      # open/close/crash carry no AppMsg
        key = (m.origin, m.counter)
        if kind == "broadcast":
            t_bcast[key] = t
        elif key in t_bcast:
            lat_sum += t - t_bcast[key]
            lat_cnt += 1
    return lat_sum / lat_cnt if lat_cnt else float("nan")


def _run_exact(spec: RunSpec, scn: VecScenario, window: Optional[int],
               snapshot_round: Optional[int], obs=None):
    net = _crossval.run_exact(scn, seed=spec.seed, protocol=spec.protocol,
                              snapshot_round=snapshot_round)
    n_bcast = sum(1 for _, kind, _, _ in net.trace if kind == "broadcast")
    alive = sum(1 for p in net.procs.values() if not p.crashed)
    frac = (net.stats.deliveries / (alive * n_bcast)
            if alive * n_bcast else 1.0)
    extras: Dict[str, float] = {
        "overhead_bytes_per_msg": overhead_per_message(net)}
    if spec.protocol == "vc":
        comparisons = sum(p.comparisons for p in net.procs.values())
        extras["comparisons_per_delivery"] = (
            comparisons / max(net.stats.deliveries, 1))
        extras["max_pending"] = max(p.max_pending
                                    for p in net.procs.values())
        extras["space_entries_max"] = max(p.local_space_entries()
                                          for p in net.procs.values())
    return net, net.stats, frac, _latency_from_trace(net.trace), extras


def _vec_extras(spec: RunSpec, res) -> Dict[str, float]:
    if spec.protocol == "vc":
        return {
            "overhead_bytes_per_msg": res.overhead_bytes_per_message(),
            "comparisons_per_delivery": res.comparisons_per_delivery(),
            "max_pending": res.max_pending,
            "space_entries_max": int((res.vc > 0).sum(axis=1).max()),
        }
    return {
        "overhead_bytes_per_msg": res.stats.control_bytes
        / max(res.stats.sent_messages, 1),
        "gated_link_rounds": int(res.series[:, 5].sum()),
        "pongs": int(res.series[:, 4].sum()),
    }


def _run_vec(spec: RunSpec, scn: VecScenario, window: Optional[int],
             snapshot_round: Optional[int], obs=None):
    if spec.protocol == "vc":
        if snapshot_round is not None:
            raise SpecError("metrics.snapshot is not supported for the "
                            "'vc' protocol (it has no gating state to "
                            "snapshot)")
        res = run_vec_vc(scn)
    else:
        res = execute_vec(scn, backend=spec.backend,
                          snapshot_round=snapshot_round)
    return (res, res.stats, res.delivered_frac(), res.mean_latency(),
            _vec_extras(spec, res))


def _run_windowed(spec: RunSpec, scn: VecScenario, window: Optional[int],
                  snapshot_round: Optional[int], obs=None):
    if window is None:
        # explicit engine="windowed" without a window: apply the budget rule
        window = _auto_window(spec, scn)
    res = _stream.execute_windowed(
        scn, window, backend=spec.backend, horizon=spec.window.horizon,
        seg_len=spec.window.seg_len, snapshot_round=snapshot_round,
        collect=spec.window.collect, obs=obs)
    extras = _vec_extras(spec, res)
    extras["peak_live"] = res.peak_live
    extras["expired_columns"] = int(res.expired.sum())
    return (res, res.stats, res.delivered_frac(), res.mean_latency(),
            extras)


def _run_sharded(spec: RunSpec, scn: VecScenario, window: Optional[int],
                 snapshot_round: Optional[int], obs=None):
    if spec.protocol == "vc":
        raise SpecError("protocol 'vc' has no sharded engine (its "
                        "delivery drain is a data-dependent host loop); "
                        "use engine='vec'")
    from ..core.vecsim.shard import execute_sharded
    devices = spec.shard.devices
    if window is None:
        # explicit engine="sharded" without a window: the per-device
        # budget rule over the devices the run will actually use
        window = _auto_window(spec, scn, devices=_device_count(spec))
    res = execute_sharded(
        scn, window, n_devices=devices, horizon=spec.window.horizon,
        seg_len=spec.window.seg_len, snapshot_round=snapshot_round,
        collect=spec.window.collect, backend=spec.backend,
        scan=spec.shard.scan, profile=spec.shard.profile, obs=obs)
    extras = _vec_extras(spec, res)
    extras["peak_live"] = res.peak_live
    extras["expired_columns"] = int(res.expired.sum())
    extras["devices"] = res.n_devices
    extras["scan"] = res.scan
    if res.seg_profile is not None:
        # scalar totals only; the per-segment list stays on the raw
        # result (report.result.seg_profile) — extras are float-coerced
        for key in ("stage_s", "dispatch_s", "block_s", "retire_s"):
            extras["profile_" + key] = float(
                sum(p[key] for p in res.seg_profile))
        extras["profile_segments"] = len(res.seg_profile)
        extras["profile_fast_segments"] = sum(
            1 for p in res.seg_profile if p["fast"])
    return (res, res.stats, res.delivered_frac(), res.mean_latency(),
            extras)


ENGINES.register("exact", EngineEntry(
    "exact", "O(objects) discrete-event reference simulator (never "
    "auto-selected; paper-faithful sub-round timing)", _run_exact))
ENGINES.register("vec", EngineEntry(
    "vec", "monolithic vectorized lockstep engine: dense (N, M_total) "
    "planes, numpy or jax backend", _run_vec))
ENGINES.register("windowed", EngineEntry(
    "windowed", "streaming windowed engine: O(N*window) live-column "
    "buffer for sustained traffic on one host", _run_windowed))
ENGINES.register("sharded", EngineEntry(
    "sharded", "device-sharded windowed engine: process axis partitioned "
    "over a jax mesh (shard_map frontier exchange), N to 10^6+; "
    "shard.scan=auto|on|off picks whole-segment lax.scan vs per-round "
    "stepping, shard.profile=True records per-segment timings",
    _run_sharded))


# --------------------------------------------------------------------- #
# Telemetry plumbing (repro.obs; DESIGN.md §2.10)
# --------------------------------------------------------------------- #
def _build_obs(spec: RunSpec, engine_name: str,
               live: bool = False) -> Optional[EngineObs]:
    """The :class:`EngineObs` accumulator a run threads through its
    engine, or None when every telemetry pillar is off — the engines
    then trace exactly the pre-telemetry program (the overhead gate in
    CI holds them to it)."""
    ob = spec.obs
    hist = ob.histograms
    if hist is None:
        # auto: on wherever an engine can feed it (the streaming
        # engines' retire reductions, and every live run)
        hist = live or engine_name in ("windowed", "sharded")
    spans = bool(ob.spans or ob.trace_out is not None)
    flight = None
    if ob.provenance is not None:
        if not live and engine_name not in ("windowed", "sharded"):
            raise SpecError(
                f"obs.provenance needs a streaming engine (the hooks "
                f"ride column retirement), but this run resolved to "
                f"engine={engine_name!r}; set an explicit window or "
                "engine='windowed'/'sharded'")
        auditor = (CausalAuditor(ob.audit) if ob.audit != "off"
                   else None)
        flight = FlightRecorder(rate=ob.provenance, seed=spec.seed,
                                sampler=ob.sampler, auditor=auditor,
                                live=live)
    if not live and not hist and not spans and ob.metrics_out is None \
            and flight is None:
        return None
    obs = EngineObs(histograms=hist, spans=spans,
                    span_capacity=ob.span_capacity)
    obs.flight = flight
    return obs


def _obs_extras(obs: Optional[EngineObs], extras: Dict[str, float]) -> None:
    """Histogram-derived latency percentiles and telemetry counters into
    the report extras."""
    if obs is None:
        return
    total = int(obs.latency_hist.sum())
    if obs.histograms and total > 0:
        p50, p99, p999 = percentiles_from_hist(
            obs.latency_hist, (50.0, 99.0, 99.9))
        extras["latency_p50"] = p50
        extras["latency_p99"] = p99
        extras["latency_p999"] = p999
        extras["latency_hist_total"] = total
    fl = obs.flight
    if fl is not None:
        extras["provenance_sampled"] = fl.sampled
        if fl.auditor is not None:
            extras["audit_pairs_checked"] = fl.auditor.pairs_checked
            extras["audit_violations"] = len(fl.auditor.violations)
    for name, value in obs.counters.items():
        extras[name] = value


def _metrics_doc(spec: RunSpec, report: "RunReport",
                 obs: EngineObs) -> dict:
    """The sink-agnostic telemetry doc a metrics sink serializes."""
    fl = obs.flight
    run = dict(engine=report.engine, backend=report.backend,
               mode=spec.mode, protocol=spec.protocol, n=report.n,
               m_app=report.m_app, rounds=report.rounds,
               seed=spec.seed)
    if "devices" in report.extras:
        run["devices"] = int(report.extras["devices"])
    return dict(
        run=run,
        summary=dict(
            wall_seconds=report.wall_seconds,
            delivered_frac=report.delivered_frac,
            mean_latency=report.mean_latency,
            **{k: v for k, v in report.extras.items()
               if isinstance(v, (int, float))}),
        gauges={k: list(v) for k, v in obs.gauges.items()},
        counters=dict(obs.counters),
        latency_hist=(obs.latency_hist
                      if obs.histograms and obs.latency_hist.sum() > 0
                      else None),
        provenance=(fl.export() if fl is not None else None))


def _write_obs_outputs(spec: RunSpec, report: "RunReport") -> None:
    ob, obs = spec.obs, report.obs
    if obs is None:
        return
    if ob.metrics_out is not None:
        SINKS.get(ob.sink).write(ob.metrics_out,
                                 _metrics_doc(spec, report, obs))
    if ob.trace_out is not None:
        try:
            run_args = spec.to_dict()
        except SpecError:
            run_args = {"scenario": "prebuilt"}
        extra = None
        fl = obs.flight
        if fl is not None and fl.completed:
            extra = provenance_trace_events(
                fl.export(),
                n_devices=int(report.extras.get("devices", 1)))
        write_chrome_trace(ob.trace_out, obs.spans, run_args=run_args,
                           extra_events=extra)


# --------------------------------------------------------------------- #
# Live serving mode
# --------------------------------------------------------------------- #
def build_live_scenario(spec: RunSpec) -> VecScenario:
    """The broadcast-free base a live run serves over: the spec's
    topology and dynamics with every pre-scripted broadcast stripped
    (live traffic arrives through the ingest queue instead)."""
    scn = build_scenario(spec)
    if scn.m_app:
        scn = replace(scn, bcast_round=np.empty(0, np.int32),
                      bcast_origin=np.empty(0, np.int32)).validate()
    return scn


def _select_live_engine(spec: RunSpec, scn: VecScenario
                        ) -> Tuple[str, int]:
    """Streaming-engine selection for live mode: the explicit engine if
    named, else sharded on a multi-device mesh, windowed otherwise; the
    window follows the batch budget rule with ``M_total`` read from the
    serving capacity (``live.messages`` + pre-scripted adds)."""
    if spec.engine in ("windowed", "sharded"):
        name = spec.engine
    elif spec.backend == "numpy":
        name = "windowed"
    else:
        name = "sharded" if _device_count(spec) > 1 else "windowed"
    window = spec.window.window
    if window is None:
        devices = _device_count(spec) if name == "sharded" else 1
        budget = devices * spec.memory_budget_mb * 2 ** 20
        m_total = spec.live.messages + scn.n_adds
        window = int(min(max(64, budget // (8 * scn.n)), max(m_total, 1)))
    return name, window


def _run_live(spec: RunSpec, on_tick=None) -> RunReport:
    scn = build_live_scenario(spec)
    engine_name, window = _select_live_engine(spec, scn)
    obs = _build_obs(spec, engine_name, live=True)
    lv = spec.live
    arrival_params = dict(rate_lo=lv.rate_lo, period=lv.period,
                          duty=lv.duty)
    ob = spec.obs
    ops = None
    if ob.ops_out is not None or ob.watch:
        ops = OpsPlane(out=ob.ops_out, sink=ob.ops_sink,
                       every=ob.ops_every, slo_p99=lv.slo_p99,
                       watch=True if ob.watch else None)
    loop = LiveLoop(
        scn, window, engine=engine_name, backend=spec.backend,
        devices=spec.shard.devices, scan=spec.shard.scan,
        seg_len=spec.window.seg_len, horizon=spec.window.horizon,
        collect=spec.window.collect, arrivals=lv.arrivals,
        admission=lv.admission, rate=lv.rate, messages=lv.messages,
        queue_cap=lv.queue_cap, per_round_cap=lv.per_round_cap,
        slo_p99=lv.slo_p99, seed=spec.seed,
        arrival_params=arrival_params, profile=spec.shard.profile,
        obs=obs, on_tick=on_tick, ops=ops)
    lr = loop.run()
    res = lr.result

    extras = _vec_extras(spec, res)
    extras["peak_live"] = lr.peak_live
    for key in ("offered", "admitted", "shed_queue", "shed_policy",
                "unserved", "queue_peak", "backpressure_ticks",
                "overflow_catches", "requests_per_sec", "p50", "p99",
                "p999", "mean_latency_rounds"):
        v = getattr(lr, key)
        if isinstance(v, float) and not np.isfinite(v):
            continue
        extras["serve_" + key] = v
    if lr.slo_ok is not None:
        extras["serve_slo_ok"] = int(lr.slo_ok)
    _obs_extras(obs, extras)

    report = RunReport(
        spec=spec, engine=engine_name,
        backend=getattr(res, "backend", resolve_backend(spec.backend)),
        window=getattr(res, "window", window),
        wall_seconds=lr.wall_seconds, n=scn.n,
        m_app=lr.scenario.m_app, rounds=lr.scenario.rounds,
        stats=res.stats, delivered_frac=lr.delivered_frac,
        mean_latency=res.mean_latency(), extras=extras, result=res,
        scenario=lr.scenario, live=lr, obs=obs)
    # the live result is re-indexed to the admitted scenario, so the
    # batch-mode checkers run on it unchanged
    if spec.metrics.oracle:
        report.oracle = _check_oracle(spec, lr.scenario, engine_name, res)
    if spec.metrics.crossval:
        report.crossval_ok = _check_crossval(spec, lr.scenario,
                                             report.window, engine_name,
                                             res)
    _write_obs_outputs(spec, report)
    return report


# --------------------------------------------------------------------- #
# The front door
# --------------------------------------------------------------------- #
def run(spec: RunSpec, on_tick=None) -> RunReport:
    """Validate ``spec``, build the scenario, pick the engine, execute,
    and measure — the one entry point every benchmark and example uses.
    ``on_tick`` (live mode only) is called with a small progress dict
    after every serving tick."""
    spec.validate()
    if spec.mode == "live":
        return _run_live(spec, on_tick=on_tick)
    scn = build_scenario(spec)
    engine_name, window = select_engine(spec, scn)
    snapshot_round = _snapshot_round(spec, scn)
    runner = ENGINES.get(engine_name)
    obs = _build_obs(spec, engine_name)

    t0 = time.perf_counter()
    result, stats, frac, latency, extras = runner(spec, scn, window,
                                                  snapshot_round, obs=obs)
    wall = time.perf_counter() - t0
    _obs_extras(obs, extras)

    if engine_name == "exact":
        backend = "object"
    elif spec.protocol == "vc":
        backend = "numpy"
    else:
        backend = getattr(result, "backend", resolve_backend(spec.backend))

    report = RunReport(
        spec=spec, engine=engine_name, backend=backend,
        # the result records the window actually used (covers explicit
        # engine="windowed"/"sharded" with the budget-derived default)
        window=(getattr(result, "window", window)
                if engine_name in ("windowed", "sharded") else None),
        wall_seconds=wall, n=scn.n, m_app=scn.m_app, rounds=scn.rounds,
        stats=stats, delivered_frac=frac, mean_latency=latency,
        extras=extras, result=result, scenario=scn, obs=obs)

    if spec.metrics.oracle:
        report.oracle = _check_oracle(spec, scn, engine_name, result)
    if spec.metrics.crossval:
        report.crossval_ok = _check_crossval(spec, scn, report.window,
                                             engine_name, result)
    _write_obs_outputs(spec, report)
    return report


def _check_oracle(spec: RunSpec, scn: VecScenario, engine: str, result):
    if engine == "exact":
        crashed = {pid for pid, p in result.procs.items() if p.crashed}
        return check_trace(result.trace, crashed=crashed,
                           all_pids=set(range(scn.n)))
    if getattr(result, "delivered", None) is None:
        raise SpecError(
            "metrics.oracle needs the full delivered matrix; set "
            "window.collect='full' (aggregate-mode windowed runs keep "
            "only per-message counters)")
    crashed = set(np.nonzero(result.state["crashed"])[0].tolist())
    return check_trace(build_trace(result), crashed=crashed,
                       all_pids=set(range(scn.n)))


def _check_crossval(spec: RunSpec, scn: VecScenario,
                    window: Optional[int], engine: str, result) -> bool:
    # reuse the run we just executed when it carries the full delivered
    # matrix; the exact engine's own run can't serve as the vec side
    reuse = (result if engine != "exact"
             and getattr(result, "delivered", None) is not None else None)
    out = _crossval.cross_validate(
        scn, seed=spec.seed, backend=resolve_backend(spec.backend)
        if spec.protocol != "vc" else "numpy",
        window=window, protocol=spec.protocol, vec_result=reuse)
    ok = out["vec_multiset"] == out["exact_multiset"]
    if spec.protocol == "vc":
        ok = ok and out["vec_clocks"] == out["exact_clocks"]
    return bool(ok)
