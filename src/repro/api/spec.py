"""Declarative experiment specs: the one description every engine runs.

A :class:`RunSpec` names a complete protocol experiment — protocol,
engine, backend, population, topology, traffic, dynamics, windowing and
metrics — as composable frozen dataclass sections.  ``repro.api.run``
turns one into a :class:`~repro.api.run.RunReport` by dispatching
through the string-keyed registries (``repro.api.registry``), so the
exact event engine, the monolithic vec engine and the streaming
windowed engine are all reachable from the same object, and a spec
round-trips through JSON for CLI / CI use (``python -m repro.api``).

Validation is eager and informative: :meth:`RunSpec.validate` raises
:class:`SpecError` naming the offending field and the valid registry
keys, so a typo fails at spec time, not three layers into an engine.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Union

__all__ = ["SpecError", "TopologySpec", "TrafficSpec", "DynamicsSpec",
           "WindowSpec", "ShardSpec", "MetricsSpec", "LiveSpec",
           "ObsSpec", "RunSpec"]


class SpecError(ValueError):
    """An invalid or inconsistent :class:`RunSpec`."""


@dataclass(frozen=True)
class TopologySpec:
    """Initial overlay shape (registry: ``repro.api.TOPOLOGIES``)."""

    kind: str = "ring"        # ring | kregular | smallworld
    k: int = 4                # out-link slots per process
    max_delay: int = 3        # per-link delay drawn from [1, max_delay]
    beta: float = 0.2         # smallworld rewiring probability
    free_slots: int = 1       # trailing slots left empty for additions


@dataclass(frozen=True)
class TrafficSpec:
    """Broadcast load shape (registry: ``repro.api.TRAFFIC``)."""

    kind: str = "uniform"     # uniform | poisson | bursty
    messages: int = 8         # total app broadcasts (m_app)
    rate: float = 4.0         # poisson/bursty mean broadcasts per round
    rate_lo: Optional[float] = None   # bursty off-phase rate (default rate/8)
    period: int = 64          # bursty on/off period in rounds
    duty: float = 0.25        # fraction of each period at the high rate


@dataclass(frozen=True)
class DynamicsSpec:
    """Overlay dynamics family (registry: ``repro.api.SCENARIOS``)."""

    kind: str = "none"        # none | link_add | churn | crash |
    #                           partition_heal | churn_wave
    n_adds: Optional[int] = None
    n_rms: Optional[int] = None
    n_crashes: int = 2
    waves: int = 3
    churn_window: Optional[int] = None
    n_bridge: int = 1
    traffic_during_partition: bool = False


@dataclass(frozen=True)
class WindowSpec:
    """Streaming windowed-engine knobs (``vecsim.stream``)."""

    window: Optional[int] = None   # live columns; None = auto from budget
    seg_len: int = 32              # rounds per segment between retirements
    horizon: Optional[int] = None  # force-retire columns older than this
    collect: str = "auto"          # full | aggregate | auto


@dataclass(frozen=True)
class ShardSpec:
    """Device-mesh knobs for the sharded engine (``vecsim.shard``).

    ``devices=None`` means "every device jax can see" at run time (and
    "what jax would see" during engine auto-selection); an explicit
    count builds a 1-D mesh over that many devices and fails loudly if
    fewer exist.  The memory budget (``memory_budget_mb``) is read
    *per device* when the sharded engine is auto-selected, so adding
    devices grows the auto-derived window proportionally (DESIGN.md
    §3.3).

    ``scan`` picks the segment stepping strategy: ``"off"`` dispatches
    one jitted span per round from the host (the legacy reference
    path), ``"on"`` runs each whole segment as a single ``lax.scan``
    inside ``shard_map`` with stacked schedules, donated buffers and a
    double-buffered frontier exchange (DESIGN.md §2.7) — byte-identical
    results, about an order of magnitude faster at N ≥ 1M.  ``"auto"``
    (the default) resolves to ``"on"``; the numpy backend has no
    scanned path, so ``scan="on"`` with ``backend="numpy"`` is a
    :class:`SpecError`.

    ``profile=True`` records a per-segment host/device wall-time
    breakdown (schedule staging, dispatch, host blocking, retirement)
    on the engine result (``result.seg_profile``) and scalar totals in
    the report extras — results are unaffected; the cost is a few
    clock reads per segment."""

    devices: Optional[int] = None   # mesh size; None = all visible
    scan: str = "auto"              # segment scan: auto | on | off
    profile: bool = False           # per-segment timing breakdown


@dataclass(frozen=True)
class LiveSpec:
    """Live serving-mode knobs (``mode="live"``; DESIGN.md §2.9).

    In live mode the run is an *open-loop service*: an arrival process
    (registry: ``repro.api.ARRIVALS``) submits broadcasts into a bounded
    ingest queue as simulated time passes, and an admission policy
    (registry: ``repro.api.ADMISSION``) plans each segment's micro-batch
    against the engine's window-occupancy backpressure signal.  The
    ``traffic`` section is ignored — live traffic is not pre-scripted —
    while topology/dynamics still shape the overlay under serving.

    ``per_round_cap`` bounds admissions per simulated round (default
    ``min(n, max(4, ceil(3·rate)))``); the live schedule caps are jitted
    against it, so every segment reuses one compiled trace.  ``slo_p99``
    is a rounds-to-delivery target: the report's ``slo_ok`` says whether
    the measured p99 (queueing delay included) met it."""

    arrivals: str = "poisson"      # repro.api.ARRIVALS key
    admission: str = "defer"       # repro.api.ADMISSION key
    rate: float = 8.0              # mean offered submissions per round
    messages: int = 1024           # total submissions offered
    queue_cap: int = 4096          # bounded ingest queue (tail-drop)
    per_round_cap: Optional[int] = None   # admissions per round; None=auto
    slo_p99: Optional[float] = None       # p99 rounds-to-delivery target
    rate_lo: Optional[float] = None       # bursty baseline (default rate/8)
    period: int = 256              # bursty/diurnal period in rounds
    duty: float = 0.25             # bursty high-rate fraction of period


@dataclass(frozen=True)
class ObsSpec:
    """Telemetry knobs (``repro.obs``; DESIGN.md §2.10).

    ``histograms=None`` (the default) turns the on-device
    delivery-latency histogram on wherever an engine supports it (the
    streaming windowed/sharded engines and every live run) and off on
    the monolithic/exact engines; an explicit bool forces it.  Results
    are byte-identical either way — the histogram rides the existing
    per-column aggregate reductions.

    ``spans`` records structured trace spans (live-loop ticks, segment
    stage/dispatch/block/retire phases, stager uploads) into a
    preallocated ring; ``trace_out`` writes them as Perfetto-loadable
    Chrome trace JSON and implies ``spans=True``.  ``metrics_out``
    writes the run's histogram/gauge/counter doc through the named
    ``sink`` (registry: ``repro.api.SINKS``).

    **Flight recorder** (DESIGN.md §2.11): ``provenance=R`` samples
    1-in-R application broadcasts (via ``sampler``, registry
    ``repro.api.SAMPLERS``; seeded by the run seed) and records their
    full lifecycle — exported as ``provenance`` JSONL records and
    per-message Perfetto tracks.  ``audit`` (registry
    ``repro.api.AUDIT``) runs the online causality auditor over the
    sampled records during execution; it requires ``provenance``.
    Streaming engines only (windowed/sharded/live).

    **Live ops plane**: ``ops_out`` streams per-tick gauges through
    ``ops_sink`` (registry: ``repro.api.OPS_SINKS``) every
    ``ops_every`` ticks; ``watch`` renders a terminal dashboard
    (plain lines when stderr is not a TTY).  Live mode only."""

    histograms: Optional[bool] = None   # None = auto per engine
    spans: bool = False                 # record trace spans
    span_capacity: int = 65536          # span ring size (events)
    trace_out: Optional[str] = None     # Chrome trace JSON (implies spans)
    metrics_out: Optional[str] = None   # metrics doc path (via `sink`)
    sink: str = "jsonl"                 # repro.api.SINKS key
    provenance: Optional[int] = None    # sample 1-in-N broadcasts
    sampler: str = "hash"               # repro.api.SAMPLERS key
    audit: str = "off"                  # repro.api.AUDIT key
    ops_out: Optional[str] = None       # live ops stream path
    ops_sink: str = "prometheus"        # repro.api.OPS_SINKS key
    ops_every: int = 1                  # publish every N ticks
    watch: bool = False                 # --watch terminal dashboard


@dataclass(frozen=True)
class MetricsSpec:
    """What to measure beyond the engine's NetStats."""

    snapshot: Optional[Union[int, str]] = None  # round | "last_churn"
    oracle: bool = False       # happens-before oracle on the trace
    crossval: bool = False     # replay on the exact engine and compare


@dataclass(frozen=True)
class RunSpec:
    """One experiment, declaratively: ``repro.api.run(RunSpec(...))``."""

    protocol: str = "pc"       # pc | r | vc   (repro.api.PROTOCOLS)
    mode: str = "batch"        # batch (pre-scripted) | live (open-loop)
    engine: str = "auto"       # auto | exact | vec | windowed
    backend: str = "auto"      # auto | numpy | jax | pallas
    n: int = 64                # processes
    seed: int = 0
    pong_delay: int = 1
    always_gate: bool = False  # paper-faithful unconditional gating
    memory_budget_mb: int = 1024   # N×M budget driving engine auto-select
    topology: TopologySpec = field(default_factory=TopologySpec)
    traffic: TrafficSpec = field(default_factory=TrafficSpec)
    dynamics: DynamicsSpec = field(default_factory=DynamicsSpec)
    window: WindowSpec = field(default_factory=WindowSpec)
    shard: ShardSpec = field(default_factory=ShardSpec)
    live: LiveSpec = field(default_factory=LiveSpec)
    metrics: MetricsSpec = field(default_factory=MetricsSpec)
    obs: ObsSpec = field(default_factory=ObsSpec)
    # Escape hatch: run a prebuilt VecScenario (topology/traffic/dynamics
    # sections are then ignored).  Used by the legacy shims and tests.
    scenario: Optional[Any] = None

    # ----------------------------------------------------------------- #
    # validation
    # ----------------------------------------------------------------- #
    def validate(self) -> "RunSpec":
        from . import registry as reg

        def check_key(registry, value, fld):
            if value not in registry:
                raise SpecError(
                    f"{fld}={value!r} is not a registered key; choose "
                    f"from {sorted(registry.keys())}")

        for fld, value in (("n", self.n), ("seed", self.seed),
                           ("pong_delay", self.pong_delay),
                           ("memory_budget_mb", self.memory_budget_mb)):
            if not isinstance(value, int) or isinstance(value, bool):
                raise SpecError(f"{fld}={value!r} must be an int")
        check_key(reg.PROTOCOLS, self.protocol, "protocol")
        if self.engine not in ("auto",) and self.engine not in reg.ENGINES:
            raise SpecError(
                f"engine={self.engine!r} must be 'auto' or one of "
                f"{sorted(reg.ENGINES.keys())}")
        if self.backend != "auto" and self.backend not in reg.BACKENDS:
            raise SpecError(
                f"backend={self.backend!r} must be 'auto' or one of "
                f"{sorted(reg.BACKENDS.keys())}")
        if self.n < 2:
            raise SpecError(f"n={self.n} must be >= 2")
        if self.memory_budget_mb < 1:
            raise SpecError("memory_budget_mb must be >= 1")
        if self.scenario is None:
            check_key(reg.TOPOLOGIES, self.topology.kind, "topology.kind")
            check_key(reg.TRAFFIC, self.traffic.kind, "traffic.kind")
            check_key(reg.SCENARIOS, self.dynamics.kind, "dynamics.kind")
            if self.topology.k < 2:
                raise SpecError(f"topology.k={self.topology.k} must be >= 2")
            if self.topology.max_delay < 1:
                raise SpecError("topology.max_delay must be >= 1")
            if self.traffic.messages < 0:
                raise SpecError("traffic.messages must be >= 0")
            if self.traffic.kind != "uniform" and self.traffic.rate <= 0:
                raise SpecError("traffic.rate must be > 0 for "
                                f"{self.traffic.kind!r} traffic")
            reg.SCENARIOS.get(self.dynamics.kind).check(self)
        if self.window.window is not None and self.window.window < 1:
            raise SpecError("window.window must be >= 1")
        if self.window.seg_len < 1:
            raise SpecError("window.seg_len must be >= 1")
        if self.window.collect not in ("auto", "full", "aggregate"):
            raise SpecError(f"window.collect={self.window.collect!r} must "
                            "be one of ['aggregate', 'auto', 'full']")
        proto = reg.PROTOCOLS.get(self.protocol)
        wants_window = (self.engine in ("windowed", "sharded")
                        or self.window.window is not None)
        if wants_window and not proto.windowed:
            raise SpecError(
                f"protocol {self.protocol!r} has no windowed engine "
                "(its state is O(N·m_app) already); use engine='vec' "
                "and drop window.window")
        if self.window.window is not None \
                and self.engine in ("vec", "exact"):
            raise SpecError(
                f"window.window={self.window.window} only applies to "
                f"engine 'windowed', 'sharded' or 'auto' (got engine="
                f"{self.engine!r}); the monolithic/exact engines would "
                "silently ignore it")
        if self.shard.devices is not None:
            if not isinstance(self.shard.devices, int) \
                    or isinstance(self.shard.devices, bool) \
                    or self.shard.devices < 1:
                raise SpecError(f"shard.devices={self.shard.devices!r} "
                                "must be an int >= 1 (or None for all "
                                "visible devices)")
            if self.engine in ("vec", "exact", "windowed"):
                raise SpecError(
                    f"shard.devices={self.shard.devices} only applies "
                    f"to engine 'sharded' or 'auto' (got engine="
                    f"{self.engine!r}); single-host engines would "
                    "silently ignore it")
            if self.shard.devices > 1 and self.backend == "numpy":
                raise SpecError(
                    f"shard.devices={self.shard.devices} needs the jax "
                    "backend (the mesh is a jax program); use "
                    "backend='jax' or 'auto'")
        if self.shard.scan not in ("auto", "on", "off"):
            raise SpecError(f"shard.scan={self.shard.scan!r} must be one "
                            "of ['auto', 'off', 'on']")
        if self.shard.scan != "auto":
            if self.engine in ("vec", "exact", "windowed"):
                raise SpecError(
                    f"shard.scan={self.shard.scan!r} only applies to "
                    f"engine 'sharded' or 'auto' (got engine="
                    f"{self.engine!r}); single-host engines would "
                    "silently ignore it")
            if self.shard.scan == "on" and self.backend == "numpy":
                raise SpecError(
                    "shard.scan='on' is a device-side lax.scan; the "
                    "numpy reference engine steps per round — use "
                    "backend='jax', 'pallas' or 'auto' (or scan='off')")
        if self.shard.profile and self.engine in ("vec", "exact",
                                                  "windowed"):
            raise SpecError(
                f"shard.profile=True only applies to engine 'sharded' "
                f"or 'auto' (got engine={self.engine!r}); single-host "
                "engines have no per-segment staging to profile")
        if self.engine == "sharded" and self.backend == "numpy":
            raise SpecError("engine 'sharded' is a jax device-mesh "
                            "program; use backend='jax', 'pallas' or "
                            "'auto'")
        if self.backend in ("jax", "pallas") and self.protocol == "vc":
            raise SpecError("protocol 'vc' is numpy-only (the delivery "
                            "drain is a data-dependent host loop); use "
                            "backend='numpy' or 'auto'")
        if self.obs.histograms is not None \
                and not isinstance(self.obs.histograms, bool):
            raise SpecError(f"obs.histograms={self.obs.histograms!r} "
                            "must be a bool or None (auto)")
        if not isinstance(self.obs.span_capacity, int) \
                or isinstance(self.obs.span_capacity, bool) \
                or self.obs.span_capacity < 1:
            raise SpecError(f"obs.span_capacity="
                            f"{self.obs.span_capacity!r} must be an "
                            "int >= 1")
        check_key(reg.SINKS, self.obs.sink, "obs.sink")
        ob = self.obs
        if ob.provenance is not None and (
                not isinstance(ob.provenance, int)
                or isinstance(ob.provenance, bool)
                or ob.provenance < 1):
            raise SpecError(f"obs.provenance={ob.provenance!r} must be "
                            "an int >= 1 (sample 1-in-N) or None")
        check_key(reg.SAMPLERS, ob.sampler, "obs.sampler")
        check_key(reg.AUDIT, ob.audit, "obs.audit")
        check_key(reg.OPS_SINKS, ob.ops_sink, "obs.ops_sink")
        if not isinstance(ob.ops_every, int) \
                or isinstance(ob.ops_every, bool) or ob.ops_every < 1:
            raise SpecError(f"obs.ops_every={ob.ops_every!r} must be an "
                            "int >= 1")
        if ob.audit != "off" and ob.provenance is None:
            raise SpecError("obs.audit consumes sampled provenance "
                            "records; set obs.provenance (e.g. 1 to "
                            "sample everything)")
        if ob.provenance is not None and self.mode != "live" \
                and self.engine in ("vec", "exact"):
            raise SpecError(
                f"obs.provenance needs a streaming engine (the hooks "
                f"ride column retirement); engine={self.engine!r} has "
                "no window to sample — use 'windowed', 'sharded' or "
                "'auto'")
        if self.mode != "live" and (ob.ops_out is not None or ob.watch):
            raise SpecError("obs.ops_out/obs.watch are the live ops "
                            "plane; they need mode='live'")
        snap = self.metrics.snapshot
        if snap is not None and not (isinstance(snap, int)
                                     or snap == "last_churn"):
            raise SpecError(f"metrics.snapshot={snap!r} must be a round "
                            "number or 'last_churn'")
        if self.mode not in ("batch", "live"):
            raise SpecError(f"mode={self.mode!r} must be 'batch' or 'live'")
        if self.mode == "live":
            check_key(reg.ARRIVALS, self.live.arrivals, "live.arrivals")
            check_key(reg.ADMISSION, self.live.admission, "live.admission")
            if self.live.messages < 1:
                raise SpecError("live.messages must be >= 1")
            if self.live.rate <= 0:
                raise SpecError("live.rate must be > 0")
            if self.live.queue_cap < 1:
                raise SpecError("live.queue_cap must be >= 1")
            if self.live.per_round_cap is not None \
                    and not (1 <= self.live.per_round_cap <= self.n):
                raise SpecError(
                    f"live.per_round_cap={self.live.per_round_cap} must "
                    f"be in [1, n={self.n}] (one broadcast per (origin, "
                    "round))")
            if self.engine not in ("auto", "windowed", "sharded"):
                raise SpecError(
                    f"mode='live' serves through the streaming engines; "
                    f"engine must be 'auto', 'windowed' or 'sharded' "
                    f"(got {self.engine!r})")
            if self.protocol == "vc":
                raise SpecError("mode='live' needs a windowed protocol; "
                                "'vc' has no streaming engine")
            if snap is not None:
                raise SpecError("metrics.snapshot is not supported in "
                                "mode='live' (segment boundaries are "
                                "load-dependent)")
            if self.scenario is not None:
                raise SpecError(
                    "mode='live' builds its own broadcast-free base "
                    "scenario from the topology/dynamics sections; a "
                    "prebuilt scenario belongs to batch mode (drive "
                    "LiveLoop directly for custom bases)")
        return self

    # ----------------------------------------------------------------- #
    # JSON round-trip
    # ----------------------------------------------------------------- #
    def to_dict(self) -> Dict[str, Any]:
        if self.scenario is not None:
            raise SpecError("a spec carrying a prebuilt scenario object "
                            "cannot be serialized to JSON")
        return dataclasses.asdict(replace(self, scenario=None))

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunSpec":
        """Build a spec from a (possibly partial) nested dict — unknown
        keys raise, missing keys take the dataclass defaults."""
        sections = dict(topology=TopologySpec, traffic=TrafficSpec,
                        dynamics=DynamicsSpec, window=WindowSpec,
                        shard=ShardSpec, live=LiveSpec,
                        metrics=MetricsSpec, obs=ObsSpec)
        kw: Dict[str, Any] = {}
        top_fields = {f.name for f in dataclasses.fields(cls)}
        for key, value in d.items():
            if key not in top_fields:
                raise SpecError(f"unknown RunSpec field {key!r}; valid "
                                f"fields: {sorted(top_fields)}")
            if key in sections:
                sect_cls = sections[key]
                if not isinstance(value, dict):
                    raise SpecError(
                        f"{key} must be an object of "
                        f"{sect_cls.__name__} fields, got {value!r} — "
                        f"e.g. {{\"{key}\": {{\"kind\": ...}}}}")
                sect_fields = {f.name for f in dataclasses.fields(sect_cls)}
                bad = set(value) - sect_fields
                if bad:
                    raise SpecError(
                        f"unknown {key} field(s) {sorted(bad)}; valid "
                        f"fields: {sorted(sect_fields)}")
                kw[key] = sect_cls(**value)
            else:
                kw[key] = value
        return cls(**kw)
