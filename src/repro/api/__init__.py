"""repro.api — the declarative experiment front door.

One spec, one call, every engine::

    from repro.api import RunSpec, TrafficSpec, run

    report = run(RunSpec(protocol="pc", n=10_000,
                         traffic=TrafficSpec(kind="poisson", rate=50.0,
                                             messages=5_000)))
    print(report.stats, report.extras["overhead_bytes_per_msg"])

A :class:`RunSpec` (``spec.py``) names protocol × engine × backend ×
topology × traffic × dynamics × window × metrics as validated dataclass
sections; the string-keyed registries (``registry.py``) make each axis
pluggable; :func:`run` (``run.py``) dispatches to the exact event
engine, the monolithic vec engine, or the streaming windowed engine —
auto-selected from an N×M memory budget when unspecified — and returns
a uniform :class:`RunReport`.  ``python -m repro.api`` exposes the same
surface as a CLI accepting spec JSON or flags.  DESIGN.md §3 documents
the schema, the registry keys, and the auto-selection rule.
"""

from ..core.vecsim import TrafficModel
from ..core.vecsim.live import AdmissionPolicy, ArrivalProcess, LiveReport
from ..obs.audit import CausalAuditor, CausalityViolationError
from ..obs.flight import FlightRecorder
from ..obs.ops import OpsPlane
from ..obs.sinks import MetricsSink
from ..obs.spans import EngineObs
from .registry import (ADMISSION, ARRIVALS, AUDIT, BACKENDS, ENGINES,
                       OPS_SINKS, PROTOCOLS, SAMPLERS, SCENARIOS, SINKS,
                       TOPOLOGIES, TRAFFIC, BackendEntry, EngineEntry,
                       ProtocolEntry, Registry, ScenarioEntry,
                       describe_entry)
from .run import (RunReport, build_live_scenario, build_scenario, run,
                  select_engine)
from .spec import (DynamicsSpec, LiveSpec, MetricsSpec, ObsSpec, RunSpec,
                   ShardSpec, SpecError, TopologySpec, TrafficSpec,
                   WindowSpec)

__all__ = [
    "RunSpec", "TopologySpec", "TrafficSpec", "DynamicsSpec", "WindowSpec",
    "ShardSpec", "LiveSpec", "MetricsSpec", "ObsSpec", "SpecError",
    "run", "RunReport", "build_scenario", "build_live_scenario",
    "select_engine", "LiveReport", "EngineObs", "MetricsSink",
    "Registry", "ProtocolEntry", "EngineEntry", "BackendEntry",
    "ScenarioEntry", "TrafficModel", "ArrivalProcess", "AdmissionPolicy",
    "describe_entry",
    "FlightRecorder", "CausalAuditor", "CausalityViolationError",
    "OpsPlane",
    "PROTOCOLS", "ENGINES", "BACKENDS", "TOPOLOGIES", "TRAFFIC",
    "SCENARIOS", "ARRIVALS", "ADMISSION", "SINKS",
    "SAMPLERS", "AUDIT", "OPS_SINKS",
]
