"""Pipeline parallelism (GPipe schedule) as a composable JAX transform.

``pipeline(stage_fn)`` runs a stack of S stages (params stacked on the
leading axis, sharded one-per-device over a ``stage`` mesh axis) over M
microbatches with the classic skewed clock: tick t feeds stage s the
microbatch (t - s), activations hop stage->stage via ``ppermute``.  The
whole schedule is a ``lax.scan`` inside ``shard_map``, so:

  * forward fills/drains the pipeline in M + S - 1 ticks (bubble
    fraction (S-1)/(M+S-1) — the standard GPipe bubble);
  * JAX AD differentiates straight through (ppermute transposes to the
    reverse shift), recovering the backward pipeline automatically;
  * per-stage remat bounds stashed activations to one microbatch per
    tick per stage.

The model stack plugs in by treating one superblock (or a run of them)
as ``stage_fn`` — see tests/test_pipeline.py for the wiring; the
production mesh would carry a ("stage", "data", "model") layout with
this transform on the outermost axis.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["pipeline"]


def pipeline(stage_fn: Callable, mesh: Mesh, axis: str = "stage",
             remat_stage: bool = True):
    """Build a pipelined apply: (stacked_params, microbatches) -> outputs.

    stage_fn(params_slice, x) -> y  must map (B, ...) -> (B, ...) with the
    same shape/dtype (a residual-stream stage).

    stacked_params: pytree with leading dim S (sharded over ``axis``);
    microbatches:   (M, B, ...) array (replicated over ``axis``).
    Returns (M, B, ...) outputs of the last stage.
    """
    n_stage = mesh.shape[axis]
    fn = jax.checkpoint(stage_fn) if remat_stage else stage_fn

    def run(params, mb):
        m = mb.shape[0]
        ticks = m + n_stage - 1

        def local(params_l, mb_l):
            # params_l: (1, ...) this device's stage; mb_l: (M, B, ...)
            p_here = jax.tree.map(lambda t: t[0], params_l)
            sid = jax.lax.axis_index(axis)
            state = jnp.zeros_like(mb_l[0])          # current activation
            outs = jnp.zeros_like(mb_l)              # last stage collects

            def tick(carry, t):
                state, outs = carry
                # stage 0 ingests microbatch t (when in range)
                feed = mb_l[jnp.clip(t, 0, m - 1)]
                x = jnp.where(sid == 0, feed, state)
                y = fn(p_here, x)
                # last stage emits microbatch (t - S + 1)
                out_idx = t - (n_stage - 1)
                valid = (out_idx >= 0) & (sid == n_stage - 1)
                outs = jax.lax.dynamic_update_index_in_dim(
                    outs,
                    jnp.where(valid, y, outs[jnp.clip(out_idx, 0, m - 1)]),
                    jnp.clip(out_idx, 0, m - 1), axis=0)
                # hop to the next stage
                nxt = jax.lax.ppermute(
                    y, axis, [(i, (i + 1) % n_stage)
                              for i in range(n_stage)])
                return (nxt, outs), None

            (state, outs), _ = jax.lax.scan(
                tick, (state, outs), jnp.arange(ticks))
            # only the last stage ever wrote into ``outs`` (others kept
            # zeros), so a psum over the stage axis replicates the result
            return jax.lax.psum(outs, axis)

        from jax.experimental.shard_map import shard_map
        run_sharded = shard_map(
            local, mesh=mesh,
            in_specs=(P(axis), P()),       # params sharded, mb replicated
            out_specs=P(),                  # replicated output
            check_rep=False,
        )
        return run_sharded(params, mb)

    return run
