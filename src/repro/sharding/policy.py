"""Logical-axis sharding policy (MaxText-style axis rules).

Every parameter leaf carries a tuple of logical axis names (assigned at
init, see repro.models.*).  A *policy* maps logical names to mesh axes;
``build_specs`` turns (shapes, axes, policy, mesh) into PartitionSpecs
with two safety rules applied left-to-right per leaf:

  * divisibility — a mesh axis is only assigned if it divides the dim
    (this is what routes grok-1's 8 experts to d_ff TP while qwen3-moe's
    128 experts get true expert parallelism, with no per-arch code);
  * uniqueness  — a mesh axis is used at most once per leaf.

Policies:
  * ``tp``       — tensor parallelism on "model"; params replicated over
    the data axes (small models);
  * ``fsdp``     — tp + remaining dims sharded over ("pod","data")
    (fully-sharded params for big models);
  * optimizer states always use the fsdp rules (ZeRO-1): m/v are sharded
    over data even when params are tp-replicated.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["rules_for", "build_specs", "param_policy", "batch_spec",
           "cache_specs", "named", "FSDP_THRESHOLD"]

# parameters above this count get fully-sharded (fsdp) treatment
FSDP_THRESHOLD = 15e9

MeshAxes = Tuple[str, ...]


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def rules_for(policy: str, mesh: Mesh) -> Dict[str, Any]:
    dp = _dp_axes(mesh)
    model = "model"
    if policy == "serve2d":
        # Serving layout for models too big for plain TP: weight matrices
        # shard over (data x model) JOINTLY and stay resident — no
        # per-layer parameter all-gather on the decode path (grads/opt
        # don't exist when serving, so "data" is free for weights; the
        # tiny per-token activations get gathered instead).  §Perf.
        model = tuple(dp) + ("model",)
    rules: Dict[str, Any] = {
        "vocab": model,
        "q_proj": model,
        "kv_proj": model,
        "mlp": model,
        "expert": model,
        "lru": model,
        "ssm_in": model,
        "ssm_inner": model,
        "ssm_conv": model,
        "embed": dp if policy == "fsdp" else None,
        "head_dim": None,
        "ssm_heads": None,
        "layers": None,       # scan axis stays unsharded
    }
    return rules


def param_policy(cfg) -> str:
    return "fsdp" if cfg.param_count() > FSDP_THRESHOLD else "tp"


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return mesh.shape[ax]


def _spec_for_leaf(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                   rules: Dict[str, Any], mesh: Mesh) -> P:
    assert len(shape) == len(axes), (shape, axes)
    used: set = set()
    out = []
    for dim, name in zip(shape, axes):
        ax = rules.get(name) if name is not None else None
        flat = tuple(ax) if isinstance(ax, tuple) else ((ax,) if ax else ())
        if (ax is not None and not (set(flat) & used)
                and dim % _axis_size(mesh, ax) == 0 and dim > 0):
            out.append(ax)
            used.update(flat)
        else:
            out.append(None)
    return P(*out)


def build_specs(shapes, axes, policy: str, mesh: Mesh):
    """shapes/axes: matching pytrees (ShapeDtypeStructs + logical tuples).
    Returns a pytree of PartitionSpecs."""
    rules = rules_for(policy, mesh)
    return _tree_specs(shapes, axes, rules, mesh)


def _tree_specs(shapes, axes, rules, mesh):
    # axes leaves are tuples-of-strings; walk the two trees together with
    # the axes tree's structure defining the leaves.
    flat_axes, treedef = jax.tree.flatten(
        axes, is_leaf=lambda t: isinstance(t, tuple)
        and all(isinstance(x, (str, type(None))) for x in t))
    flat_shapes = treedef.flatten_up_to(shapes)
    specs = [_spec_for_leaf(s.shape, a, rules, mesh)
             for s, a in zip(flat_shapes, flat_axes)]
    return jax.tree.unflatten(treedef, specs)


def batch_spec(mesh: Mesh, ndim: int, batch_divisible: bool = True) -> P:
    """Batch-leading activations: shard batch over (pod, data) when the
    global batch divides; everything else replicated."""
    dp = _dp_axes(mesh)
    lead = dp if (batch_divisible and dp) else None
    return P(lead, *([None] * (ndim - 1)))


def cache_specs(cfg, mesh: Mesh, batch: int, seq: int):
    """PartitionSpec factory for serving caches.

    attention (B, S, KV, D): batch over dp when divisible; KV heads over
    "model" when divisible, else the sequence axis takes "model" (context
    sharding) — the policy that keeps 32k caches inside HBM for GQA archs
    whose few KV heads don't divide the model axis."""
    dp = _dp_axes(mesh)
    dp_ok = batch % _axis_size(mesh, dp) == 0 if dp else False
    b_ax = dp if dp_ok else None
    m = mesh.shape["model"]

    def attn(kv_heads: int, cache_len: int) -> P:
        if kv_heads % m == 0:
            return P(b_ax, None, "model", None)
        if cache_len % m == 0:
            return P(b_ax, "model", None, None)
        return P(b_ax, None, None, None)

    return dict(
        attn=attn,
        conv=lambda c: P(b_ax, None, "model" if c % m == 0 else None),
        lru_h=lambda w: P(b_ax, "model" if w % m == 0 else None),
        ssm_h=lambda h: P(b_ax, "model" if h % m == 0 else None, None, None),
        batch_axis=b_ax,
    )


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda t: isinstance(t, P))


def reshard_tree(tree, axes, policy: str = "tp"):
    """Best-effort re-shard of a param tree to ``policy`` rules under the
    ambient mesh (no-op without one).  Used to hoist FSDP->TP parameter
    all-gathers to once-per-step instead of once-per-microbatch: the
    forward/backward consume the TP view while optimizer state stays
    fully sharded (§Perf)."""
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty:
        return tree
    rules = rules_for(policy, mesh)
    specs = _tree_specs(tree, axes, rules, mesh)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, specs)


def constrain(x, *spec):
    """Best-effort ``with_sharding_constraint``: applied only when a mesh
    with the named axes is active and every constrained dim divides.

    Model code calls this at sharding-critical intermediates (e.g. MoE
    dispatch buffers) so the SPMD partitioner keeps them distributed
    instead of falling back to replicate+all-reduce; on meshless CPU runs
    it is a no-op, keeping smoke tests mesh-free."""
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty:
        return x
    names = set(mesh.axis_names)
    out = []
    for dim, ax in zip(x.shape, spec):
        flat = () if ax is None else ((ax,) if isinstance(ax, str)
                                      else tuple(ax))
        flat = tuple(a for a in flat if a in names)   # drop absent axes
        if flat:
            size = int(np.prod([mesh.shape[a] for a in flat]))
            if dim % size == 0 and dim > 0:
                out.append(flat[0] if len(flat) == 1 else flat)
                continue
        out.append(None)
    if all(o is None for o in out):
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))
