"""CausalGossipTrainer — the paper's protocol as a training control plane.

Each pod is a PC-broadcast *process* (``repro.core``); the overlay between
pods is the paper's dynamic network.  Training is DiLoCo-style local SGD:

  1. a pod runs H local AdamW steps on its data shard;
  2. it computes the outer update (pseudo-gradient) vs. its round anchor,
     optionally top-k + error-feedback compressed;
  3. it PC-broadcasts the update: O(1) control metadata (<pod, counter>),
     tensors ride the data plane (a blob store keyed by message id —
    control/data split as in real fleets);
  4. every pod folds in updates **in causal order** upon delivery: if pod
     B computed its update after observing A's, no pod ever applies B's
     before A's — model lineage stays monotone with zero vector clocks.

Elasticity is the paper's own mechanism: pod joins add links that stay
*unsafe* until the ping phase completes (Algorithm 2), silent pod deaths
exhaust retries and the link is abandoned (Algorithm 3).  A joining pod
bootstraps weights from any neighbor (state transfer) and then receives
causally-ordered updates like everyone else.

Everything runs on the deterministic event simulator, so tests can assert
"no causal violation, loss decreases, replicas agree" under churn, delay,
and crash schedules.  The same Pod state machine would drive a real
transport (each pod = one pjit'd multi-chip pod; see DESIGN.md §2.2).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BoundedPCBroadcast, Network
from repro.core.base import AppMsg
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.training.compression import (ErrorFeedback, payload_bytes,
                                        topk_decompress)
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.step import make_train_step

__all__ = ["GossipConfig", "Pod", "CausalGossipTrainer"]


@dataclass
class GossipConfig:
    local_steps: int = 4            # H: inner steps per round
    outer_lr: float = 0.7           # mixing rate for foreign updates
    compress_frac: float = 0.0      # 0 = dense updates
    inner: AdamWConfig = field(default_factory=lambda: AdamWConfig(lr=1e-2))
    round_time: float = 1.0         # simulated seconds per round
    ping_timeout: float = 30.0
    max_retry: int = 5
    max_buffer: int = 256


class BlobStore:
    """Data plane: update tensors keyed by (pod, counter) message id."""

    def __init__(self):
        self.blobs: Dict[Tuple[int, int], Any] = {}
        self.bytes_stored = 0

    def put(self, mid, tree, nbytes: int):
        self.blobs[mid] = tree
        self.bytes_stored += nbytes

    def get(self, mid):
        return self.blobs[mid]


class Pod:
    """One training pod: local model replica + PC-broadcast endpoint."""

    def __init__(self, pid: int, model, cfg: GossipConfig, data_cfg,
                 store: BlobStore, seed: int = 0, shared_step=None):
        self.pid = pid
        self.model = model
        self.cfg = cfg
        self.store = store
        self.params, _ = model.init(jax.random.PRNGKey(seed))
        self.opt_state = init_opt_state(self.params)
        # pods share one jitted step (same config => same XLA program)
        self.train_step = (shared_step if shared_step is not None
                           else jax.jit(make_train_step(model, cfg.inner)))
        self.data = SyntheticLM(dataclasses.replace(data_cfg, shard=pid))
        self.data_step = 0
        self.round = 0
        self.applied: List[Tuple[int, int]] = []    # causal apply log
        self.losses: List[float] = []
        self.ef = (ErrorFeedback(cfg.compress_frac)
                   if cfg.compress_frac else None)
        self.proto = BoundedPCBroadcast(
            pid, deliver_cb=self._on_deliver, ping_mode="route",
            direct_ping_fallback=True,   # fresh-joiner bootstrap; history
                                         # arrives via adopt_weights()
            max_size=cfg.max_buffer, max_retry=cfg.max_retry,
            ping_timeout=cfg.ping_timeout)
        self.alive = True

    # ---------------- inner optimization ------------------------------ #
    def local_round(self) -> float:
        anchor = self.params
        loss = float("nan")
        for _ in range(self.cfg.local_steps):
            batch = {k: jnp.asarray(v)
                     for k, v in self.data.batch(self.data_step).items()}
            self.params, self.opt_state, m = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(m["loss"])
            self.data_step += 1
        self.losses.append(loss)
        self.round += 1
        # outer update (pseudo-gradient): anchor - new
        delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                             anchor, self.params)
        return loss, delta

    # ---------------- gossip plane ------------------------------------ #
    def publish(self, delta) -> AppMsg:
        nbytes = sum(x.nbytes for x in jax.tree.leaves(delta))
        if self.ef is not None:
            ctree = self.ef.compress(delta)
            nbytes = payload_bytes(ctree)
            blob = ("topk", ctree)
        else:
            blob = ("dense", delta)
        m = self.proto.broadcast(payload=("update", self.round))
        self.store.put((m.origin, m.counter), blob, nbytes)
        return m

    def _on_deliver(self, pid: int, msg: AppMsg) -> None:
        """Causal delivery: fold the update into the local replica."""
        if msg.origin == self.pid:
            return  # own update is already in params (and precedes the
                    # blob store write inside publish())
        mid = (msg.origin, msg.counter)
        kind, blob = self.store.get(mid)
        delta = topk_decompress(blob) if kind == "topk" else blob
        lr = self.cfg.outer_lr / 2.0
        self.params = jax.tree.map(
            lambda p, d: (p - lr * d.astype(jnp.float32)).astype(p.dtype),
            self.params, delta)
        self.applied.append(mid)

    # ---------------- elasticity --------------------------------------- #
    def adopt_weights(self, other: "Pod") -> None:
        """State transfer at join: copy a live neighbor's replica."""
        self.params = jax.tree.map(jnp.array, other.params)
        self.opt_state = init_opt_state(self.params)


class CausalGossipTrainer:
    """Drives N pods over the event-simulated overlay."""

    def __init__(self, model_factory: Callable[[], Any], n_pods: int,
                 cfg: GossipConfig, data_cfg, seed: int = 0,
                 delay: float = 0.05):
        self.cfg = cfg
        self.net = Network(seed=seed, default_delay=delay,
                           oob_delay=delay / 2)
        self.store = BlobStore()
        self.model_factory = model_factory
        self.data_cfg = data_cfg
        self.pods: Dict[int, Pod] = {}
        self._next_pid = 0
        self._shared_step = jax.jit(
            make_train_step(model_factory(), cfg.inner))
        for _ in range(n_pods):
            self._spawn()
        pids = list(self.pods)
        for i, p in enumerate(pids):      # ring + chord bootstrap overlay
            self.net.connect(p, pids[(i + 1) % len(pids)])
            if len(pids) > 3:
                self.net.connect(p, pids[(i + len(pids) // 2) % len(pids)])

    def _spawn(self) -> Pod:
        pid = self._next_pid
        self._next_pid += 1
        pod = Pod(pid, self.model_factory(), self.cfg, self.data_cfg,
                  self.store, seed=0, shared_step=self._shared_step)
        self.pods[pid] = pod
        self.net.add_process(pod.proto)
        return pod

    # ---------------- elastic membership ------------------------------- #
    def join(self, neighbors: Optional[List[int]] = None) -> int:
        """A new pod joins mid-run: weights from a neighbor, links gated
        by ping phases (the paper's Algorithm 2 doing elastic scaling)."""
        pod = self._spawn()
        alive = [p for p in self.pods.values()
                 if p.alive and p.pid != pod.pid]
        neighbors = neighbors or [p.pid for p in
                                  alive[-3:]]  # arbitrary live subset
        pod.adopt_weights(self.pods[neighbors[0]])
        for q in neighbors:
            self.net.connect(pod.pid, q)
            self.net.connect(q, pod.pid)
        return pod.pid

    def leave(self, pid: int, graceful: bool = True) -> None:
        self.pods[pid].alive = False
        if graceful:
            self.net.depart(pid)
        else:
            self.net.crash(pid)          # silent: Algorithm 3 cleans up

    # ---------------- main loop ---------------------------------------- #
    def run_rounds(self, n_rounds: int,
                   churn: Optional[Callable[[int, "CausalGossipTrainer"],
                                            None]] = None,
                   stragglers: Optional[Dict[int, int]] = None):
        """``stragglers`` maps pid -> period: that pod only completes a
        round every ``period`` rounds (simulating slow hardware).  Because
        dissemination is non-blocking causal broadcast, nobody waits — the
        straggler just contributes updates less often (the paper's
        no-global-barrier property doing straggler mitigation)."""
        stragglers = stragglers or {}
        for r in range(n_rounds):
            for pod in list(self.pods.values()):
                if not pod.alive:
                    continue
                period = stragglers.get(pod.pid, 1)
                if period > 1 and r % period:
                    continue                    # straggler sits this one out
                loss, delta = pod.local_round()
                pod.publish(delta)
                # interleave protocol traffic with compute
                self.net.run(until=self.net.time + self.cfg.round_time / 4)
            if churn is not None:
                churn(r, self)
            self.net.run(until=self.net.time + self.cfg.round_time)
        self.net.run(until=self.net.time + 100 * self.cfg.round_time)

    # ---------------- diagnostics --------------------------------------- #
    def mean_loss(self, last: int = 1) -> float:
        vals = [np.mean(p.losses[-last:]) for p in self.pods.values()
                if p.alive and p.losses]
        return float(np.mean(vals))

    def replica_drift(self) -> float:
        """Max parameter L2 distance between live replicas."""
        live = [p for p in self.pods.values() if p.alive]
        if len(live) < 2:
            return 0.0
        flats = [np.concatenate([np.asarray(x).ravel() for x in
                                 jax.tree.leaves(p.params)]) for p in live]
        ref = flats[0]
        return float(max(np.linalg.norm(f - ref) /
                         (np.linalg.norm(ref) + 1e-9) for f in flats[1:]))

    def causal_report(self):
        from repro.core import check_trace
        crashed = {p.pid for p in self.pods.values() if not p.alive}
        return check_trace(self.net.trace, crashed=crashed,
                           check_agreement=False)
