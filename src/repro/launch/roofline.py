"""Roofline accounting from compiled dry-run artifacts (DESIGN.md §6).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs        / (chips x 197 TFLOP/s bf16)
    memory     = HLO_bytes        / (chips x 819 GB/s HBM)
    collective = collective_bytes / (chips x 50 GB/s link)

``HLO_FLOPs``/``HLO_bytes`` come from ``compiled.cost_analysis()`` of
*unrolled* layer-count variants (L, L') so per-layer costs are exact
(XLA counts a while body once, so scanned modules cannot be costed
directly); collective bytes are parsed from the partitioned HLO text with
ring-model wire costs.  ``MODEL_FLOPS`` is the analytic 6·N·D (dense) /
6·N_active·D (MoE) plus attention/SSD terms, so the useful-compute ratio
exposes remat and dispatch overheads.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass
from typing import Dict, List, Tuple

__all__ = ["HW", "parse_collectives", "terms_from", "model_flops",
           "dominant"]

# TPU v5e hardware model (per chip)
HW = dict(peak_flops=197e12, hbm_bw=819e9, link_bw=50e9, hbm_bytes=16e9)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(?P<types>[^=]*?)\s*(?P<op>all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(", )
_TYPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                      r"pred|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_RE2 = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _result_bytes(types: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(types):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_RE2.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring cost model).

    HLO shapes in a partitioned module are per-device, so:
      all-gather: result x (g-1)/g   (receives g-1 chunks of result/g)
      all-reduce: 2 x result x (g-1)/g
      reduce-scatter: result x (g-1)  (result is the 1/g shard)
      all-to-all: result x (g-1)/g
      collective-permute: result
    ``-done`` lines carry no replica_groups and are skipped via -start
    matching plus plain ops."""
    out: Dict[str, float] = {}
    total = 0.0
    for line in hlo.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        size = _result_bytes(m.group("types"))
        g = _group_size(line)
        if op == "collective-permute":
            # no replica_groups attribute; wire = moved bytes
            wire = float(size) if "source_target_pairs" in line else 0.0
            out[op] = out.get(op, 0.0) + wire
            total += wire
            continue
        if g <= 1:
            wire = 0.0
        elif op == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif op == "all-gather":
            wire = size * (g - 1) / g
        elif op == "reduce-scatter":
            wire = float(size) * (g - 1)
        elif op == "all-to-all":
            wire = size * (g - 1) / g
        else:  # collective-permute
            wire = float(size)
        out[op] = out.get(op, 0.0) + wire
        total += wire
    out["total"] = total
    return out


def terms_from(flops: float, bytes_hbm: float, wire_per_device: float,
               chips: int) -> Dict[str, float]:
    """Three roofline terms in seconds.  ``flops``/``bytes_hbm`` are
    whole-step totals across chips; wire bytes are per-device (HLO is the
    per-device program) so collective_bytes = wire x chips."""
    compute = flops / (chips * HW["peak_flops"])
    memory = bytes_hbm / (chips * HW["hbm_bw"])
    coll = (wire_per_device * chips) / (chips * HW["link_bw"])
    return dict(compute=compute, memory=memory, collective=coll)


def dominant(terms: Dict[str, float]) -> str:
    return max(("compute", "memory", "collective"), key=lambda k: terms[k])


# ------------------------------------------------------------------ #
# analytic MODEL_FLOPS
# ------------------------------------------------------------------ #
def model_flops(cfg, shape) -> float:
    """Useful FLOPs per step (global): the 6·N·D convention + attention.

    train: 6 x active-params x tokens + attention/SSD sequence terms
    prefill: 2 x active-params x tokens + fwd attention
    decode: 2 x active-params x batch (one token per sequence)."""
    b, s = shape.global_batch, shape.seq_len
    n_active = cfg.active_param_count()
    kinds = cfg.layer_kinds()

    def seq_extra(mult: float, seq: int) -> float:
        """attention-like S^2 terms; coefficient convention: the causal
        QK^T+PV pair costs 2*B*S*span*H*hd flops forward (2 matmuls x 2
        flops / 2 causal), so mult = 2 for fwd-only and 6 for training."""
        total = 0.0
        for kind in kinds:
            if kind == "attn":
                win = cfg.window or seq
                kv_span = min(seq, win)
                total += mult * b * seq * kv_span * cfg.num_heads * \
                    cfg.head_dim  # QK^T + PV, causal halving folded in
            elif kind == "ssm":
                q, n, h, p = (cfg.ssm_chunk, cfg.ssm_state, cfg.ssm_heads,
                              cfg.ssm_head_dim)
                fwd = 2 * b * seq * (q * n + h * q * p + 2 * h * n * p)
                total += fwd * (mult / 2)
            elif kind == "rec":
                w = cfg.lru_width or cfg.d_model
                total += (mult / 2) * 2 * b * seq * 4 * w  # gates+scan, cheap
        if cfg.is_encdec:
            # encoder self-attn + decoder cross-attn
            es = cfg.encoder_seq
            total += cfg.encoder_layers * mult * b * es * es * \
                cfg.num_heads * cfg.head_dim
            total += len(kinds) * mult * b * seq * es * cfg.num_heads * \
                cfg.head_dim
        return total

    if shape.kind == "train":
        return 6.0 * n_active * b * s + seq_extra(6.0, s)
    if shape.kind == "prefill":
        return 2.0 * n_active * b * s + seq_extra(2.0, s)
    # decode: one token per sequence against an s-long context
    attn_read = 0.0
    for kind in kinds:
        if kind == "attn":
            span = min(s, cfg.window or s)
            attn_read += 4.0 * b * span * cfg.num_heads * cfg.head_dim
        elif kind == "ssm":
            attn_read += 4.0 * b * cfg.ssm_heads * cfg.ssm_state * \
                cfg.ssm_head_dim
    return 2.0 * n_active * b + attn_read
