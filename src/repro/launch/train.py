"""Training launcher.

Two modes:

  * ``--mode spmd``   — single-pod synchronous training: pjit'd train step
    over the local mesh (the production-mesh variant of the same step is
    what the dry-run proves at 16x16 / 2x16x16);
  * ``--mode gossip`` — multi-pod causal-gossip training (the paper's
    protocol as the cross-pod plane), simulated in-process: N pods, local
    AdamW + PC-broadcast outer updates, optional churn and compression.

Both checkpoint/restart through ``repro.checkpoint`` (atomic, resharding
restores, deterministic data resume).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --preset smoke \
      --steps 50 --ckpt-dir /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --mode gossip --pods 4 \
      --rounds 10 --churn
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import ARCHS, get_arch
from repro.data.pipeline import DataConfig, SyntheticLM, prefetch
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.step import make_train_step


def spmd_main(args):
    cfg = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = replace(cfg.smoke(), compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, remat=args.remat)
    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=args.lr), microbatches=args.microbatches))

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq_len,
                                  args.batch, seed=args.seed))
    start_step = 0
    params = opt_state = None
    if args.ckpt_dir and (s := ckpt.latest_step(args.ckpt_dir)) is not None:
        print(f"resuming from step {s}")
        model_tmp, _ = None, None
        params, _ = model.init(jax.random.PRNGKey(args.seed))
        opt_state = init_opt_state(params)
        state, meta = ckpt.restore(args.ckpt_dir, s, like={
            "params": params, "opt": opt_state._asdict()})
        params = state["params"]
        from repro.training.optimizer import OptState
        opt_state = OptState(**state["opt"])
        start_step = meta["data_step"]
    if params is None:
        params, _ = model.init(jax.random.PRNGKey(args.seed))
        opt_state = init_opt_state(params)

    it = prefetch(data.iterate(start_step))
    t0 = time.time()
    for i, batch in enumerate(it):
        step = start_step + i
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % args.log_every == 0:
            dt = time.time() - t0
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} ({dt:.1f}s)",
                  flush=True)
        if args.ckpt_dir and step and step % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step,
                      {"params": params, "opt": opt_state._asdict()},
                      meta={"data_step": step + 1, "arch": cfg.name})
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps,
                  {"params": params, "opt": opt_state._asdict()},
                  meta={"data_step": args.steps, "arch": cfg.name})
    print(f"done: final loss {float(m['loss']):.4f}")
    return float(m["loss"])


def gossip_main(args):
    from repro.runtime.gossip import CausalGossipTrainer, GossipConfig
    cfg = get_arch(args.arch)
    cfg = replace(cfg.smoke(), compute_dtype="float32",
                  param_dtype="float32")
    dc = DataConfig(cfg.vocab_size, args.seq_len, args.batch,
                    seed=args.seed)
    g = GossipConfig(local_steps=args.local_steps,
                     compress_frac=args.compress)
    tr = CausalGossipTrainer(lambda: build_model(cfg, remat="none"),
                             args.pods, g, dc, seed=args.seed)

    def churn(r, t):
        if not args.churn:
            return
        if r == args.rounds // 3:
            pid = t.join()
            print(f"[round {r}] pod {pid} joined (ping-phase gated)")
        if r == 2 * args.rounds // 3:
            victim = next(p.pid for p in t.pods.values() if p.alive)
            t.leave(victim, graceful=False)
            print(f"[round {r}] pod {victim} crashed silently")

    for r in range(args.rounds):
        tr.run_rounds(1, churn=churn if args.churn else None)
        print(f"round {r:3d} mean_loss {tr.mean_loss():.4f} "
              f"drift {tr.replica_drift():.4f}", flush=True)
    rep = tr.causal_report()
    print("causal check:", rep.summary())
    assert rep.causal_ok and not rep.double_deliveries
    return tr.mean_loss()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["spmd", "gossip"], default="spmd")
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    # gossip
    ap.add_argument("--pods", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--compress", type=float, default=0.0)
    ap.add_argument("--churn", action="store_true")
    args = ap.parse_args()
    if args.mode == "spmd":
        spmd_main(args)
    else:
        gossip_main(args)


if __name__ == "__main__":
    main()
