"""ShapeDtypeStruct stand-ins + PartitionSpecs for every model input.

``input_specs(cfg, shape, mesh)`` returns (abstract_inputs, in_specs) for
the step function the (arch x shape) cell lowers: train_step for train
shapes, prefill/decode for serving shapes.  Nothing here allocates device
memory — params, optimizer state and caches are all abstract.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.transformer import (StackSpec, cache_seq_len,
                                      init_stack_cache, stack_layout)
from repro.sharding.policy import (batch_spec, build_specs, cache_specs,
                                   param_policy)
from repro.training.optimizer import OptState

__all__ = ["input_specs", "shapes_and_axes", "abstract_opt_state",
           "make_batch", "make_serving_inputs", "param_specs", "opt_specs"]


def input_specs(cfg: "ArchConfig", shape: "ShapeSpec", mesh):
    """ShapeDtypeStruct stand-ins for every model input of a cell
    (weak-type-correct, shardable, no device allocation).

    train/prefill -> (batch dict, spec dict); decode -> ((token, caches,
    cur_index), specs).  The dry-run driver composes these with the
    abstract params/optimizer state (`shapes_and_axes`,
    `abstract_opt_state`)."""
    if shape.kind == "decode":
        return make_serving_inputs(cfg, shape, mesh)
    return make_batch(cfg, shape, mesh,
                      with_labels=(shape.kind == "train"))


def shapes_and_axes(model, key=None):
    """(param ShapeDtypeStructs, logical-axes pytree) without allocating.

    The axes tree (pure-python tuples) leaves ``init`` via a side channel
    so only the array pytree is traced by eval_shape."""
    key = key if key is not None else jax.random.PRNGKey(0)
    box: Dict[str, Any] = {}

    def f(k):
        p, a = model.init(k)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(f, key)
    return shapes, box["axes"]


def abstract_opt_state(param_shapes, master_weights: bool = False
                       ) -> OptState:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return OptState(m=jax.tree.map(f32, param_shapes),
                    v=jax.tree.map(f32, param_shapes),
                    step=jax.ShapeDtypeStruct((), jnp.int32),
                    master=(jax.tree.map(f32, param_shapes)
                            if master_weights else None))


def param_specs(cfg, param_shapes, axes, mesh, policy: Optional[str] = None):
    return build_specs(param_shapes, axes, policy or param_policy(cfg), mesh)


def opt_specs(cfg, param_shapes, axes, mesh, master_weights: bool = False):
    """ZeRO-1: moments (and the f32 master copy) always use fsdp rules."""
    mspec = build_specs(param_shapes, axes, "fsdp", mesh)
    return OptState(m=mspec, v=mspec, step=P(),
                    master=mspec if master_weights else None)


# ------------------------------------------------------------------ #
# batches (train / prefill)
# ------------------------------------------------------------------ #
def make_batch(cfg: ArchConfig, shape: ShapeSpec, mesh,
               with_labels: bool = True):
    """(abstract batch dict, spec dict) for train/prefill inputs."""
    b, s = shape.global_batch, shape.seq_len
    cd = jnp.dtype(cfg.compute_dtype)
    dp = batch_spec(mesh, 2, b % _dp_size(mesh) == 0)
    batch: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        # VLM stub: precomputed patch/text embeddings + 3D M-RoPE positions
        batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cd)
        specs["embeds"] = P(dp[0], None, None)
        batch["positions"] = jax.ShapeDtypeStruct((b, 3, s), jnp.int32)
        specs["positions"] = P(dp[0], None, None)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["tokens"] = dp
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cd)
        specs["enc_embeds"] = P(dp[0], None, None)
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = dp
    return batch, specs


def _dp_size(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a in ("pod", "data")]))


# ------------------------------------------------------------------ #
# serving caches (decode)
# ------------------------------------------------------------------ #
def make_serving_inputs(cfg: ArchConfig, shape: ShapeSpec, mesh):
    """(abstract (token, caches, cur_index), specs) for decode cells."""
    b, s = shape.global_batch, shape.seq_len
    cd = jnp.dtype(cfg.compute_dtype)
    pol = cache_specs(cfg, mesh, b, s)
    layout = stack_layout(cfg)

    caches = jax.eval_shape(
        lambda: [_full_stack_cache(cfg, spec, b, s, cd) for spec in layout])
    specs = [_stack_cache_specs(cfg, spec, pol, s) for spec in layout]

    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    token_spec = P(pol["batch_axis"])
    cur = jax.ShapeDtypeStruct((), jnp.int32)
    return (token, caches, cur), (token_spec, specs, P())


def _full_stack_cache(cfg, spec: StackSpec, b: int, s: int, dtype):
    out = init_stack_cache(cfg, spec, b, s, dtype)
    if cfg.is_encdec:
        for i, kind in enumerate(spec.pattern):
            shp = (spec.n_rep, b, cfg.encoder_seq, cfg.num_kv_heads,
                   cfg.head_dim)
            out[f"b{i}_x"] = (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))
    return out


def _stack_cache_specs(cfg, spec: StackSpec, pol, s: int):
    out: Dict[str, Any] = {}

    def lift(p: P) -> P:              # prepend the stacked (n_rep) axis
        return P(None, *p)

    for i, kind in enumerate(spec.pattern):
        if kind == "attn":
            cl = cache_seq_len(cfg, "attn", s)
            sp = lift(pol["attn"](cfg.num_kv_heads, cl))
            out[f"b{i}"] = (sp, sp)
        elif kind == "rec":
            w = cfg.lru_width or cfg.d_model
            conv = lift(pol["conv"](w))
            h = lift(pol["lru_h"](w))
            out[f"b{i}"] = (conv, h)
        else:  # ssm
            conv = lift(pol["conv"](cfg.d_inner + 2 * cfg.ssm_state))
            h = lift(pol["ssm_h"](cfg.ssm_heads))
            out[f"b{i}"] = (conv, h)
        if cfg.is_encdec:
            sp = lift(pol["attn"](cfg.num_kv_heads, cfg.encoder_seq))
            out[f"b{i}_x"] = (sp, sp)
    return out
