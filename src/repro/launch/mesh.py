"""Production meshes.  Functions, never module-level constants — importing
this module must not touch jax device state."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (16 data x 16 model).  Multi-pod: 2 pods
    = 512 chips as (2 pod x 16 data x 16 model); the "pod" axis carries
    either synchronous gradient reduction (the dry-run's proof obligation)
    or — in the causal-gossip deployment — nothing inside the step, with
    PC-broadcast handling cross-pod update dissemination out-of-band."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = jax.device_count()
    assert data * model <= n, (data, model, n)
    types = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((data, model), ("data", "model"), axis_types=types)
