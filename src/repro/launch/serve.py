"""Serving launcher: batched generation with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8
"""

from __future__ import annotations

import argparse
import time
from dataclasses import replace

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serving.engine import Request, ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = replace(get_arch(args.arch).smoke(), compute_dtype="float32",
                  param_dtype="float32")
    model = build_model(cfg, remat="none")
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    eng = ServingEngine(model, params,
                        ServeConfig(batch=args.slots, max_len=args.max_len,
                                    seed=args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        r = Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=plen).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
        reqs.append(r)
        eng.submit(r)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in reqs)
    for r in reqs:
        print(f"req {r.rid:2d} prompt[{len(r.prompt):2d}] -> "
              f"{r.out_tokens}")
    print(f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s, {eng.ticks} engine ticks, "
          f"batch-efficiency {total_tokens/max(eng.ticks,1):.2f} tok/tick)")


if __name__ == "__main__":
    main()
