import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every runnable (arch x shape) cell and both production meshes this
lowers + compiles the real step function against ShapeDtypeStruct inputs
(no allocation), prints memory_analysis()/cost_analysis(), and — for the
roofline — compiles small *unrolled* layer-count variants whose finite
differences give exact per-layer flops/bytes/collective-wire costs
(DESIGN.md §6; XLA cost analysis counts scan bodies once, so the scanned
full-model compile proves shardability+memory while the unrolled variants
price the layers).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] --out dryrun.json
"""

import argparse
import json
import sys
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get_arch, runnable_shapes
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (dominant, model_flops, parse_collectives,
                                   terms_from)
from repro.launch.specs import (abstract_opt_state, make_batch,
                                make_serving_inputs, opt_specs, param_specs,
                                shapes_and_axes)
from repro.models import build_model
from repro.models.transformer import stack_layout
from repro.sharding.policy import param_policy
from repro.training.optimizer import AdamWConfig
from repro.training.step import make_prefill_step, make_train_step


def build_cell(cfg, shape, mesh, *, unroll=False, remat="dots",
               microbatch_seqs: int = 4, seq_shard: bool = False):
    """(jitted-lowerable fn, abstract args, in_specs, out_specs).

    Train cells use gradient accumulation sized so each microbatch holds
    ~``microbatch_seqs`` sequences per device (activation memory control).
    """
    model = build_model(cfg, remat=remat, unroll=unroll,
                        seq_shard=seq_shard)
    shapes, axes = shapes_and_axes(model)
    # NOTE: a "serve2d" resident layout (weights over data x model, no
    # per-layer AG on the decode path) was tried for FSDP-class serving
    # cells and REFUTED as a blanket policy: dims that don't divide 256
    # (qwen2-vl d_ff=29568) fall back to replication and explode memory;
    # per-dim factorized 2D sharding is future work (§Perf, grok decode).
    pspec = param_specs(cfg, shapes, axes, mesh)

    if shape.kind == "train":
        master = cfg.param_dtype == "bfloat16"
        ospec = opt_specs(cfg, shapes, axes, mesh, master_weights=master)
        batch, bspec = make_batch(cfg, shape, mesh)
        dp = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                          if a in ("pod", "data")]))
        per_dev = max(1, shape.global_batch // dp)
        mb = max(1, per_dev // microbatch_seqs)
        # NOTE: hoisting a TP reshard of FSDP params (make_train_step's
        # param_axes/compute_policy) was tried and REFUTED: the partitioner
        # re-gathers per microbatch regardless while the TP param copies
        # triple temp memory (EXPERIMENTS.md §Perf, grok iteration 3).
        step = make_train_step(model, AdamWConfig(master_weights=master),
                               microbatches=mb, unroll=unroll)
        args = (shapes, abstract_opt_state(shapes, master), batch)
        return step, args, (pspec, ospec, bspec), (pspec, ospec, None)

    if shape.kind == "prefill":
        batch, bspec = make_batch(cfg, shape, mesh, with_labels=False)
        prefill = make_prefill_step(model)
        fn = lambda params, b: prefill(params, b)
        return fn, (shapes, batch), (pspec, bspec), None

    # decode
    (token, caches, cur), (tspec, cspec, curspec) = make_serving_inputs(
        cfg, shape, mesh)
    fn = model.decode_step
    return (fn, (shapes, token, caches, cur),
            (pspec, tspec, cspec, curspec), (None, cspec))


def lower_compile(cfg, shape, mesh, *, unroll=False, remat="dots",
                  seq_shard=False):
    fn, args, in_specs, out_specs = build_cell(cfg, shape, mesh,
                                               unroll=unroll, remat=remat,
                                               seq_shard=seq_shard)
    from jax.sharding import NamedSharding

    def to_sharding(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda t: isinstance(t, jax.sharding.PartitionSpec))

    with mesh:
        jitted = jax.jit(fn,
                         in_shardings=to_sharding(in_specs),
                         out_shardings=(to_sharding(out_specs)
                                        if out_specs is not None else None))
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def cost_of(compiled):
    ca = compiled.cost_analysis()
    wire = parse_collectives(compiled.as_text())
    return dict(flops=float(ca.get("flops", 0.0)),
                bytes=float(ca.get("bytes accessed", 0.0)),
                wire=float(wire["total"]),
                wire_by_op={k: v for k, v in wire.items() if k != "total"})


def _variant_layers(cfg):
    """Layer-count variants for the finite-difference costing.

    2 vs 3 pattern-repeats (not 1 vs 2): with aggressive sharding hints
    the partitioner can pick a different global strategy for a 1-repeat
    module, breaking cost linearity; 2->3 stays within one regime."""
    plen = len(cfg.block_pattern) or 1
    variants = {"a": 2 * plen, "b": 3 * plen}
    tail = cfg.num_layers % plen
    if tail:
        variants["tail"] = tail
    return plen, variants


def roofline_cell(cfg, shape, mesh, chips, *, remat="dots",
                  seq_shard=False):
    """Per-cell roofline via unrolled variants (exact per-layer costs)."""
    plen, variants = _variant_layers(cfg)
    costs = {}
    for name, nl in variants.items():
        vcfg = replace(cfg, num_layers=nl,
                       encoder_layers=min(cfg.encoder_layers, 1))
        _, comp = lower_compile(vcfg, shape, mesh, unroll=True, remat=remat,
                                seq_shard=seq_shard)
        costs[name] = cost_of(comp)
    if cfg.is_encdec and shape.kind != "decode":
        vcfg = replace(cfg, num_layers=2 * plen, encoder_layers=2)
        _, comp = lower_compile(vcfg, shape, mesh, unroll=True, remat=remat,
                                seq_shard=seq_shard)
        costs["enc2"] = cost_of(comp)

    n_full = cfg.num_layers // plen
    tail = cfg.num_layers % plen

    def combine(key):
        body = costs["b"][key] - costs["a"][key]
        base = costs["a"][key] - 2 * body
        total = base + n_full * body
        if tail:
            total += costs["tail"][key] - base
        if "enc2" in costs:
            enc_body = costs["enc2"][key] - costs["a"][key]
            total += (cfg.encoder_layers - 1) * enc_body
        return total, body, base

    flops, flops_body, flops_base = combine("flops")
    bytes_, _, _ = combine("bytes")
    wire, wire_body, wire_base = combine("wire")
    # per-device HLO costs -> global flops/bytes for the terms
    terms = terms_from(flops * chips, bytes_ * chips, wire, chips)
    mf = model_flops(cfg, shape)
    return dict(
        hlo_flops_per_device=flops, hlo_bytes_per_device=bytes_,
        wire_bytes_per_device=wire,
        wire_body_per_layer=wire_body,
        terms=terms, bottleneck=dominant(terms),
        model_flops=mf,
        useful_ratio=mf / (flops * chips) if flops else float("nan"),
        wire_by_op_one=costs["a"]["wire_by_op"],
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             do_roofline: bool = True, remat: str = "dots",
             bf16_params: bool = False, seq_shard: bool = False,
             verbose: bool = True):
    cfg = get_arch(arch)
    if bf16_params:
        cfg = replace(cfg, param_dtype="bfloat16")
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    lowered, compiled = lower_compile(cfg, shape, mesh, remat=remat,
                                      seq_shard=seq_shard)
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    rec = dict(
        arch=arch, shape=shape_name, mesh="x".join(
            str(s) for s in mesh.devices.shape),
        policy=param_policy(cfg),
        compile_s=round(time.time() - t0, 1),
        argument_gb=mem.argument_size_in_bytes / 1e9,
        output_gb=mem.output_size_in_bytes / 1e9,
        temp_gb=mem.temp_size_in_bytes / 1e9,
        peak_gb=(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes
                 - mem.alias_size_in_bytes) / 1e9,
        scanned_flops=float(ca.get("flops", 0.0)),
    )
    if verbose:
        print(f"[{arch} x {shape_name} x {rec['mesh']}] compiled in "
              f"{rec['compile_s']}s  args={rec['argument_gb']:.2f}GB "
              f"temp={rec['temp_gb']:.2f}GB", flush=True)
        print("  memory_analysis:", mem, flush=True)
        print("  cost_analysis(flops, scanned):", rec["scanned_flops"],
              flush=True)
    if do_roofline:
        t1 = time.time()
        rl = roofline_cell(cfg, shape, mesh, chips, remat=remat,
                           seq_shard=seq_shard)
        rec["roofline"] = rl
        rec["roofline_s"] = round(time.time() - t1, 1)
        if verbose:
            t = rl["terms"]
            print(f"  roofline: compute={t['compute']*1e3:.2f}ms "
                  f"memory={t['memory']*1e3:.2f}ms "
                  f"collective={t['collective']*1e3:.2f}ms "
                  f"-> {rl['bottleneck']} | useful={rl['useful_ratio']:.2f}",
                  flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--bf16-params", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--out")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in sorted(ARCHS):
            for shape in runnable_shapes(ARCHS[arch]):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                results.append(run_cell(arch, shape, mp,
                                        do_roofline=not args.no_roofline,
                                        remat=args.remat,
                                        bf16_params=args.bf16_params,
                                        seq_shard=args.seq_shard))
            except Exception as e:  # noqa: BLE001 — report all failures
                failures.append((arch, shape, mp, repr(e)))
                print(f"FAILED [{arch} x {shape} x multi_pod={mp}]: {e!r}",
                      flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} records -> {args.out}")
    if failures:
        print(f"{len(failures)} FAILURES"); sys.exit(1)
    print(f"dry-run OK: {len(results)} cells compiled")


if __name__ == "__main__":
    main()
