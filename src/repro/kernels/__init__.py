"""Pallas TPU kernels for the training substrate's compute hot spots.

The paper itself has no kernel-level contribution (it is a broadcast
protocol); these kernels belong to the LM substrate the framework trains
and serves (DESIGN.md §2.3): flash attention, the Mamba-2 SSD chunked
scan, and the RG-LRU linear scan.  Each has kernel.py (pl.pallas_call +
BlockSpec), ops.py (jit'd wrapper), ref.py (pure-jnp oracle) and an
interpret-mode shape/dtype sweep in tests/.
"""
