"""RG-LRU linear scan as a Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t, elementwise over the LRU width.  Grid:
(B, W/BW, S/BS) — the sequence axis innermost (sequential); the running
state (1, BW) lives in VMEM scratch.  Within a block the recurrence is a
``fori_loop`` of fused multiply-adds over rows — VPU work (this kernel is
bandwidth-bound by construction: 2 loads + 1 store per element), so the
tile choice (BW = 128 lanes, BS = 256 rows) is about HBM->VMEM pipelining,
not the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan_kernel", "rglru_scan_pallas"]


def rglru_scan_kernel(a_ref, b_ref, h0_ref, h_ref, state_ref):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        state_ref[...] = h0_ref[0].astype(jnp.float32)      # (1, BW)

    a = a_ref[0].astype(jnp.float32)                        # (BS, BW)
    b = b_ref[0].astype(jnp.float32)

    def body(t, h):
        h = a[t][None, :] * h + b[t][None, :]
        # Index every axis with a slice: a bare int index reaches the
        # swap discharge rule as a scalar without a .shape and crashes
        # interpret mode, so the leading block axis uses pl.dslice too.
        pl.store(h_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 h[:, None, :].astype(h_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, a.shape[0], body, state_ref[...])
    state_ref[...] = h


def rglru_scan_pallas(a, b, h0, *, block_s: int = 256, block_w: int = 128,
                      interpret: bool = False):
    """a, b (B, S, W); h0 (B, W) -> h (B, S, W) with h[:, t] the state
    after step t.  S, W must be multiples of the blocks (ops.py pads W;
    S padding with a=1, b=0 keeps trailing state exact)."""
    bb, s, w = a.shape
    assert s % block_s == 0 and w % block_w == 0, (s, w)

    grid = (bb, w // block_w, s // block_s)

    def abmap(i, jw, js):
        return (i, js, jw)

    def h0map(i, jw, js):
        return (i, 0, jw)

    h = pl.pallas_call(
        rglru_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), abmap),
            pl.BlockSpec((1, block_s, block_w), abmap),
            pl.BlockSpec((1, 1, block_w), h0map),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), abmap),
        out_shape=jax.ShapeDtypeStruct((bb, s, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b, h0[:, None, :])
    return h
