"""Pure-jnp oracle: the model's associative-scan RG-LRU is the reference."""
from repro.models.rglru import rglru_scan_ref

__all__ = ["rglru_scan_ref"]
