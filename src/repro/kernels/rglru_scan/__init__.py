from .ops import *
