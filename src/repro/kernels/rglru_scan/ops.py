"""jit'd wrapper matching ``repro.models.rglru.rglru_scan_ref``."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import rglru_scan_pallas

__all__ = ["rglru_scan"]


def rglru_scan(a, bx, h0=None, block_s: int = 256, block_w: int = 128,
               interpret: bool = True):
    """a, bx (B, S, W); optional h0 (B, W).  Returns (h (B,S,W), h_last).

    interpret=True by default on this CPU-only box; pass False on TPU."""
    b, s, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)
    bs = min(block_s, s) if s % block_s else block_s
    bw = min(block_w, w) if w % block_w else block_w
    pad_s = (-s) % bs
    pad_w = (-w) % bw
    if pad_s or pad_w:
        # a=1, b=0 padding is the scan identity -> state passes through
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)),
                    constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad_s), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    h = rglru_scan_pallas(a.astype(jnp.float32), bx.astype(jnp.float32),
                          h0, block_s=bs, block_w=bw, interpret=interpret)
    h = h[:, :s, :w]
    return h, h[:, -1]
