"""Flash attention (forward) as a Pallas TPU kernel.

Online-softmax tiled attention: the grid is (batch*q_heads, Sq/BQ, Skv/BK)
with the KV axis innermost (sequential on TPU); running max / sum / output
accumulators live in VMEM scratch and persist across the KV loop.  GQA maps
query head h to KV head h // (H/KV) in the K/V index_maps.  Causal masking
is applied only where needed; fully-masked blocks contribute a masked
no-op (TPU grids are dense).

VMEM budget at the default tiles (BQ=BK=128, D<=256): q/k/v blocks
3*128*256*4 B = 384 KiB + f32 accumulators ~130 KiB — comfortably inside
the ~16 MiB/core VMEM of a v5e, and all matmul dims are multiples of the
128x128 MXU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

NEG_INF = -1e30


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                           l_ref, *, scale: float, causal: bool,
                           block_q: int, block_k: int, seq_kv: int):
    """One (bh, iq, ik) grid step."""
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0].astype(jnp.float32)                  # (BK, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BQ, BK)

    # mask: causal + kv-padding (columns beyond the true seq_kv)
    iq = pl.program_id(1)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_kv
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        mask &= kpos <= qpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # (BQ, 1)
    m_new = jnp.maximum(m_prev[:, 0], s.max(axis=1))
    alpha = jnp.exp(m_prev[:, 0] - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)   # fully-masked rows would otherwise be 1
    l_ref[...] = l_ref[...] * alpha[:, None] + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new[:, None]

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           seq_kv: int = 0, interpret: bool = False):
    """q (B, H, Sq, D); k, v (B, KV, Skv, D) -> (B, H, Sq, D).

    Sq/Skv must be multiples of the block sizes (ops.py pads; the true
    KV length ``seq_kv`` masks the padding — it defaults to the padded
    length, i.e. no padding)."""
    b, h, sq, d = q.shape
    kv, skv = k.shape[1], k.shape[2]
    seq_kv = seq_kv or skv
    groups = h // kv
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)
    scale = d ** -0.5

    grid = (b * h, sq // block_q, skv // block_k)

    def qmap(bh, iq, ik):
        return (bh, iq, 0)

    def kvmap(bh, iq, ik):
        bi, hi = bh // h, bh % h
        return (bi * kv + hi // groups, ik, 0)

    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * kv, skv, d)
    vr = v.reshape(b * kv, skv, d)

    out = pl.pallas_call(
        functools.partial(flash_attention_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, seq_kv=seq_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), qmap),
            pl.BlockSpec((1, block_k, d), kvmap),
            pl.BlockSpec((1, block_k, d), kvmap),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), qmap),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
