"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal: bool = True):
    """q (B, H, Sq, D); k, v (B, KV, Skv, D) -> (B, H, Sq, D), GQA."""
    b, h, sq, d = q.shape
    kv, skv = k.shape[1], k.shape[2]
    groups = h // kv
    qg = q.reshape(b, kv, groups, sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)
