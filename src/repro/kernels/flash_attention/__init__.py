from .ops import *
