"""jit'd wrapper: padding, layout, and a custom-vjp whose backward falls
back to the jnp oracle (recompute) — the forward kernel is the serving /
prefill hot path; training backward reuses XLA's fused attention grad."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                    interpret=False):
    """q (B, H, Sq, D); k, v (B, KV, Skv, D) -> (B, H, Sq, D)."""
    qp, sq = _pad_to(q, 2, block_q)
    kp, skv = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    # causal offset assumption: ref/causal masks assume aligned ends; the
    # kernel masks kv-padding via seq_kv and q-padding rows are discarded.
    out = flash_attention_pallas(qp, kp, vp, causal=causal, block_q=block_q,
                                 block_k=block_k, seq_kv=skv,
                                 interpret=interpret)
    return out[:, :, :sq]


def _fwd(q, k, v, causal, block_q, block_k, interpret):
    return flash_attention(q, k, v, causal, block_q, block_k, interpret), (q, k, v)


def _bwd(causal, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda a, b, c: attention_ref(a, b, c, causal=causal),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
