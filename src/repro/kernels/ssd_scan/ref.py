"""Pure-jnp oracle: the model's chunked SSD scan is the reference."""
from repro.models.ssm import ssd_chunk_scan_ref

__all__ = ["ssd_chunk_scan_ref"]
