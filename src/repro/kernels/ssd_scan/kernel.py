"""Chunked SSD (Mamba-2) scan as a Pallas TPU kernel.

Grid: (B*H, n_chunks) — chunks innermost (sequential on TPU); the running
inter-chunk state (N, P) lives in VMEM scratch.  Per grid step, the three
dense products ((Q,N)x(N,Q), (Q,Q)x(Q,P), (N,Q)x(Q,P)) all hit the MXU
with hardware-aligned dims at the default Q=128, N=128, P=64.

VMEM at defaults: xb 32 KiB + bm/cm 2*64 KiB + state 32 KiB + y 32 KiB +
(Q,Q) temporaries ~64 KiB -> well under budget; the B/C blocks are shared
across the H grid axis (n_groups=1), which the index_map expresses by
ignoring the head coordinate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel", "ssd_scan_pallas"]


def ssd_scan_kernel(al_ref, xb_ref, bm_ref, cm_ref, y_ref, hout_ref,
                    state_ref, *, nheads: int):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    al = al_ref[0, 0].astype(jnp.float32)                  # (Q, 1)
    l = jnp.cumsum(al[:, 0])                               # (Q,)
    xb = xb_ref[0, 0].astype(jnp.float32)                  # (Q, P)
    bm = bm_ref[0, 0].astype(jnp.float32)                  # (Q, N)
    cm = cm_ref[0, 0].astype(jnp.float32)                  # (Q, N)

    q = l.shape[0]
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    seg = l[:, None] - l[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    seg = jnp.where(kj <= qi, seg, -1e30)
    att = cb * jnp.exp(seg)
    y = jax.lax.dot_general(att, xb, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # intra

    h_prev = state_ref[...]                                # (N, P)
    y += jax.lax.dot_general(cm, h_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32
                             ) * jnp.exp(l)[:, None]       # inter

    lq = l[q - 1]
    wb = bm * jnp.exp(lq - l)[:, None]                     # (Q, N)
    upd = jax.lax.dot_general(wb, xb, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = h_prev * jnp.exp(lq) + upd
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c == nc - 1)
    def _finish():
        hout_ref[0] = state_ref[...].astype(hout_ref.dtype)


def ssd_scan_pallas(al, xb, bm, cm, *, nheads: int, interpret: bool = False):
    """al (BH, NC, Q, 1) log-decay; xb (BH, NC, Q, P); bm/cm (B, NC, Q, N)
    shared across heads.  Returns (y (BH, NC, Q, P), h (BH, N, P))."""
    bh, nc, qq, _ = al.shape
    p = xb.shape[-1]
    n = bm.shape[-1]

    def bhmap(i, c):
        return (i, c, 0, 0)

    def bcmap(i, c):
        return (i // nheads, c, 0, 0)

    y, h = pl.pallas_call(
        functools.partial(ssd_scan_kernel, nheads=nheads),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, qq, 1), bhmap),
            pl.BlockSpec((1, 1, qq, p), bhmap),
            pl.BlockSpec((1, 1, qq, n), bcmap),
            pl.BlockSpec((1, 1, qq, n), bcmap),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qq, p), bhmap),
            pl.BlockSpec((1, n, p), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc, qq, p), xb.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(al, xb, bm, cm)
    return y, h
