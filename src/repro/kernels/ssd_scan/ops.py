"""jit'd wrapper matching ``repro.models.ssm.ssd_chunk_scan_ref``'s
contract (same inputs/outputs, chunk padding included)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_pallas

__all__ = ["ssd_chunk_scan"]


def ssd_chunk_scan(xbar, a_log, Bm, Cm, chunk: int = 128,
                   interpret: bool = True):
    """xbar (B,S,H,P); a_log (B,S,H); Bm/Cm (B,S,N) ->
    (y (B,S,H,P), h_final (B,H,N,P)).

    interpret=True by default: this box is CPU-only; on TPU pass False."""
    b, s, h, p = xbar.shape
    n = Bm.shape[-1]
    q = min(chunk, s) if s % chunk else chunk
    if s % q:
        pad = q - s % q
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    sp = xbar.shape[1]
    nc = sp // q

    # layouts: (B,S,H,P) -> (B*H, NC, Q, P); a_log -> (B*H, NC, Q, 1)
    xb = xbar.reshape(b, nc, q, h, p).transpose(0, 3, 1, 2, 4).reshape(
        b * h, nc, q, p)
    al = a_log.reshape(b, nc, q, h).transpose(0, 3, 1, 2).reshape(
        b * h, nc, q, 1)
    bm = Bm.reshape(b, nc, q, n)
    cm = Cm.reshape(b, nc, q, n)

    y, hfin = ssd_scan_pallas(al, xb, bm, cm, nheads=h, interpret=interpret)
    y = y.reshape(b, h, nc, q, p).transpose(0, 2, 3, 1, 4).reshape(
        b, sp, h, p)[:, :s]
    return y, hfin.reshape(b, h, n, p)
