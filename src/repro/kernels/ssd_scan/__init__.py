from .ops import *
