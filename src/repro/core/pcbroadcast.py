"""Algorithm 2 — PC-broadcast (Preventive Causal broadcast).

Extends R-broadcast with *safe links* (Definition 8).  A newly added link is
removed from the dissemination set ``Q`` until a **ping phase** completes
(Definition 9): the ping pi travels over safe links only — behind every
message its sender delivered before it (FIFO) — while the pong rho may come
back over any channel.  Messages delivered during the phase are buffered per
unsafe link (Definition 10) and flushed over the new link on pong receipt,
after which the link joins ``Q`` (Lemma 3).

Ping transport is configurable:
  * ``"flood"`` — pings are disseminated like broadcast messages over safe
    links, deduplicated on (frm, id); maximally faithful to Lemma 2.
  * ``"route"`` — pings follow a shortest path over the current safe-link
    graph, hop by hop over FIFO links (the paper: "We leave aside the
    implementation of this send function (e.g. broadcast or routing)").
    Fig. 7's "at most 3 hops" matches this mode; it is what large
    simulations use.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional, Set, Tuple

from .base import AppMsg, Ping, Pong, msg_id
from .rbroadcast import RBroadcast

__all__ = ["PCBroadcast"]


class PCBroadcast(RBroadcast):
    """Algorithm 2 at process p.

    ``Q``  — safe outgoing links only (inherited).
    ``B``  — map unsafe link -> (ping counter, buffered delivered messages).
    ``ctrl_counter`` — control message identifier (paper line 4).
    """

    def __init__(self, pid: int, deliver_cb=None, ping_mode: str = "flood",
                 always_gate: bool = False,
                 direct_ping_fallback: bool = False):
        super().__init__(pid, deliver_cb)
        assert ping_mode in ("flood", "route")
        self.ping_mode = ping_mode
        # Paper's Algorithm 2 gates every added link (always_gate=True).
        # Default is a sound fast-path: a process that has DELIVERED nothing
        # yet cannot have messages missing from a new link (Definition 8 is
        # satisfied vacuously — every future delivery is forwarded on the
        # link in FIFO order), so such links are safe on creation.  This is
        # what makes cold bootstrap (building the initial static overlay)
        # ping-free; it never weakens safety and is exercised by the same
        # property tests as the faithful mode.
        self.always_gate = always_gate
        # Fresh-joiner bootstrap (DESIGN.md §2.2): a process whose ONLY
        # links are new has no safe path for inbound pings — the paper's
        # ping phase cannot complete (its model adds links between already
        # -connected processes).  With this flag, a ping with no safe
        # route is sent over the gated link itself.  That is safe exactly
        # when no pre-gate message can still be in flight toward the
        # target — true for fresh joiners whose history arrives by state
        # transfer — so the runtime enables it only on join links.
        self.direct_ping_fallback = direct_ping_fallback
        self.n_delivered = 0
        self.ctrl_counter = 0
        # B: link q -> [buffer_counter, list-of-buffered-msgs]
        self.B: Dict[int, List] = {}
        self._seen_pings: Set[Tuple[int, int]] = set()

    # ------------------------------------------------------------------ #
    # SAFETY (Algorithm 2, lines 6-20)
    # ------------------------------------------------------------------ #
    def on_open(self, q: int) -> None:
        """upon open(q) — the link p->q was just added by the membership
        layer.  If p has other outgoing links the new one may act as a
        shortcut (Fig. 3) and starts *unsafe*; if it is p's sole link there
        is no alternate path to shortcut, and it is used immediately."""
        self.Q.add(q)
        if len(self.Q) > 1 and (self.always_gate or self.n_delivered > 0):
            self._begin_ping_phase(q)

    def _begin_ping_phase(self, q: int) -> None:
        self.ctrl_counter += 1                    # counter <- counter + 1
        self.Q.discard(q)                         # Q <- Q \ q   (unsafe)
        self.B[q] = [self.ctrl_counter, []]       # B[q] <- empty buffer
        self._send_ping(q, self.ctrl_counter)     # ping(p, q, counter)
        self.on_ping_sent(q, self.ctrl_counter)   # Algorithm 3 hook

    def on_ping_sent(self, q: int, ping_id: int) -> None:
        """Hook for Algorithm 3 (retry bookkeeping + timeout)."""

    def _send_ping(self, q: int, ping_id: int) -> None:
        ping = Ping(self.pid, q, ping_id)
        if self.ping_mode == "flood":
            self._seen_pings.add((ping.frm, ping.id))
            for nb in list(self.Q):
                self.send(nb, ping)
        else:
            path = self._safe_route(q)
            if path is None:
                if self.direct_ping_fallback:
                    self.send(q, Ping(self.pid, q, ping_id, route=()))
                return  # else: no safe path now; timeout/retry (Alg. 3)
            self.send(path[0], Ping(self.pid, q, ping_id, route=tuple(path[1:])))

    def _safe_route(self, target: int) -> Optional[List[int]]:
        """BFS shortest path self -> target over the *safe-link* graph.

        The simulator grants routing a topology oracle; a deployment would
        use the overlay's routing service.  The path rides FIFO links hop by
        hop, so Lemma 2's flushing argument is preserved."""
        if target in self.Q:
            return [target]
        procs = self.net.procs
        prev: Dict[int, int] = {self.pid: self.pid}
        dq = deque([self.pid])
        while dq:
            u = dq.popleft()
            proc = procs.get(u)
            if proc is None or getattr(proc, "crashed", False):
                continue
            for v in getattr(proc, "Q", ()):  # safe links only
                if v in prev:
                    continue
                prev[v] = u
                if v == target:
                    path = [v]
                    while path[-1] != self.pid:
                        path.append(prev[path[-1]])
                    path.reverse()
                    return path[1:]  # drop self
                dq.append(v)
        return None

    def on_close(self, q: int) -> None:
        """upon close(q) — drop membership and any pending buffer."""
        self.Q.discard(q)
        self.B.pop(q, None)                       # B <- B \ q

    # ------------------------------------------------------------------ #
    # Control-message handling
    # ------------------------------------------------------------------ #
    def on_receive(self, src: int, msg: Any) -> None:
        if isinstance(msg, Ping):
            self._on_ping(src, msg)
        else:
            super().on_receive(src, msg)

    def _on_ping(self, src: int, ping: Ping) -> None:
        if ping.to == self.pid:
            # upon receivePing(from, to, id): pong(from, to, id).
            # The reply may travel over any communication mean (oob).
            self.net.stats.sent_control += 1
            self.net.send_oob(self.pid, ping.frm, Pong(ping.frm, ping.to, ping.id))
            return
        if self.ping_mode == "flood":
            key = (ping.frm, ping.id)
            if key in self._seen_pings:
                return
            self._seen_pings.add(key)
            for nb in list(self.Q):               # forward over safe links
                self.send(nb, ping)
        else:  # route mode: forward along the precomputed path
            if not ping.route:
                return  # malformed/stale
            nxt, rest = ping.route[0], ping.route[1:]
            if nxt in self.Q or nxt == ping.to and self.net.has_link(self.pid, nxt):
                self.send(nxt, Ping(ping.frm, ping.to, ping.id, route=rest))
            # else: route went stale (link removed) — drop; Alg. 3 retries.

    def on_oob(self, src: int, msg: Any) -> None:
        if isinstance(msg, Pong):
            self._on_pong(msg)

    def _on_pong(self, pong: Pong) -> None:
        """upon receivePong(from, to, id)  — from = p.

        Flush the buffer over the new link, then mark it safe.  Pongs whose
        id does not match the buffer's current counter are stale replies to
        a reset ping phase and are discarded (Fig. 6c)."""
        ent = self.B.get(pong.to)
        if ent is None or ent[0] != pong.id:
            return                                 # no matching buffer
        for m in ent[1]:                           # foreach m in B[to]
            self.send(pong.to, m)                  #   sendTo(to, m)
        del self.B[pong.to]                        # B <- B \ to
        self.Q.add(pong.to)                        # Q <- Q U to   (now safe)
        self.on_link_safe(pong.to, pong.id)        # Algorithm 3 hook

    def on_link_safe(self, q: int, ping_id: int) -> None:
        """Hook for Algorithm 3 (clears retry state)."""

    # ------------------------------------------------------------------ #
    # DISSEMINATION (Algorithm 2, lines 21-26)
    # ------------------------------------------------------------------ #
    # function PC-broadcast(m): R-broadcast(m) — inherited broadcast().

    def r_deliver(self, m: AppMsg) -> None:
        """upon R-deliver(m): buffer into every unsafe link, then deliver."""
        for q in self.B:                           # foreach q in B
            self.B[q][1].append(m)                 #   B[q] <- B[q] U m
        self.n_delivered += 1
        self.deliver(m)                            # PC-deliver(m)
        self.on_pc_deliver(m)                      # Algorithm 3 hook

    def on_pc_deliver(self, m: AppMsg) -> None:
        """Hook for Algorithm 3 (buffer bound check)."""

    # ------------------------------------------------------------------ #
    # Introspection
    # ------------------------------------------------------------------ #
    @property
    def unsafe_links(self) -> List[int]:
        return list(self.B.keys())

    def buffer_sizes(self) -> Dict[int, int]:
        return {q: len(ent[1]) for q, ent in self.B.items()}
