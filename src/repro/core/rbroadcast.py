"""Algorithm 1 — R-broadcast (uniform reliable broadcast by flooding).

Line-faithful implementation of the paper's Algorithm 1: each process keeps
its neighborhood ``Q`` and a ``received`` set; on first receipt it forwards
the message on **all** outgoing links and delivers it.  Over FIFO links and a
*static* overlay this is causal (Theorem 1, Friedman-Manor); over a dynamic
overlay it may violate causal order (Fig. 3) — which our tests demonstrate.

Method map (paper, Algorithm 1):

  ``__init__``        INITIALLY lines 1-3: ``Q`` <- neighborhood,
                      ``received`` <- empty set
  ``broadcast``       function R-broadcast(m), lines 4-7:
                      received <- received U m; foreach q in Q: sendTo(q, m);
                      R-deliver(m)
  ``on_receive``      upon receive(m), lines 8-12: first receipt only —
                      received <- received U m; forward to every q in Q;
                      R-deliver(m)
  ``on_open/on_close``the membership layer's open(q)/close(q) signals:
                      Q <- Q U q / Q \\ q.  R-broadcast uses a link the
                      moment it exists — exactly what breaks causal order
                      under dynamicity (Fig. 3) and what Algorithm 2 gates.
  ``r_deliver``       R-deliver(m); PC-broadcast (Algorithm 2) overrides
                      this hook to buffer into unsafe links first.

``prune_received`` implements the paper's §6 future-work item for *static*
networks (received-set space reclamation; see the class docstring).
"""

from __future__ import annotations

from typing import Any, Dict, Set, Tuple

from .base import AppMsg, Ping, Pong, Protocol, msg_id

__all__ = ["RBroadcast"]


class RBroadcast(Protocol):
    """Algorithm 1.  ``Q`` = neighborhood, ``received`` = seen message ids.

    ``prune_received`` implements the paper's §6 future-work item for
    *static* networks: every process eventually receives exactly
    ``in_degree`` copies of each message (one per incoming link under
    flooding), so once that count is reached the id can be reclaimed —
    received-set space becomes O(in-flight) instead of O(N).  Unsafe
    under dynamic membership (the paper says so; we only enable it on
    static overlays)."""

    def __init__(self, pid: int, deliver_cb=None, prune_received=False):
        super().__init__(pid, deliver_cb)
        self.Q: Set[int] = set()                      # p's neighborhood
        self.received: Set[Tuple[int, int]] = set()   # received message ids
        self.prune_received = prune_received
        self._receipts: dict = {}                     # id -> copies seen
        self.pruned = 0

    # -- membership ------------------------------------------------------ #
    def on_open(self, q: int) -> None:
        # R-broadcast uses every link as soon as it exists (no safety gate):
        # this is exactly what makes it violate causal order under dynamicity.
        self.Q.add(q)

    def on_close(self, q: int) -> None:
        self.Q.discard(q)

    # -- dissemination (Algorithm 1) -------------------------------------- #
    def broadcast(self, payload: Any = None) -> AppMsg:
        """function R-broadcast(m)"""
        m = self.next_message(payload)
        self.net.record_broadcast(self.pid, m)
        self.received.add(msg_id(m))                 # received <- received U m
        for q in list(self.Q):                       # foreach q in Q: sendTo
            self.send(q, m)
        self.r_deliver(m)
        return m

    def on_receive(self, src: int, msg: Any) -> None:
        """upon receive(m)"""
        if isinstance(msg, AppMsg):
            mid = msg_id(msg)
            if mid in self.received:                 # if m not in received
                self.net.stats.duplicate_receipts += 1
                self._count_receipt(mid)
                return
            self.received.add(mid)
            self._count_receipt(mid)
            for q in list(self.Q):                   # forward
                self.send(q, msg)
            self.r_deliver(msg)
        elif isinstance(msg, (Ping, Pong)):
            # Plain R-broadcast has no safety machinery; ignore strays.
            pass

    def _count_receipt(self, mid) -> None:
        if not self.prune_received:
            return
        in_deg = sum(1 for (a, b), lk in self.net.links.items()
                     if b == self.pid and lk.alive)
        c = self._receipts.get(mid, 0) + 1
        if c >= in_deg:                     # all copies arrived: reclaim
            self.received.discard(mid)
            self._receipts.pop(mid, None)
            self.pruned += 1
        else:
            self._receipts[mid] = c

    # -- delivery ---------------------------------------------------------- #
    def r_deliver(self, m: AppMsg) -> None:
        """R-deliver(m).  Subclasses (PC-broadcast) hook here."""
        self.deliver(m)
