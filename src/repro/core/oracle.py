"""Happens-before oracle: checks broadcast properties from the event trace.

The oracle is protocol-agnostic and assumes nothing about the protocol's
correctness: the causal past of a broadcast m' is rebuilt transitively from
the global trace (everything its broadcaster had *delivered* when it
broadcast m', closed under those messages' own pasts).  From it we check:

  * causal order  (Definition 6): if C delivers m and m' with
    b(m) -> b(m'), then C delivered m first;
  * uniform integrity: at most one delivery of each message per process;
  * validity: a correct broadcaster delivers its own messages;
  * uniform agreement (quiescent): once the network is idle, all correct
    processes delivered the same set.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, List, Set, Tuple

from .base import AppMsg, msg_id

__all__ = ["OracleReport", "check_trace"]

MsgId = Tuple[int, int]


@dataclass
class OracleReport:
    causal_violations: List[Tuple[int, MsgId, MsgId]] = field(default_factory=list)
    double_deliveries: List[Tuple[int, MsgId]] = field(default_factory=list)
    validity_violations: List[MsgId] = field(default_factory=list)
    agreement_violations: List[Tuple[int, MsgId]] = field(default_factory=list)
    n_broadcasts: int = 0
    n_deliveries: int = 0

    @property
    def causal_ok(self) -> bool:
        return not self.causal_violations

    @property
    def ok(self) -> bool:
        return (not self.causal_violations and not self.double_deliveries
                and not self.validity_violations and not self.agreement_violations)

    def summary(self) -> str:
        return (f"broadcasts={self.n_broadcasts} deliveries={self.n_deliveries} "
                f"causal_violations={len(self.causal_violations)} "
                f"double={len(self.double_deliveries)} "
                f"validity={len(self.validity_violations)} "
                f"agreement={len(self.agreement_violations)}")


def check_trace(trace, crashed: Set[int] = frozenset(),
                check_agreement: bool = True,
                all_pids: Set[int] | None = None) -> OracleReport:
    """Validate a ``Network.trace`` against the broadcast specification.

    ``crashed`` — pids exempt from validity/agreement (faulty processes).
    ``check_agreement`` — only meaningful on a quiescent (idle) network.
    ``all_pids`` — full membership; without it, agreement is checked only
    over processes that delivered at least one message.
    """
    rep = OracleReport()
    past: Dict[MsgId, FrozenSet[MsgId]] = {}
    delivered_at: Dict[int, Dict[MsgId, int]] = {}   # pid -> id -> order index
    delivered_seq: Dict[int, List[MsgId]] = {}       # pid -> delivery order
    broadcaster: Dict[MsgId, int] = {}

    for (_, kind, pid, data) in trace:
        if kind == "broadcast":
            mid = msg_id(data)
            rep.n_broadcasts += 1
            broadcaster[mid] = pid
            # Transitive causal past: everything pid delivered so far, closed
            # under those messages' pasts (computed at *their* broadcast).
            direct = list(delivered_at.get(pid, ()))
            closure: Set[MsgId] = set(direct)
            for d in direct:
                closure |= past.get(d, frozenset())
            past[mid] = frozenset(closure)
        elif kind == "deliver":
            mid = msg_id(data)
            rep.n_deliveries += 1
            seen = delivered_at.setdefault(pid, {})
            if mid in seen:
                rep.double_deliveries.append((pid, mid))
                continue
            seen[mid] = len(seen)
            delivered_seq.setdefault(pid, []).append(mid)

    # Causal order: every message in past(m') delivered before m' (if ever).
    for pid, seq in delivered_seq.items():
        index = delivered_at[pid]
        for mid in seq:
            i = index[mid]
            for dep in past.get(mid, frozenset()):
                j = index.get(dep)
                if j is not None and j > i:
                    rep.causal_violations.append((pid, dep, mid))

    # Validity: correct broadcasters deliver their own messages.
    for mid, src in broadcaster.items():
        if src in crashed:
            continue
        if mid not in delivered_at.get(src, {}):
            rep.validity_violations.append(mid)

    # Uniform agreement (quiescent check): any message delivered anywhere
    # must be delivered by every correct process.
    if check_agreement:
        all_delivered: Set[MsgId] = set()
        for pid, seen in delivered_at.items():
            all_delivered |= set(seen)
        members = set(all_pids) if all_pids is not None else (
            delivered_at.keys() | {broadcaster[m] for m in broadcaster})
        for pid in members:
            if pid in crashed:
                continue
            seen = delivered_at.get(pid, {})
            for mid in all_delivered:
                if mid not in seen:
                    rep.agreement_violations.append((pid, mid))
    return rep
