"""Overlay construction and Spray-like dynamics (paper §4 experiment).

``ring_plus_random`` builds the static bootstrap topology: a directed ring
(guaranteeing strong connectivity, hence Definition 3's unpartitioned
assumption) plus ``k-1`` random extra out-links per process — a close
approximation of the random graphs peer-sampling services converge to.

``SprayOverlay`` drives dynamicity the way the paper describes its
experiment: each process initiates a view exchange once per ``period``
(so each neighborhood changes at least once, and on average twice, per
period), and each exchange makes both participants drop half of their
partial view and adopt the other half from their partner.  All link churn
flows through ``Network.connect``/``disconnect`` so the protocol under test
sees every ``open``/``close``.
"""

from __future__ import annotations

import math
import random
from typing import Callable, Dict, List, Optional, Sequence

from .events import Network

__all__ = ["ring_plus_random", "view_size", "SprayOverlay"]


def view_size(n: int, c: float = 1.0) -> int:
    """Partial-view size ~ log of the network size (Spray converges to
    ln(N)-sized views; the paper's Fig. 7 runs have ~17 links/process)."""
    return max(2, int(round(c * math.log(max(n, 2)) + 1)))


def ring_plus_random(net: Network, pids: Sequence[int], k: Optional[int] = None,
                     rng: Optional[random.Random] = None) -> None:
    """Connect ``pids`` in a directed ring plus ``k-1`` random out-links."""
    rng = rng or net.rng
    n = len(pids)
    k = k if k is not None else view_size(n)
    for i, p in enumerate(pids):
        net.connect(p, pids[(i + 1) % n])
        extra = 0
        while extra < k - 1 and n > 2:
            q = pids[rng.randrange(n)]
            if q != p and not net.has_link(p, q):
                net.connect(p, q)
                extra += 1


class SprayOverlay:
    """Periodic half-view exchanges between random neighbor pairs."""

    def __init__(self, net: Network, pids: Sequence[int], period: float = 60.0,
                 rng: Optional[random.Random] = None):
        self.net = net
        self.pids = list(pids)
        self.period = period
        self.rng = rng or net.rng
        self.exchanges = 0
        self.links_added = 0
        self.links_removed = 0
        self._running = False

    def start(self) -> None:
        self._running = True
        for p in self.pids:
            # Desynchronize first exchanges uniformly over one period.
            self.net.call_later(self.rng.uniform(0, self.period),
                                lambda p=p: self._tick(p))

    def stop(self) -> None:
        self._running = False

    def _tick(self, p: int) -> None:
        if not self._running:
            return
        proc = self.net.procs.get(p)
        if proc is not None and not getattr(proc, "crashed", False):
            self.exchange(p)
        self.net.call_later(self.period, lambda: self._tick(p))

    def exchange(self, p: int) -> None:
        """One Spray-style exchange initiated by ``p`` with a random
        neighbor ``q``: both shed half their view and adopt the peer's
        shed half (paper: "both add and remove half of their partial
        view")."""
        out_p = [x for x in self.net.neighbors(p)]
        if not out_p:
            return
        q = self.rng.choice(out_p)
        proc_q = self.net.procs.get(q)
        if proc_q is None or getattr(proc_q, "crashed", False):
            return
        out_q = [x for x in self.net.neighbors(q)]

        give_p = self._half(out_p, exclude={q})
        give_q = self._half(out_q, exclude={p})

        self._apply(p, remove=give_p, add=give_q)
        self._apply(q, remove=give_q, add=give_p)
        self.exchanges += 1

    def _half(self, view: List[int], exclude=frozenset()) -> List[int]:
        cand = [x for x in view if x not in exclude]
        self.rng.shuffle(cand)
        return cand[: max(1, len(cand) // 2)] if cand else []

    def _apply(self, p: int, remove: List[int], add: List[int]) -> None:
        current = set(self.net.neighbors(p))
        for x in add:
            if x != p and x not in current:
                proc_x = self.net.procs.get(x)
                if proc_x is None or getattr(proc_x, "crashed", False):
                    continue
                self.net.connect(p, x)
                current.add(x)
                self.links_added += 1
        for x in remove:
            # Keep at least 2 out-links so flooding connectivity survives
            # (the paper assumes churn never partitions the overlay).
            if len(current) <= 2:
                break
            if self.net.has_link(p, x):
                self.net.disconnect(p, x)
                current.discard(x)
                self.links_removed += 1
