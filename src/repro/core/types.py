"""Shared simulation types: traffic stats and link-delay models.

These are the types every simulation substrate speaks, whatever its
execution model:

  * ``repro.core.events.Network``  — the exact discrete-event simulator
    (one Python object + heap event per process/message);
  * ``repro.core.vecsim``          — the vectorized lockstep-round engine
    (whole network as dense arrays, DESIGN.md §2.4).

``NetStats`` is the common accounting schema: both engines fill the same
fields, so ``benchmarks/`` and ``examples/`` consume either engine's
output unchanged.  Field semantics that differ between the engines (only
``duplicate_receipts``, which the vec engine derives rather than counts)
are documented in DESIGN.md §2.4.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable

__all__ = ["NetStats", "DelayFn", "constant_delay", "uniform_delay",
           "LegacyEntryPointWarning"]


class LegacyEntryPointWarning(DeprecationWarning):
    """Emitted by the pre-``repro.api`` entry points (``run_vec``,
    ``run_vec_windowed``).  They keep their exact signatures and behavior,
    but new code should go through the one front door —
    ``repro.api.run(RunSpec(...))`` — which dispatches to the same engine
    implementations.  CI runs the in-repo benchmarks and examples with
    this category escalated to an error, so nothing shipped in the repo
    regresses onto the legacy surface."""

# A transmission-delay model: (current time, rng) -> delay.
DelayFn = Callable[[float, random.Random], float]


def constant_delay(d: float) -> DelayFn:
    return lambda t, rng: d


def uniform_delay(lo: float, hi: float) -> DelayFn:
    return lambda t, rng: rng.uniform(lo, hi)


@dataclass
class NetStats:
    """Traffic accounting, fed by the protocol's ``control_bytes`` hooks."""

    sent_messages: int = 0
    sent_control: int = 0  # ping/pong count
    control_bytes: int = 0  # causality-control bytes piggybacked on app msgs
    oob_messages: int = 0
    deliveries: int = 0
    duplicate_receipts: int = 0
