"""Message types and the protocol base class shared by Algorithms 1-3.

Message identifiers follow the paper (§3.4, *Reliable broadcast*): each
broadcast message piggybacks a single ``(origin, counter)`` pair — O(1)
control information.  ``control_bytes`` makes that accounting explicit so
benchmarks can compare against the vector-clock baseline's O(N) overhead.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

__all__ = ["AppMsg", "Ping", "Pong", "Protocol", "msg_id", "control_bytes"]

# Wire-size model (bytes) used for overhead accounting.  A process id and a
# counter are both modelled as 8-byte integers.
_INT = 8


@dataclass(frozen=True)
class AppMsg:
    """An application broadcast message.

    ``origin``/``counter`` identify the message (O(1) control information).
    ``payload`` is application data (not counted as overhead).
    ``vc`` is ONLY used by the vector-clock baseline (None for PC-broadcast);
    its size is what Table 1 charges as O(N) message overhead.
    """

    origin: int
    counter: int
    payload: Any = None
    vc: Optional[Tuple[int, ...]] = None  # baseline only


@dataclass(frozen=True)
class Ping:
    """Ping pi: travels over *safe* links (flooded or routed)."""

    frm: int
    to: int
    id: int
    # routing support: remaining path (tuple of pids) when ping_mode="route"
    route: Optional[Tuple[int, ...]] = None


@dataclass(frozen=True)
class Pong:
    """Pong rho: may travel over *any* communication mean (out-of-band)."""

    frm: int  # the pinging process (paper: from = p)
    to: int   # the pinged process
    id: int


def msg_id(m: AppMsg) -> Tuple[int, int]:
    return (m.origin, m.counter)


def control_bytes(m: Any) -> int:
    """Causality-control bytes carried by a message (overhead accounting)."""
    if isinstance(m, AppMsg):
        if m.vc is not None:
            return _INT * 2 + _INT * len(m.vc)  # id + vector clock
        return _INT * 2  # id only — the paper's O(1)
    if isinstance(m, (Ping, Pong)):
        return _INT * 3
    return 0


class Protocol:
    """Base class: a process running one broadcast protocol instance."""

    def __init__(self, pid: int, deliver_cb: Optional[Callable[[int, AppMsg], None]] = None):
        self.pid = pid
        self.net = None  # set by Network.add_process
        self.crashed = False
        self.counter = 0  # per-process broadcast message counter
        self.delivered_log: List[AppMsg] = []
        self._deliver_cb = deliver_cb

    # -- hooks the Network invokes ------------------------------------- #
    def on_open(self, q: int) -> None:  # link self -> q added
        raise NotImplementedError

    def on_close(self, q: int) -> None:  # link self -> q removed
        raise NotImplementedError

    def on_receive(self, src: int, msg: Any) -> None:
        raise NotImplementedError

    def on_oob(self, src: int, msg: Any) -> None:
        pass

    def on_timeout(self, payload: Any) -> None:
        pass

    # -- helpers -------------------------------------------------------- #
    def send(self, dst: int, msg: Any) -> None:
        self.net.stats.control_bytes += control_bytes(msg)
        if isinstance(msg, (Ping, Pong)):
            self.net.stats.sent_control += 1
        self.net.send(self.pid, dst, msg)

    def deliver(self, m: AppMsg) -> None:
        self.delivered_log.append(m)
        self.net.record_delivery(self.pid, m)
        if self._deliver_cb is not None:
            self._deliver_cb(self.pid, m)

    def next_message(self, payload: Any = None) -> AppMsg:
        self.counter += 1
        return AppMsg(self.pid, self.counter, payload)
