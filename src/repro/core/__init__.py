"""repro.core — the paper's contribution, reproduced exactly.

Discrete-event implementations of the paper's three algorithms plus the
vector-clock baseline, the Spray-like dynamic overlay, and a
happens-before oracle validating the broadcast specification.

The TPU-native tensorized adaptation lives in ``repro.core.engine``;
the scenario-driven vectorized large-N simulator (50k-100k processes,
cross-validated against the exact engine) in ``repro.core.vecsim``.
Shared stats/delay types live in ``repro.core.types``.
"""

from .base import AppMsg, Ping, Pong, Protocol, control_bytes, msg_id
from .bounded import BoundedPCBroadcast
from .events import Link, Network
from .oracle import OracleReport, check_trace
from .overlay import SprayOverlay, ring_plus_random, view_size
from .pcbroadcast import PCBroadcast
from .rbroadcast import RBroadcast
from .types import DelayFn, NetStats, constant_delay, uniform_delay
from .vector_clock import VCBroadcast

__all__ = [
    "AppMsg", "Ping", "Pong", "Protocol", "control_bytes", "msg_id",
    "BoundedPCBroadcast", "Link", "NetStats", "Network",
    "DelayFn", "constant_delay", "uniform_delay",
    "OracleReport", "check_trace",
    "SprayOverlay", "ring_plus_random", "view_size",
    "PCBroadcast", "RBroadcast", "VCBroadcast",
]
