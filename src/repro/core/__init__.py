"""repro.core — the paper's contribution, reproduced exactly.

Discrete-event implementations of the paper's three algorithms plus the
vector-clock baseline, the Spray-like dynamic overlay, and a
happens-before oracle validating the broadcast specification.

The TPU-native tensorized adaptation lives in ``repro.core.engine``.
"""

from .base import AppMsg, Ping, Pong, Protocol, control_bytes, msg_id
from .bounded import BoundedPCBroadcast
from .events import Link, NetStats, Network
from .oracle import OracleReport, check_trace
from .overlay import SprayOverlay, ring_plus_random, view_size
from .pcbroadcast import PCBroadcast
from .rbroadcast import RBroadcast
from .vector_clock import VCBroadcast

__all__ = [
    "AppMsg", "Ping", "Pong", "Protocol", "control_bytes", "msg_id",
    "BoundedPCBroadcast", "Link", "NetStats", "Network",
    "OracleReport", "check_trace",
    "SprayOverlay", "ring_plus_random", "view_size",
    "PCBroadcast", "RBroadcast", "VCBroadcast",
]
