"""Deprecation shim: these metrics moved to :mod:`repro.obs.graphs`.

Kept so external callers of ``repro.core.metrics`` keep working; the
implementations live in ``repro.obs`` with the rest of the telemetry
subsystem.  Importing this module warns with
:class:`~repro.core.types.LegacyEntryPointWarning` — CI escalates that
category to an error, so nothing shipped in this repo imports through
here.
"""

from __future__ import annotations

import warnings

from ..obs.graphs import (_bfs_depths, full_graph, mean_shortest_path,
                          overhead_per_message, safe_graph,
                          unsafe_link_stats)
from .types import LegacyEntryPointWarning

__all__ = [
    "safe_graph",
    "full_graph",
    "mean_shortest_path",
    "unsafe_link_stats",
    "overhead_per_message",
]

warnings.warn(
    "repro.core.metrics moved to repro.obs (import safe_graph, "
    "mean_shortest_path, overhead_per_message... from repro.obs or "
    "repro.obs.graphs)",
    LegacyEntryPointWarning, stacklevel=2)
