"""Vectorized vector-clock causal broadcast — the measured Table 1 baseline.

Runs the classic Fidge/Mattern baseline (``repro.core.vector_clock``) on
the same lockstep-round substrate as the PC-broadcast vec engine, so
``bench_table1 --engine vec`` can report *measured* — not modeled — VC
columns at populations the object simulator cannot reach.  The whole
network is dense arrays:

  * ``vc[p, j]``    — process ``p``'s clock entry for broadcasting origin
    ``origins[j]`` (columns are the distinct broadcast origins, sorted by
    pid; when every process broadcasts this is the full (N, N) clock);
  * ``stamp[m, j]`` — message ``m``'s piggybacked clock, fixed at its
    broadcast round from the origin's clock (own entry pre-incremented);
  * ``rcv/arr/delivered`` — (N, M) first-receipt round, earliest scheduled
    arrival, and delivery round, exactly like the PC engine's buffers.

Per round: link removals/additions (every link is usable immediately —
VC needs no link-safety gating, which is the point of the comparison),
crashes, broadcasts (origin stamps + delivers its own message), first
receipts (gossip-forward on first receipt, park in the pending set), and
a per-process delivery drain that rescans pending until a fixpoint —
the O(W·N) loop Table 1 charges this family with.

What is measured, and how faithfully:

  * **per-hop piggyback bytes** — every forwarded copy of ``m`` carries
    ``16 + 8·|entries(stamp[m])|`` bytes, the exact-engine
    ``control_bytes`` accounting for an ``AppMsg`` with a ``vc`` tuple;
  * **comparison counts** — each readiness check scans the stamp's
    present (nonzero) entries in sorted-pid order and stops at the first
    failing entry, mirroring ``VCBroadcast._ready``.  Drains fire only
    at processes that received something this round; lockstep batching
    coalesces same-round receipts into one drain, so absolute counts are
    a lower bound on the event-interleaved exact engine's (the W·N
    growth, which is the claim under test, is unaffected);
  * **delivered multisets and final clock values** — byte-identical to
    ``core.vector_clock.VCBroadcast`` replayed on the exact event engine
    (``crossval.cross_validate(..., protocol="vc")`` asserts this at
    N ≤ 256 in the tier-1 suite).

NumPy only: the drain fixpoint is data-dependent per round, which fits
the host-loop numpy backend; a jitted ``lax.while_loop`` port is
possible but unneeded at the M ~ tens of Table 1 scenarios.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from ..types import NetStats
from .scenario import INF, VecScenario

__all__ = ["VCVecRunResult", "run_vec_vc"]

# Wire-size model shared with repro.core.base.control_bytes: an AppMsg
# carrying a vc tuple costs id (2 ints) + one int per (pid, counter) pair.
_INT = 8


@dataclass
class VCVecRunResult:
    """Result of a vectorized vector-clock baseline run."""

    scenario: VecScenario
    delivered: np.ndarray        # (N, m_app) delivery round, -1 = never
    rcv: np.ndarray              # (N, m_app) first-receipt round, INF = never
    vc: np.ndarray               # (N, B) final clocks, B = distinct origins
    origins: np.ndarray          # (B,) sorted distinct broadcast origins
    stamp: np.ndarray            # (m_app, B) per-message piggybacked clocks
    state: Dict[str, np.ndarray]  # final adj/delay/active/crashed
    stats: NetStats
    series: np.ndarray           # (rounds, 3): deliveries, sent, comparisons
    comparisons: int             # total vector-entry comparisons
    max_pending: int             # peak pending-set size at any process (W)
    backend: str = "numpy"

    @property
    def delivered_app(self) -> np.ndarray:
        return self.delivered

    def delivered_frac(self) -> float:
        ok = ~self.state["crashed"]
        d = self.delivered[ok]
        return float((d >= 0).mean()) if d.size else 1.0

    def mean_latency(self) -> float:
        d = self.delivered
        got = d >= 0
        if not got.any():
            return float("nan")
        lat = d - self.scenario.bcast_round[None, :]
        return float(lat[got].mean())

    def final_clocks(self) -> List[Dict[int, int]]:
        """Per-process ``{origin: delivered count}`` dicts with only the
        nonzero entries — the exact ``VCBroadcast.vc`` representation,
        for byte-level cross-validation."""
        out: List[Dict[int, int]] = []
        for p in range(self.scenario.n):
            row = self.vc[p]
            out.append({int(self.origins[j]): int(row[j])
                        for j in np.nonzero(row > 0)[0]})
        return out

    def overhead_bytes_per_message(self) -> float:
        """Measured piggyback bytes per sent copy (Table 1's O(N) term)."""
        return self.stats.control_bytes / max(self.stats.sent_messages, 1)

    def comparisons_per_delivery(self) -> float:
        """Measured vector-entry comparisons per delivery (Table 1's
        O(W·N) delivery execution time)."""
        return self.comparisons / max(self.stats.deliveries, 1)


def run_vec_vc(scn: VecScenario, backend: str = "numpy") -> VCVecRunResult:
    """Execute ``scn`` under the vector-clock baseline protocol.

    Uses only the app-broadcast schedule plus the link/crash dynamics of
    the scenario (VC has no ping phase, so the ``m_app + n_adds`` slot
    split of the PC engine collapses to ``m_app`` message columns)."""
    if backend not in ("numpy", "auto"):
        raise ValueError(
            f"the vector-clock vec engine is numpy-only (got backend "
            f"{backend!r}); see the module docstring")
    n, k, m = scn.n, scn.k, scn.m_app
    rounds = scn.rounds

    origins = np.unique(scn.bcast_origin).astype(np.int64)
    b = len(origins)
    col_of = np.full(n, -1, np.int64)
    col_of[origins] = np.arange(b)
    bc_col = col_of[scn.bcast_origin]          # (m,) stamp column per message

    vc = np.zeros((n, b), np.int32)
    stamp = np.zeros((m, b), np.int32)
    stamped = np.zeros(m, bool)
    arr = np.full((n, m), INF, np.int32)
    rcv = np.full((n, m), INF, np.int32)
    delivered = np.full((n, m), -1, np.int32)
    adj = scn.adj0.astype(np.int32).copy()
    delay = scn.delay0.astype(np.int32).copy()
    active = (scn.adj0 >= 0).copy()
    crashed = np.zeros(n, bool)

    series = np.zeros((rounds, 3), np.int64)   # deliveries, sent, comparisons
    control_bytes = 0
    sent = 0
    comparisons = 0
    max_pending = 0

    # Per-message payload size of a forwarded copy, fixed at stamp time.
    msg_bytes = np.zeros(m, np.int64)

    for t in range(rounds):
        # -- 1/2/3. link removals, additions, crashes -------------------- #
        for e in np.nonzero(scn.rm_round == t)[0]:
            active[int(scn.rm_p[e]), int(scn.rm_k[e])] = False
        for e in np.nonzero(scn.add_round == t)[0]:
            p, kk = int(scn.add_p[e]), int(scn.add_k[e])
            adj[p, kk] = int(scn.add_q[e])
            delay[p, kk] = int(scn.add_delay[e])
            active[p, kk] = True               # usable immediately: no gate
        for e in np.nonzero(scn.crash_round == t)[0]:
            crashed[int(scn.crash_pid[e])] = True

        # -- 4. broadcasts: stamp from the origin's clock, deliver ------- #
        # Same-timestamp order matches the exact replay: scheduled
        # broadcasts fire before this round's arrivals, so a stamp never
        # includes a same-round receipt.
        bc_now = np.nonzero(scn.bcast_round == t)[0]
        for i in bc_now:
            o = int(scn.bcast_origin[i])
            if crashed[o]:
                continue
            c = int(bc_col[i])
            stamp[i] = vc[o]
            stamp[i, c] += 1
            vc[o, c] += 1
            stamped[i] = True
            rcv[o, i] = t
            delivered[o, i] = t
            msg_bytes[i] = _INT * 2 + _INT * int((stamp[i] > 0).sum())

        # -- 5. first receipts: gossip-forward, park in pending ---------- #
        newly = (arr == t) & (rcv == INF) & ~crashed[:, None]
        rcv[newly] = t

        # -- 6. forward this round's originations + first receipts ------- #
        send_mask = newly.copy()
        for i in bc_now:
            o = int(scn.bcast_origin[i])
            if stamped[i] and delivered[o, i] == t:
                send_mask[o, i] = True
        rows_idx, cols_idx = np.nonzero(send_mask)
        if rows_idx.size:
            arr_flat = arr.reshape(-1)
            copies = np.zeros(len(rows_idx), np.int64)
            for kk in range(k):
                ok = active[:, kk] & (adj[:, kk] >= 0) & ~crashed
                sel = ok[rows_idx]
                if not sel.any():
                    continue
                copies[sel] += 1
                r, c = rows_idx[sel], cols_idx[sel]
                lin = adj[r, kk].astype(np.int64) * m + c
                np.minimum.at(arr_flat, lin,
                              (t + delay[r, kk]).astype(np.int32))
            sent_now = int(copies.sum())
            sent += sent_now
            control_bytes += int((msg_bytes[cols_idx] * copies).sum())
            series[t, 1] = sent_now

        # -- 7. delivery drain: rescan pending until a fixpoint ---------- #
        # Drains fire where something was received this round (the exact
        # engine drains on receive); a delivery can only unblock more
        # pending messages at the same process, so the fixpoint is local.
        drain_rows = np.nonzero(newly.any(axis=1))[0]
        if drain_rows.size:
            present = stamp > 0                       # (m, b)
            pres_cnt = present.sum(axis=1).astype(np.int64)
            pres_cum = np.cumsum(present, axis=1, dtype=np.int64)
            need = stamp.copy()
            need[np.arange(m), bc_col] -= 1           # own entry: off by one
            pend = ((rcv[drain_rows] != INF)
                    & (delivered[drain_rows] < 0))    # (R, m)
            max_pending = max(max_pending, int(pend.sum(axis=1).max()))
            while pend.any():
                vcr = vc[drain_rows]                  # (R, b)
                fails = (present[None] & (vcr[:, None, :]
                                          < need[None]))  # (R, m, b)
                fail_any = fails.any(axis=2)
                first = fails.argmax(axis=2)          # first failing column
                # entries scanned by the early-exit check: all present
                # entries when ready, else present entries up to and
                # including the first failing one (sorted-pid order)
                cnt = np.where(fail_any,
                               pres_cum[np.arange(m)[None, :], first],
                               pres_cnt[None])
                scanned = int(cnt[pend].sum())
                comparisons += scanned
                series[t, 2] += scanned
                ready = pend & ~fail_any
                if not ready.any():
                    break
                rr, mm = np.nonzero(ready)
                delivered[drain_rows[rr], mm] = t
                np.add.at(vc, (drain_rows[rr], bc_col[mm]), 1)
                pend &= ~ready

        series[t, 0] = int((delivered == t).sum())

    first_receipts = int((arr < rounds).sum())
    stats = NetStats(
        sent_messages=sent,
        sent_control=0,                       # VC has no ping/pong traffic
        control_bytes=control_bytes,
        oob_messages=0,
        deliveries=int((delivered >= 0).sum()),
        duplicate_receipts=max(0, sent - first_receipts),
    )
    state = dict(adj=adj, delay=delay, active=active, crashed=crashed)
    return VCVecRunResult(
        scenario=scn, delivered=delivered, rcv=rcv, vc=vc, origins=origins,
        stamp=stamp, state=state, stats=stats, series=series,
        comparisons=comparisons, max_pending=max_pending)
