"""repro.core.vecsim.shard — the device-sharded streaming engine.

The streaming windowed engine (``vecsim.stream``) removed the *traffic*
cap — O(N·W) memory however many messages flow — but the process axis
still had to fit one device, topping out around N ≈ 100k on a host.
This package partitions that axis across a JAX device mesh with
``shard_map``: each device owns an ``N/D`` row-block of every plane
(arrival/delivery buffers, the ``(N, K)`` adjacency slot table, gating
state), and the only cross-shard traffic is a per-round **frontier
exchange** — a ring ``ppermute`` of this round's delivered columns and
their scatter-min arrival contributions, replacing the global scatter.
Pong detection rides a second, much thinner query ring; retirement
aggregates are ``psum``-reduced across the mesh between segments.

The round body replicates the monolithic JAX span semantics operation
for operation (DESIGN.md §2.5 walks the partitioning argument), and the
host driver shares the windowed engine's activation/retirement *logic*
via :class:`~repro.core.vecsim.stream.ColumnWindow` — which is why a
sharded run's delivered matrix, per-round series and ``NetStats`` are
byte-identical to the windowed engine's on any scenario small enough to
run both, at every device count (differentially fuzzed in
``tests/test_vecsim_fuzz.py``, matrix-tested in
``tests/test_vecsim_shard.py``).

At scale the state never round-trips to the host between segments (the
single-host engine's known bottleneck): spans, retirement reductions
and column recycling all execute device-side, and the host sees only
(W,)-sized aggregates.  With ``scan="on"`` (the default) even the
per-round dispatch disappears: each segment runs as a single
``lax.scan`` over rounds inside ``shard_map`` with stacked schedule
inputs, donated buffers and a double-buffered frontier exchange, and
topology-quiescent segments drop into a bit-packed int16 fast body
(DESIGN.md §2.7) — the ≥10x throughput step at N = 1M.  ``benchmarks/bench_scale.py`` drives a
sustained-traffic run at N ≥ 1M processes on a forced host-device mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=D``) — the
population regime the paper's constant-size control information is
about, and two orders of magnitude past the single-host engines.

Modules:
  mesh     — device-mesh resolution and process-axis padding
  spanner  — the ``shard_map`` span runner and retirement kernels
  driver   — ``execute_sharded``: the host driver and result type

Reachable from the front door as ``engine="sharded"``
(``repro.api.run``); auto-selected when the memory budget forces
windowing and more than one device is visible (DESIGN.md §3.3).
"""

from .driver import ShardedRunResult, ShardedStepper, execute_sharded
from .mesh import pad_rows, resolve_devices, shard_mesh

__all__ = ["ShardedRunResult", "ShardedStepper", "execute_sharded",
           "resolve_devices",
           "shard_mesh", "pad_rows"]
