"""``execute_sharded`` — the host driver of the device-sharded engine.

Structurally the twin of ``stream.execute_windowed``: the same
:class:`~repro.core.vecsim.stream.ColumnWindow` activates messages into
live columns, the same segment loop advances rounds, and the same
retirement *rules* recycle columns — but the state lives on the device
mesh for the whole run.  Segments execute through the ``shard_map`` span
runner, retirement decisions are made from ``psum``-reduced per-column
aggregates, and column recycling is a masked device-side update; the
host never materializes an ``(N, W)`` plane unless the run is small
enough to collect the full delivered matrix (``collect="full"``).

Byte-identity contract: for any scenario both engines can run, the
returned delivered matrix, per-round series, ``NetStats``, per-message
aggregates, ``peak_live`` and overflow behavior equal the windowed
engine's exactly, at every device count — asserted by
``tests/test_vecsim_shard.py`` and the differential fuzz suite.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ..scenario import INF, VecScenario
from ..sim import SERIES_FIELDS, SlotSchedule, init_topo_state, \
    stats_from_series
from ..stream import ColumnWindow, WindowedRunResult
from .mesh import inverse_tables, pad_rows, resolve_devices, shard_mesh
from .spanner import (INT16_LIMIT, STATE_KEYS, resolve_scan,
                      resolve_shard_backend, shard_fast_span_runner,
                      shard_retire_kernels, shard_span_runner)

__all__ = ["ShardedRunResult", "execute_sharded"]


@dataclass
class ShardedRunResult(WindowedRunResult):
    """A windowed-engine result produced by the sharded engine: same
    fields and semantics, plus the device count that executed it and
    the resolved segment-loop mode (``scan`` = "on"/"off")."""

    n_devices: int = 1
    scan: str = "off"


def _padded_state(scn: VecScenario, w: int, n_pad: int) -> Dict[str, np.ndarray]:
    """Host-built initial state with inert padding rows: no links, no
    arrivals, crashed (so the all-alive-delivered retirement rule and
    the per-round stats never see them)."""
    st = init_topo_state(scn, w)
    n = scn.n
    if n_pad == n:
        return st
    extra = n_pad - n
    pad = dict(
        arr=np.full((extra, w), INF, np.int32),
        delivered=np.full((extra, w), -1, np.int32),
        adj=np.full((extra, scn.k), -1, np.int32),
        delay=np.ones((extra, scn.k), np.int32),
        active=np.zeros((extra, scn.k), bool),
        gate=np.full((extra, scn.k), -1, np.int32),
        flush=np.full((extra, scn.k), INF, np.int32),
        ping=np.full((extra, scn.k), -1, np.int32),
        crashed=np.ones(extra, bool),
        ever_del=np.zeros(extra, bool),
    )
    return {key: np.concatenate([st[key], pad[key]]) for key in st}


def execute_sharded(scn: VecScenario, window: int,
                    n_devices: Optional[int] = None,
                    horizon: Optional[int] = None, seg_len: int = 32,
                    snapshot_round: Optional[int] = None,
                    collect: str = "auto",
                    backend: str = "jax",
                    scan: str = "auto") -> ShardedRunResult:
    """Run ``scn`` through a ``window``-column streaming buffer sharded
    over ``n_devices`` devices (``None`` = all visible).  Parameters
    match :func:`~repro.core.vecsim.stream.execute_windowed`; the
    engine *is* a jax mesh program, so ``backend`` only chooses how the
    per-shard round body executes: ``"jax"`` (plain lax, the default)
    or ``"pallas"`` (per-shard delivery-sweep kernel launches inside
    ``shard_map``, DESIGN.md §2.6); ``"auto"`` resolves like the other
    engines (pallas only where the kernels compile).

    ``scan`` picks the segment loop (DESIGN.md §2.7): ``"on"`` (and
    ``"auto"``) runs each segment as one device-resident ``lax.scan``
    over rounds — one host dispatch per segment, donated buffers,
    double-buffered frontier exchange, and (for topology-quiescent
    segments) the bit-packed fast body; ``"off"`` keeps the per-round
    host-driven stepping.  The two modes are byte-identical
    (``tests/test_vecsim_scan.py``); ``"off"`` exists as the reference
    and escape hatch.

    This is the engine implementation behind ``repro.api.run`` with
    ``engine="sharded"``; prefer the front door in new code."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    backend = resolve_shard_backend(backend)
    scan = resolve_scan(scan)

    d = resolve_devices(n_devices)
    mesh = shard_mesh(d)
    w = int(window)
    if w < 1:
        raise ValueError("window must be >= 1")
    seg_len = max(1, int(seg_len))
    n, m_app, m_total = scn.n, scn.m_app, scn.m_total
    n_pad = pad_rows(n, d)
    rounds = scn.rounds
    pc = scn.mode == "pc"
    gating = scn.n_adds > 0
    if collect == "auto":
        collect = "full" if n * max(m_total, 1) <= (1 << 26) else "aggregate"
    if collect not in ("full", "aggregate"):
        raise ValueError(f"unknown collect mode {collect!r}")

    cw = ColumnWindow(scn, w)
    row = NamedSharding(mesh, P("shard"))
    rep = NamedSharding(mesh, P())
    st0 = _padded_state(scn, w, n_pad)
    state = tuple(jax.device_put(st0[key], row) for key in STATE_KEYS)
    if scan == "on":
        # host mirror of the (padded) topology tables, advanced past
        # each segment's add/rm events so the fast body's inverse
        # tables are always built from the segment-entry topology
        topo_adj = st0["adj"].copy()
        topo_delay = st0["delay"].copy()
        topo_active = st0["active"].copy()
    del st0

    series = np.zeros((rounds, len(SERIES_FIELDS)), np.int64)
    delivered_full = (np.full((n, m_total), -1, np.int32)
                      if collect == "full" else None)
    deliv_count = np.zeros(m_total, np.int64)
    bcast_done = np.zeros(m_app, bool)
    expired = np.zeros(m_total, bool)
    first_receipts = 0
    lat_sum = 0
    lat_cnt = 0
    snapshot: Optional[Dict[str, np.ndarray]] = None

    caps = cw.segment_caps(rounds, seg_len)
    runner = shard_span_runner(d, scn.k, pc, scn.always_gate,
                               scn.pong_delay, gating=gating,
                               backend=backend, scan=scan == "on")
    reduce_run, apply_run = shard_retire_kernels(d)
    rounds_dev = np.int32(rounds)

    if scan == "on":
        caps_r = cw.round_caps(rounds)
        # The fast body needs the gating machinery quiescent for the
        # whole run (gate/flush/ping state can straddle segments) and
        # the arrival clock to fit int16; per segment it additionally
        # needs a topology-quiescent span (no add/rm events).
        max_dl = int(max(topo_delay.max(initial=1),
                         scn.add_delay.max(initial=1)))
        fast_allowed = (not (pc and gating)
                        and rounds + max_dl < INT16_LIMIT - 1)
        fast_tabs: Optional[tuple] = None

    def seg_topo_events(lo: int, hi: int):
        a0, a1 = np.searchsorted(cw.add_round_s, [lo, hi])
        r0, r1 = np.searchsorted(cw.rm_round_s, [lo, hi])
        return int(a0), int(a1), int(r0), int(r1)

    def apply_topo_events(lo: int, hi: int) -> None:
        """Advance the host topology mirror past segment ``[lo, hi)``
        (same event semantics as the round body's phases 1-2: additions
        set adj/delay/active, removals deactivate in place)."""
        nonlocal fast_tabs
        a0, a1, r0, r1 = seg_topo_events(lo, hi)
        if a1 > a0:
            topo_adj[cw.add_p_s[a0:a1], cw.add_k_s[a0:a1]] = \
                cw.add_q_s[a0:a1]
            topo_delay[cw.add_p_s[a0:a1], cw.add_k_s[a0:a1]] = \
                cw.add_delay_s[a0:a1]
            topo_active[cw.add_p_s[a0:a1], cw.add_k_s[a0:a1]] = True
        if r1 > r0:
            topo_active[cw.rm_p_s[r0:r1], cw.rm_k_s[r0:r1]] = False
        if a1 > a0 or r1 > r0:
            fast_tabs = None

    def fast_runner_and_tables():
        nonlocal fast_tabs
        if fast_tabs is None:
            sig, tabs = inverse_tables(topo_adj, topo_delay, topo_active)
            fast_tabs = (sig, tuple(jax.device_put(tb, row)
                                    for tb in tabs))
        sig, tabs = fast_tabs
        return shard_fast_span_runner(d, sig), tabs

    def host_state() -> Dict[str, np.ndarray]:
        return {key: np.asarray(v)[:n] for key, v in zip(STATE_KEYS, state)}

    def run_segment(lo: int, hi: int) -> None:
        nonlocal state
        ts = np.full(seg_len, -3, np.int32)
        ts[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
        ts_dev = jax.device_put(ts, rep)
        if scan == "off":
            padded = cw.padded_schedule(lo, hi, caps)
            sched_dev = {f.name: jax.device_put(getattr(padded, f.name),
                                                rep)
                         for f in SlotSchedule.__dataclass_fields__
                         .values()}
            state, stats = runner(state, sched_dev, ts_dev)
        else:
            a0, a1, r0, r1 = seg_topo_events(lo, hi)
            sst = cw.stacked_schedule(lo, hi, caps_r, seg_len)
            if fast_allowed and a1 == a0 and r1 == r0:
                frun, tabs = fast_runner_and_tables()
                ia = np.packbits(
                    np.concatenate([cw.slot_app,
                                    np.zeros((-w) % 8, bool)]),
                    bitorder="little")
                sched_dev = {key: jax.device_put(sst[key], rep)
                             for key in ("bc_round", "bc_origin",
                                         "bc_slot", "cr_round", "cr_pid")}
                state, stats = frun(state, tabs, jax.device_put(ia, rep),
                                    sched_dev, ts_dev)
            else:
                sched_dev = {key: jax.device_put(v, rep)
                             for key, v in sst.items()}
                state, stats = runner(state, sched_dev, ts_dev)
            apply_topo_events(lo, hi)
        series[lo:hi] = np.asarray(stats, np.int64)[: hi - lo]

    def column_origins() -> np.ndarray:
        """Per-column broadcast origin (app columns only; -1 elsewhere),
        so the reduce kernel's owner shard can answer bcast_done."""
        origins = np.full(w, -1, np.int32)
        app = cw.slot_app & (cw.slot_msg >= 0)
        if app.any():
            origins[app] = scn.bcast_origin[cw.slot_msg[app]]
        return origins

    def record_and_free(cols: np.ndarray, by_expiry: np.ndarray,
                        red, hung: np.ndarray) -> None:
        """Fold retired columns into the host aggregates and recycle
        their device-side planes — the sharded twin of the windowed
        driver's ``record_and_free``."""
        nonlocal state, first_receipts, lat_sum, lat_cnt
        if not len(cols):
            return
        cnt, arrcnt, sumdel, _, _, _, _, bdone = red
        ids = cw.slot_msg[cols]
        deliv_count[ids] = cnt[cols]
        expired[ids] |= by_expiry
        first_receipts += int(arrcnt[cols].sum())
        app = cw.slot_app[cols]
        if delivered_full is not None:
            delivered_full[:, ids] = np.asarray(state[1][:, cols])[:n]
        retire = np.zeros(w, bool)
        retire[cols] = True
        if app.any():
            acols = cols[app]
            births = cw.slot_birth[acols].astype(np.int64)
            lat_sum += int((sumdel[acols] - cnt[acols] * births).sum())
            lat_cnt += int(cnt[acols].sum())
            bcast_done[ids[app]] = bdone[acols] > 0
        state = apply_run(state, retire, retire & cw.slot_app, hung)
        cw.free_cols(cols)

    def retire(t_now: int) -> int:
        live = cw.slot_msg >= 0
        if not live.any():
            return 0
        red = tuple(np.asarray(x)
                    for x in reduce_run(state, column_origins(), rounds_dev))
        cnt, arrcnt, sumdel, alive, alivedel, blockcnt, refcnt, bdone = red
        full_del = alivedel == int(alive)
        blocked = (blockcnt > 0) & cw.slot_app
        ref = refcnt > 0
        dead = (cnt == 0) & (cw.slot_birth < t_now)
        done = live & ~ref & ((full_del & ~blocked) | dead)
        by_exp = np.zeros(w, bool)
        hung = np.zeros(w, bool)
        if horizon is not None:
            by_exp = live & ~done & (t_now - cw.slot_birth > horizon)
            hung = by_exp & ref
            done |= by_exp
        cols = np.nonzero(done)[0]
        record_and_free(cols, by_exp[cols], red, hung)
        return len(cols)

    t = 0
    while t < rounds:
        t_end = min(t + seg_len, rounds)
        if snapshot_round is not None and t <= snapshot_round:
            t_end = min(t_end, snapshot_round + 1)
        t_end = cw.activate(t, t_end)
        run_segment(t, t_end)
        if snapshot_round is not None and t_end - 1 == snapshot_round:
            snapshot = host_state()
            snapshot["is_app"] = cw.slot_app.copy()
            snapshot["slot_msg"] = cw.slot_msg.copy()
        retire(t_end)
        t = t_end

    # Drain: whatever is still live keeps its end-of-run values, exactly
    # like the windowed engine at t == rounds.
    live_cols = cw.live_cols()
    if len(live_cols):
        red = tuple(np.asarray(x)
                    for x in reduce_run(state, column_origins(), rounds_dev))
        record_and_free(live_cols, np.zeros(len(live_cols), bool), red,
                        np.zeros(w, bool))

    stats = stats_from_series(series, first_receipts)
    return ShardedRunResult(
        scenario=scn, window=w, backend=backend, stats=stats, series=series,
        delivered=delivered_full, deliv_count=deliv_count,
        bcast_done=bcast_done, expired=expired, state=host_state(),
        snapshot=snapshot, peak_live=cw.peak_live, lat_sum=lat_sum,
        lat_cnt=lat_cnt, n_devices=d, scan=scan)
