"""``execute_sharded`` — the host driver of the device-sharded engine.

Structurally the twin of ``stream.execute_windowed``: the same
:class:`~repro.core.vecsim.stream.ColumnWindow` activates messages into
live columns, the same segment loop advances rounds, and the same
retirement *rules* recycle columns — but the state lives on the device
mesh for the whole run.  Segments execute through the ``shard_map`` span
runner, retirement decisions are made from ``psum``-reduced per-column
aggregates, and column recycling is a masked device-side update; the
host never materializes an ``(N, W)`` plane unless the run is small
enough to collect the full delivered matrix (``collect="full"``).

With ``scan="on"`` a segment costs one dispatch and O(W) host bytes
(DESIGN.md §2.8): the scanned span runners return the retirement
aggregates fused into the segment program itself (no standalone reduce
dispatch), schedules stage through segment-persistent device buffers
that skip re-upload when a field's content is unchanged — with the next
segment's activation-independent fields prefetched while the current
segment executes — and the fast body's inverse-adjacency tables are
cached by topology content across quiescent segments.

Byte-identity contract: for any scenario both engines can run, the
returned delivered matrix, per-round series, ``NetStats``, per-message
aggregates, ``peak_live`` and overflow behavior equal the windowed
engine's exactly, at every device count — asserted by
``tests/test_vecsim_shard.py`` and the differential fuzz suite.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..scenario import INF, VecScenario
from ..sim import SERIES_FIELDS, STACKED_SCHED_FIELDS, SlotSchedule, \
    init_topo_state, stats_from_series
from ..stream import ColumnWindow, WindowedRunResult
from .mesh import inverse_tables, pad_rows, resolve_devices, shard_mesh, \
    topology_digest
from .spanner import (INT16_LIMIT, STATE_KEYS, resolve_scan,
                      resolve_shard_backend, shard_fast_span_runner,
                      shard_retire_kernels, shard_span_runner)

__all__ = ["ShardedRunResult", "execute_sharded"]


@dataclass
class ShardedRunResult(WindowedRunResult):
    """A windowed-engine result produced by the sharded engine: same
    fields and semantics, plus the device count that executed it, the
    resolved segment-loop mode (``scan`` = "on"/"off") and — when the
    run was profiled — the per-segment host/device timing breakdown
    (``seg_profile``: one dict per segment with ``lo``/``hi`` round
    bounds, whether the fast body ran, and ``stage_s``/``dispatch_s``/
    ``block_s``/``retire_s`` wall components)."""

    n_devices: int = 1
    scan: str = "off"
    seg_profile: Optional[List[dict]] = field(default=None, repr=False)


def _padded_state(scn: VecScenario, w: int, n_pad: int) -> Dict[str, np.ndarray]:
    """Host-built initial state with inert padding rows: no links, no
    arrivals, crashed (so the all-alive-delivered retirement rule and
    the per-round stats never see them)."""
    st = init_topo_state(scn, w)
    n = scn.n
    if n_pad == n:
        return st
    extra = n_pad - n
    pad = dict(
        arr=np.full((extra, w), INF, np.int32),
        delivered=np.full((extra, w), -1, np.int32),
        adj=np.full((extra, scn.k), -1, np.int32),
        delay=np.ones((extra, scn.k), np.int32),
        active=np.zeros((extra, scn.k), bool),
        gate=np.full((extra, scn.k), -1, np.int32),
        flush=np.full((extra, scn.k), INF, np.int32),
        ping=np.full((extra, scn.k), -1, np.int32),
        crashed=np.ones(extra, bool),
        ever_del=np.zeros(extra, bool),
    )
    return {key: np.concatenate([st[key], pad[key]]) for key in st}


class _SegmentStager:
    """Segment-persistent schedule staging for the scanned path.

    Owns one device-resident buffer per stacked schedule field, reused
    across segments: a field is re-uploaded only when its host content
    actually changed (quiescent traffic/churn segments re-use the
    all-sentinel planes already on device), and the
    activation-independent fields of segment k+1 — everything except
    ``bc_slot``/``add_slot``/``is_app``, which depend on column
    assignment — are staged while segment k executes on the mesh
    (``prefetch``), overlapping the host fill + upload with device
    compute.  The schedule buffers are never donated, which is what
    makes the reuse sound."""

    #: fields whose segment content is known before ``activate`` runs
    PREFETCHABLE = (frozenset(STACKED_SCHED_FIELDS)
                    - {"bc_slot", "add_slot"}) | {"ts"}

    def __init__(self, cw: ColumnWindow, caps, seg_len: int, rounds: int,
                 put):
        self.cw = cw
        self.caps = caps
        self.seg_len = seg_len
        self.rounds = rounds
        self.put = put
        self.host: Dict[str, np.ndarray] = {}
        self.dev: Dict[str, object] = {}
        self.pending: Optional[tuple] = None

    def _ts(self, lo: int, hi: int) -> np.ndarray:
        ts = np.full(self.seg_len, -3, np.int32)
        ts[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
        return ts

    def _stage(self, key: str, host: np.ndarray):
        old = self.host.get(key)
        if old is None or not np.array_equal(old, host):
            # copy: some sources (e.g. ``is_app``) alias ColumnWindow
            # arrays that mutate in place between segments
            self.host[key] = np.array(host, copy=True)
            self.dev[key] = self.put(host)
        return self.dev[key]

    def _build(self, lo: int, hi: int, fields) -> Dict[str, object]:
        sst = self.cw.stacked_schedule(lo, hi, self.caps, self.seg_len,
                                       fields=fields)
        out = {key: self._stage(key, v) for key, v in sst.items()}
        if "ts" in fields:
            out["ts"] = self._stage("ts", self._ts(lo, hi))
        return out

    def prefetch(self, lo: int) -> None:
        """Stage segment ``[lo, lo + seg_len)``'s activation-independent
        fields now, while the previous segment still executes.  The
        prediction can miss (activation or a horizon sweep may shorten
        the next segment); ``stage`` then rebuilds — per-field content
        comparison keeps a mispredicted upload from ever being *used*.
        """
        hi = min(lo + self.seg_len, self.rounds)
        if lo >= hi:
            self.pending = None
            return
        self.pending = (lo, hi, self._build(lo, hi, self.PREFETCHABLE))

    def stage(self, lo: int, hi: int) -> Dict[str, object]:
        """Device arrays for segment ``[lo, hi)``: the prefetched fields
        when the prediction held, everything else built and compared
        now.  Always includes ``ts`` and ``is_app``."""
        rest = frozenset(("bc_slot", "add_slot", "is_app"))
        if self.pending is not None and self.pending[:2] == (lo, hi):
            out = dict(self.pending[2])
        else:
            out = self._build(lo, hi, self.PREFETCHABLE)
        out.update(self._build(lo, hi, rest))
        self.pending = None
        return out


def execute_sharded(scn: VecScenario, window: int,
                    n_devices: Optional[int] = None,
                    horizon: Optional[int] = None, seg_len: int = 32,
                    snapshot_round: Optional[int] = None,
                    collect: str = "auto",
                    backend: str = "jax",
                    scan: str = "auto",
                    profile: bool = False) -> ShardedRunResult:
    """Run ``scn`` through a ``window``-column streaming buffer sharded
    over ``n_devices`` devices (``None`` = all visible).  Parameters
    match :func:`~repro.core.vecsim.stream.execute_windowed`; the
    engine *is* a jax mesh program, so ``backend`` only chooses how the
    per-shard round body executes: ``"jax"`` (plain lax, the default)
    or ``"pallas"`` (per-shard delivery-sweep kernel launches inside
    ``shard_map``, DESIGN.md §2.6); ``"auto"`` resolves like the other
    engines (pallas only where the kernels compile).

    ``scan`` picks the segment loop (DESIGN.md §2.7/§2.8): ``"on"``
    (and ``"auto"``) runs each segment as one device-resident
    ``lax.scan`` over rounds — one host dispatch per segment with the
    retirement reduce fused into it, donated state, segment-persistent
    prefetched schedule buffers, and (for topology-quiescent segments)
    the bit-packed fast body; ``"off"`` keeps the per-round host-driven
    stepping.  The two modes are byte-identical
    (``tests/test_vecsim_scan.py``); ``"off"`` exists as the reference
    and escape hatch.

    ``profile=True`` records a per-segment host/device timing breakdown
    on the result (``seg_profile``), at the cost of a few clock reads
    per segment — results are unaffected.

    This is the engine implementation behind ``repro.api.run`` with
    ``engine="sharded"``; prefer the front door in new code."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    backend = resolve_shard_backend(backend)
    scan = resolve_scan(scan)

    d = resolve_devices(n_devices)
    mesh = shard_mesh(d)
    w = int(window)
    if w < 1:
        raise ValueError("window must be >= 1")
    seg_len = max(1, int(seg_len))
    n, m_app, m_total = scn.n, scn.m_app, scn.m_total
    n_pad = pad_rows(n, d)
    rounds = scn.rounds
    pc = scn.mode == "pc"
    gating = scn.n_adds > 0
    if collect == "auto":
        collect = "full" if n * max(m_total, 1) <= (1 << 26) else "aggregate"
    if collect not in ("full", "aggregate"):
        raise ValueError(f"unknown collect mode {collect!r}")

    cw = ColumnWindow(scn, w, horizon=horizon)
    row = NamedSharding(mesh, P("shard"))
    rep = NamedSharding(mesh, P())
    st0 = _padded_state(scn, w, n_pad)
    state = tuple(jax.device_put(st0[key], row) for key in STATE_KEYS)
    if scan == "on":
        # host mirror of the (padded) topology tables, advanced past
        # each segment's add/rm events so the fast body's inverse
        # tables are always built from the segment-entry topology
        topo_adj = st0["adj"].copy()
        topo_delay = st0["delay"].copy()
        topo_active = st0["active"].copy()
    del st0

    series = np.zeros((rounds, len(SERIES_FIELDS)), np.int64)
    delivered_full = (np.full((n, m_total), -1, np.int32)
                      if collect == "full" else None)
    deliv_count = np.zeros(m_total, np.int64)
    bcast_done = np.zeros(m_app, bool)
    expired = np.zeros(m_total, bool)
    first_receipts = 0
    lat_sum = 0
    lat_cnt = 0
    snapshot: Optional[Dict[str, np.ndarray]] = None
    seg_profile: Optional[List[dict]] = [] if profile else None
    clock = time.perf_counter

    caps = cw.segment_caps(rounds, seg_len)
    runner = shard_span_runner(d, scn.k, pc, scn.always_gate,
                               scn.pong_delay, gating=gating,
                               backend=backend, scan=scan == "on")
    reduce_run, apply_run = shard_retire_kernels(d)
    rounds_dev = jax.device_put(np.int32(rounds), rep)

    if scan == "on":
        caps_r = cw.round_caps(rounds)
        stager = _SegmentStager(cw, caps_r, seg_len, rounds,
                                lambda a: jax.device_put(a, rep))
        # The fast body needs the gating machinery quiescent for the
        # whole run (gate/flush/ping state can straddle segments) and
        # the arrival clock to fit int16; per segment it additionally
        # needs a topology-quiescent span (no add/rm events).
        max_dl = int(max(topo_delay.max(initial=1),
                         scn.add_delay.max(initial=1)))
        fast_allowed = (not (pc and gating)
                        and rounds + max_dl < INT16_LIMIT - 1)
        fast_tabs: Optional[tuple] = None
        # inverse tables keyed by topology content: quiescent stretches
        # between (or cycling through) churn events rebuild nothing
        tab_cache: Dict[bytes, tuple] = {}

    def seg_topo_events(lo: int, hi: int):
        a0, a1 = np.searchsorted(cw.add_round_s, [lo, hi])
        r0, r1 = np.searchsorted(cw.rm_round_s, [lo, hi])
        return int(a0), int(a1), int(r0), int(r1)

    def apply_topo_events(lo: int, hi: int) -> None:
        """Advance the host topology mirror past segment ``[lo, hi)``
        (same event semantics as the round body's phases 1-2: additions
        set adj/delay/active, removals deactivate in place)."""
        nonlocal fast_tabs
        a0, a1, r0, r1 = seg_topo_events(lo, hi)
        if a1 > a0:
            topo_adj[cw.add_p_s[a0:a1], cw.add_k_s[a0:a1]] = \
                cw.add_q_s[a0:a1]
            topo_delay[cw.add_p_s[a0:a1], cw.add_k_s[a0:a1]] = \
                cw.add_delay_s[a0:a1]
            topo_active[cw.add_p_s[a0:a1], cw.add_k_s[a0:a1]] = True
        if r1 > r0:
            topo_active[cw.rm_p_s[r0:r1], cw.rm_k_s[r0:r1]] = False
        if a1 > a0 or r1 > r0:
            fast_tabs = None

    def fast_runner_and_tables():
        nonlocal fast_tabs
        if fast_tabs is None:
            key = topology_digest(topo_adj, topo_delay, topo_active)
            ent = tab_cache.get(key)
            if ent is None:
                sig, tabs = inverse_tables(topo_adj, topo_delay,
                                           topo_active)
                ent = (sig, tuple(jax.device_put(tb, row) for tb in tabs))
                if len(tab_cache) >= 16:
                    tab_cache.pop(next(iter(tab_cache)))
                tab_cache[key] = ent
            fast_tabs = ent
        sig, tabs = fast_tabs
        return shard_fast_span_runner(d, sig), tabs

    def host_state() -> Dict[str, np.ndarray]:
        return {key: np.asarray(v)[:n] for key, v in zip(STATE_KEYS, state)}

    def column_origins() -> np.ndarray:
        """Per-column broadcast origin (app columns only; -1 elsewhere),
        so the reduce kernel's owner shard can answer bcast_done."""
        origins = np.full(w, -1, np.int32)
        app = cw.slot_app & (cw.slot_msg >= 0)
        if app.any():
            origins[app] = scn.bcast_origin[cw.slot_msg[app]]
        return origins

    def run_segment(lo: int, hi: int):
        """Dispatch segment ``[lo, hi)``; returns the (device) stats
        rows and, on the scanned path, the fused retirement aggregates.
        """
        nonlocal state
        t0 = clock()
        if scan == "off":
            ts = np.full(seg_len, -3, np.int32)
            ts[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
            ts_dev = jax.device_put(ts, rep)
            padded = cw.padded_schedule(lo, hi, caps)
            sched_dev = {f.name: jax.device_put(getattr(padded, f.name),
                                                rep)
                         for f in SlotSchedule.__dataclass_fields__
                         .values()}
            t1 = clock()
            state, stats = runner(state, sched_dev, ts_dev)
            red = None
            fast = False
        else:
            a0, a1, r0, r1 = seg_topo_events(lo, hi)
            origins_dev = jax.device_put(column_origins(), rep)
            fast = fast_allowed and a1 == a0 and r1 == r0
            if fast:
                frun, tabs = fast_runner_and_tables()
                sched_dev = stager.stage(lo, hi)
                ia = np.packbits(
                    np.concatenate([cw.slot_app,
                                    np.zeros((-w) % 8, bool)]),
                    bitorder="little")
                ia_dev = stager._stage("__ia_pack", ia)
                t1 = clock()
                state, stats, red = frun(
                    state, tabs, ia_dev,
                    {key: sched_dev[key]
                     for key in ("bc_round", "bc_origin", "bc_slot",
                                 "cr_round", "cr_pid")},
                    sched_dev["ts"], origins_dev, rounds_dev)
            else:
                sched_dev = stager.stage(lo, hi)
                ts_dev = sched_dev.pop("ts")
                t1 = clock()
                state, stats, red = runner(state, sched_dev, ts_dev,
                                           origins_dev, rounds_dev)
            apply_topo_events(lo, hi)
        if seg_profile is not None:
            seg_profile.append(dict(lo=lo, hi=hi, fast=fast,
                                    stage_s=t1 - t0,
                                    dispatch_s=clock() - t1))
        return stats, red

    def record_and_free(cols: np.ndarray, by_expiry: np.ndarray,
                        red, hung: np.ndarray) -> None:
        """Fold retired columns into the host aggregates and recycle
        their device-side planes — the sharded twin of the windowed
        driver's ``record_and_free``."""
        nonlocal state, first_receipts, lat_sum, lat_cnt
        if not len(cols):
            return
        cnt, arrcnt, sumdel, _, _, _, _, bdone = red
        ids = cw.slot_msg[cols]
        deliv_count[ids] = cnt[cols]
        expired[ids] |= by_expiry
        first_receipts += int(arrcnt[cols].sum())
        app = cw.slot_app[cols]
        if delivered_full is not None:
            delivered_full[:, ids] = np.asarray(state[1][:, cols])[:n]
        retire = np.zeros(w, bool)
        retire[cols] = True
        if app.any():
            acols = cols[app]
            births = cw.slot_birth[acols].astype(np.int64)
            lat_sum += int((sumdel[acols] - cnt[acols] * births).sum())
            lat_cnt += int(cnt[acols].sum())
            bcast_done[ids[app]] = bdone[acols] > 0
        state = apply_run(state, retire, retire & cw.slot_app, hung)
        cw.free_cols(cols)

    def retire(t_now: int, red_dev=None) -> int:
        """Retire columns from the fused segment aggregates (scanned
        path) or a standalone ``reduce_run`` dispatch (per-round path
        and the drain)."""
        live = cw.slot_msg >= 0
        if not live.any():
            return 0
        if red_dev is None:
            red_dev = reduce_run(state, column_origins(), rounds_dev)
        red = tuple(np.asarray(x) for x in red_dev)
        cnt, arrcnt, sumdel, alive, alivedel, blockcnt, refcnt, bdone = red
        full_del = alivedel == int(alive)
        blocked = (blockcnt > 0) & cw.slot_app
        ref = refcnt > 0
        dead = (cnt == 0) & (cw.slot_birth < t_now)
        done = live & ~ref & ((full_del & ~blocked) | dead)
        by_exp = np.zeros(w, bool)
        hung = np.zeros(w, bool)
        if horizon is not None:
            by_exp = live & ~done & (t_now - cw.slot_birth > horizon)
            hung = by_exp & ref
            done |= by_exp
        cols = np.nonzero(done)[0]
        record_and_free(cols, by_exp[cols], red, hung)
        return len(cols)

    t = 0
    while t < rounds:
        t_end = min(t + seg_len, rounds)
        if snapshot_round is not None and t <= snapshot_round:
            t_end = min(t_end, snapshot_round + 1)
        t_end = cw.activate(t, t_end)
        stats_dev, red_dev = run_segment(t, t_end)
        if scan == "on":
            # stage segment k+1's activation-independent schedule fields
            # while segment k executes on the mesh
            stager.prefetch(t_end)
        t0 = clock()
        series[t:t_end] = np.asarray(stats_dev, np.int64)[: t_end - t]
        if snapshot_round is not None and t_end - 1 == snapshot_round:
            snapshot = host_state()
            snapshot["is_app"] = cw.slot_app.copy()
            snapshot["slot_msg"] = cw.slot_msg.copy()
        t1 = clock()
        retire(t_end, red_dev)
        if seg_profile is not None:
            seg_profile[-1]["block_s"] = t1 - t0
            seg_profile[-1]["retire_s"] = clock() - t1
        t = t_end

    # Drain: whatever is still live keeps its end-of-run values, exactly
    # like the windowed engine at t == rounds.  The final boundary sweep
    # often freed every column (apply_run mutated the state after the
    # fused reduce, so its aggregates cannot be reused); skip the
    # standalone reduce dispatch entirely when nothing is live.
    live_cols = cw.live_cols()
    if len(live_cols):
        red = tuple(np.asarray(x)
                    for x in reduce_run(state, column_origins(), rounds_dev))
        record_and_free(live_cols, np.zeros(len(live_cols), bool), red,
                        np.zeros(w, bool))

    stats = stats_from_series(series, first_receipts)
    return ShardedRunResult(
        scenario=scn, window=w, backend=backend, stats=stats, series=series,
        delivered=delivered_full, deliv_count=deliv_count,
        bcast_done=bcast_done, expired=expired, state=host_state(),
        snapshot=snapshot, peak_live=cw.peak_live, lat_sum=lat_sum,
        lat_cnt=lat_cnt, n_devices=d, scan=scan, seg_profile=seg_profile)
