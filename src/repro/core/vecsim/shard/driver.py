"""``execute_sharded`` — the host driver of the device-sharded engine.

Structurally the twin of ``stream.execute_windowed``: the same
:class:`~repro.core.vecsim.stream.ColumnWindow` activates messages into
live columns, the same segment loop advances rounds, and the same
retirement *rules* recycle columns — but the state lives on the device
mesh for the whole run.  Segments execute through the ``shard_map`` span
runner, retirement decisions are made from ``psum``-reduced per-column
aggregates, and column recycling is a masked device-side update; the
host never materializes an ``(N, W)`` plane unless the run is small
enough to collect the full delivered matrix (``collect="full"``).

With ``scan="on"`` a segment costs one dispatch and O(W) host bytes
(DESIGN.md §2.8): the scanned span runners return the retirement
aggregates fused into the segment program itself (no standalone reduce
dispatch), schedules stage through segment-persistent device buffers
that skip re-upload when a field's content is unchanged — with the next
segment's activation-independent fields prefetched while the current
segment executes — and the fast body's inverse-adjacency tables are
cached by topology content across quiescent segments.

Byte-identity contract: for any scenario both engines can run, the
returned delivered matrix, per-round series, ``NetStats``, per-message
aggregates, ``peak_live`` and overflow behavior equal the windowed
engine's exactly, at every device count — asserted by
``tests/test_vecsim_shard.py`` and the differential fuzz suite.

Like the windowed engine, the segment loop is exposed as a stepper
(:class:`ShardedStepper`, one ``advance()`` per segment) so the live
serving front door (``vecsim.live``) can interleave admission control
between segments; :func:`execute_sharded` is the one-shot wrapper.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ....obs.hist import NB
from ....obs.spans import NULL_RECORDER
from ..scenario import INF, VecScenario
from ..sim import SERIES_FIELDS, STACKED_SCHED_FIELDS, SlotSchedule, \
    init_topo_state, stats_from_series
from ..stream import ColumnWindow, WindowedRunResult
from .mesh import inverse_tables, pad_rows, resolve_devices, shard_mesh, \
    topology_digest
from .spanner import (INT16_LIMIT, STATE_KEYS, resolve_scan,
                      resolve_shard_backend, shard_column_gather,
                      shard_fast_span_runner, shard_retire_kernels,
                      shard_span_runner)

__all__ = ["ShardedRunResult", "ShardedStepper", "execute_sharded"]


@dataclass
class ShardedRunResult(WindowedRunResult):
    """A windowed-engine result produced by the sharded engine: same
    fields and semantics, plus the device count that executed it, the
    resolved segment-loop mode (``scan`` = "on"/"off") and — when the
    run was profiled — the per-segment host/device timing breakdown
    (``seg_profile``: one dict per segment with ``lo``/``hi`` round
    bounds, whether the fast body ran, and ``stage_s``/``dispatch_s``/
    ``block_s``/``retire_s`` wall components)."""

    n_devices: int = 1
    scan: str = "off"
    seg_profile: Optional[List[dict]] = field(default=None, repr=False)


def _padded_state(scn: VecScenario, w: int, n_pad: int) -> Dict[str, np.ndarray]:
    """Host-built initial state with inert padding rows: no links, no
    arrivals, crashed (so the all-alive-delivered retirement rule and
    the per-round stats never see them)."""
    st = init_topo_state(scn, w)
    n = scn.n
    if n_pad == n:
        return st
    extra = n_pad - n
    pad = dict(
        arr=np.full((extra, w), INF, np.int32),
        delivered=np.full((extra, w), -1, np.int32),
        adj=np.full((extra, scn.k), -1, np.int32),
        delay=np.ones((extra, scn.k), np.int32),
        active=np.zeros((extra, scn.k), bool),
        gate=np.full((extra, scn.k), -1, np.int32),
        flush=np.full((extra, scn.k), INF, np.int32),
        ping=np.full((extra, scn.k), -1, np.int32),
        crashed=np.ones(extra, bool),
        ever_del=np.zeros(extra, bool),
    )
    return {key: np.concatenate([st[key], pad[key]]) for key in st}


class _SegmentStager:
    """Segment-persistent schedule staging for the scanned path.

    Owns one device-resident buffer per stacked schedule field, reused
    across segments: a field is re-uploaded only when its host content
    actually changed (quiescent traffic/churn segments re-use the
    all-sentinel planes already on device), and the
    activation-independent fields of segment k+1 — everything except
    ``bc_slot``/``add_slot``/``is_app``, which depend on column
    assignment — are staged while segment k executes on the mesh
    (``prefetch``), overlapping the host fill + upload with device
    compute.  The schedule buffers are never donated, which is what
    makes the reuse sound."""

    #: fields whose segment content is known before ``activate`` runs
    PREFETCHABLE = (frozenset(STACKED_SCHED_FIELDS)
                    - {"bc_slot", "add_slot"}) | {"ts"}

    def __init__(self, cw: ColumnWindow, caps, seg_len: int, rounds: int,
                 put, rec=None):
        self.cw = cw
        self.caps = caps
        self.seg_len = seg_len
        self.rounds = rounds
        self.put = put
        self.host: Dict[str, np.ndarray] = {}
        self.dev: Dict[str, object] = {}
        self.pending: Optional[tuple] = None
        # telemetry: content-cache effectiveness (repro.obs), and a span
        # around each actual device upload when tracing
        self.uploads = 0
        self.skips = 0
        self.rec = rec if rec is not None else NULL_RECORDER
        self._sid_upload = self.rec.name("stager.upload")

    def _ts(self, lo: int, hi: int) -> np.ndarray:
        ts = np.full(self.seg_len, -3, np.int32)
        ts[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
        return ts

    def _stage(self, key: str, host: np.ndarray):
        old = self.host.get(key)
        if old is None or not np.array_equal(old, host):
            # copy: some sources (e.g. ``is_app``) alias ColumnWindow
            # arrays that mutate in place between segments
            self.host[key] = np.array(host, copy=True)
            self.uploads += 1
            self.rec.begin(self._sid_upload)
            self.dev[key] = self.put(host)
            self.rec.end()
        else:
            self.skips += 1
        return self.dev[key]

    def _build(self, lo: int, hi: int, fields) -> Dict[str, object]:
        sst = self.cw.stacked_schedule(lo, hi, self.caps, self.seg_len,
                                       fields=fields)
        out = {key: self._stage(key, v) for key, v in sst.items()}
        if "ts" in fields:
            out["ts"] = self._stage("ts", self._ts(lo, hi))
        return out

    def prefetch(self, lo: int) -> None:
        """Stage segment ``[lo, lo + seg_len)``'s activation-independent
        fields now, while the previous segment still executes.  The
        prediction can miss (activation or a horizon sweep may shorten
        the next segment); ``stage`` then rebuilds — per-field content
        comparison keeps a mispredicted upload from ever being *used*.
        """
        hi = min(lo + self.seg_len, self.rounds)
        if lo >= hi:
            self.pending = None
            return
        self.pending = (lo, hi, self._build(lo, hi, self.PREFETCHABLE))

    def stage(self, lo: int, hi: int) -> Dict[str, object]:
        """Device arrays for segment ``[lo, hi)``: the prefetched fields
        when the prediction held, everything else built and compared
        now.  Always includes ``ts`` and ``is_app``."""
        rest = frozenset(("bc_slot", "add_slot", "is_app"))
        if self.pending is not None and self.pending[:2] == (lo, hi):
            out = dict(self.pending[2])
        else:
            out = self._build(lo, hi, self.PREFETCHABLE)
        out.update(self._build(lo, hi, rest))
        self.pending = None
        return out


class ShardedStepper:
    """The sharded engine, one segment per :meth:`advance` call — the
    device-mesh twin of :class:`~repro.core.vecsim.stream.WindowedStepper`
    with identical stepping semantics.  ``cw`` optionally supplies an
    externally-built :class:`ColumnWindow` (the live front door passes
    its growable subclass; when that window flags ``mutable_schedule``
    the scanned path skips cross-segment schedule prefetch, since the
    next segment's traffic is not yet admitted while this one runs)."""

    def __init__(self, scn: VecScenario, window: int,
                 n_devices: Optional[int] = None,
                 horizon: Optional[int] = None, seg_len: int = 32,
                 snapshot_round: Optional[int] = None,
                 collect: str = "auto",
                 backend: str = "jax",
                 scan: str = "auto",
                 profile: bool = False,
                 cw: Optional[ColumnWindow] = None,
                 obs=None):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        self._jax = jax
        self.backend = backend = resolve_shard_backend(backend)
        self.scan = scan = resolve_scan(scan)
        self.d = d = resolve_devices(n_devices)
        self.mesh = shard_mesh(d)
        self.w = w = int(window)
        if w < 1:
            raise ValueError("window must be >= 1")
        self.seg_len = seg_len = max(1, int(seg_len))
        self.scn = scn
        self.horizon = None if horizon is None else int(horizon)
        self.snapshot_round = snapshot_round
        n = scn.n
        self.n_pad = n_pad = pad_rows(n, d)
        self.rounds = rounds = scn.rounds
        self.pc = pc = scn.mode == "pc"
        self.gating = gating = scn.n_adds > 0

        self.cw = cw = cw if cw is not None else ColumnWindow(
            scn, w, horizon=horizon)
        self.m_app = cw.m_app_cap
        self.m_total = m_total = self.m_app + scn.n_adds
        if collect == "auto":
            collect = ("full" if n * max(m_total, 1) <= (1 << 26)
                       else "aggregate")
        if collect not in ("full", "aggregate"):
            raise ValueError(f"unknown collect mode {collect!r}")
        self.collect = collect

        self.row = row = NamedSharding(self.mesh, P("shard"))
        self.rep = rep = NamedSharding(self.mesh, P())
        st0 = _padded_state(scn, w, n_pad)
        self.state = tuple(jax.device_put(st0[key], row)
                           for key in STATE_KEYS)
        if scan == "on":
            # host mirror of the (padded) topology tables, advanced past
            # each segment's add/rm events so the fast body's inverse
            # tables are always built from the segment-entry topology
            self.topo_adj = st0["adj"].copy()
            self.topo_delay = st0["delay"].copy()
            self.topo_active = st0["active"].copy()
        del st0

        self.series = np.zeros((rounds, len(SERIES_FIELDS)), np.int64)
        self.delivered_full = (np.full((n, m_total), -1, np.int32)
                               if collect == "full" else None)
        self.deliv_count = np.zeros(m_total, np.int64)
        self.deliv_round_sum = np.zeros(m_total, np.int64)
        self.bcast_done = np.zeros(self.m_app, bool)
        self.expired = np.zeros(m_total, bool)
        self.first_receipts = 0
        self.lat_sum = 0
        self.lat_cnt = 0
        self.snapshot: Optional[Dict[str, np.ndarray]] = None
        self.seg_profile: Optional[List[dict]] = [] if profile else None
        self._clock = time.perf_counter
        self.t = 0

        # telemetry (repro.obs): the segment bodies are telemetry-free
        # either way — the latency histogram is a separate per-retirement
        # dispatch over only the retiring columns (shard_hist_runner), so
        # both arms of the CI overhead gate lean on the same traced
        # segment program
        self.obs = obs
        self.hist = obs is not None and obs.histograms
        self._rec = obs.spans if obs is not None else NULL_RECORDER
        self._sid = {name: self._rec.name(f"segment.{name}")
                     for name in ("stage", "dispatch", "block", "retire")}
        # flight recorder (repro.obs.flight): host-side provenance
        # hooks riding the retiring-column gather — O(sample) transfer,
        # segment bodies untouched
        self._flight = getattr(obs, "flight", None)
        if self._flight is not None:
            self._pgather = shard_column_gather()

        self.caps = cw.segment_caps(rounds, seg_len)
        self.runner = shard_span_runner(d, scn.k, pc, scn.always_gate,
                                        scn.pong_delay, gating=gating,
                                        backend=backend, scan=scan == "on")
        self.reduce_run, self.apply_run = shard_retire_kernels(d)
        if self.hist:
            import jax.numpy as jnp

            from ....obs.hist import bucket_index_jnp

            # jitted retiring-column gather + on-device log bucketing:
            # the host pulls one uint8 index plane (NB = invalid, kept
            # out of the histogram by the bincount slice) instead of the
            # raw int32 delivered slice — 4x less transfer, and the
            # bucket fold rides the fused elementwise gather
            def _bucket_take(a, c, b):
                d = jnp.take(a, c, axis=1)
                v = d - b[None, :]
                ok = (d >= 0) & (v >= 0)
                return jnp.where(ok, bucket_index_jnp(v),
                                 NB).astype(jnp.uint8)

            self._take = jax.jit(_bucket_take)
        self.rounds_dev = jax.device_put(np.int32(rounds), rep)

        if scan == "on":
            self.caps_r = cw.round_caps(rounds)
            self.stager = _SegmentStager(cw, self.caps_r, seg_len, rounds,
                                         lambda a: jax.device_put(a, rep),
                                         rec=self._rec)
            # The fast body needs the gating machinery quiescent for the
            # whole run (gate/flush/ping state can straddle segments)
            # and the arrival clock to fit int16; per segment it
            # additionally needs a topology-quiescent span (no add/rm
            # events).
            max_dl = int(max(self.topo_delay.max(initial=1),
                             scn.add_delay.max(initial=1)))
            self.fast_allowed = (not (pc and gating)
                                 and rounds + max_dl < INT16_LIMIT - 1)
            self.fast_tabs: Optional[tuple] = None
            # inverse tables keyed by topology content: quiescent
            # stretches between (or cycling through) churn events
            # rebuild nothing
            self.tab_cache: Dict[bytes, tuple] = {}

    @property
    def done(self) -> bool:
        return self.t >= self.rounds

    def _seg_topo_events(self, lo: int, hi: int):
        cw = self.cw
        a0, a1 = np.searchsorted(cw.add_round_s, [lo, hi])
        r0, r1 = np.searchsorted(cw.rm_round_s, [lo, hi])
        return int(a0), int(a1), int(r0), int(r1)

    def _apply_topo_events(self, lo: int, hi: int) -> None:
        """Advance the host topology mirror past segment ``[lo, hi)``
        (same event semantics as the round body's phases 1-2: additions
        set adj/delay/active, removals deactivate in place)."""
        cw = self.cw
        a0, a1, r0, r1 = self._seg_topo_events(lo, hi)
        if a1 > a0:
            self.topo_adj[cw.add_p_s[a0:a1], cw.add_k_s[a0:a1]] = \
                cw.add_q_s[a0:a1]
            self.topo_delay[cw.add_p_s[a0:a1], cw.add_k_s[a0:a1]] = \
                cw.add_delay_s[a0:a1]
            self.topo_active[cw.add_p_s[a0:a1], cw.add_k_s[a0:a1]] = True
        if r1 > r0:
            self.topo_active[cw.rm_p_s[r0:r1], cw.rm_k_s[r0:r1]] = False
        if a1 > a0 or r1 > r0:
            self.fast_tabs = None

    def _fast_runner_and_tables(self):
        jax = self._jax
        if self.fast_tabs is None:
            key = topology_digest(self.topo_adj, self.topo_delay,
                                  self.topo_active)
            ent = self.tab_cache.get(key)
            if ent is None:
                sig, tabs = inverse_tables(self.topo_adj, self.topo_delay,
                                           self.topo_active)
                ent = (sig, tuple(jax.device_put(tb, self.row)
                                  for tb in tabs))
                if len(self.tab_cache) >= 16:
                    self.tab_cache.pop(next(iter(self.tab_cache)))
                self.tab_cache[key] = ent
            self.fast_tabs = ent
        sig, tabs = self.fast_tabs
        return shard_fast_span_runner(self.d, sig), tabs

    def host_state(self) -> Dict[str, np.ndarray]:
        return {key: np.asarray(v)[: self.scn.n]
                for key, v in zip(STATE_KEYS, self.state)}

    def _column_origins(self) -> np.ndarray:
        """Per-column broadcast origin (app columns only; -1 elsewhere),
        so the reduce kernel's owner shard can answer bcast_done."""
        cw = self.cw
        origins = np.full(self.w, -1, np.int32)
        app = cw.slot_app & (cw.slot_msg >= 0)
        if app.any():
            origins[app] = cw.bc_origin[cw.slot_msg[app]]
        return origins

    def _column_base(self) -> np.ndarray:
        """Per-column latency reference round for the on-device latency
        histogram (app columns only; -1 = no base, count nowhere).  The
        default base is the column's birth round — the batch engines'
        latency convention — overridden per message by
        ``obs.latency_base`` (live mode: the submission round, so the
        histogram includes queueing delay)."""
        cw = self.cw
        base = np.full(self.w, -1, np.int32)
        app = cw.slot_app & (cw.slot_msg >= 0)
        if app.any():
            lb = self.obs.latency_base if self.obs is not None else None
            if lb is not None:
                base[app] = lb[cw.slot_msg[app]]
            else:
                base[app] = cw.slot_birth[app]
        return base

    def _run_segment(self, lo: int, hi: int):
        """Dispatch segment ``[lo, hi)``; returns the (device) stats
        rows and, on the scanned path, the fused retirement aggregates.
        """
        jax, cw, seg_len = self._jax, self.cw, self.seg_len
        rec, sid = self._rec, self._sid
        t0 = self._clock()
        rec.begin(sid["stage"])
        if self.scan == "off":
            ts = np.full(seg_len, -3, np.int32)
            ts[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
            ts_dev = jax.device_put(ts, self.rep)
            padded = cw.padded_schedule(lo, hi, self.caps)
            sched_dev = {f.name: jax.device_put(getattr(padded, f.name),
                                                self.rep)
                         for f in SlotSchedule.__dataclass_fields__
                         .values()}
            rec.end()
            t1 = self._clock()
            rec.begin(sid["dispatch"])
            self.state, stats = self.runner(self.state, sched_dev, ts_dev)
            rec.end()
            red = None
            fast = False
        else:
            a0, a1, r0, r1 = self._seg_topo_events(lo, hi)
            origins_dev = jax.device_put(self._column_origins(), self.rep)
            fast = self.fast_allowed and a1 == a0 and r1 == r0
            if fast:
                frun, tabs = self._fast_runner_and_tables()
                sched_dev = self.stager.stage(lo, hi)
                ia = np.packbits(
                    np.concatenate([cw.slot_app,
                                    np.zeros((-self.w) % 8, bool)]),
                    bitorder="little")
                ia_dev = self.stager._stage("__ia_pack", ia)
                rec.end()
                t1 = self._clock()
                rec.begin(sid["dispatch"])
                self.state, stats, red = frun(
                    self.state, tabs, ia_dev,
                    {key: sched_dev[key]
                     for key in ("bc_round", "bc_origin", "bc_slot",
                                 "cr_round", "cr_pid")},
                    sched_dev["ts"], origins_dev, self.rounds_dev)
                rec.end()
            else:
                sched_dev = self.stager.stage(lo, hi)
                ts_dev = sched_dev.pop("ts")
                rec.end()
                t1 = self._clock()
                rec.begin(sid["dispatch"])
                self.state, stats, red = self.runner(
                    self.state, sched_dev, ts_dev, origins_dev,
                    self.rounds_dev)
                rec.end()
            self._apply_topo_events(lo, hi)
        if self.seg_profile is not None:
            self.seg_profile.append(dict(lo=lo, hi=hi, fast=fast,
                                         stage_s=t1 - t0,
                                         dispatch_s=self._clock() - t1))
        return stats, red

    def _record_and_free(self, cols: np.ndarray, by_expiry: np.ndarray,
                         red, hung: np.ndarray,
                         t_now: Optional[int] = None) -> None:
        """Fold retired columns into the host aggregates and recycle
        their device-side planes — the sharded twin of the windowed
        driver's ``_record_and_free``."""
        if not len(cols):
            return
        cw = self.cw
        cnt, arrcnt, sumdel, bdone = red[0], red[1], red[2], red[7]
        ids = cw.slot_msg[cols]
        self.deliv_count[ids] = cnt[cols]
        self.deliv_round_sum[ids] = sumdel[cols].astype(np.int64)
        self.expired[ids] |= by_expiry
        self.first_receipts += int(arrcnt[cols].sum())
        app = cw.slot_app[cols]
        if self.delivered_full is not None:
            self.delivered_full[:, ids] = \
                np.asarray(self.state[1][:, cols])[: self.scn.n]
        retire = np.zeros(self.w, bool)
        retire[cols] = True
        if app.any():
            acols = cols[app]
            births = cw.slot_birth[acols].astype(np.int64)
            self.lat_sum += int((sumdel[acols] - cnt[acols] * births).sum())
            self.lat_cnt += int(cnt[acols].sum())
            self.bcast_done[ids[app]] = bdone[acols] > 0
            if self.hist:
                # latency histogram over only the retiring app columns,
                # read while their delivered plane is still intact
                # (apply_run below recycles it): one jitted gather of
                # the retiring slice — padded to a few power-of-two
                # widths so it compiles a handful of shapes — with the
                # log bucketing fused on device, so the host pulls a
                # uint8 bucket-index plane and folds it with a single
                # bincount.  Cheap enough that the CI overhead gate's
                # enabled arm holds on a CPU mesh; shard_hist_runner is
                # the fully on-device twin for accelerator meshes
                # (parity-tested)
                base = self._column_base()
                r = min(max(8, 1 << (len(acols) - 1).bit_length()),
                        max(self.w, 8))
                cols_p = np.zeros(r, np.int32)
                base_p = np.full(r, self.rounds + 1, np.int32)
                cols_p[: len(acols)] = acols
                bb = base[acols]
                # negative base (no reference round) joins the padding
                # sentinel: latency < 0, bucketed to NB and sliced off
                base_p[: len(acols)] = np.where(bb >= 0, bb,
                                                self.rounds + 1)
                idx = np.asarray(self._take(self.state[1], cols_p,
                                            base_p))
                counts = np.bincount(idx.ravel(), minlength=NB + 1)
                self.obs.add_hist(counts[:NB].astype(np.int64))
        fl = self._flight
        if fl is not None and fl.open_count and app.any():
            # sampled provenance: gather only the sampled retiring
            # columns' delivered rows (padded to a few power-of-two
            # widths, same shape discipline as the hist gather) while
            # the plane is intact — apply_run below recycles it
            aidx = ids[app]
            m = fl.sampled_mask(aidx)
            if m.any():
                scols = cols[app][m]
                r = min(max(8, 1 << (len(scols) - 1).bit_length()),
                        max(self.w, 8))
                cols_p = np.zeros(r, np.int32)
                cols_p[: len(scols)] = scols
                rows = np.asarray(self._pgather(self.state[1], cols_p))
                fl.on_retire(aidx[m], rows[: self.scn.n, : len(scols)],
                             self.t if t_now is None else t_now,
                             by_expiry[app][m])
        self.state = self.apply_run(self.state, retire,
                                    retire & cw.slot_app, hung)
        cw.free_cols(cols)

    def _retire(self, t_now: int, red_dev=None) -> int:
        """Retire columns from the fused segment aggregates (scanned
        path) or a standalone ``reduce_run`` dispatch (per-round path
        and the drain)."""
        cw, w = self.cw, self.w
        live = cw.slot_msg >= 0
        if not live.any():
            return 0
        if red_dev is None:
            red_dev = self.reduce_run(
                self.state, self._column_origins(), self.rounds_dev)
        red = tuple(np.asarray(x) for x in red_dev)
        (cnt, arrcnt, sumdel, alive, alivedel, blockcnt, refcnt,
         bdone) = red[:8]
        full_del = alivedel == int(alive)
        blocked = (blockcnt > 0) & cw.slot_app
        ref = refcnt > 0
        dead = (cnt == 0) & (cw.slot_birth < t_now)
        done = live & ~ref & ((full_del & ~blocked) | dead)
        by_exp = np.zeros(w, bool)
        hung = np.zeros(w, bool)
        if self.horizon is not None:
            by_exp = live & ~done & (t_now - cw.slot_birth > self.horizon)
            hung = by_exp & ref
            done |= by_exp
        fl = self._flight
        if fl is not None and fl.open_count:
            blk = np.nonzero(live & blocked & ~done)[0]
            if len(blk):
                bids = cw.slot_msg[blk]
                m = fl.sampled_mask(bids)
                if m.any():
                    fl.on_blocked(bids[m], t_now)
        cols = np.nonzero(done)[0]
        self._record_and_free(cols, by_exp[cols], red, hung, t_now)
        return len(cols)

    def advance(self) -> int:
        """Run one segment (activate -> dispatch -> retire); returns the
        new current round.  May raise
        :class:`~repro.core.vecsim.stream.WindowOverflowError` from
        ``activate`` with the engine state untouched since the previous
        segment boundary."""
        t = self.t
        if t >= self.rounds:
            return t
        t_end = min(t + self.seg_len, self.rounds)
        if self.snapshot_round is not None and t <= self.snapshot_round:
            t_end = min(t_end, self.snapshot_round + 1)
        b0 = self.cw.next_bc
        t_end = self.cw.activate(t, t_end)
        fl = self._flight
        if fl is not None and self.cw.next_bc > b0:
            b1 = self.cw.next_bc
            fl.on_activate(np.arange(b0, b1), self.cw.bc_origin[b0:b1],
                           self.cw.bc_round[b0:b1])
        stats_dev, red_dev = self._run_segment(t, t_end)
        if self.scan == "on" and not self.cw.mutable_schedule:
            # stage segment k+1's activation-independent schedule fields
            # while segment k executes on the mesh (pre-scripted runs
            # only: a live window admits segment k+1's traffic after
            # this segment completes, so there is nothing to prefetch)
            self.stager.prefetch(t_end)
        t0 = self._clock()
        self._rec.begin(self._sid["block"])
        self.series[t:t_end] = np.asarray(stats_dev, np.int64)[: t_end - t]
        if (self.snapshot_round is not None
                and t_end - 1 == self.snapshot_round):
            self.snapshot = self.host_state()
            self.snapshot["is_app"] = self.cw.slot_app.copy()
            self.snapshot["slot_msg"] = self.cw.slot_msg.copy()
        self._rec.end()
        t1 = self._clock()
        self._rec.begin(self._sid["retire"])
        self._retire(t_end, red_dev)
        self._rec.end()
        if self.seg_profile is not None:
            self.seg_profile[-1]["block_s"] = t1 - t0
            self.seg_profile[-1]["retire_s"] = self._clock() - t1
        if self.obs is not None:
            seg = self.series[t:t_end]
            self.obs.gauge("piggyback_bytes",
                           16 * int(seg[:, 1].sum() + seg[:, 3].sum())
                           + 24 * int(seg[:, 2].sum()))
            self.obs.gauge("window_occupancy",
                           int((self.cw.slot_msg >= 0).sum()))
        self.t = t_end
        return t_end

    def finish(self) -> ShardedRunResult:
        """Drain still-live columns and build the run result.  Whatever
        is still live keeps its end-of-run values, exactly like the
        windowed engine at ``t == rounds``.  The final boundary sweep
        often freed every column (apply_run mutated the state after the
        fused reduce, so its aggregates cannot be reused); skip the
        standalone reduce dispatch entirely when nothing is live."""
        cw = self.cw
        live_cols = cw.live_cols()
        if len(live_cols):
            red = tuple(np.asarray(x)
                        for x in self.reduce_run(
                            self.state, self._column_origins(),
                            self.rounds_dev))
            self._record_and_free(live_cols,
                                  np.zeros(len(live_cols), bool), red,
                                  np.zeros(self.w, bool))
        if self.obs is not None and self.scan == "on":
            self.obs.count("stager_uploads", self.stager.uploads)
            self.obs.count("stager_skips", self.stager.skips)
        stats = stats_from_series(self.series, self.first_receipts)
        return ShardedRunResult(
            scenario=self.scn, window=self.w, backend=self.backend,
            stats=stats, series=self.series, delivered=self.delivered_full,
            deliv_count=self.deliv_count, bcast_done=self.bcast_done,
            expired=self.expired, state=self.host_state(),
            snapshot=self.snapshot, peak_live=cw.peak_live,
            lat_sum=self.lat_sum, lat_cnt=self.lat_cnt,
            deliv_round_sum=self.deliv_round_sum,
            n_devices=self.d, scan=self.scan, seg_profile=self.seg_profile)


def execute_sharded(scn: VecScenario, window: int,
                    n_devices: Optional[int] = None,
                    horizon: Optional[int] = None, seg_len: int = 32,
                    snapshot_round: Optional[int] = None,
                    collect: str = "auto",
                    backend: str = "jax",
                    scan: str = "auto",
                    profile: bool = False,
                    obs=None) -> ShardedRunResult:
    """Run ``scn`` through a ``window``-column streaming buffer sharded
    over ``n_devices`` devices (``None`` = all visible).  Parameters
    match :func:`~repro.core.vecsim.stream.execute_windowed`; the
    engine *is* a jax mesh program, so ``backend`` only chooses how the
    per-shard round body executes: ``"jax"`` (plain lax, the default)
    or ``"pallas"`` (per-shard delivery-sweep kernel launches inside
    ``shard_map``, DESIGN.md §2.6); ``"auto"`` resolves like the other
    engines (pallas only where the kernels compile).

    ``scan`` picks the segment loop (DESIGN.md §2.7/§2.8): ``"on"``
    (and ``"auto"``) runs each segment as one device-resident
    ``lax.scan`` over rounds — one host dispatch per segment with the
    retirement reduce fused into it, donated state, segment-persistent
    prefetched schedule buffers, and (for topology-quiescent segments)
    the bit-packed fast body; ``"off"`` keeps the per-round host-driven
    stepping.  The two modes are byte-identical
    (``tests/test_vecsim_scan.py``); ``"off"`` exists as the reference
    and escape hatch.

    ``profile=True`` records a per-segment host/device timing breakdown
    on the result (``seg_profile``), at the cost of a few clock reads
    per segment — results are unaffected.

    This is the engine implementation behind ``repro.api.run`` with
    ``engine="sharded"``; prefer the front door in new code."""
    stepper = ShardedStepper(scn, window, n_devices=n_devices,
                             horizon=horizon, seg_len=seg_len,
                             snapshot_round=snapshot_round, collect=collect,
                             backend=backend, scan=scan, profile=profile,
                             obs=obs)
    while not stepper.done:
        stepper.advance()
    return stepper.finish()
