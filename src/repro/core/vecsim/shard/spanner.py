"""``shard_map`` span runner: the windowed round body over a device mesh.

Partitioning (DESIGN.md §2.5): every per-process plane — ``arr`` /
``delivered`` ``(N, W)`` buffers, the ``(N, K)`` adjacency/delay/gating
tables, ``crashed``/``ever_del`` — is row-block sharded over a 1-D
``("shard",)`` mesh; schedules, the ``is_app`` column mask and the round
index stream are replicated.  Per round, three things cross shards:

  * **frontier exchange** — the flood-forward + flush scatter (monolithic
    phases 7/8) becomes a ring: each device's contribution plane for this
    round's delivered columns (``vals`` = ``t + delay`` where sending,
    ``INF`` elsewhere, with its global target rows) visits every device
    via ``lax.ppermute``; each visit scatter-mins the rows it owns.
    Scatter-min is associative/commutative on ints, so the result is
    bit-equal to the monolithic global scatter regardless of hop order;
  * **pong query ring** — pong detection reads ``delivered[q, s]`` at the
    gated link's remote target; the ``(N/D, K)`` query triples (target,
    ping slot, answer) ride a second ring and come home after D hops.
    This ring is K columns wide, not W, and is elided entirely (with the
    whole gating machinery) when the scenario schedules no additions;
  * **stats psum** — the per-round series row is ``psum``-reduced so every
    shard returns the identical replicated ``(rounds, 6)`` series.

Everything else is owner-local: schedule events (removals, additions
with the Algorithm 2 gating decision, crashes, broadcasts) apply on the
shard owning their process row and drop elsewhere; arrivals/deliveries
are element-wise.  The retirement kernels at the bottom give the host
driver (``driver.py``) per-column aggregates (``psum`` over the mesh)
and a masked column-recycle, so state never leaves the devices between
segments.

The body mirrors ``sim.jax_span_runner`` operation for operation —
tests assert byte-identical delivered/series/NetStats against the
windowed engine at every device count.

**Scanned segments (DESIGN.md §2.7).**  With ``scan=True`` the whole
segment runs as one ``lax.scan`` over rounds *inside* the ``shard_map``
body: the host dispatches once per segment instead of once per round,
schedules arrive as stacked per-round scan inputs, the state tuple is
donated (``donate_argnums``), and the frontier exchange is
double-buffered — round ``r``'s ring contributions land in a
``pending`` carry plane and fold into ``arr`` at the top of round
``r + 1`` (every contribution values ``>= r + 1``, and nothing reads
``arr`` between the scatter and the fold, so the deferral is exact; a
residual fold after the scan covers the last round).  For segments
whose topology is static and whose gating machinery is quiescent, the
driver swaps in :func:`shard_fast_span_runner`, which additionally
keeps the live planes in int16, moves the frontier as a bit-packed
uint8 plane via an all-gather ring, and turns the per-target scatter
into gathers over host-built inverse-adjacency tables
(:func:`~repro.core.vecsim.shard.mesh.inverse_tables`).
"""

from __future__ import annotations

import functools

from ..scenario import INF
from ..sim import SERIES_FIELDS, _STATE_KEYS
from .mesh import shard_mesh

__all__ = ["shard_span_runner", "shard_fast_span_runner",
           "shard_retire_kernels", "shard_hist_runner",
           "shard_column_gather",
           "resolve_shard_backend", "resolve_scan", "STATE_KEYS",
           "INT16_LIMIT"]

STATE_KEYS = _STATE_KEYS

# int16 ceiling of the fast scanned body: arrival rounds live in int16
# planes there, with this value standing in for INF.  The driver only
# selects the fast body when rounds + max_delay stays safely below it.
INT16_LIMIT = 32767


def resolve_scan(scan: str) -> str:
    """Resolve the sharded engine's ``scan`` knob — the one place the
    accepted names live.  ``"auto"`` resolves to ``"on"``: the scanned
    segment body is a pure jax program, so wherever the mesh runs at
    all it runs scanned; ``"off"`` keeps the per-round host-driven
    stepping (the byte-level reference path)."""
    if scan == "auto":
        return "on"
    if scan in ("on", "off"):
        return scan
    raise ValueError(f"unknown scan mode {scan!r} (the sharded segment "
                     "loop runs scan 'auto', 'on' or 'off')")


def resolve_shard_backend(backend: str) -> str:
    """Validate/resolve the sharded engine's round-body backend — the
    one place the accepted names live.  ``"jax"`` passes through,
    ``"pallas"`` requires the kernels to initialize, and ``"auto"``
    resolves like the other engines (numpy can never shard, so auto
    lands on jax wherever Pallas does not compile)."""
    if backend == "auto":
        from ..sim import resolve_backend
        backend = resolve_backend("auto")
        if backend == "numpy":  # pragma: no cover - needs jax to get here
            backend = "jax"
    if backend == "pallas":
        from .. import kernels
        kernels.require_pallas()
    elif backend != "jax":
        raise ValueError(f"unknown sharded backend {backend!r} (the mesh "
                         "program runs backend 'jax' or 'pallas')")
    return backend


def _shift(d: int):
    """Forward ring permutation on the ``shard`` axis."""
    return [(i, (i + 1) % d) for i in range(d)]


def _column_partials(state, origins, rounds, off):
    """This shard's contribution to the per-column retirement
    aggregates — the single definition both consumers trace through:
    :func:`shard_retire_kernels`'s standalone ``reduce`` (the scan="off"
    and drain paths) and the fused reduce at the tail of the scanned
    span runners, so the device-resident retirement decisions cannot
    drift from the reference reduction.  Returns the 8-tuple
    ``(cnt, arrcnt, sumdel, alive, alivedel, blocked, ref, bdone)``
    *before* the mesh ``psum``; callers psum it across shards.

    Deliberately telemetry-free: the delivery-latency histogram is a
    separate retirement-time dispatch (:func:`shard_hist_runner`) over
    only the retiring columns, so enabling telemetry never re-traces or
    slows the segment bodies (DESIGN.md §2.10).
    """
    import jax.numpy as jnp

    (arr, delivered, adj, delay, active, gate, flush, ping,
     crashed, ever_del) = state
    n_loc, w = arr.shape
    inf = jnp.int32(INF)
    got = delivered >= 0
    cnt = got.sum(axis=0).astype(jnp.int64)
    arrcnt = (arr < rounds).sum(axis=0).astype(jnp.int64)
    sumdel = jnp.where(got, delivered, 0).sum(axis=0).astype(jnp.int64)
    alive = (~crashed).sum().astype(jnp.int64)
    alivedel = (got & ~crashed[:, None]).sum(axis=0).astype(jnp.int64)
    gated = (gate >= 0) & active & ~crashed[:, None]
    min_gate = jnp.where(gated, gate, inf).min(axis=1)
    blocked = ((got & (delivered >= min_gate[:, None]))
               .sum(axis=0).astype(jnp.int64))
    pidx = jnp.where((ping >= 0) & ~crashed[:, None], ping,
                     w).reshape(-1)
    ref = jnp.zeros(w, jnp.int64).at[pidx].add(1, mode="drop")
    ol = origins - off
    owned = (ol >= 0) & (ol < n_loc) & (origins >= 0)
    ocl = jnp.clip(ol, 0, n_loc - 1)
    bdone = jnp.where(owned, got[ocl, jnp.arange(w)],
                      False).astype(jnp.int64)
    return (cnt, arrcnt, sumdel, alive, alivedel, blocked, ref, bdone)


@functools.lru_cache(maxsize=None)
def shard_span_runner(n_devices: int, k: int, pc: bool, always_gate: bool,
                      pong_delay: int, gating: bool = True,
                      backend: str = "jax", scan: bool = False):
    """Jitted sharded span runner; per-round (``scan=False``) it is
    ``(state, sched, ts) -> (state, stats)`` — the contract of
    :func:`~repro.core.vecsim.sim.jax_span_runner` with state as
    row-block-sharded global arrays.  Scanned (``scan=True``) it takes
    ``(state, sched, ts, origins, rounds)`` and additionally returns the
    fused per-column retirement aggregates (``_column_partials``,
    psum'd), so a segment is one dispatch with no standalone reduce.
    Negative rounds in ``ts`` are padding and leave the state untouched.
    One compilation per (mesh, shape) signature, cached.

    ``backend="pallas"`` launches the delivery-sweep kernels
    (``vecsim.kernels``) per shard inside the ``shard_map`` body: the
    deliver sweep on the local row block, one ``slot_frontier`` kernel
    per link slot building the combined flush+forward contribution
    plane, and a ``ring_apply`` kernel at each ring hop scattering the
    visiting plane into the rows this shard owns.  The ring permutes
    and the pong query ring stay ``lax.ppermute`` — byte-identical to
    the jax body at every device count.

    ``scan=True`` is the device-resident segment loop: the ``lax.scan``
    over rounds moves *inside* the ``shard_map`` body, ``sched``'s
    event fields become stacked ``(seg_len, cap)`` per-round planes
    (``ColumnWindow.stacked_schedule``), the state argument is donated,
    and the frontier exchange double-buffers through a ``pending``
    carry plane: round ``r``'s ring scatter lands in ``pending`` and
    folds into ``arr`` at the top of round ``r + 1`` (exact — every
    contribution values ``>= r + 1`` and nothing reads ``arr`` in
    between), with a residual fold after the scan.  Byte-identical to
    ``scan=False`` per construction; ``tests/test_vecsim_scan.py``
    asserts it."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    backend = resolve_shard_backend(backend)
    pallas = backend == "pallas"
    if pallas:
        from .. import kernels as kx

    mesh = shard_mesh(n_devices)
    d = n_devices
    inf = jnp.int32(INF)
    perm = _shift(d)

    def real_step(sched, state, t, pending=None):
        deferred = pending is not None
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed, ever_del) = state
        if deferred:
            # double-buffered frontier: the previous round's in-flight
            # ring contributions land now, before anything reads arr
            arr = jnp.minimum(arr, pending)
        n_loc = arr.shape[0]
        width = arr.shape[1]
        me = jax.lax.axis_index("shard")
        off = (me * n_loc).astype(jnp.int32)
        is_app = sched["is_app"]
        stats = jnp.zeros(len(SERIES_FIELDS), jnp.int64)

        # -- 1. removals (owner-local; other shards drop) ---------------- #
        if sched["rm_round"].shape[0]:
            sel = sched["rm_round"] == t
            pl = sched["rm_p"].astype(jnp.int32) - off
            p_ = jnp.where(sel & (pl >= 0) & (pl < n_loc), pl, n_loc)
            k_ = sched["rm_k"]
            active = active.at[p_, k_].set(False, mode="drop")
            gate = gate.at[p_, k_].set(-1, mode="drop")
            flush = flush.at[p_, k_].set(inf, mode="drop")
            ping = ping.at[p_, k_].set(-1, mode="drop")

        # -- 2. additions (+ Algorithm 2 gating, owner-local) ------------- #
        if sched["add_round"].shape[0]:
            sel = sched["add_round"] == t
            add_p, add_k = sched["add_p"], sched["add_k"]
            add_slot = sched["add_slot"]
            pl = add_p.astype(jnp.int32) - off
            owned = (pl >= 0) & (pl < n_loc)
            p_ = jnp.where(sel & owned, pl, n_loc)
            adj = adj.at[p_, add_k].set(sched["add_q"], mode="drop")
            delay = delay.at[p_, add_k].set(sched["add_delay"], mode="drop")
            active = active.at[p_, add_k].set(True, mode="drop")
            if pc:
                safe_links = active & (gate < 0)
                safe_cnt = safe_links.sum(axis=1)
                pcl = jnp.clip(pl, 0, n_loc - 1)
                own_slot_safe = safe_links[pcl, add_k]
                other_safe = (safe_cnt[pcl]
                              - own_slot_safe.astype(jnp.int32)) >= 1
                if always_gate:
                    want = other_safe
                else:
                    has_del = ever_del | ((delivered >= 0)
                                          & is_app[None, :]).any(axis=1)
                    want = other_safe & has_del[pcl]
                want = want & ~crashed[pcl] & owned
                gsel = sel & want
                pg = jnp.where(gsel, pl, n_loc)
                gate = gate.at[pg, add_k].set(t, mode="drop")
                flush = flush.at[pg, add_k].set(inf, mode="drop")
                ping = ping.at[pg, add_k].set(add_slot, mode="drop")
                delivered = delivered.at[pg, add_slot].set(t, mode="drop")
                csel = sel & ~want & owned
                pc_ = jnp.where(csel, pl, n_loc)
                gate = gate.at[pc_, add_k].set(-1, mode="drop")
                flush = flush.at[pc_, add_k].set(inf, mode="drop")
                ping = ping.at[pc_, add_k].set(-1, mode="drop")

        # -- 3. crashes (owner-local) ------------------------------------- #
        if sched["cr_round"].shape[0]:
            sel = sched["cr_round"] == t
            pl = sched["cr_pid"].astype(jnp.int32) - off
            p_ = jnp.where(sel & (pl >= 0) & (pl < n_loc), pl, n_loc)
            crashed = crashed.at[p_].set(True, mode="drop")

        # -- 4. broadcasts (owner-local) ---------------------------------- #
        if sched["bc_round"].shape[0]:
            ol = sched["bc_origin"].astype(jnp.int32) - off
            owned = (ol >= 0) & (ol < n_loc)
            ocl = jnp.clip(ol, 0, n_loc - 1)
            sel = (sched["bc_round"] == t) & owned & ~crashed[ocl]
            o_ = jnp.where(sel, ol, n_loc)
            delivered = delivered.at[o_, sched["bc_slot"]].max(t, mode="drop")

        # -- 5. arrivals -> deliveries (element-wise, local) -------------- #
        if pallas:
            delivered, napp32, nping32 = kx.deliver_sweep(
                arr, delivered, crashed, is_app, t)
            napp = napp32.astype(jnp.int64)
            nping = nping32.astype(jnp.int64)
        else:
            newly = (arr == t) & (delivered < 0) & ~crashed[:, None]
            delivered = jnp.where(newly, t, delivered)

        # -- 6. pong detection: the query ring ---------------------------- #
        if pc and gating:
            # Exactly the monolithic read delivered[clip(adj), clip(ping)]
            # for *every* slot, masked afterwards — the triples visit all
            # D shards and come home with the answer filled in by the
            # target row's owner.
            q = jnp.clip(adj, 0, n_loc * d - 1).reshape(-1)
            s = jnp.clip(ping, 0, width - 1).reshape(-1)
            ans = jnp.full(q.shape, jnp.int32(-1))
            for _hop in range(d):
                ql = q - off
                hit = (ql >= 0) & (ql < n_loc)
                qcl = jnp.clip(ql, 0, n_loc - 1)
                ans = jnp.where(hit, delivered[qcl, s], ans)
                if d > 1:
                    q = jax.lax.ppermute(q, "shard", perm)
                    s = jax.lax.ppermute(s, "shard", perm)
                    ans = jax.lax.ppermute(ans, "shard", perm)
            tgt_del = ans.reshape(adj.shape)
            fire = ((gate >= 0) & (flush == inf) & (ping >= 0)
                    & (tgt_del >= 0) & ~crashed[:, None])
            flush = jnp.where(fire, t + pong_delay, flush)
            stats = stats.at[4].set(fire.sum().astype(jnp.int64))

        # -- 7+8. flush + forward: the frontier exchange ------------------ #
        # Per link slot, the flush contributions (phase 7) and this
        # round's flood-forward contributions (phase 8) min-combine into
        # one (N/D, W) plane that rides the ring; both value t + delay
        # over the same link, and scatter-min commutes, so the fusion is
        # exact.  A slot flushed this round becomes safe *before* the
        # forward pass, as in the monolithic body (gk_eff below).
        if not pallas:
            new_del = delivered == t
            napp = (new_del & is_app[None, :]).sum(axis=1)
            nping = (new_del & ~is_app[None, :]).sum(axis=1)
            has_new = new_del.any(axis=1) & ~crashed
        elig_cnt = jnp.zeros(n_loc, jnp.int64)
        flush_sent = jnp.int64(0)
        # deferred mode scatters into a fresh pending plane (folded into
        # arr at the next round's entry); immediate mode scatters into
        # arr directly, as the windowed reference does
        dest = jnp.full_like(arr, inf) if deferred else arr
        for kk in range(k):
            gk = gate[:, kk]
            dk = (t + delay[:, kk])[:, None].astype(jnp.int32)
            if pc and gating:
                do = (flush[:, kk] == t) & active[:, kk] & ~crashed
                gk_eff = jnp.where(flush[:, kk] == t, -1, gk)
            else:
                do = jnp.zeros_like(crashed)
                gk_eff = gk
            ok = active[:, kk] & (gk_eff < 0) & (adj[:, kk] >= 0) & ~crashed
            elig_cnt += ok.astype(jnp.int64)
            if pallas:
                # slot kernel: combined flush+forward contribution plane
                # (a row with a delivery this round is never crashed, so
                # the jax body's has_new conjunct is implied by new_del)
                vals, win_cnt = kx.slot_frontier(
                    delivered, gk, delay[:, kk], do, ok, is_app, t,
                    gating=pc and gating)
                flush_sent += win_cnt.astype(jnp.int64)
            else:
                if pc and gating:
                    win = ((delivered >= gk[:, None]) & (delivered < t)
                           & do[:, None] & is_app[None, :])
                    flush_sent += win.sum().astype(jnp.int64)
                fwd = ok & has_new
                vals = jnp.where(new_del & fwd[:, None], dk, inf)
                if pc and gating:
                    vals = jnp.minimum(vals, jnp.where(win, dk, inf))
            tgt = adj[:, kk].astype(jnp.int32)
            for hop in range(d):
                if pallas:
                    dest = kx.ring_apply(dest, vals, tgt, off)
                else:
                    tl = tgt - off
                    rows = jnp.where((tl >= 0) & (tl < n_loc), tl, n_loc)
                    dest = dest.at[rows, :].min(vals, mode="drop")
                if hop < d - 1:
                    vals = jax.lax.ppermute(vals, "shard", perm)
                    tgt = jax.lax.ppermute(tgt, "shard", perm)
        if not deferred:
            arr = dest
        if pc and gating:
            cleared = flush == t
            gate = jnp.where(cleared, -1, gate)
            ping = jnp.where(cleared, -1, ping)
            flush = jnp.where(cleared, inf, flush)
        stats = stats.at[0].set(napp.sum().astype(jnp.int64))
        stats = stats.at[1].set((napp.astype(jnp.int64) * elig_cnt).sum())
        stats = stats.at[2].set((nping.astype(jnp.int64) * elig_cnt).sum())
        stats = stats.at[3].set(flush_sent)
        stats = stats.at[5].set((gate >= 0).sum().astype(jnp.int64))
        stats = jax.lax.psum(stats, "shard")

        out = (arr, delivered, adj, delay, active, gate, flush, ping,
               crashed, ever_del)
        if deferred:
            return (out, dest), stats
        return out, stats

    def step(sched, state, t):
        t = t.astype(jnp.int32)
        return jax.lax.cond(
            t >= 0,
            lambda s: real_step(sched, s, t),
            lambda s: (s, jnp.zeros(len(SERIES_FIELDS), jnp.int64)),
            state)

    if scan:
        def scan_step(sched, carry, t):
            t = t.astype(jnp.int32)
            return jax.lax.cond(
                t >= 0,
                lambda c: real_step(sched, c[0], t, c[1]),
                lambda c: (c, jnp.zeros(len(SERIES_FIELDS), jnp.int64)),
                carry)

        def span(state, sched, ts, origins, rounds):
            is_app = sched["is_app"]
            events = {key: v for key, v in sched.items() if key != "is_app"}
            pending0 = jnp.full_like(state[0], inf)

            def body(carry, x):
                t, ev = x
                sch = dict(ev)
                sch["is_app"] = is_app
                return scan_step(sch, carry, t)

            (state, pending), stats = jax.lax.scan(
                body, (tuple(state), pending0), (ts, events))
            # residual fold: the last round's in-flight frontier (padding
            # rounds skip real_step, so pending survives to here intact)
            state = (jnp.minimum(state[0], pending),) + tuple(state[1:])
            # fused retirement reduce (DESIGN.md §2.8): the per-column
            # aggregates the driver's retire() consumes come out of the
            # same dispatch as the segment itself, while the planes are
            # still hot — shared definition with shard_retire_kernels
            me = jax.lax.axis_index("shard")
            off = (me * state[0].shape[0]).astype(jnp.int32)
            red = tuple(jax.lax.psum(x, "shard")
                        for x in _column_partials(state, origins,
                                                  rounds, off))
            return state, stats, red
    else:
        def span(state, sched, ts):
            return jax.lax.scan(lambda c, t: step(sched, c, t), state, ts)

    # check_rep=False: lax.cond trips shard_map's replication checker
    # (jax-ml/jax known limitation); the stats output really is
    # replicated — it comes out of an explicit psum on every branch.
    _run = jax.jit(shard_map(
        span, mesh=mesh,
        in_specs=((P("shard"), P(), P(), P(), P()) if scan
                  else (P("shard"), P(), P())),
        out_specs=((P("shard"), P(), P()) if scan
                   else (P("shard"), P())),
        check_rep=False),
        # scanned segments own the live buffers for many rounds: donate
        # them so the carry updates in place instead of doubling the
        # peak (N, W) footprint
        donate_argnums=(0,) if scan else ())

    def run(state, sched, ts, origins=None, rounds=None):
        # x64 so the int64 stats accumulators (and their psum) are
        # honored; every state/schedule array carries an explicit dtype,
        # so nothing else widens — byte-parity with the windowed series.
        with enable_x64():
            if scan:
                return _run(state, sched, ts, origins, rounds)
            return _run(state, sched, ts)

    run.jitted = _run
    return run


@functools.lru_cache(maxsize=None)
def shard_fast_span_runner(n_devices: int, classes_sig: tuple):
    """The scanned segment body specialized for quiescent segments: no
    link additions/removals in the segment and no live gating machinery
    anywhere in the run (the driver checks both before selecting it;
    crashes and broadcasts are fine — they ride stacked scan inputs).

    Same ``(state, ...) -> (state, stats, red)`` byte-contract as the
    scanned :func:`shard_span_runner` — including the fused retirement
    aggregates, computed on the widened int32 exit state — reached very
    differently (the N=1M hot path, DESIGN.md §2.7–2.8):

      * ``arr``/``delivered`` live in **int16** for the duration of the
        segment (entry/exit converts; ``INT16_LIMIT`` stands in for
        ``INF``; the driver guarantees ``rounds + max_delay`` fits);
      * the per-round delivery frontier ``delivered == t`` is
        **bit-packed** to ``(N/D, W/8)`` uint8 (8 columns/byte) — the
        per-round series comes from SWAR byte popcounts, and the ring
        moves W/8 bytes per row instead of 4W;
      * the frontier crosses shards as an **all-gather** (D-1
        ``ppermute`` hops, blocks concatenated in ring order), and each
        receiver row OR-combines its eligible in-neighbors' packed rows
        by *gathering* over the host-built per-delay-class inverse
        tables (``classes_sig`` = ``inverse_tables``'s ``(delay, B)``
        signature, the structural compile key) — sender eligibility is
        folded into the tables, and a crashed sender's frontier row is
        all-zero by construction, so no runtime edge masking remains;
      * the exchange is **double-buffered**: the gathered OR lands in a
        packed ``pending`` carry and folds into ``arr`` (value
        ``t + delay`` per class) at the next round's entry, with a
        residual fold after the scan — the same deferral contract as
        the generic scanned body;
      * stats stack through the scan and ``psum`` once per segment
        (integer sums, so the reassociation is exact), and the state
        argument is donated.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..kernels import pack_columns, popcount_bytes, unpack_columns

    mesh = shard_mesh(n_devices)
    d = n_devices
    inf = jnp.int32(INF)
    lim16 = jnp.int16(INT16_LIMIT)
    perm = _shift(d)
    classes = tuple(classes_sig)

    def span(state, tabs, ia_pack, sched, ts, origins, rounds):
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed, ever_del) = state
        n_loc, width = arr.shape
        wp = -(-max(width, 1) // 8)
        me = jax.lax.axis_index("shard")
        off = (me * n_loc).astype(jnp.int32)
        n_glob = n_loc * d

        arr16 = jnp.where(arr >= inf, lim16, arr.astype(jnp.int16))
        del16 = delivered.astype(jnp.int16)

        # Receiver-side gather positions into the all-gathered frontier,
        # hoisted out of the scan: ring hop j delivers the block owned
        # by shard (me - j) % d, so global source row s = blk*n_loc + r
        # sits at ((me - blk) % d) * n_loc + r.  "No source" entries
        # point past the end; the gather fills them with zero bytes.
        poss = []
        for ci, (dl, b) in enumerate(classes):
            ip = tabs[ci]
            blk = ip // n_loc
            pos = ((me - blk) % d) * n_loc + (ip - blk * n_loc)
            poss.append(jnp.where(ip >= n_glob, n_glob,
                                  pos).astype(jnp.int32))
        # per-row eligible-link count: static over the segment except
        # for crashes, which zero the whole row (matching the reference
        # body's per-slot `ok &= ~crashed`)
        linkcnt = (active & (adj >= 0)).sum(axis=1).astype(jnp.int64)
        gated = (gate >= 0).sum().astype(jnp.int64)

        def fold(arr16, pend, tprev):
            # deferred packed frontier: contributions gathered during
            # round tprev arrive with value tprev + delay
            for ci, (dl, b) in enumerate(classes):
                pb = unpack_columns(pend[ci], width)
                arr16 = jnp.where(
                    pb, jnp.minimum(arr16, tprev + jnp.int16(dl)), arr16)
            return arr16

        def body(carry, x):
            arr16, del16, crs, pend, tprev = carry
            t, bc_r, bc_o, bc_s, cr_r, cr_p = x
            t16 = t.astype(jnp.int16)
            arr16 = fold(arr16, pend, tprev)
            # crashes / broadcasts (owner-local; sentinel rounds in the
            # stacked rows never match a real t)
            if cr_r.shape[0]:
                pl = cr_p.astype(jnp.int32) - off
                p_ = jnp.where((cr_r == t) & (pl >= 0) & (pl < n_loc),
                               pl, n_loc)
                crs = crs.at[p_].set(True, mode="drop")
            if bc_r.shape[0]:
                ol = bc_o.astype(jnp.int32) - off
                owned = (ol >= 0) & (ol < n_loc)
                ocl = jnp.clip(ol, 0, n_loc - 1)
                sel = (bc_r == t) & owned & ~crs[ocl]
                o_ = jnp.where(sel, ol, n_loc)
                del16 = del16.at[o_, bc_s].max(t16, mode="drop")
            # arrivals -> deliveries (padding rounds: t16 < 0 matches
            # no arr/delivered value, so everything below is a no-op)
            newly = (arr16 == t16) & (del16 < 0) & ~crs[:, None]
            del16 = jnp.where(newly, t16, del16)
            # pack this round's frontier once; the barrier pins a single
            # materialization (XLA otherwise re-runs the producer chain
            # per consumer: stats, ring, and gather)
            g = jax.lax.optimization_barrier(pack_columns(del16 == t16))
            rowsum = jnp.sum(popcount_bytes(g), axis=1, dtype=jnp.int64)
            napp = jnp.sum(popcount_bytes(g & ia_pack[None, :]), axis=1,
                           dtype=jnp.int64)
            elig = jnp.where(crs, 0, linkcnt)
            z = jnp.int64(0)
            stats = jnp.stack([
                napp.sum(), (napp * elig).sum(),
                ((rowsum - napp) * elig).sum(), z, z,
                jnp.where(t >= 0, gated, z)])
            # all-gather the packed frontier around the ring
            blocks = [g]
            for _hop in range(d - 1):
                blocks.append(jax.lax.ppermute(blocks[-1], "shard", perm))
            gg = jnp.concatenate(blocks, axis=0) if d > 1 else g
            pend_new = []
            for ci, (dl, b) in enumerate(classes):
                pos = poss[ci]
                acc = jnp.take(gg, pos[:, 0], axis=0, mode="fill",
                               fill_value=0)
                for col in range(1, b):
                    acc = acc | jnp.take(gg, pos[:, col], axis=0,
                                         mode="fill", fill_value=0)
                pend_new.append(acc)
            return (arr16, del16, crs, tuple(pend_new), t16), stats

        pend0 = tuple(jnp.zeros((n_loc, wp), jnp.uint8) for _ in classes)
        xs = (ts.astype(jnp.int32), sched["bc_round"], sched["bc_origin"],
              sched["bc_slot"], sched["cr_round"], sched["cr_pid"])
        carry0 = (arr16, del16, crashed, pend0, jnp.int16(0))
        (arr16, del16, crashed, pend, tprev), stats = jax.lax.scan(
            body, carry0, xs)
        arr16 = fold(arr16, pend, tprev)
        stats = jax.lax.psum(stats, "shard")
        arr = jnp.where(arr16 >= lim16, inf, arr16.astype(jnp.int32))
        delivered = del16.astype(jnp.int32)
        state = (arr, delivered, adj, delay, active, gate, flush, ping,
                 crashed, ever_del)
        # fused retirement reduce on the widened exit state — same
        # shared reduction as the generic scanned body (DESIGN.md §2.8)
        red = tuple(jax.lax.psum(x, "shard")
                    for x in _column_partials(state, origins, rounds, off))
        return state, stats, red

    _run = jax.jit(shard_map(
        span, mesh=mesh,
        in_specs=(P("shard"), P("shard"), P(), P(), P(), P(), P()),
        out_specs=(P("shard"), P(), P()),
        check_rep=False),
        donate_argnums=(0,))

    def run(state, tabs, ia_pack, sched, ts, origins, rounds):
        with enable_x64():
            return _run(state, tabs, ia_pack, sched, ts, origins, rounds)

    run.jitted = _run
    return run


@functools.lru_cache(maxsize=None)
def shard_retire_kernels(n_devices: int):
    """The two device-side retirement kernels the driver calls between
    segments: ``reduce(state, origins, horizon_limit) -> per-column
    aggregates`` (psum-replicated across the mesh) and ``apply(state,
    retire_mask, app_retire, hung) -> state`` (fold ``ever_del``, clear
    hung gates, recycle columns).  Together they are the sharded twin of
    ``stream.execute_windowed``'s host-side ``retire`` /
    ``record_and_free`` — the host only ever sees (W,)-sized arrays.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = shard_mesh(n_devices)
    inf = jnp.int32(INF)

    def reduce_fn(state, origins, rounds):
        n_loc = state[0].shape[0]
        me = jax.lax.axis_index("shard")
        off = (me * n_loc).astype(jnp.int32)
        out = _column_partials(state, origins, rounds, off)
        return tuple(jax.lax.psum(x, "shard") for x in out)

    _reduce = jax.jit(shard_map(
        reduce_fn, mesh=mesh,
        in_specs=(P("shard"), P(), P()),
        out_specs=P()))

    def apply_fn(state, retire, app_retire, hung):
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed, ever_del) = state
        w = arr.shape[1]
        # app-delivery memory folds *before* the columns are wiped
        ever_del = ever_del | ((delivered >= 0)
                               & app_retire[None, :]).any(axis=1)
        # a gate whose ping column is being force-expired can never
        # resolve: clear it so the link goes safe (stream.retire's
        # horizon escape hatch, device-side)
        sel = (ping >= 0) & hung[jnp.clip(ping, 0, w - 1)]
        gate = jnp.where(sel, -1, gate)
        flush = jnp.where(sel, inf, flush)
        ping = jnp.where(sel, -1, ping)
        arr = jnp.where(retire[None, :], inf, arr)
        delivered = jnp.where(retire[None, :], -1, delivered)
        return (arr, delivered, adj, delay, active, gate, flush, ping,
                crashed, ever_del)

    _apply = jax.jit(shard_map(
        apply_fn, mesh=mesh,
        in_specs=(P("shard"), P(), P(), P()),
        out_specs=P("shard")))

    def reduce_run(state, origins, rounds):
        with enable_x64():
            return _reduce(state, origins, rounds)

    def apply_run(state, retire, app_retire, hung):
        with enable_x64():
            return _apply(state, retire, app_retire, hung)

    return reduce_run, apply_run


@functools.lru_cache(maxsize=None)
def shard_hist_runner(n_devices: int):
    """On-device retirement-time delivery-latency histogram
    (``repro.obs.hist`` bucket contract): gather the retiring columns
    out of the sharded ``delivered`` plane, bucket each valid
    delivery's ``delivered - base`` latency on device, and psum the
    ``(NB,)`` totals across the mesh.  Columns padded with
    ``base = -1`` contribute nothing, mirroring ``hist_np``'s
    negative-value mask.

    This is the fully on-device twin of the sharded driver's fold
    (device bucket indices + host bincount): both run once per
    retirement batch over only the retiring columns — O(N x messages)
    work for the whole run, segment bodies telemetry-free — and are
    byte-identical (``tests/test_obs.py`` parity-checks them).  The
    driver pulls the uint8 index plane because on a CPU mesh the
    shard_map reduce costs more than the transfer it saves; this
    runner is the shape the fold takes when the delivered plane lives
    on a real accelerator mesh and any host pull is the expensive
    direction.

    The bucketing is the cumulative-count formulation: NB integer
    ``value < upper_bound`` comparisons and a diff, byte-identical to
    ``bucket_index_np`` + bincount because both are pure integer
    threshold counts over the same bucket edges.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ....obs.hist import NB

    mesh = shard_mesh(n_devices)
    # bucket upper bounds: exact buckets 0..15, then power-of-two
    # decades [2**(4+j), 2**(5+j)); the last bucket is open-ended
    hi = [k + 1 for k in range(16)] + [1 << k for k in range(5, 20)]
    assert len(hi) + 1 == NB

    def hist_fn(delivered, cols, base):
        d = delivered[:, cols]
        valid = (d >= 0) & (base >= 0)[None, :]
        v = jnp.where(valid, d - base[None, :], -1)
        # cumulative counts at each bucket's upper bound; prepend the
        # (normally zero) count of negative latencies so they fall out
        # of bucket 0 exactly as hist_np's v >= 0 mask drops them
        cum = jnp.stack([(valid & (v < 0)).sum().astype(jnp.int64)]
                        + [(valid & (v < h)).sum().astype(jnp.int64)
                           for h in hi]
                        + [valid.sum().astype(jnp.int64)])
        return jax.lax.psum(jnp.diff(cum), "shard")

    _run = jax.jit(shard_map(
        hist_fn, mesh=mesh,
        in_specs=(P("shard"), P(), P()),
        out_specs=P()))

    def run(delivered, cols, base):
        with enable_x64():
            return _run(delivered, cols, base)

    return run


@functools.lru_cache(maxsize=None)
def shard_column_gather():
    """Jitted retiring-column gather for the flight recorder
    (``repro.obs.flight``): pull the delivered-plane rows of only the
    (power-of-two padded) sampled retiring columns before ``apply_run``
    recycles them.  Same O(sample) transfer pattern as the latency
    histogram's ``_bucket_take``, minus the bucketing — provenance
    wants the raw per-receiver delivery rounds."""
    import jax
    import jax.numpy as jnp

    def _take(a, c):
        return jnp.take(a, c, axis=1)

    return jax.jit(_take)
