"""``shard_map`` span runner: the windowed round body over a device mesh.

Partitioning (DESIGN.md §2.5): every per-process plane — ``arr`` /
``delivered`` ``(N, W)`` buffers, the ``(N, K)`` adjacency/delay/gating
tables, ``crashed``/``ever_del`` — is row-block sharded over a 1-D
``("shard",)`` mesh; schedules, the ``is_app`` column mask and the round
index stream are replicated.  Per round, three things cross shards:

  * **frontier exchange** — the flood-forward + flush scatter (monolithic
    phases 7/8) becomes a ring: each device's contribution plane for this
    round's delivered columns (``vals`` = ``t + delay`` where sending,
    ``INF`` elsewhere, with its global target rows) visits every device
    via ``lax.ppermute``; each visit scatter-mins the rows it owns.
    Scatter-min is associative/commutative on ints, so the result is
    bit-equal to the monolithic global scatter regardless of hop order;
  * **pong query ring** — pong detection reads ``delivered[q, s]`` at the
    gated link's remote target; the ``(N/D, K)`` query triples (target,
    ping slot, answer) ride a second ring and come home after D hops.
    This ring is K columns wide, not W, and is elided entirely (with the
    whole gating machinery) when the scenario schedules no additions;
  * **stats psum** — the per-round series row is ``psum``-reduced so every
    shard returns the identical replicated ``(rounds, 6)`` series.

Everything else is owner-local: schedule events (removals, additions
with the Algorithm 2 gating decision, crashes, broadcasts) apply on the
shard owning their process row and drop elsewhere; arrivals/deliveries
are element-wise.  The retirement kernels at the bottom give the host
driver (``driver.py``) per-column aggregates (``psum`` over the mesh)
and a masked column-recycle, so state never leaves the devices between
segments.

The body mirrors ``sim.jax_span_runner`` operation for operation —
tests assert byte-identical delivered/series/NetStats against the
windowed engine at every device count.
"""

from __future__ import annotations

import functools

from ..scenario import INF
from ..sim import SERIES_FIELDS, _STATE_KEYS
from .mesh import shard_mesh

__all__ = ["shard_span_runner", "shard_retire_kernels",
           "resolve_shard_backend", "STATE_KEYS"]

STATE_KEYS = _STATE_KEYS


def resolve_shard_backend(backend: str) -> str:
    """Validate/resolve the sharded engine's round-body backend — the
    one place the accepted names live.  ``"jax"`` passes through,
    ``"pallas"`` requires the kernels to initialize, and ``"auto"``
    resolves like the other engines (numpy can never shard, so auto
    lands on jax wherever Pallas does not compile)."""
    if backend == "auto":
        from ..sim import resolve_backend
        backend = resolve_backend("auto")
        if backend == "numpy":  # pragma: no cover - needs jax to get here
            backend = "jax"
    if backend == "pallas":
        from .. import kernels
        kernels.require_pallas()
    elif backend != "jax":
        raise ValueError(f"unknown sharded backend {backend!r} (the mesh "
                         "program runs backend 'jax' or 'pallas')")
    return backend


def _shift(d: int):
    """Forward ring permutation on the ``shard`` axis."""
    return [(i, (i + 1) % d) for i in range(d)]


@functools.lru_cache(maxsize=None)
def shard_span_runner(n_devices: int, k: int, pc: bool, always_gate: bool,
                      pong_delay: int, gating: bool = True,
                      backend: str = "jax"):
    """Jitted ``(state, sched, ts) -> (state, stats)`` sharded span
    runner; same contract as :func:`~repro.core.vecsim.sim.
    jax_span_runner` with state as row-block-sharded global arrays.
    Negative rounds in ``ts`` are padding and leave the state untouched.
    One compilation per (mesh, shape) signature, cached.

    ``backend="pallas"`` launches the delivery-sweep kernels
    (``vecsim.kernels``) per shard inside the ``shard_map`` body: the
    deliver sweep on the local row block, one ``slot_frontier`` kernel
    per link slot building the combined flush+forward contribution
    plane, and a ``ring_apply`` kernel at each ring hop scattering the
    visiting plane into the rows this shard owns.  The ring permutes
    and the pong query ring stay ``lax.ppermute`` — byte-identical to
    the jax body at every device count."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    backend = resolve_shard_backend(backend)
    pallas = backend == "pallas"
    if pallas:
        from .. import kernels as kx

    mesh = shard_mesh(n_devices)
    d = n_devices
    inf = jnp.int32(INF)
    perm = _shift(d)

    def real_step(sched, state, t):
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed, ever_del) = state
        n_loc = arr.shape[0]
        width = arr.shape[1]
        me = jax.lax.axis_index("shard")
        off = (me * n_loc).astype(jnp.int32)
        is_app = sched["is_app"]
        stats = jnp.zeros(len(SERIES_FIELDS), jnp.int64)

        # -- 1. removals (owner-local; other shards drop) ---------------- #
        if sched["rm_round"].shape[0]:
            sel = sched["rm_round"] == t
            pl = sched["rm_p"].astype(jnp.int32) - off
            p_ = jnp.where(sel & (pl >= 0) & (pl < n_loc), pl, n_loc)
            k_ = sched["rm_k"]
            active = active.at[p_, k_].set(False, mode="drop")
            gate = gate.at[p_, k_].set(-1, mode="drop")
            flush = flush.at[p_, k_].set(inf, mode="drop")
            ping = ping.at[p_, k_].set(-1, mode="drop")

        # -- 2. additions (+ Algorithm 2 gating, owner-local) ------------- #
        if sched["add_round"].shape[0]:
            sel = sched["add_round"] == t
            add_p, add_k = sched["add_p"], sched["add_k"]
            add_slot = sched["add_slot"]
            pl = add_p.astype(jnp.int32) - off
            owned = (pl >= 0) & (pl < n_loc)
            p_ = jnp.where(sel & owned, pl, n_loc)
            adj = adj.at[p_, add_k].set(sched["add_q"], mode="drop")
            delay = delay.at[p_, add_k].set(sched["add_delay"], mode="drop")
            active = active.at[p_, add_k].set(True, mode="drop")
            if pc:
                safe_links = active & (gate < 0)
                safe_cnt = safe_links.sum(axis=1)
                pcl = jnp.clip(pl, 0, n_loc - 1)
                own_slot_safe = safe_links[pcl, add_k]
                other_safe = (safe_cnt[pcl]
                              - own_slot_safe.astype(jnp.int32)) >= 1
                if always_gate:
                    want = other_safe
                else:
                    has_del = ever_del | ((delivered >= 0)
                                          & is_app[None, :]).any(axis=1)
                    want = other_safe & has_del[pcl]
                want = want & ~crashed[pcl] & owned
                gsel = sel & want
                pg = jnp.where(gsel, pl, n_loc)
                gate = gate.at[pg, add_k].set(t, mode="drop")
                flush = flush.at[pg, add_k].set(inf, mode="drop")
                ping = ping.at[pg, add_k].set(add_slot, mode="drop")
                delivered = delivered.at[pg, add_slot].set(t, mode="drop")
                csel = sel & ~want & owned
                pc_ = jnp.where(csel, pl, n_loc)
                gate = gate.at[pc_, add_k].set(-1, mode="drop")
                flush = flush.at[pc_, add_k].set(inf, mode="drop")
                ping = ping.at[pc_, add_k].set(-1, mode="drop")

        # -- 3. crashes (owner-local) ------------------------------------- #
        if sched["cr_round"].shape[0]:
            sel = sched["cr_round"] == t
            pl = sched["cr_pid"].astype(jnp.int32) - off
            p_ = jnp.where(sel & (pl >= 0) & (pl < n_loc), pl, n_loc)
            crashed = crashed.at[p_].set(True, mode="drop")

        # -- 4. broadcasts (owner-local) ---------------------------------- #
        if sched["bc_round"].shape[0]:
            ol = sched["bc_origin"].astype(jnp.int32) - off
            owned = (ol >= 0) & (ol < n_loc)
            ocl = jnp.clip(ol, 0, n_loc - 1)
            sel = (sched["bc_round"] == t) & owned & ~crashed[ocl]
            o_ = jnp.where(sel, ol, n_loc)
            delivered = delivered.at[o_, sched["bc_slot"]].max(t, mode="drop")

        # -- 5. arrivals -> deliveries (element-wise, local) -------------- #
        if pallas:
            delivered, napp32, nping32 = kx.deliver_sweep(
                arr, delivered, crashed, is_app, t)
            napp = napp32.astype(jnp.int64)
            nping = nping32.astype(jnp.int64)
        else:
            newly = (arr == t) & (delivered < 0) & ~crashed[:, None]
            delivered = jnp.where(newly, t, delivered)

        # -- 6. pong detection: the query ring ---------------------------- #
        if pc and gating:
            # Exactly the monolithic read delivered[clip(adj), clip(ping)]
            # for *every* slot, masked afterwards — the triples visit all
            # D shards and come home with the answer filled in by the
            # target row's owner.
            q = jnp.clip(adj, 0, n_loc * d - 1).reshape(-1)
            s = jnp.clip(ping, 0, width - 1).reshape(-1)
            ans = jnp.full(q.shape, jnp.int32(-1))
            for _hop in range(d):
                ql = q - off
                hit = (ql >= 0) & (ql < n_loc)
                qcl = jnp.clip(ql, 0, n_loc - 1)
                ans = jnp.where(hit, delivered[qcl, s], ans)
                if d > 1:
                    q = jax.lax.ppermute(q, "shard", perm)
                    s = jax.lax.ppermute(s, "shard", perm)
                    ans = jax.lax.ppermute(ans, "shard", perm)
            tgt_del = ans.reshape(adj.shape)
            fire = ((gate >= 0) & (flush == inf) & (ping >= 0)
                    & (tgt_del >= 0) & ~crashed[:, None])
            flush = jnp.where(fire, t + pong_delay, flush)
            stats = stats.at[4].set(fire.sum().astype(jnp.int64))

        # -- 7+8. flush + forward: the frontier exchange ------------------ #
        # Per link slot, the flush contributions (phase 7) and this
        # round's flood-forward contributions (phase 8) min-combine into
        # one (N/D, W) plane that rides the ring; both value t + delay
        # over the same link, and scatter-min commutes, so the fusion is
        # exact.  A slot flushed this round becomes safe *before* the
        # forward pass, as in the monolithic body (gk_eff below).
        if not pallas:
            new_del = delivered == t
            napp = (new_del & is_app[None, :]).sum(axis=1)
            nping = (new_del & ~is_app[None, :]).sum(axis=1)
            has_new = new_del.any(axis=1) & ~crashed
        elig_cnt = jnp.zeros(n_loc, jnp.int64)
        flush_sent = jnp.int64(0)
        for kk in range(k):
            gk = gate[:, kk]
            dk = (t + delay[:, kk])[:, None].astype(jnp.int32)
            if pc and gating:
                do = (flush[:, kk] == t) & active[:, kk] & ~crashed
                gk_eff = jnp.where(flush[:, kk] == t, -1, gk)
            else:
                do = jnp.zeros_like(crashed)
                gk_eff = gk
            ok = active[:, kk] & (gk_eff < 0) & (adj[:, kk] >= 0) & ~crashed
            elig_cnt += ok.astype(jnp.int64)
            if pallas:
                # slot kernel: combined flush+forward contribution plane
                # (a row with a delivery this round is never crashed, so
                # the jax body's has_new conjunct is implied by new_del)
                vals, win_cnt = kx.slot_frontier(
                    delivered, gk, delay[:, kk], do, ok, is_app, t,
                    gating=pc and gating)
                flush_sent += win_cnt.astype(jnp.int64)
            else:
                if pc and gating:
                    win = ((delivered >= gk[:, None]) & (delivered < t)
                           & do[:, None] & is_app[None, :])
                    flush_sent += win.sum().astype(jnp.int64)
                fwd = ok & has_new
                vals = jnp.where(new_del & fwd[:, None], dk, inf)
                if pc and gating:
                    vals = jnp.minimum(vals, jnp.where(win, dk, inf))
            tgt = adj[:, kk].astype(jnp.int32)
            for hop in range(d):
                if pallas:
                    arr = kx.ring_apply(arr, vals, tgt, off)
                else:
                    tl = tgt - off
                    rows = jnp.where((tl >= 0) & (tl < n_loc), tl, n_loc)
                    arr = arr.at[rows, :].min(vals, mode="drop")
                if hop < d - 1:
                    vals = jax.lax.ppermute(vals, "shard", perm)
                    tgt = jax.lax.ppermute(tgt, "shard", perm)
        if pc and gating:
            cleared = flush == t
            gate = jnp.where(cleared, -1, gate)
            ping = jnp.where(cleared, -1, ping)
            flush = jnp.where(cleared, inf, flush)
        stats = stats.at[0].set(napp.sum().astype(jnp.int64))
        stats = stats.at[1].set((napp.astype(jnp.int64) * elig_cnt).sum())
        stats = stats.at[2].set((nping.astype(jnp.int64) * elig_cnt).sum())
        stats = stats.at[3].set(flush_sent)
        stats = stats.at[5].set((gate >= 0).sum().astype(jnp.int64))
        stats = jax.lax.psum(stats, "shard")

        return (arr, delivered, adj, delay, active, gate, flush, ping,
                crashed, ever_del), stats

    def step(sched, state, t):
        t = t.astype(jnp.int32)
        return jax.lax.cond(
            t >= 0,
            lambda s: real_step(sched, s, t),
            lambda s: (s, jnp.zeros(len(SERIES_FIELDS), jnp.int64)),
            state)

    def span(state, sched, ts):
        return jax.lax.scan(lambda c, t: step(sched, c, t), state, ts)

    # check_rep=False: lax.cond trips shard_map's replication checker
    # (jax-ml/jax known limitation); the stats output really is
    # replicated — it comes out of an explicit psum on every branch.
    _run = jax.jit(shard_map(
        span, mesh=mesh,
        in_specs=(P("shard"), P(), P()),
        out_specs=(P("shard"), P()),
        check_rep=False))

    def run(state, sched, ts):
        # x64 so the int64 stats accumulators (and their psum) are
        # honored; every state/schedule array carries an explicit dtype,
        # so nothing else widens — byte-parity with the windowed series.
        with enable_x64():
            return _run(state, sched, ts)

    return run


@functools.lru_cache(maxsize=None)
def shard_retire_kernels(n_devices: int):
    """The two device-side retirement kernels the driver calls between
    segments: ``reduce(state, origins, horizon_limit) -> per-column
    aggregates`` (psum-replicated across the mesh) and ``apply(state,
    retire_mask, app_retire, hung) -> state`` (fold ``ever_del``, clear
    hung gates, recycle columns).  Together they are the sharded twin of
    ``stream.execute_windowed``'s host-side ``retire`` /
    ``record_and_free`` — the host only ever sees (W,)-sized arrays.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = shard_mesh(n_devices)
    inf = jnp.int32(INF)

    def reduce_fn(state, origins, rounds):
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed, ever_del) = state
        n_loc, w = arr.shape
        me = jax.lax.axis_index("shard")
        off = (me * n_loc).astype(jnp.int32)
        got = delivered >= 0
        cnt = got.sum(axis=0).astype(jnp.int64)
        arrcnt = (arr < rounds).sum(axis=0).astype(jnp.int64)
        sumdel = jnp.where(got, delivered, 0).sum(axis=0).astype(jnp.int64)
        alive = (~crashed).sum().astype(jnp.int64)
        alivedel = (got & ~crashed[:, None]).sum(axis=0).astype(jnp.int64)
        gated = (gate >= 0) & active & ~crashed[:, None]
        min_gate = jnp.where(gated, gate, inf).min(axis=1)
        blocked = ((got & (delivered >= min_gate[:, None]))
                   .sum(axis=0).astype(jnp.int64))
        pidx = jnp.where((ping >= 0) & ~crashed[:, None], ping,
                         w).reshape(-1)
        ref = jnp.zeros(w, jnp.int64).at[pidx].add(1, mode="drop")
        ol = origins - off
        owned = (ol >= 0) & (ol < n_loc) & (origins >= 0)
        ocl = jnp.clip(ol, 0, n_loc - 1)
        bdone = jnp.where(owned, got[ocl, jnp.arange(w)],
                          False).astype(jnp.int64)
        out = (cnt, arrcnt, sumdel, alive, alivedel, blocked, ref, bdone)
        return tuple(jax.lax.psum(x, "shard") for x in out)

    _reduce = jax.jit(shard_map(
        reduce_fn, mesh=mesh,
        in_specs=(P("shard"), P(), P()),
        out_specs=P()))

    def apply_fn(state, retire, app_retire, hung):
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed, ever_del) = state
        w = arr.shape[1]
        # app-delivery memory folds *before* the columns are wiped
        ever_del = ever_del | ((delivered >= 0)
                               & app_retire[None, :]).any(axis=1)
        # a gate whose ping column is being force-expired can never
        # resolve: clear it so the link goes safe (stream.retire's
        # horizon escape hatch, device-side)
        sel = (ping >= 0) & hung[jnp.clip(ping, 0, w - 1)]
        gate = jnp.where(sel, -1, gate)
        flush = jnp.where(sel, inf, flush)
        ping = jnp.where(sel, -1, ping)
        arr = jnp.where(retire[None, :], inf, arr)
        delivered = jnp.where(retire[None, :], -1, delivered)
        return (arr, delivered, adj, delay, active, gate, flush, ping,
                crashed, ever_del)

    _apply = jax.jit(shard_map(
        apply_fn, mesh=mesh,
        in_specs=(P("shard"), P(), P(), P()),
        out_specs=P("shard")))

    def reduce_run(state, origins, rounds):
        with enable_x64():
            return _reduce(state, origins, rounds)

    def apply_run(state, retire, app_retire, hung):
        with enable_x64():
            return _apply(state, retire, app_retire, hung)

    return reduce_run, apply_run
