"""Device-mesh plumbing for the sharded streaming engine.

One 1-D mesh axis (``"shard"``) partitions the process axis; everything
else (message columns, link slots) stays replicated or local.  CPU runs
get a multi-device mesh by forcing host platform devices *before* jax
initializes::

    XLA_FLAGS=--xla_force_host_platform_device_count=4

(tests spawn subprocesses so the flag precedes jax import, same pattern
as ``tests/test_engine.py``).
"""

from __future__ import annotations

import functools
import hashlib
from typing import Optional

import numpy as np

__all__ = ["resolve_devices", "shard_mesh", "pad_rows", "inverse_tables",
           "topology_digest"]


def topology_digest(adj: np.ndarray, delay: np.ndarray,
                    active: np.ndarray) -> bytes:
    """Content key of a topology snapshot, for caching the (expensive)
    :func:`inverse_tables` build across quiescent segments: churn that
    cycles back to a previously seen link table — or runs whose only
    events touch other state — hit the cache instead of re-sorting the
    whole edge set."""
    h = hashlib.blake2b(digest_size=16)
    for a in (adj, delay, active):
        h.update(np.ascontiguousarray(a).tobytes())
    return h.digest()


def resolve_devices(n_devices: Optional[int] = None) -> int:
    """Resolve a device-count request against what jax actually has.

    ``None`` means "all visible devices".  Asking for more devices than
    exist is an error naming the ``XLA_FLAGS`` escape hatch rather than
    a silent fallback — a sharded run that quietly collapses to one
    device would invalidate the benchmark it was asked for.
    """
    import jax

    avail = jax.device_count()
    if n_devices is None:
        return avail
    n_devices = int(n_devices)
    if n_devices < 1:
        raise ValueError(f"n_devices={n_devices} must be >= 1")
    if n_devices > avail:
        raise RuntimeError(
            f"sharded engine asked for {n_devices} devices but jax sees "
            f"{avail}; on CPU force host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_devices} (before jax initializes)")
    return n_devices


@functools.lru_cache(maxsize=None)
def shard_mesh(n_devices: int):
    """The cached 1-D ``("shard",)`` mesh over the first ``n_devices``
    devices (cached so every runner/kernel shares one Mesh object and
    jit caches key consistently)."""
    import jax

    devs = jax.devices()[:resolve_devices(n_devices)]
    return jax.sharding.Mesh(np.array(devs), ("shard",))


def pad_rows(n: int, n_devices: int) -> int:
    """Process-axis length padded up to a multiple of the device count.

    Padding rows are inert by construction (no links, never any arrival,
    marked crashed so the all-alive-delivered retirement rule ignores
    them) and are sliced off every host-side export.
    """
    return -(-n // n_devices) * n_devices


def inverse_tables(adj: np.ndarray, delay: np.ndarray, active: np.ndarray):
    """Per-delay-class inverse adjacency for the scanned fast body.

    The fast segment body (``shard_fast_span_runner``) propagates the
    round's delivery frontier by *gathering* at the receiver instead of
    scattering at the sender: each global row ``q`` OR-combines the
    bit-packed frontier rows of every eligible in-neighbor.  This
    builds those in-neighbor lists on the host, one table per distinct
    link delay ``dl`` (the gather's fold value is ``t + dl``, so rows
    of different delay cannot share a table):

        ``sig``  — tuple of ``(dl, B_dl)`` pairs (``B_dl`` = max
                   in-degree within the class), the structural cache
                   key of the compiled fast runner;
        ``tabs`` — matching ``(N, B_dl)`` int32 arrays of global source
                   rows, padded with ``N`` ("no source"; the gather
                   fills out-of-range indices with an empty frontier).

    Sender eligibility — ``active & (adj >= 0)`` — is folded into the
    tables at build time, which is why the fast path is only selected
    for segments with no link additions/removals (the driver rebuilds
    after topology-changing segments).  Crash eligibility needs no
    table entry: a crashed row's frontier is all-zero by construction,
    so gathering from it is a no-op.  Duplicate parallel links (two
    slots, same ``(p, q, dl)``) yield duplicate entries, which the OR
    absorbs exactly like the per-round scatter-min absorbs them.
    """
    n = adj.shape[0]
    mask = active & (adj >= 0)
    src, slot = np.nonzero(mask)
    tgt = adj[src, slot].astype(np.int64)
    dls = delay[src, slot].astype(np.int64)
    sig = []
    tabs = []
    for dl in np.unique(dls):
        m = dls == dl
        t_, s_ = tgt[m], src[m]
        order = np.argsort(t_, kind="stable")
        t_, s_ = t_[order], s_[order]
        cnt = np.bincount(t_, minlength=n)
        b = max(1, int(cnt.max()))
        starts = np.concatenate([[0], np.cumsum(cnt)])
        pos = np.arange(len(t_)) - starts[t_]
        tab = np.full((n, b), n, np.int32)
        tab[t_, pos] = s_
        sig.append((int(dl), b))
        tabs.append(tab)
    return tuple(sig), tabs
