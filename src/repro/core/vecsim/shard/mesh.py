"""Device-mesh plumbing for the sharded streaming engine.

One 1-D mesh axis (``"shard"``) partitions the process axis; everything
else (message columns, link slots) stays replicated or local.  CPU runs
get a multi-device mesh by forcing host platform devices *before* jax
initializes::

    XLA_FLAGS=--xla_force_host_platform_device_count=4

(tests spawn subprocesses so the flag precedes jax import, same pattern
as ``tests/test_engine.py``).
"""

from __future__ import annotations

import functools
from typing import Optional

import numpy as np

__all__ = ["resolve_devices", "shard_mesh", "pad_rows"]


def resolve_devices(n_devices: Optional[int] = None) -> int:
    """Resolve a device-count request against what jax actually has.

    ``None`` means "all visible devices".  Asking for more devices than
    exist is an error naming the ``XLA_FLAGS`` escape hatch rather than
    a silent fallback — a sharded run that quietly collapses to one
    device would invalidate the benchmark it was asked for.
    """
    import jax

    avail = jax.device_count()
    if n_devices is None:
        return avail
    n_devices = int(n_devices)
    if n_devices < 1:
        raise ValueError(f"n_devices={n_devices} must be >= 1")
    if n_devices > avail:
        raise RuntimeError(
            f"sharded engine asked for {n_devices} devices but jax sees "
            f"{avail}; on CPU force host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_devices} (before jax initializes)")
    return n_devices


@functools.lru_cache(maxsize=None)
def shard_mesh(n_devices: int):
    """The cached 1-D ``("shard",)`` mesh over the first ``n_devices``
    devices (cached so every runner/kernel shares one Mesh object and
    jit caches key consistently)."""
    import jax

    devs = jax.devices()[:resolve_devices(n_devices)]
    return jax.sharding.Mesh(np.array(devs), ("shard",))


def pad_rows(n: int, n_devices: int) -> int:
    """Process-axis length padded up to a multiple of the device count.

    Padding rows are inert by construction (no links, never any arrival,
    marked crashed so the all-alive-delivered retirement rule ignores
    them) and are sliced off every host-side export.
    """
    return -(-n // n_devices) * n_devices
