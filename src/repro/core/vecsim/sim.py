"""Vectorized lockstep-round execution of a :class:`VecScenario`.

The whole network is dense arrays (DESIGN.md §2.4):

  * ``arr[q, m]``       — earliest known arrival round of message ``m`` at
    process ``q`` (INF = never);
  * ``delivered[q, m]`` — delivery round (-1 = not yet);
  * ``adj/delay/active``— the ``(N, K)`` out-link slot table;
  * ``gate/flush/ping`` — per-slot ping-phase machinery (Algorithm 2):
    ``gate`` is the round the link was gated (-1 = safe), ``ping`` the
    message slot its ping floods under, ``flush`` the round at which the
    pong arrives and the per-link buffer is flushed;
  * ``crashed[p]``      — silent-crash flag (Fig. 5b): the process stops
    delivering and forwarding, its links die silently.

Each round applies, in order: link removals, link additions (with the
Algorithm 2 gating decision), crashes, broadcasts, arrival deliveries,
pong detection, buffer flushes, and flood-forwarding of this round's
deliveries over safe links.  The phase order matches the event engine's
same-timestamp event order, which is what the cross-validation harness
(``crossval.py``) relies on.

The round body is written in *slot space*: schedules name the message
**column** each broadcast/ping occupies, and an ``is_app`` mask replaces
``[:, :m_app]`` prefix slicing.  The monolithic entry point
(:func:`run_vec`) uses the identity mapping (column ``i`` = message
``i``); the streaming windowed engine (``vecsim.stream``) reuses the
same spans over a fixed-width live-column buffer, which is what makes
windowed and monolithic runs byte-identical wherever both can run.

Three backends execute the identical semantics:

  * ``numpy``  — readable reference, mutation + ``np.minimum.at`` scatter;
  * ``jax``    — one ``lax.scan`` over rounds, jitted; the process axis is
    pure scatter/gather so the body matches ``repro.core.engine.step``;
  * ``pallas`` — the same scan with the per-round delivery sweep fused
    into Pallas kernels (``vecsim.kernels``, DESIGN.md §2.6); interpret
    mode on CPU, compiled on TPU.

Tests assert the backends produce byte-identical ``delivered``
matrices and per-round stats series on random scenarios.
"""

from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ..types import LegacyEntryPointWarning, NetStats
from .scenario import INF, VecScenario

__all__ = ["VecRunResult", "run_vec", "execute_vec", "SERIES_FIELDS",
           "SlotSchedule", "full_schedule", "span_runner_for",
           "STACKED_SCHED_FIELDS", "stack_schedules"]

# Wire-size model shared with repro.core.base.control_bytes.
_CTRL_APP = 16    # AppMsg: (origin, counter)
_CTRL_PING = 24   # Ping:   (frm, to, id)

# Per-round stats emitted by both backends (int64 numpy (rounds, 6)).
SERIES_FIELDS = ("deliveries", "sent_app", "sent_ping", "flush_sent",
                 "pongs", "gated")


@dataclass
class SlotSchedule:
    """Slot-space schedules for a span of rounds.

    ``bc_slot``/``add_slot`` name the message *column* of each broadcast
    / link-addition ping; ``is_app`` marks which columns carry app
    messages.  Rounds are absolute.  The monolithic run uses the
    identity mapping (:func:`full_schedule`); the windowed engine remaps
    onto live buffer columns per segment."""

    is_app: np.ndarray       # (W,) bool
    bc_round: np.ndarray     # (B,)
    bc_origin: np.ndarray    # (B,)
    bc_slot: np.ndarray      # (B,)
    add_round: np.ndarray    # (E,)
    add_p: np.ndarray
    add_k: np.ndarray
    add_q: np.ndarray
    add_delay: np.ndarray
    add_slot: np.ndarray     # (E,) ping column of each addition
    rm_round: np.ndarray     # (R,)
    rm_p: np.ndarray
    rm_k: np.ndarray
    cr_round: np.ndarray     # (C,)
    cr_pid: np.ndarray


# Event fields of a SlotSchedule: everything except the per-column
# ``is_app`` mask, which is segment-wide rather than per-round.  These
# are the fields the scanned sharded runner consumes as stacked
# ``lax.scan`` inputs (one leading round axis), so the list is the
# contract between ``ColumnWindow.stacked_schedule`` and the runner.
STACKED_SCHED_FIELDS = tuple(
    name for name in SlotSchedule.__dataclass_fields__ if name != "is_app")


def sched_sentinel(name: str):
    """Padding sentinel of a :class:`SlotSchedule` event field — the one
    place the convention lives, shared by the per-round padding
    (``ColumnWindow.padded_schedule``) and the vectorized stacker
    (``ColumnWindow.stacked_schedule``) so the two schedule paths cannot
    drift.  Round fields pad with ``-2`` (never matches a real round, so
    a padded entry is dead in every body); ``add_delay`` pads with ``1``
    (a valid delay that is never read behind a sentinel round); every
    other field pads with ``0``."""
    if name.endswith("_round"):
        return -2
    return 1 if name == "add_delay" else 0


def stack_schedules(schedules) -> Dict[str, np.ndarray]:
    """Stack per-round padded :class:`SlotSchedule`\\ s along a leading
    round axis for device-side ``lax.scan`` consumption.

    Every schedule must be padded to identical caps (use
    ``ColumnWindow.padded_schedule`` with per-round caps) so each field
    stacks to a rectangular ``(rounds, cap)`` array.  ``is_app`` is
    shared across the span (column identity cannot change mid-segment —
    activation and retirement only happen at segment boundaries), so it
    is returned unstacked under its own key."""
    schedules = list(schedules)
    if not schedules:
        raise ValueError("stack_schedules needs at least one schedule")
    out = {name: np.stack([getattr(s, name) for s in schedules])
           for name in STACKED_SCHED_FIELDS}
    out["is_app"] = schedules[0].is_app
    return out


def full_schedule(scn: VecScenario) -> SlotSchedule:
    """Identity slot mapping: column ``i`` is message ``i``, ping of
    addition ``e`` is column ``m_app + e``."""
    m_app = scn.m_app
    is_app = np.zeros(scn.m_total, bool)
    is_app[:m_app] = True
    return SlotSchedule(
        is_app=is_app,
        bc_round=scn.bcast_round, bc_origin=scn.bcast_origin,
        bc_slot=np.arange(m_app, dtype=np.int32),
        add_round=scn.add_round, add_p=scn.add_p, add_k=scn.add_k,
        add_q=scn.add_q, add_delay=scn.add_delay,
        add_slot=(m_app + np.arange(scn.n_adds)).astype(np.int32),
        rm_round=scn.rm_round, rm_p=scn.rm_p, rm_k=scn.rm_k,
        cr_round=scn.crash_round, cr_pid=scn.crash_pid)


@dataclass
class VecRunResult:
    scenario: VecScenario
    delivered: np.ndarray          # (N, M_total) delivery round, -1 = never
    state: Dict[str, np.ndarray]   # final arrays (numpy)
    stats: NetStats
    series: np.ndarray             # (rounds, len(SERIES_FIELDS))
    snapshot: Optional[Dict[str, np.ndarray]] = None  # state after snap round
    backend: str = "numpy"

    @property
    def delivered_app(self) -> np.ndarray:
        return self.delivered[:, : self.scenario.m_app]

    def delivered_frac(self) -> float:
        """Fraction of (correct process, app message) pairs delivered."""
        ok = ~self.state["crashed"]
        d = self.delivered_app[ok]
        return float((d >= 0).mean()) if d.size else 1.0

    def mean_latency(self) -> float:
        """Mean rounds from broadcast to delivery over delivered pairs."""
        d = self.delivered_app
        got = d >= 0
        if not got.any():
            return float("nan")
        lat = d - self.scenario.bcast_round[None, :]
        return float(lat[got].mean())


def init_topo_state(scn: VecScenario, width: int) -> Dict[str, np.ndarray]:
    """Topology/gating state plus a ``width``-column message buffer."""
    n, k = scn.n, scn.k
    return dict(
        arr=np.full((n, width), INF, np.int32),
        delivered=np.full((n, width), -1, np.int32),
        adj=scn.adj0.astype(np.int32).copy(),
        delay=scn.delay0.astype(np.int32).copy(),
        active=(scn.adj0 >= 0).copy(),
        gate=np.full((n, k), -1, np.int32),
        flush=np.full((n, k), INF, np.int32),
        ping=np.full((n, k), -1, np.int32),
        crashed=np.zeros(n, bool),
        # app-delivery memory of columns already retired by the windowed
        # engine; always all-False on monolithic runs (the live columns
        # hold the complete history there).
        ever_del=np.zeros(n, bool),
    )


def _init_state(scn: VecScenario) -> Dict[str, np.ndarray]:
    return init_topo_state(scn, scn.m_total)


def stats_from_series(series: np.ndarray, first_receipts: int) -> NetStats:
    tot = series.sum(axis=0)
    deliveries, sent_app, sent_ping, flush_sent, pongs, _ = (
        int(x) for x in tot)
    sent = sent_app + sent_ping + flush_sent
    return NetStats(
        sent_messages=sent,
        sent_control=sent_ping + pongs,
        control_bytes=_CTRL_APP * (sent_app + flush_sent)
        + _CTRL_PING * sent_ping,
        oob_messages=pongs,
        deliveries=deliveries,
        duplicate_receipts=max(0, sent - first_receipts),
    )


# --------------------------------------------------------------------- #
# NumPy backend — one span of rounds over a slot-space schedule
# --------------------------------------------------------------------- #
def np_span(st: Dict[str, np.ndarray], sched: SlotSchedule, t0: int, t1: int,
            series: np.ndarray, *, pc: bool, always_gate: bool,
            pong_delay: int, gating: bool = True) -> None:
    """Advance ``st`` through rounds ``[t0, t1)`` in place, writing
    per-round stats into ``series[t0:t1]``.

    ``gating=False`` asserts the *whole scenario* schedules no link
    additions — the only source of gates — letting the span skip the
    pong/flush phases entirely (they are half the dense work per round
    on churn-free sustained traffic).  It must NOT be derived from a
    windowed segment's schedule: a segment without additions can still
    carry gates opened by an earlier segment."""
    arr, delivered = st["arr"], st["delivered"]
    adj, delay, active = st["adj"], st["delay"], st["active"]
    gate, flush, ping = st["gate"], st["flush"], st["ping"]
    crashed, ever_del = st["crashed"], st["ever_del"]
    n, k = adj.shape
    app_idx = np.nonzero(sched.is_app)[0]
    is_app = sched.is_app

    for t in range(t0, t1):
        row = series[t]
        # -- 1. removals ------------------------------------------------ #
        for e in np.nonzero(sched.rm_round == t)[0]:
            p, kk = int(sched.rm_p[e]), int(sched.rm_k[e])
            active[p, kk] = False
            gate[p, kk], flush[p, kk], ping[p, kk] = -1, INF, -1
        # -- 2. additions (+ Algorithm 2 gating decision) ---------------- #
        adds = np.nonzero(sched.add_round == t)[0]
        for e in adds:
            p, kk = int(sched.add_p[e]), int(sched.add_k[e])
            adj[p, kk] = int(sched.add_q[e])
            delay[p, kk] = int(sched.add_delay[e])
            active[p, kk] = True
            gate[p, kk], flush[p, kk], ping[p, kk] = -1, INF, -1
        if pc:
            for e in adds:
                p, kk = int(sched.add_p[e]), int(sched.add_k[e])
                if crashed[p]:
                    continue
                other_safe = any(active[p, j] and gate[p, j] < 0
                                 for j in range(k) if j != kk)
                has_del = bool(ever_del[p]) or bool(
                    (delivered[p, app_idx] >= 0).any())
                if other_safe and (always_gate or has_del):
                    slot = int(sched.add_slot[e])
                    gate[p, kk], ping[p, kk] = t, slot
                    delivered[p, slot] = t   # own ping floods from phase 8
        # -- 3. crashes (silent; links die with the process) ------------- #
        for e in np.nonzero(sched.cr_round == t)[0]:
            crashed[int(sched.cr_pid[e])] = True
        # -- 4. broadcasts ----------------------------------------------- #
        for i in np.nonzero(sched.bc_round == t)[0]:
            o, s = int(sched.bc_origin[i]), int(sched.bc_slot[i])
            if not crashed[o] and delivered[o, s] < 0:
                delivered[o, s] = t
        # -- 5. arrivals -> deliveries ------------------------------------ #
        newly = (arr == t) & (delivered < 0) & ~crashed[:, None]
        delivered[newly] = t
        # -- 6. pong detection -------------------------------------------- #
        if pc and gating:
            q_ = np.clip(adj, 0, n - 1)
            s_ = np.clip(ping, 0, delivered.shape[1] - 1)
            fire = ((gate >= 0) & (flush == INF) & (ping >= 0)
                    & (delivered[q_, s_] >= 0) & ~crashed[:, None])
            flush[fire] = t + pong_delay
            row[4] = int(fire.sum())
        # -- 7. flush buffered app messages over now-safe links ----------- #
        if pc and gating:
            flushing = np.nonzero((flush == t) & active & ~crashed[:, None])
            for p, kk in zip(*flushing):
                p, kk = int(p), int(kk)
                q, g, d = int(adj[p, kk]), int(gate[p, kk]), int(delay[p, kk])
                dp = delivered[p, app_idx]
                win = (dp >= g) & (dp < t)
                row[3] += int(win.sum())
                arr[q, app_idx] = np.minimum(
                    arr[q, app_idx],
                    np.where(win, np.int32(t + d), INF))
            cleared = flush == t
            gate[cleared], ping[cleared], flush[cleared] = -1, -1, INF
        # -- 8. forward this round's deliveries over safe links ----------- #
        # Sparse scatter: only the (process, message) cells delivered this
        # round generate sends, so scatter-min over their flat indices
        # instead of materializing dense (N, M) value planes per slot.
        new_del = delivered == t
        napp = (new_del & is_app[None, :]).sum(axis=1)
        nping = (new_del & ~is_app[None, :]).sum(axis=1)
        row[0] = int(napp.sum())
        rows_idx, cols_idx = np.nonzero(new_del)
        arr_flat = arr.reshape(-1)
        width = arr.shape[1]
        elig_cnt = np.zeros(n, np.int64)
        for kk in range(k):
            ok = (active[:, kk] & (gate[:, kk] < 0) & (adj[:, kk] >= 0)
                  & ~crashed)
            elig_cnt += ok
            if rows_idx.size == 0:
                continue
            sel = ok[rows_idx]
            if not sel.any():
                continue
            r, c = rows_idx[sel], cols_idx[sel]
            lin = adj[r, kk].astype(np.int64) * width + c
            np.minimum.at(arr_flat, lin,
                          (t + delay[r, kk]).astype(np.int32))
        row[1] = int((napp * elig_cnt).sum())
        row[2] = int((nping * elig_cnt).sum())
        row[5] = int((gate >= 0).sum())


def _run_np(scn: VecScenario, snapshot_round: Optional[int]):
    st = _init_state(scn)
    sched = full_schedule(scn)
    series = np.zeros((scn.rounds, len(SERIES_FIELDS)), np.int64)
    kw = dict(pc=scn.mode == "pc", always_gate=scn.always_gate,
              pong_delay=scn.pong_delay, gating=scn.n_adds > 0)
    snapshot = None
    if snapshot_round is None:
        np_span(st, sched, 0, scn.rounds, series, **kw)
    else:
        np_span(st, sched, 0, snapshot_round + 1, series, **kw)
        snapshot = {key: v.copy() for key, v in st.items()}
        np_span(st, sched, snapshot_round + 1, scn.rounds, series, **kw)
    return st, series, snapshot


# --------------------------------------------------------------------- #
# JAX backend — jitted lax.scan spans over slot-space schedules
# --------------------------------------------------------------------- #
_STATE_KEYS = ("arr", "delivered", "adj", "delay", "active", "gate",
               "flush", "ping", "crashed", "ever_del")


def _device_phase_lib(pc: bool, always_gate: bool):
    """Shared lax implementations of the schedule-event phases (1-4) and
    the pong-detection comparison (6), used by both the jax and pallas
    span runners so the two backends cannot drift apart on the
    event-application semantics."""
    import jax.numpy as jnp

    inf = jnp.int32(INF)

    def apply_events(sched, state, t):
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed, ever_del) = state
        n = arr.shape[0]
        is_app = sched["is_app"]

        # -- 1. removals -------------------------------------------------- #
        if sched["rm_round"].shape[0]:
            sel = sched["rm_round"] == t
            p_, k_ = jnp.where(sel, sched["rm_p"], n), sched["rm_k"]
            active = active.at[p_, k_].set(False, mode="drop")
            gate = gate.at[p_, k_].set(-1, mode="drop")
            flush = flush.at[p_, k_].set(inf, mode="drop")
            ping = ping.at[p_, k_].set(-1, mode="drop")

        # -- 2. additions -------------------------------------------------- #
        if sched["add_round"].shape[0]:
            sel = sched["add_round"] == t
            add_p, add_k = sched["add_p"], sched["add_k"]
            add_slot = sched["add_slot"]
            p_ = jnp.where(sel, add_p, n)
            adj = adj.at[p_, add_k].set(sched["add_q"], mode="drop")
            delay = delay.at[p_, add_k].set(sched["add_delay"], mode="drop")
            active = active.at[p_, add_k].set(True, mode="drop")
            if pc:
                safe_links = active & (gate < 0)
                safe_cnt = safe_links.sum(axis=1)
                pcl = jnp.clip(add_p, 0, n - 1)
                own_slot_safe = safe_links[pcl, add_k]
                other_safe = (safe_cnt[pcl]
                              - own_slot_safe.astype(jnp.int32)) >= 1
                if always_gate:
                    want = other_safe
                else:
                    has_del = ever_del | ((delivered >= 0)
                                          & is_app[None, :]).any(axis=1)
                    want = other_safe & has_del[pcl]
                want = want & ~crashed[pcl]
                gsel = sel & want
                pg = jnp.where(gsel, add_p, n)
                gate = gate.at[pg, add_k].set(t, mode="drop")
                flush = flush.at[pg, add_k].set(inf, mode="drop")
                ping = ping.at[pg, add_k].set(add_slot, mode="drop")
                delivered = delivered.at[pg, add_slot].set(t, mode="drop")
                csel = sel & ~want
                pc_ = jnp.where(csel, add_p, n)
                gate = gate.at[pc_, add_k].set(-1, mode="drop")
                flush = flush.at[pc_, add_k].set(inf, mode="drop")
                ping = ping.at[pc_, add_k].set(-1, mode="drop")

        # -- 3. crashes ----------------------------------------------------- #
        if sched["cr_round"].shape[0]:
            sel = sched["cr_round"] == t
            p_ = jnp.where(sel, sched["cr_pid"], n)
            crashed = crashed.at[p_].set(True, mode="drop")

        # -- 4. broadcasts -------------------------------------------------- #
        if sched["bc_round"].shape[0]:
            origin = sched["bc_origin"]
            sel = ((sched["bc_round"] == t)
                   & ~crashed[jnp.clip(origin, 0, n - 1)])
            o_ = jnp.where(sel, origin, n)
            delivered = delivered.at[o_, sched["bc_slot"]].max(t, mode="drop")

        return (arr, delivered, adj, delay, active, gate, flush, ping,
                crashed, ever_del)

    def pong_fire(delivered, adj, gate, flush, ping, crashed):
        """Phase 6 comparison: which gated links observe their ping
        delivered at the link target this round."""
        n = delivered.shape[0]
        q_ = jnp.clip(adj, 0, n - 1)
        s_ = jnp.clip(ping, 0, delivered.shape[1] - 1)
        tgt_del = delivered[q_, s_]
        return ((gate >= 0) & (flush == inf) & (ping >= 0)
                & (tgt_del >= 0) & ~crashed[:, None])

    return apply_events, pong_fire


def state_to_device(st: Dict[str, np.ndarray]):
    import jax.numpy as jnp
    return tuple(jnp.asarray(st[key]) for key in _STATE_KEYS)


def state_to_host(state) -> Dict[str, np.ndarray]:
    # np.array (not asarray): views of jax CPU buffers are read-only and
    # the windowed driver mutates the host state between segments.
    return {key: np.array(v) for key, v in zip(_STATE_KEYS, state)}


def sched_to_device(sched: SlotSchedule) -> Dict[str, object]:
    import jax.numpy as jnp
    return {f.name: jnp.asarray(getattr(sched, f.name))
            for f in sched.__dataclass_fields__.values()}


@functools.lru_cache(maxsize=None)
def jax_span_runner(k: int, pc: bool, always_gate: bool, pong_delay: int,
                    gating: bool = True):
    """Jitted ``(state, sched, ts) -> (state, stats)`` span runner.  One
    compilation per distinct (state, sched, ts) shape signature; negative
    rounds in ``ts`` are padding and leave the state untouched.
    ``gating=False`` (scenario-wide no-additions promise, see
    :func:`np_span`) elides the pong/flush phases from the trace."""
    import jax
    import jax.numpy as jnp

    from jax.experimental import enable_x64

    inf = jnp.int32(INF)

    def scatter_min(arr, rows, vals, valid):
        n = arr.shape[0]
        rows = jnp.where(valid, rows, n)          # out of bounds -> dropped
        return arr.at[rows, :].min(vals, mode="drop")

    apply_events, pong_fire = _device_phase_lib(pc, always_gate)

    def real_step(sched, state, t):
        # -- 1-4. removals / additions / crashes / broadcasts --------------- #
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed, ever_del) = apply_events(sched, state, t)
        n = arr.shape[0]
        is_app = sched["is_app"]
        # int64: per-round send counts reach rate·N·k, which wraps int32
        # at the sustained scales this engine exists for (the numpy twin
        # accumulates in int64 too); the runner executes under enable_x64
        # so the dtype is honored.
        stats = jnp.zeros(len(SERIES_FIELDS), jnp.int64)

        # -- 5. arrivals -> deliveries -------------------------------------- #
        newly = (arr == t) & (delivered < 0) & ~crashed[:, None]
        delivered = jnp.where(newly, t, delivered)

        # -- 6. pong detection ---------------------------------------------- #
        if pc and gating:
            fire = pong_fire(delivered, adj, gate, flush, ping, crashed)
            flush = jnp.where(fire, t + pong_delay, flush)
            stats = stats.at[4].set(fire.sum().astype(jnp.int64))

        # -- 7. flush buffered app messages over now-safe links ------------- #
        if pc and gating:
            flush_sent = jnp.int64(0)
            for kk in range(k):
                do = (flush[:, kk] == t) & active[:, kk] & ~crashed
                win = ((delivered >= gate[:, kk][:, None])
                       & (delivered < t) & do[:, None] & is_app[None, :])
                flush_sent += win.sum().astype(jnp.int64)
                vals = jnp.where(
                    win, (t + delay[:, kk])[:, None].astype(jnp.int32), inf)
                arr = scatter_min(arr, adj[:, kk], vals, do)
            stats = stats.at[3].set(flush_sent)
            cleared = flush == t
            gate = jnp.where(cleared, -1, gate)
            ping = jnp.where(cleared, -1, ping)
            flush = jnp.where(cleared, inf, flush)

        # -- 8. forward this round's deliveries over safe links ------------- #
        new_del = delivered == t
        napp = (new_del & is_app[None, :]).sum(axis=1)
        nping = (new_del & ~is_app[None, :]).sum(axis=1)
        has_new = new_del.any(axis=1) & ~crashed
        elig_cnt = jnp.zeros(n, jnp.int64)
        for kk in range(k):
            ok = (active[:, kk] & (gate[:, kk] < 0) & (adj[:, kk] >= 0)
                  & ~crashed)
            elig_cnt += ok.astype(jnp.int64)
            fwd = ok & has_new
            vals = jnp.where(new_del & fwd[:, None],
                             (t + delay[:, kk])[:, None].astype(jnp.int32),
                             inf)
            arr = scatter_min(arr, adj[:, kk], vals, fwd)
        stats = stats.at[0].set(napp.sum().astype(jnp.int64))
        stats = stats.at[1].set((napp.astype(jnp.int64) * elig_cnt).sum())
        stats = stats.at[2].set((nping.astype(jnp.int64) * elig_cnt).sum())
        stats = stats.at[5].set((gate >= 0).sum().astype(jnp.int64))

        return (arr, delivered, adj, delay, active, gate, flush, ping,
                crashed, ever_del), stats

    def step(sched, state, t):
        t = t.astype(jnp.int32)
        return jax.lax.cond(
            t >= 0,
            lambda s: real_step(sched, s, t),
            lambda s: (s, jnp.zeros(len(SERIES_FIELDS), jnp.int64)),
            state)

    @jax.jit
    def _run(state, sched, ts):
        return jax.lax.scan(lambda c, t: step(sched, c, t), state, ts)

    def run(state, sched, ts):
        # x64 so the int64 stats accumulators are honored; every array in
        # the carry/schedule carries an explicit dtype, so nothing else
        # widens (tests assert byte-parity with the int64 numpy series)
        with enable_x64():
            return _run(state, sched, ts)

    return run


@functools.lru_cache(maxsize=None)
def pallas_span_runner(k: int, pc: bool, always_gate: bool, pong_delay: int,
                       gating: bool = True,
                       interpret: Optional[bool] = None):
    """Jitted ``(state, sched, ts) -> (state, stats)`` span runner with
    the per-round delivery sweep fused into Pallas kernels (DESIGN.md
    §2.6) — same contract and byte-identical results as
    :func:`jax_span_runner`.

    Schedule events and pong detection stay in lax (shared with the jax
    runner through :func:`_device_phase_lib`); the ``(N, W)``-plane
    phases launch kernels: the gating-free path runs the single fused
    deliver+forward sweep, the gated path splits at the pong boundary
    (deliver kernel, lax pong ring, flush+forward kernel).  The int64
    NetStats math runs in lax over the kernels' int32 per-row counts.
    """
    import jax
    import jax.numpy as jnp

    from jax.experimental import enable_x64

    from . import kernels as kx

    kx.require_pallas()
    inf = jnp.int32(INF)
    apply_events, pong_fire = _device_phase_lib(pc, always_gate)

    def real_step(sched, state, t):
        # -- 1-4. removals / additions / crashes / broadcasts --------------- #
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed, ever_del) = apply_events(sched, state, t)
        is_app = sched["is_app"]
        stats = jnp.zeros(len(SERIES_FIELDS), jnp.int64)

        if pc and gating:
            # -- 5. deliver-sweep kernel ------------------------------------ #
            delivered, napp, nping = kx.deliver_sweep(
                arr, delivered, crashed, is_app, t, interpret=interpret)
            # -- 6. pong detection (cross-column gather; lax) --------------- #
            fire = pong_fire(delivered, adj, gate, flush, ping, crashed)
            flush = jnp.where(fire, t + pong_delay, flush)
            stats = stats.at[4].set(fire.sum().astype(jnp.int64))
            # -- 7+8. fused flush + forward frontier-sweep kernel ----------- #
            # A slot flushing this round forwards as safe in the same
            # round (the monolithic body clears gates between phases 7
            # and 8): gk_eff mirrors that clearing for the fwd mask.
            do = (flush == t) & active & ~crashed[:, None]
            gk_eff = jnp.where(flush == t, -1, gate)
            fwd_ok = (active & (gk_eff < 0) & (adj >= 0)
                      & ~crashed[:, None])
            arr, flush_sent = kx.frontier_sweep(
                arr, delivered, adj, delay, gate, do, fwd_ok, is_app, t,
                interpret=interpret)
            stats = stats.at[3].set(flush_sent.astype(jnp.int64))
            cleared = flush == t
            gate = jnp.where(cleared, -1, gate)
            ping = jnp.where(cleared, -1, ping)
            flush = jnp.where(cleared, inf, flush)
        else:
            # -- 5+8. single fused deliver + forward sweep kernel ----------- #
            fwd_ok = (active & (gate < 0) & (adj >= 0) & ~crashed[:, None])
            arr, delivered, napp, nping = kx.fused_sweep(
                arr, delivered, crashed, adj, delay, fwd_ok, is_app, t,
                interpret=interpret)

        elig_cnt = fwd_ok.sum(axis=1).astype(jnp.int64)
        napp = napp.astype(jnp.int64)
        nping = nping.astype(jnp.int64)
        stats = stats.at[0].set(napp.sum())
        stats = stats.at[1].set((napp * elig_cnt).sum())
        stats = stats.at[2].set((nping * elig_cnt).sum())
        stats = stats.at[5].set((gate >= 0).sum().astype(jnp.int64))

        return (arr, delivered, adj, delay, active, gate, flush, ping,
                crashed, ever_del), stats

    def step(sched, state, t):
        t = t.astype(jnp.int32)
        return jax.lax.cond(
            t >= 0,
            lambda s: real_step(sched, s, t),
            lambda s: (s, jnp.zeros(len(SERIES_FIELDS), jnp.int64)),
            state)

    @jax.jit
    def _run(state, sched, ts):
        return jax.lax.scan(lambda c, t: step(sched, c, t), state, ts)

    def run(state, sched, ts):
        with enable_x64():
            return _run(state, sched, ts)

    return run


def span_runner_for(backend: str):
    """The device span-runner factory for a backend name."""
    return pallas_span_runner if backend == "pallas" else jax_span_runner


def _run_jax(scn: VecScenario, snapshot_round: Optional[int],
             backend: str = "jax"):
    import jax.numpy as jnp

    run = span_runner_for(backend)(scn.k, scn.mode == "pc", scn.always_gate,
                                   scn.pong_delay, gating=scn.n_adds > 0)
    sched = sched_to_device(full_schedule(scn))
    state0 = state_to_device(_init_state(scn))
    if snapshot_round is None:
        final, series = run(state0, sched,
                            jnp.arange(scn.rounds, dtype=jnp.int32))
        return state_to_host(final), np.asarray(series, np.int64), None
    # split the scan at the snapshot and resume from it — no re-simulation
    snap_state, series_a = run(
        state0, sched, jnp.arange(snapshot_round + 1, dtype=jnp.int32))
    snapshot = state_to_host(snap_state)
    final, series_b = run(
        snap_state, sched, jnp.arange(snapshot_round + 1, scn.rounds,
                                      dtype=jnp.int32))
    series = np.concatenate([np.asarray(series_a, np.int64),
                             np.asarray(series_b, np.int64)])
    return state_to_host(final), series, snapshot


def resolve_backend(backend: str) -> str:
    """Resolve ``"auto"`` and validate explicit backend names.

    ``auto`` picks jax when importable (numpy otherwise) — and the
    fused Pallas kernels only when an actual TPU can compile them;
    anywhere Pallas is unavailable or interpret-only, auto falls back
    to the jax backend.  ``backend="pallas"`` asked for by name raises
    :class:`~repro.core.vecsim.kernels.PallasUnavailableError` when the
    kernels cannot initialize."""
    if backend == "auto":
        try:
            import jax
        except ImportError:
            return "numpy"
        from . import kernels
        ok, _ = kernels.pallas_available()
        if ok and jax.default_backend() == "tpu":
            return "pallas"
        return "jax"
    if backend == "pallas":
        from . import kernels
        kernels.require_pallas()
        return "pallas"
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def execute_vec(scn: VecScenario, backend: str = "auto",
                snapshot_round: Optional[int] = None,
                window: Optional[int] = None,
                collect: Optional[str] = None, **window_kw):
    """Execute ``scn`` in lockstep rounds; returns delivery matrix, final
    state, ``NetStats`` (same schema as the exact simulator) and a
    per-round stats series.  ``snapshot_round`` additionally captures the
    full state right after that round (for mid-churn topology metrics).

    ``window`` switches to the streaming windowed engine
    (``vecsim.stream``): the message axis is processed through a fixed
    buffer of ``window`` live columns with O(N·window) memory, returning
    a :class:`~repro.core.vecsim.stream.WindowedRunResult` instead.
    ``collect`` and the extra keyword arguments (``horizon``,
    ``seg_len``) apply only to windowed runs.

    This is the engine implementation behind ``repro.api.run``; prefer
    the front door (``repro.api.run(RunSpec(...))``) in new code."""
    if window is not None:
        from .stream import execute_windowed
        return execute_windowed(scn, window, backend=backend,
                                snapshot_round=snapshot_round,
                                collect=collect if collect is not None
                                else "auto", **window_kw)
    if window_kw or collect is not None:
        extra = sorted(window_kw) + (["collect"] if collect is not None
                                     else [])
        raise TypeError(f"monolithic run_vec got windowed-only arguments "
                        f"{extra}")
    backend = resolve_backend(backend)
    if backend in ("jax", "pallas"):
        st, series, snapshot = _run_jax(scn, snapshot_round, backend)
    else:
        st, series, snapshot = _run_np(scn, snapshot_round)
    first_receipts = int((st["arr"] < scn.rounds).sum())
    stats = stats_from_series(series, first_receipts)
    return VecRunResult(scenario=scn, delivered=st["delivered"], state=st,
                        stats=stats, series=series, snapshot=snapshot,
                        backend=backend)


def run_vec(scn: VecScenario, backend: str = "auto",
            snapshot_round: Optional[int] = None,
            window: Optional[int] = None,
            collect: Optional[str] = None, **window_kw):
    """Legacy entry point — identical signature and behavior to
    :func:`execute_vec`, which it delegates to after emitting a
    :class:`~repro.core.types.LegacyEntryPointWarning`.  New code goes
    through the one front door: ``repro.api.run(RunSpec(...))``."""
    warnings.warn(
        "run_vec is a legacy entry point; use repro.api.run(RunSpec(...)) "
        "(see DESIGN.md §3)", LegacyEntryPointWarning, stacklevel=2)
    return execute_vec(scn, backend=backend, snapshot_round=snapshot_round,
                       window=window, collect=collect, **window_kw)
