"""Vectorized lockstep-round execution of a :class:`VecScenario`.

The whole network is dense arrays (DESIGN.md §2.4):

  * ``arr[q, m]``       — earliest known arrival round of message ``m`` at
    process ``q`` (INF = never);
  * ``delivered[q, m]`` — delivery round (-1 = not yet);
  * ``adj/delay/active``— the ``(N, K)`` out-link slot table;
  * ``gate/flush/ping`` — per-slot ping-phase machinery (Algorithm 2):
    ``gate`` is the round the link was gated (-1 = safe), ``ping`` the
    message slot its ping floods under, ``flush`` the round at which the
    pong arrives and the per-link buffer is flushed;
  * ``crashed[p]``      — silent-crash flag (Fig. 5b): the process stops
    delivering and forwarding, its links die silently.

Each round applies, in order: link removals, link additions (with the
Algorithm 2 gating decision), crashes, broadcasts, arrival deliveries,
pong detection, buffer flushes, and flood-forwarding of this round's
deliveries over safe links.  The phase order matches the event engine's
same-timestamp event order, which is what the cross-validation harness
(``crossval.py``) relies on.

Two backends execute the identical semantics:

  * ``numpy``  — readable reference, mutation + ``np.minimum.at`` scatter;
  * ``jax``    — one ``lax.scan`` over rounds, jitted; the process axis is
    pure scatter/gather so the body matches ``repro.core.engine.step``.

Tests assert the two backends produce byte-identical ``delivered``
matrices and per-round stats series on random scenarios.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ..types import NetStats
from .scenario import INF, VecScenario

__all__ = ["VecRunResult", "run_vec", "SERIES_FIELDS"]

# Wire-size model shared with repro.core.base.control_bytes.
_CTRL_APP = 16    # AppMsg: (origin, counter)
_CTRL_PING = 24   # Ping:   (frm, to, id)

# Per-round stats emitted by both backends (int64 numpy (rounds, 6)).
SERIES_FIELDS = ("deliveries", "sent_app", "sent_ping", "flush_sent",
                 "pongs", "gated")


@dataclass
class VecRunResult:
    scenario: VecScenario
    delivered: np.ndarray          # (N, M_total) delivery round, -1 = never
    state: Dict[str, np.ndarray]   # final arrays (numpy)
    stats: NetStats
    series: np.ndarray             # (rounds, len(SERIES_FIELDS))
    snapshot: Optional[Dict[str, np.ndarray]] = None  # state after snap round
    backend: str = "numpy"

    @property
    def delivered_app(self) -> np.ndarray:
        return self.delivered[:, : self.scenario.m_app]

    def delivered_frac(self) -> float:
        """Fraction of (correct process, app message) pairs delivered."""
        ok = ~self.state["crashed"]
        d = self.delivered_app[ok]
        return float((d >= 0).mean()) if d.size else 1.0

    def mean_latency(self) -> float:
        """Mean rounds from broadcast to delivery over delivered pairs."""
        d = self.delivered_app
        got = d >= 0
        if not got.any():
            return float("nan")
        lat = d - self.scenario.bcast_round[None, :]
        return float(lat[got].mean())


def _init_state(scn: VecScenario) -> Dict[str, np.ndarray]:
    n, k, m = scn.n, scn.k, scn.m_total
    return dict(
        arr=np.full((n, m), INF, np.int32),
        delivered=np.full((n, m), -1, np.int32),
        adj=scn.adj0.astype(np.int32).copy(),
        delay=scn.delay0.astype(np.int32).copy(),
        active=(scn.adj0 >= 0).copy(),
        gate=np.full((n, k), -1, np.int32),
        flush=np.full((n, k), INF, np.int32),
        ping=np.full((n, k), -1, np.int32),
        crashed=np.zeros(n, bool),
    )


def _stats_from_series(series: np.ndarray, arr: np.ndarray,
                       rounds: int) -> NetStats:
    tot = series.sum(axis=0)
    deliveries, sent_app, sent_ping, flush_sent, pongs, _ = (
        int(x) for x in tot)
    sent = sent_app + sent_ping + flush_sent
    # arr only records the EARLIEST arrival per (q, m); later copies are
    # duplicates by construction (the vec engine never drops in-flight
    # traffic — fidelity note in DESIGN.md §2.4).
    first_receipts = int((arr < rounds).sum())
    return NetStats(
        sent_messages=sent,
        sent_control=sent_ping + pongs,
        control_bytes=_CTRL_APP * (sent_app + flush_sent)
        + _CTRL_PING * sent_ping,
        oob_messages=pongs,
        deliveries=deliveries,
        duplicate_receipts=max(0, sent - first_receipts),
    )


# --------------------------------------------------------------------- #
# NumPy backend
# --------------------------------------------------------------------- #
def _run_np(scn: VecScenario, snapshot_round: Optional[int]):
    st = _init_state(scn)
    arr, delivered = st["arr"], st["delivered"]
    adj, delay, active = st["adj"], st["delay"], st["active"]
    gate, flush, ping = st["gate"], st["flush"], st["ping"]
    crashed = st["crashed"]
    n, k, m_app = scn.n, scn.k, scn.m_app
    pc = scn.mode == "pc"
    series = np.zeros((scn.rounds, len(SERIES_FIELDS)), np.int64)
    snapshot = None

    for t in range(scn.rounds):
        # -- 1. removals ------------------------------------------------ #
        for e in np.nonzero(scn.rm_round == t)[0]:
            p, kk = int(scn.rm_p[e]), int(scn.rm_k[e])
            active[p, kk] = False
            gate[p, kk], flush[p, kk], ping[p, kk] = -1, INF, -1
        # -- 2. additions (+ Algorithm 2 gating decision) ---------------- #
        adds = np.nonzero(scn.add_round == t)[0]
        for e in adds:
            p, kk = int(scn.add_p[e]), int(scn.add_k[e])
            adj[p, kk] = int(scn.add_q[e])
            delay[p, kk] = int(scn.add_delay[e])
            active[p, kk] = True
            gate[p, kk], flush[p, kk], ping[p, kk] = -1, INF, -1
        if pc:
            for e in adds:
                p, kk = int(scn.add_p[e]), int(scn.add_k[e])
                if crashed[p]:
                    continue
                other_safe = any(active[p, j] and gate[p, j] < 0
                                 for j in range(k) if j != kk)
                has_del = bool((delivered[p, :m_app] >= 0).any())
                if other_safe and (scn.always_gate or has_del):
                    slot = m_app + int(e)
                    gate[p, kk], ping[p, kk] = t, slot
                    delivered[p, slot] = t   # own ping floods from phase 8
        # -- 3. crashes (silent; links die with the process) ------------- #
        for e in np.nonzero(scn.crash_round == t)[0]:
            crashed[int(scn.crash_pid[e])] = True
        # -- 4. broadcasts ----------------------------------------------- #
        for i in np.nonzero(scn.bcast_round == t)[0]:
            o = int(scn.bcast_origin[i])
            if not crashed[o] and delivered[o, i] < 0:
                delivered[o, i] = t
        # -- 5. arrivals -> deliveries ------------------------------------ #
        newly = (arr == t) & (delivered < 0) & ~crashed[:, None]
        delivered[newly] = t
        # -- 6. pong detection -------------------------------------------- #
        if pc:
            q_ = np.clip(adj, 0, n - 1)
            s_ = np.clip(ping, 0, delivered.shape[1] - 1)
            fire = ((gate >= 0) & (flush == INF) & (ping >= 0)
                    & (delivered[q_, s_] >= 0) & ~crashed[:, None])
            flush[fire] = t + scn.pong_delay
            series[t, 4] = int(fire.sum())
        # -- 7. flush buffered app messages over now-safe links ----------- #
        if pc:
            flushing = np.nonzero((flush == t) & active & ~crashed[:, None])
            for p, kk in zip(*flushing):
                p, kk = int(p), int(kk)
                q, g, d = int(adj[p, kk]), int(gate[p, kk]), int(delay[p, kk])
                win = (delivered[p, :m_app] >= g) & (delivered[p, :m_app] < t)
                series[t, 3] += int(win.sum())
                arr[q, :m_app] = np.minimum(
                    arr[q, :m_app],
                    np.where(win, np.int32(t + d), INF))
            cleared = flush == t
            gate[cleared], ping[cleared], flush[cleared] = -1, -1, INF
        # -- 8. forward this round's deliveries over safe links ----------- #
        # Sparse scatter: only the (process, message) cells delivered this
        # round generate sends, so scatter-min over their flat indices
        # instead of materializing dense (N, M) value planes per slot.
        new_del = delivered == t
        napp = new_del[:, :m_app].sum(axis=1)
        nping = new_del[:, m_app:].sum(axis=1)
        series[t, 0] = int(napp.sum())
        rows_idx, cols_idx = np.nonzero(new_del)
        arr_flat = arr.reshape(-1)
        m_total = arr.shape[1]
        elig_cnt = np.zeros(n, np.int64)
        for kk in range(k):
            ok = (active[:, kk] & (gate[:, kk] < 0) & (adj[:, kk] >= 0)
                  & ~crashed)
            elig_cnt += ok
            if rows_idx.size == 0:
                continue
            sel = ok[rows_idx]
            if not sel.any():
                continue
            r, c = rows_idx[sel], cols_idx[sel]
            lin = adj[r, kk].astype(np.int64) * m_total + c
            np.minimum.at(arr_flat, lin,
                          (t + delay[r, kk]).astype(np.int32))
        series[t, 1] = int((napp * elig_cnt).sum())
        series[t, 2] = int((nping * elig_cnt).sum())
        series[t, 5] = int((gate >= 0).sum())
        if snapshot_round is not None and t == snapshot_round:
            snapshot = {key: v.copy() for key, v in st.items()}

    return st, series, snapshot


# --------------------------------------------------------------------- #
# JAX backend — one jitted lax.scan over rounds
# --------------------------------------------------------------------- #
def _run_jax(scn: VecScenario, snapshot_round: Optional[int]):
    import jax
    import jax.numpy as jnp

    m_app = scn.m_app
    bc_round = jnp.asarray(scn.bcast_round)
    bc_origin = jnp.asarray(scn.bcast_origin)
    add_round = jnp.asarray(scn.add_round)
    add_p = jnp.asarray(scn.add_p)
    add_k = jnp.asarray(scn.add_k)
    add_q = jnp.asarray(scn.add_q)
    add_delay = jnp.asarray(scn.add_delay)
    add_slot = jnp.asarray(m_app + np.arange(scn.n_adds, dtype=np.int32))
    rm_round = jnp.asarray(scn.rm_round)
    rm_p = jnp.asarray(scn.rm_p)
    rm_k = jnp.asarray(scn.rm_k)
    cr_round = jnp.asarray(scn.crash_round)
    cr_pid = jnp.asarray(scn.crash_pid)
    K, pc = scn.k, scn.mode == "pc"
    pong_delay = scn.pong_delay
    inf = jnp.int32(INF)

    def scatter_min(arr, rows, vals, valid):
        n = arr.shape[0]
        rows = jnp.where(valid, rows, n)          # out of bounds -> dropped
        return arr.at[rows, :].min(vals, mode="drop")

    def step(state, t):
        (arr, delivered, adj, delay, active, gate, flush, ping,
         crashed) = state
        n = arr.shape[0]
        t = t.astype(jnp.int32)
        stats = jnp.zeros(len(SERIES_FIELDS), jnp.int32)

        # -- 1. removals -------------------------------------------------- #
        if rm_round.shape[0]:
            sel = rm_round == t
            p_, k_ = jnp.where(sel, rm_p, n), rm_k
            active = active.at[p_, k_].set(False, mode="drop")
            gate = gate.at[p_, k_].set(-1, mode="drop")
            flush = flush.at[p_, k_].set(inf, mode="drop")
            ping = ping.at[p_, k_].set(-1, mode="drop")

        # -- 2. additions -------------------------------------------------- #
        if add_round.shape[0]:
            sel = add_round == t
            p_ = jnp.where(sel, add_p, n)
            adj = adj.at[p_, add_k].set(add_q, mode="drop")
            delay = delay.at[p_, add_k].set(add_delay, mode="drop")
            active = active.at[p_, add_k].set(True, mode="drop")
            if pc:
                safe_links = active & (gate < 0)
                safe_cnt = safe_links.sum(axis=1)
                pcl = jnp.clip(add_p, 0, n - 1)
                own_slot_safe = safe_links[pcl, add_k]
                other_safe = (safe_cnt[pcl]
                              - own_slot_safe.astype(jnp.int32)) >= 1
                if scn.always_gate:
                    want = other_safe
                else:
                    has_del = (delivered[:, :m_app] >= 0).any(axis=1)
                    want = other_safe & has_del[pcl]
                want = want & ~crashed[pcl]
                gsel = sel & want
                pg = jnp.where(gsel, add_p, n)
                gate = gate.at[pg, add_k].set(t, mode="drop")
                flush = flush.at[pg, add_k].set(inf, mode="drop")
                ping = ping.at[pg, add_k].set(add_slot, mode="drop")
                delivered = delivered.at[pg, add_slot].set(t, mode="drop")
                csel = sel & ~want
                pc_ = jnp.where(csel, add_p, n)
                gate = gate.at[pc_, add_k].set(-1, mode="drop")
                flush = flush.at[pc_, add_k].set(inf, mode="drop")
                ping = ping.at[pc_, add_k].set(-1, mode="drop")

        # -- 3. crashes ----------------------------------------------------- #
        if cr_round.shape[0]:
            sel = cr_round == t
            p_ = jnp.where(sel, cr_pid, n)
            crashed = crashed.at[p_].set(True, mode="drop")

        # -- 4. broadcasts -------------------------------------------------- #
        if bc_round.shape[0]:
            sel = (bc_round == t) & ~crashed[jnp.clip(bc_origin, 0, n - 1)]
            o_ = jnp.where(sel, bc_origin, n)
            slots = jnp.arange(m_app, dtype=jnp.int32)
            delivered = delivered.at[o_, slots].max(t, mode="drop")

        # -- 5. arrivals -> deliveries -------------------------------------- #
        newly = (arr == t) & (delivered < 0) & ~crashed[:, None]
        delivered = jnp.where(newly, t, delivered)

        # -- 6. pong detection ---------------------------------------------- #
        if pc:
            q_ = jnp.clip(adj, 0, n - 1)
            s_ = jnp.clip(ping, 0, delivered.shape[1] - 1)
            tgt_del = delivered[q_, s_]
            fire = ((gate >= 0) & (flush == inf) & (ping >= 0)
                    & (tgt_del >= 0) & ~crashed[:, None])
            flush = jnp.where(fire, t + pong_delay, flush)
            stats = stats.at[4].set(fire.sum().astype(jnp.int32))

        # -- 7. flush buffered app messages over now-safe links ------------- #
        if pc:
            d_app = delivered[:, :m_app]
            flush_sent = jnp.int32(0)
            for kk in range(K):
                do = (flush[:, kk] == t) & active[:, kk] & ~crashed
                win = ((d_app >= gate[:, kk][:, None])
                       & (d_app < t) & do[:, None])
                flush_sent += win.sum().astype(jnp.int32)
                vals = jnp.where(
                    win, (t + delay[:, kk])[:, None].astype(jnp.int32), inf)
                pad = jnp.full((n, delivered.shape[1] - m_app), inf,
                               jnp.int32)
                arr = scatter_min(arr, adj[:, kk],
                                  jnp.concatenate([vals, pad], axis=1), do)
            stats = stats.at[3].set(flush_sent)
            cleared = flush == t
            gate = jnp.where(cleared, -1, gate)
            ping = jnp.where(cleared, -1, ping)
            flush = jnp.where(cleared, inf, flush)

        # -- 8. forward this round's deliveries over safe links ------------- #
        new_del = delivered == t
        napp = new_del[:, :m_app].sum(axis=1)
        nping = new_del[:, m_app:].sum(axis=1)
        has_new = new_del.any(axis=1) & ~crashed
        elig_cnt = jnp.zeros(n, jnp.int32)
        for kk in range(K):
            ok = (active[:, kk] & (gate[:, kk] < 0) & (adj[:, kk] >= 0)
                  & ~crashed)
            elig_cnt += ok.astype(jnp.int32)
            fwd = ok & has_new
            vals = jnp.where(new_del & fwd[:, None],
                             (t + delay[:, kk])[:, None].astype(jnp.int32),
                             inf)
            arr = scatter_min(arr, adj[:, kk], vals, fwd)
        stats = stats.at[0].set(napp.sum().astype(jnp.int32))
        stats = stats.at[1].set((napp * elig_cnt).sum().astype(jnp.int32))
        stats = stats.at[2].set((nping * elig_cnt).sum().astype(jnp.int32))
        stats = stats.at[5].set((gate >= 0).sum().astype(jnp.int32))

        return (arr, delivered, adj, delay, active, gate, flush, ping,
                crashed), stats

    def to_device(st):
        return (jnp.asarray(st["arr"]), jnp.asarray(st["delivered"]),
                jnp.asarray(st["adj"]), jnp.asarray(st["delay"]),
                jnp.asarray(st["active"]), jnp.asarray(st["gate"]),
                jnp.asarray(st["flush"]), jnp.asarray(st["ping"]),
                jnp.asarray(st["crashed"]))

    def to_host(state):
        keys = ("arr", "delivered", "adj", "delay", "active", "gate",
                "flush", "ping", "crashed")
        return {key: np.asarray(v) for key, v in zip(keys, state)}

    @jax.jit
    def run(state, rounds_arr):
        return jax.lax.scan(step, state, rounds_arr)

    state0 = to_device(_init_state(scn))
    if snapshot_round is None:
        final, series = run(state0, jnp.arange(scn.rounds, dtype=jnp.int32))
        return to_host(final), np.asarray(series, np.int64), None
    # split the scan at the snapshot and resume from it — no re-simulation
    snap_state, series_a = run(
        state0, jnp.arange(snapshot_round + 1, dtype=jnp.int32))
    snapshot = to_host(snap_state)
    final, series_b = run(
        snap_state, jnp.arange(snapshot_round + 1, scn.rounds,
                               dtype=jnp.int32))
    series = np.concatenate([np.asarray(series_a, np.int64),
                             np.asarray(series_b, np.int64)])
    return to_host(final), series, snapshot


def run_vec(scn: VecScenario, backend: str = "auto",
            snapshot_round: Optional[int] = None) -> VecRunResult:
    """Execute ``scn`` in lockstep rounds; returns delivery matrix, final
    state, ``NetStats`` (same schema as the exact simulator) and a
    per-round stats series.  ``snapshot_round`` additionally captures the
    full state right after that round (for mid-churn topology metrics)."""
    if backend == "auto":
        try:
            import jax  # noqa: F401
            backend = "jax"
        except ImportError:
            backend = "numpy"
    if backend == "jax":
        st, series, snapshot = _run_jax(scn, snapshot_round)
    elif backend == "numpy":
        st, series, snapshot = _run_np(scn, snapshot_round)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    stats = _stats_from_series(series, st["arr"], scn.rounds)
    return VecRunResult(scenario=scn, delivered=st["delivered"], state=st,
                        stats=stats, series=series, snapshot=snapshot,
                        backend=backend)
