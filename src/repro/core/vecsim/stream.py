"""Streaming windowed execution: sustained traffic in O(N·W) memory.

The monolithic engine (``sim.run_vec``) materializes dense
``(N, M_total)`` arrival/delivery matrices, so memory — not the protocol
— caps how much traffic a run can carry: N=50k works for a handful of
broadcasts, never sustained load.  This module processes the message
axis through a fixed buffer of ``W`` live *columns* instead:

  * a message (app broadcast or link-addition ping) is **activated** —
    assigned a free buffer column — just before its scheduled round;
  * rounds advance segment-by-segment through the *same* slot-space span
    runners as the monolithic engine (``sim.np_span`` /
    ``sim.jax_span_runner``), so the per-round semantics are literally
    shared code;
  * between segments, columns are **retired**: their per-message results
    fold into online aggregates and the column is recycled.

Retirement is exact — a column leaves the buffer only when nothing in
the monolithic run could still touch it:

  1. every non-crashed process has delivered it, AND no pending gated
     link could still flush it (some process delivered it at or after
     the link's gate round), for app columns;
  2. ping columns additionally stay while any live ``ping[p, k]`` slot
     references them (pong detection reads their delivery row);
  3. columns that can never become live (their broadcast was skipped by
     a crashed origin, or their link addition did not gate) retire as
     soon as their round has passed.

Under those rules a windowed run's delivered matrix, per-round stats
series and ``NetStats`` are byte-identical to the monolithic run's on
any scenario small enough to run both — the differential fuzz suite
asserts exactly that.  An optional ``horizon`` force-retires columns
older than ``horizon`` rounds; that bounds buffer residency for
pathological scenarios at the (documented, flagged in ``expired``) cost
of dropping whatever late activity the column still had.

Memory is O(N·W) regardless of how many messages the schedule carries,
which is what lets one host sustain millions of broadcasts at N ≥ 10k
(``benchmarks/bench_throughput.py``).

The segment loop itself lives in :class:`WindowedStepper` — one
``advance()`` per segment — so a caller that interleaves work between
segments (the live serving front door, ``vecsim.live``) drives the
*same* engine code as the one-shot :func:`execute_windowed` wrapper.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from ..types import LegacyEntryPointWarning, NetStats
from .scenario import INF, VecScenario
from .sim import (SERIES_FIELDS, STACKED_SCHED_FIELDS, SlotSchedule,
                  init_topo_state, np_span, resolve_backend, sched_sentinel,
                  stats_from_series)

__all__ = ["WindowedRunResult", "WindowOverflowError", "ColumnWindow",
           "WindowedStepper", "run_vec_windowed", "execute_windowed"]


class WindowOverflowError(RuntimeError):
    """The live-column buffer filled up and nothing could retire.

    ``round`` carries the first round whose due event found no free
    column — with the round-granular horizon sweeps in
    :meth:`ColumnWindow.activate` it is the same round for every
    ``seg_len`` choice (the differential fuzz suite asserts exactly
    that).

    The raise happens *before* any column assignment or schedule-cursor
    movement, so the window (and with it the whole engine) is left
    exactly as it was at the segment boundary: a caller may catch the
    error, free up capacity (retire, shed, defer admissions) and call
    ``activate`` again — the live serving loop's backpressure path."""

    def __init__(self, message: str, round: Optional[int] = None):
        super().__init__(message)
        self.round = round


@dataclass
class WindowedRunResult:
    """Result of a streaming windowed run.

    ``delivered`` is the full ``(N, M_total)`` matrix only when the run
    was small enough to collect it (``collect="full"``); sustained runs
    keep per-message aggregates instead.  ``stats``/``series`` match the
    monolithic run byte-for-byte whenever no column was horizon-expired.
    """

    scenario: VecScenario
    window: int
    backend: str
    stats: NetStats
    series: np.ndarray              # (rounds, len(SERIES_FIELDS)) int64
    delivered: Optional[np.ndarray]  # (N, M_total) or None (aggregate mode)
    deliv_count: np.ndarray         # (M_total,) deliveries per message
    bcast_done: np.ndarray          # (m_app,) broadcast actually happened
    expired: np.ndarray             # (M_total,) retired by horizon expiry
    state: Dict[str, np.ndarray]    # final topology state + live buffer
    snapshot: Optional[Dict[str, np.ndarray]]
    peak_live: int                  # max live columns ever resident
    lat_sum: int                    # sum of (deliver - broadcast) rounds
    lat_cnt: int                    # delivered (process, app msg) pairs
    # (M_total,) sum of delivery rounds over the processes that
    # delivered each message — with deliv_count this gives the
    # per-message mean delivery round the live front door turns into
    # rounds-to-delivery latency percentiles.
    deliv_round_sum: Optional[np.ndarray] = None

    @property
    def m_app(self) -> int:
        return self.scenario.m_app

    @property
    def delivered_app(self) -> Optional[np.ndarray]:
        return (None if self.delivered is None
                else self.delivered[:, : self.m_app])

    def delivered_frac(self) -> float:
        """Fraction of (correct process, app message) pairs delivered.
        Exact (same formula as the monolithic result) when the full
        matrix was collected; aggregate mode reports deliveries over
        *all* ``N × m_app`` pairs — the per-message counts include
        processes that crashed after the message retired, so dividing by
        the finally-alive population could exceed 1; on crash-free runs
        the two formulas agree exactly."""
        if self.delivered is not None:
            ok = ~self.state["crashed"]
            d = self.delivered[ok][:, : self.m_app]
            return float((d >= 0).mean()) if d.size else 1.0
        denom = self.scenario.n * self.m_app
        if not denom:
            return 1.0
        return float(self.deliv_count[: self.m_app].sum()) / denom

    def mean_latency(self) -> float:
        """Mean rounds from broadcast to delivery over delivered pairs."""
        return self.lat_sum / self.lat_cnt if self.lat_cnt else float("nan")


def _pad(a: np.ndarray, cap: int, fill) -> np.ndarray:
    if len(a) == cap:
        return a
    out = np.full(cap, fill, a.dtype)
    out[: len(a)] = a
    return out


def _window_caps(rounds_arr: np.ndarray, total_rounds: int,
                 seg_len: int) -> int:
    """Max number of events falling in any ``seg_len``-round span."""
    if not len(rounds_arr):
        return 0
    counts = np.bincount(np.clip(rounds_arr, 0, total_rounds),
                         minlength=total_rounds + 1)
    cum = np.concatenate([[0], np.cumsum(counts)])
    hi = np.minimum(np.arange(total_rounds) + seg_len, total_rounds + 1)
    return int((cum[hi] - cum[: total_rounds]).max())


class ColumnWindow:
    """Host-side live-column bookkeeping shared by the windowed drivers.

    Owns the round-sorted activation streams (broadcasts + link
    additions), the column -> message assignment, the live high-water
    mark, and the segment-sliced slot-space schedules.  Both streaming
    drivers — the single-host engine below and the device-sharded engine
    (``vecsim.shard.driver``) — go through this one class, so they
    activate, overflow and peak in byte-identical ways; only the span
    execution and the retirement *mechanics* differ between them.

    The broadcast stream is held in instance-owned ``bc_round`` /
    ``bc_origin`` arrays (views of the scenario arrays for the
    pre-scripted engines).  The live serving front door
    (``vecsim.live``) subclasses with a growable admitted buffer and
    appends broadcasts between segments; everything here routes through
    ``self.bc_round[:self.m_bc]`` so both cases share one code path.
    The global message-id space is split at ``m_app_cap``: app message
    ``i`` is id ``i``, link-addition ping ``e`` is id ``m_app_cap + e``.

    ``horizon`` mirrors the drivers' force-expiry knob: when set,
    :meth:`activate` additionally caps every segment at the earliest
    round a live column comes due for expiry (``birth + horizon + 1``),
    so the boundary retirement sweep lands *exactly* on the expiry
    round.  That makes expiry — and therefore overflow timing — a
    round-granular property of the scenario rather than an artifact of
    where the ``seg_len`` grid happens to fall; without it a longer
    segment kept overdue columns alive to the next boundary and could
    overflow a window a shorter segment squeezed through.
    """

    #: set by the live subclass: schedules may grow between segments,
    #: so drivers must not prefetch/cache segment schedules ahead.
    mutable_schedule = False

    def __init__(self, scn: VecScenario, window: int,
                 horizon: Optional[int] = None):
        self.scn = scn
        self.w = int(window)
        self.horizon = None if horizon is None else int(horizon)
        m_app = scn.m_app
        # Broadcast activation stream (round-sorted by scenario
        # construction).  Pre-scripted: a view of the scenario arrays,
        # fully scheduled up front (m_bc == m_app).
        self.bc_round = scn.bcast_round
        self.bc_origin = scn.bcast_origin
        self.m_bc = m_app           # broadcasts scheduled so far
        self.m_app_cap = m_app      # id split: ping e -> m_app_cap + e
        self.next_bc = 0            # first not-yet-activated broadcast
        self.next_add = 0           # first not-yet-activated addition
        self.peak_live = 0

        self.slot_msg = np.full(self.w, -1, np.int64)   # global id, -1 = free
        self.slot_birth = np.zeros(self.w, np.int32)    # activation round
        self.slot_app = np.zeros(self.w, bool)
        self.bc_live_slot = np.full(m_app, -1, np.int32)
        self.add_live_slot = np.full(scn.n_adds, -1, np.int32)

        # Round-sorted copies of the schedules so each segment slices
        # with two binary searches instead of an O(M_total) mask
        # (broadcasts are sorted by construction; churn/crash arrays are
        # sorted here once).  Stable sort keeps same-round relative
        # order, which the round body is insensitive to anyway
        # (same-round events commute).
        self.add_ord = np.argsort(scn.add_round, kind="stable")
        self.add_round_s = scn.add_round[self.add_ord]
        self.add_p_s = scn.add_p[self.add_ord]
        self.add_k_s = scn.add_k[self.add_ord]
        self.add_q_s = scn.add_q[self.add_ord]
        self.add_delay_s = scn.add_delay[self.add_ord]
        rm_ord = np.argsort(scn.rm_round, kind="stable")
        self.rm_round_s = scn.rm_round[rm_ord]
        self.rm_p_s, self.rm_k_s = scn.rm_p[rm_ord], scn.rm_k[rm_ord]
        cr_ord = np.argsort(scn.crash_round, kind="stable")
        self.cr_round_s = scn.crash_round[cr_ord]
        self.cr_pid_s = scn.crash_pid[cr_ord]

    def seg_schedule(self, lo: int, hi: int) -> SlotSchedule:
        b0, b1 = np.searchsorted(self.bc_round[: self.m_bc], [lo, hi])
        a0, a1 = np.searchsorted(self.add_round_s, [lo, hi])
        r0, r1 = np.searchsorted(self.rm_round_s, [lo, hi])
        c0, c1 = np.searchsorted(self.cr_round_s, [lo, hi])
        return SlotSchedule(
            is_app=self.slot_app,
            bc_round=self.bc_round[b0:b1],
            bc_origin=self.bc_origin[b0:b1],
            bc_slot=self.bc_live_slot[b0:b1],
            add_round=self.add_round_s[a0:a1],
            add_p=self.add_p_s[a0:a1], add_k=self.add_k_s[a0:a1],
            add_q=self.add_q_s[a0:a1],
            add_delay=self.add_delay_s[a0:a1],
            add_slot=self.add_live_slot[self.add_ord[a0:a1]],
            rm_round=self.rm_round_s[r0:r1],
            rm_p=self.rm_p_s[r0:r1], rm_k=self.rm_k_s[r0:r1],
            cr_round=self.cr_round_s[c0:c1],
            cr_pid=self.cr_pid_s[c0:c1])

    def segment_caps(self, total_rounds: int,
                     seg_len: int) -> Tuple[int, int, int, int]:
        """Per-segment event-count caps (broadcasts, adds, removals,
        crashes) so every padded segment schedule reuses one jitted
        trace."""
        scn = self.scn
        return (_window_caps(self.bc_round[: self.m_bc], total_rounds,
                             seg_len),
                _window_caps(scn.add_round, total_rounds, seg_len),
                _window_caps(scn.rm_round, total_rounds, seg_len),
                _window_caps(scn.crash_round, total_rounds, seg_len))

    def padded_schedule(self, lo: int, hi: int,
                        caps: Tuple[int, int, int, int]) -> SlotSchedule:
        """The segment schedule padded to ``caps`` with sentinel rounds
        (-2 never matches a real round), shared by both jitted drivers
        so the padding conventions cannot drift apart."""
        sched = self.seg_schedule(lo, hi)
        cap = dict(zip(("bc", "add", "rm", "cr"), caps))
        return SlotSchedule(is_app=sched.is_app, **{
            name: _pad(getattr(sched, name), cap[name.split("_", 1)[0]],
                       sched_sentinel(name))
            for name in STACKED_SCHED_FIELDS})

    def round_caps(self, total_rounds: int) -> Tuple[int, int, int, int]:
        """Per-*round* event-count caps (seg_len=1 segment caps): the
        row widths of the stacked scan inputs the scanned sharded
        runner consumes, constant over the whole run so every segment
        reuses one jitted trace."""
        return self.segment_caps(total_rounds, 1)

    def stacked_schedule(self, lo: int, hi: int,
                         caps: Tuple[int, int, int, int],
                         pad_rounds: int,
                         fields: Optional[frozenset] = None,
                         ) -> Dict[str, np.ndarray]:
        """The ``[lo, hi)`` segment schedule as stacked per-round scan
        inputs: each event field becomes a ``(pad_rounds, cap)`` array
        whose row ``i`` is the round ``lo + i`` schedule padded to the
        per-round ``caps`` (:meth:`round_caps`).  Rows past ``hi - lo``
        are all-sentinel (round -2 never matches), mirroring the ``ts``
        padding convention, so a ragged final segment scans the same
        trace as a full one.  ``is_app`` rides along unstacked.

        Built directly — one searchsorted per event family and one
        scatter per field into sentinel-filled ``(pad_rounds, cap)``
        buffers — instead of padding and stacking ``hi - lo`` per-round
        schedules, so staging a segment costs O(events), not
        O(seg_len · fields).  ``fields`` optionally restricts the output
        (the sharded driver prefetches the activation-independent
        fields of segment k+1 while segment k executes; ``bc_slot``,
        ``add_slot`` and ``is_app`` depend on column assignment and can
        only be staged after ``activate``)."""
        out: Dict[str, np.ndarray] = {}

        def fill(rs, cap, cols):
            names = [n for n in cols
                     if fields is None or n in fields]
            if not names:
                return
            i0, i1 = np.searchsorted(rs, [lo, hi])
            rnd = rs[i0:i1]
            row = rnd - lo
            # position within the round group = index minus the index
            # of the first event sharing the round (rs is sorted)
            pos = (np.arange(i0, i1)
                   - np.searchsorted(rs, rnd, side="left"))
            for name in names:
                src = cols[name]() if callable(cols[name]) else cols[name]
                buf = np.full((pad_rounds, cap), sched_sentinel(name),
                              src.dtype)
                buf[row, pos] = src[i0:i1]
                out[name] = buf

        fill(self.bc_round[: self.m_bc], caps[0], {
            "bc_round": self.bc_round, "bc_origin": self.bc_origin,
            "bc_slot": lambda: self.bc_live_slot})
        fill(self.add_round_s, caps[1], {
            "add_round": self.add_round_s, "add_p": self.add_p_s,
            "add_k": self.add_k_s, "add_q": self.add_q_s,
            "add_delay": self.add_delay_s,
            "add_slot": lambda: self.add_live_slot[self.add_ord]})
        fill(self.rm_round_s, caps[2], {
            "rm_round": self.rm_round_s, "rm_p": self.rm_p_s,
            "rm_k": self.rm_k_s})
        fill(self.cr_round_s, caps[3], {
            "cr_round": self.cr_round_s, "cr_pid": self.cr_pid_s})
        if fields is None or "is_app" in fields:
            out["is_app"] = self.slot_app
        return out

    def _assign(self, free: np.ndarray, nb_a: int, na_a: int) -> None:
        """Bind the next ``nb_a`` broadcasts and ``na_a`` additions to
        the leading free columns, in merged round order (broadcasts
        before additions on round ties, original index order within a
        kind — the stable lexsort is what keeps the column -> message
        mapping byte-identical run to run)."""
        n_assign = nb_a + na_a
        b0, a0 = self.next_bc, self.next_add
        r_all = np.concatenate([
            self.bc_round[b0: b0 + nb_a],
            self.add_round_s[a0: a0 + na_a]]).astype(np.int64)
        kind = np.zeros(n_assign, np.int8)
        kind[nb_a:] = 1
        order = np.lexsort((kind, r_all))
        col = np.empty(n_assign, np.int64)
        col[order] = free[:n_assign]
        bc_cols, add_cols = col[:nb_a], col[nb_a:]
        bc_ids = np.arange(b0, b0 + nb_a)
        self.slot_msg[bc_cols] = bc_ids
        self.slot_birth[bc_cols] = self.bc_round[b0: b0 + nb_a]
        self.slot_app[bc_cols] = True
        self.bc_live_slot[bc_ids] = bc_cols
        add_idx = self.add_ord[a0: a0 + na_a]
        self.slot_msg[add_cols] = self.m_app_cap + add_idx
        self.slot_birth[add_cols] = self.add_round_s[a0: a0 + na_a]
        self.slot_app[add_cols] = False
        self.add_live_slot[add_idx] = add_cols
        self.next_bc = b0 + nb_a
        self.next_add = a0 + na_a

    def activate(self, t: int, t_end: int) -> int:
        """Assign free columns to events due before ``t_end``; returns
        the (possibly shortened) segment end.  Raises
        :class:`WindowOverflowError` when the buffer is already full at
        ``t`` with an event due — *before* touching any state, so the
        window is re-enterable after a catch (the live loop's
        backpressure path).  Also tracks the live high-water mark.

        When a horizon is set the returned segment end is additionally
        capped at the earliest expiry-due round of any live column
        (``min birth + horizon + 1``), so the boundary retirement sweep
        fires force-expiries at exactly their due round — expiry (and
        with it overflow) timing is then identical for every ``seg_len``
        choice, which is what lets the fuzz suite assert full
        seg_len-invariance instead of skipping overflowing draws.
        """
        b_hi = self.next_bc + int(np.searchsorted(
            self.bc_round[self.next_bc: self.m_bc], t_end))
        a_hi = int(np.searchsorted(self.add_round_s, t_end))
        nb, na = b_hi - self.next_bc, a_hi - self.next_add
        if nb or na:
            free = np.nonzero(self.slot_msg < 0)[0]
            kfree = len(free)
            nb_a, na_a = nb, na
            if nb + na > kfree:
                # The merged stream blocks: find the round of the first
                # event that does not fit BEFORE mutating anything, so
                # an overflow raise leaves the window untouched.  The
                # (kfree+1)-th smallest merged (round, kind) key lives
                # within the first kfree+1 events of each stream, so
                # the scratch stays O(W) even with a deep backlog.
                bs = self.bc_round[
                    self.next_bc: min(b_hi, self.next_bc + kfree + 1)]
                as_ = self.add_round_s[
                    self.next_add: min(a_hi, self.next_add + kfree + 1)]
                keys = np.concatenate([bs.astype(np.int64) * 2,
                                       as_.astype(np.int64) * 2 + 1])
                keys.sort()
                blocked_key = int(keys[kfree])
                blocked_at = blocked_key >> 1
                if blocked_at <= t:
                    raise WindowOverflowError(
                        f"window={self.w} cannot hold the live messages "
                        f"at round {t} "
                        f"({int((self.slot_msg >= 0).sum())} live, "
                        f"next event needs a free column); raise the "
                        f"window or set a horizon", round=t)
                # stop the segment just before the first blocked event
                # and retry after the next retirement sweep; everything
                # earlier in the merged order still fits.
                t_end = blocked_at
                if blocked_key & 1:      # first blocked event is an add
                    nb_a = int(np.searchsorted(bs, blocked_at,
                                               side="right"))
                    na_a = kfree - nb_a
                else:                    # first blocked event: broadcast
                    na_a = int(np.searchsorted(as_, blocked_at,
                                               side="left"))
                    nb_a = kfree - na_a
            if nb_a + na_a:
                self._assign(free, nb_a, na_a)
        live = self.slot_msg >= 0
        if self.horizon is not None and live.any():
            # land the next boundary exactly on the earliest expiry-due
            # round (always > t: anything due at t expired in the sweep
            # that closed the previous segment)
            expiry_due = int(self.slot_birth[live].min()) + self.horizon + 1
            if expiry_due < t_end:
                t_end = expiry_due
        self.peak_live = max(self.peak_live, int(live.sum()))
        return t_end

    def live_cols(self) -> np.ndarray:
        return np.nonzero(self.slot_msg >= 0)[0]

    def free_cols(self, cols: np.ndarray) -> None:
        self.slot_msg[cols] = -1


class WindowedStepper:
    """The windowed engine, one segment per :meth:`advance` call.

    Holds everything :func:`execute_windowed` used to keep in closure
    scope — topology state, the :class:`ColumnWindow`, per-message
    aggregates, the per-round series — and exposes the segment loop as
    an explicit stepper so the live serving front door can interleave
    admission control between segments while running byte-identical
    engine code.  ``cw`` optionally supplies an externally-built
    window (the live loop passes its growable subclass).
    """

    def __init__(self, scn: VecScenario, window: int, backend: str = "auto",
                 horizon: Optional[int] = None, seg_len: int = 32,
                 snapshot_round: Optional[int] = None,
                 collect: str = "auto",
                 cw: Optional[ColumnWindow] = None,
                 obs=None):
        from ...obs.spans import NULL_RECORDER
        self.backend = backend = resolve_backend(backend)
        # telemetry (repro.obs): histogram folding happens at column
        # retirement on the host planes, spans wrap the segment phases
        self.obs = obs
        self.hist = obs is not None and obs.histograms
        self._rec = obs.spans if obs is not None else NULL_RECORDER
        self._sid = {name: self._rec.name(f"segment.{name}")
                     for name in ("dispatch", "retire")}
        # flight recorder (repro.obs.flight): host-side provenance
        # hooks; None keeps every path a plain attribute test
        self._flight = getattr(obs, "flight", None)
        self.w = w = int(window)
        if w < 1:
            raise ValueError("window must be >= 1")
        self.seg_len = seg_len = max(1, int(seg_len))
        self.scn = scn
        self.horizon = None if horizon is None else int(horizon)
        self.snapshot_round = snapshot_round
        self.rounds = scn.rounds
        self.pc = scn.mode == "pc"
        # gates only ever open at link additions, so a scenario with
        # none can skip the pong/flush phases in every segment
        self.gating = scn.n_adds > 0

        self.cw = cw if cw is not None else ColumnWindow(
            scn, w, horizon=horizon)
        # the id space is the window's (the live subclass reserves
        # capacity beyond the scenario's pre-scripted broadcasts)
        self.m_app = self.cw.m_app_cap
        self.m_total = self.m_app + scn.n_adds
        n = scn.n
        if collect == "auto":
            collect = ("full" if n * max(self.m_total, 1) <= (1 << 26)
                       else "aggregate")
        if collect not in ("full", "aggregate"):
            raise ValueError(f"unknown collect mode {collect!r}")
        self.collect = collect

        self.st = init_topo_state(scn, w)
        self.series = np.zeros((self.rounds, len(SERIES_FIELDS)), np.int64)
        self.delivered_full = (np.full((n, self.m_total), -1, np.int32)
                               if collect == "full" else None)
        self.deliv_count = np.zeros(self.m_total, np.int64)
        self.deliv_round_sum = np.zeros(self.m_total, np.int64)
        self.bcast_done = np.zeros(self.m_app, bool)
        self.expired = np.zeros(self.m_total, bool)
        self.first_receipts = 0
        self.lat_sum = 0
        self.lat_cnt = 0
        self.snapshot: Optional[Dict[str, np.ndarray]] = None
        self.t = 0

        if backend in ("jax", "pallas"):
            import jax.numpy as jnp

            from .sim import (sched_to_device, span_runner_for,
                              state_to_device, state_to_host)
            self._jnp = jnp
            self._sched_to_device = sched_to_device
            self._state_to_device = state_to_device
            self._state_to_host = state_to_host
            self._caps = self.cw.segment_caps(self.rounds, seg_len)
            self._runner = span_runner_for(backend)(
                scn.k, self.pc, scn.always_gate, scn.pong_delay,
                gating=self.gating)

    @property
    def done(self) -> bool:
        return self.t >= self.rounds

    def _run_segment(self, lo: int, hi: int) -> None:
        scn, st = self.scn, self.st
        if self.backend == "numpy":
            np_span(st, self.cw.seg_schedule(lo, hi), lo, hi, self.series,
                    pc=self.pc, always_gate=scn.always_gate,
                    pong_delay=scn.pong_delay, gating=self.gating)
            return
        padded = self.cw.padded_schedule(lo, hi, self._caps)
        ts = np.full(self.seg_len, -3, np.int32)
        ts[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
        # The full state round-trips host<->device each segment so the
        # retirement sweep can run in numpy — a memcpy on the CPU
        # backend this targets today.  On a real accelerator the copy
        # of arr/delivered would dominate; moving the retirement
        # reductions and column resets device-side (pulling only the
        # (W,) retire mask) is the known next optimization.
        state, stats = self._runner(self._state_to_device(st),
                                    self._sched_to_device(padded),
                                    self._jnp.asarray(ts))
        st.update(self._state_to_host(state))
        self.series[lo:hi] = np.asarray(stats, np.int64)[: hi - lo]

    def _record_and_free(self, cols: np.ndarray, by_expiry: np.ndarray,
                         red=None, t_now: Optional[int] = None) -> None:
        """Fold retired columns into the aggregates and recycle them.
        When the pallas retirement sweep already reduced the planes
        (``red`` = the :func:`kernels.retire_reduce` columns), the
        delivery counts, first receipts and latency sums come from
        those five scalars per column instead of fresh plane reads."""
        if not len(cols):
            return
        st, cw = self.st, self.cw
        ids = cw.slot_msg[cols]
        d = st["delivered"][:, cols]
        app = cw.slot_app[cols]
        if red is None:
            d64 = d.astype(np.int64)
            self.deliv_count[ids] = (d >= 0).sum(axis=0)
            self.deliv_round_sum[ids] = np.where(d >= 0, d64, 0).sum(axis=0)
            self.first_receipts += int((st["arr"][:, cols]
                                        < self.rounds).sum())
            if app.any():
                da = d[:, app]
                got = da >= 0
                self.lat_sum += int(
                    (da - cw.slot_birth[cols][app][None, :])[got].sum())
                self.lat_cnt += int(got.sum())
        else:
            cnt, arrcnt, sumdel = (x.astype(np.int64) for x in red)
            self.deliv_count[ids] = cnt[cols]
            self.deliv_round_sum[ids] = sumdel[cols]
            self.first_receipts += int(arrcnt[cols].sum())
            if app.any():
                acols = cols[app]
                births = cw.slot_birth[acols].astype(np.int64)
                self.lat_sum += int((sumdel[acols]
                                     - cnt[acols] * births).sum())
                self.lat_cnt += int(cnt[acols].sum())
        if self.hist and app.any():
            # latency histogram fold (repro.obs): once per column, at
            # retirement, before the plane wipe below recycles it.  The
            # base is the column birth round (batch latency convention)
            # or the live loop's per-message submission round.
            acols = cols[app]
            lb = self.obs.latency_base
            base = np.asarray(lb[ids[app]] if lb is not None
                              else cw.slot_birth[acols], np.int64)
            da = d[:, app]
            if self.backend == "pallas":
                from . import kernels as kx
                h = np.asarray(kx.latency_hist_jit()(
                    base.astype(np.int32), da), np.int64)
                self.obs.add_hist(h.sum(axis=0))
            else:
                from ...obs.hist import hist_np
                valid = (da >= 0) & (base >= 0)[None, :]
                self.obs.add_hist(hist_np(
                    (da.astype(np.int64) - base[None, :])[valid]))
        fl = self._flight
        if fl is not None and fl.open_count and app.any():
            # sampled provenance: hand the per-receiver delivery rounds
            # of retiring sampled app columns to the flight recorder
            # while the delivered plane is still intact
            aidx = ids[app]
            m = fl.sampled_mask(aidx)
            if m.any():
                fl.on_retire(aidx[m], d[:, app][:, m],
                             self.t if t_now is None else t_now,
                             by_expiry[app][m])
        self.expired[ids] |= by_expiry
        if app.any():
            st["ever_del"] |= (d[:, app] >= 0).any(axis=1)
            aidx = ids[app]
            self.bcast_done[aidx] = (
                st["delivered"][cw.bc_origin[aidx], cols[app]] >= 0)
        if self.delivered_full is not None:
            self.delivered_full[:, ids] = d
        st["arr"][:, cols] = INF
        st["delivered"][:, cols] = -1
        cw.slot_msg[cols] = -1

    def _retire(self, t_now: int) -> int:
        """Retire every column the monolithic run could no longer touch
        (plus horizon expiries); returns how many were freed."""
        st, cw, w = self.st, self.cw, self.w
        slot_msg, slot_birth, slot_app = (cw.slot_msg, cw.slot_birth,
                                          cw.slot_app)
        live = slot_msg >= 0
        if not live.any():
            return 0
        delivered, gate, ping = st["delivered"], st["gate"], st["ping"]
        flush, crashed, active = st["flush"], st["crashed"], st["active"]
        alive = ~crashed
        gated = (gate >= 0) & active & ~crashed[:, None]
        red = None
        if self.backend == "pallas":
            # The retirement-reduce kernel folds the per-column
            # reductions — total / alive-row delivery counts,
            # gate-window blockers, plus the record-side first-receipt
            # counts and delivered-round sums — into one pass over the
            # live planes; the retirement *decisions* stay host-side,
            # identically to the numpy path, and ``_record_and_free``
            # consumes the same reduction instead of re-reading planes.
            from . import kernels as kx
            min_gate = np.where(gated, gate, INF).min(axis=1)
            cnt, alivedel, blockcnt, arrcnt, sumdel = (
                np.asarray(x)
                for x in kx.retire_reduce_jit()(st["arr"], delivered,
                                                crashed, min_gate,
                                                self.rounds))
            red = (cnt, arrcnt, sumdel)
            full_del = alivedel == int(alive.sum())
            blocked = (blockcnt > 0) & slot_app
        else:
            full_del = (delivered[alive] >= 0).all(axis=0)
            cnt = (delivered >= 0).sum(axis=0)
            if gated.any():
                min_gate = np.where(gated, gate, INF).min(axis=1)
                blocked = (((delivered >= 0)
                            & (delivered >= min_gate[:, None])).any(axis=0)
                           & slot_app)
            else:
                blocked = np.zeros(w, bool)
        ref = np.zeros(w, bool)
        pv = ping[(ping >= 0) & ~crashed[:, None]]
        ref[pv] = True
        dead = (cnt == 0) & (slot_birth < t_now)
        done = live & ~ref & ((full_del & ~blocked) | dead)
        by_exp = np.zeros(w, bool)
        if self.horizon is not None:
            by_exp = live & ~done & (t_now - slot_birth > self.horizon)
            hung = by_exp & ref
            if hung.any():
                # a gate whose ping column is being force-expired can
                # never resolve (its pong will never be observed): clear
                # it so the link goes safe and the slot stops pinning
                # the column — the buffered messages it would have
                # flushed are dropped, which is the documented price of
                # the horizon.
                sel = (ping >= 0) & hung[np.clip(ping, 0, w - 1)]
                gate[sel], flush[sel], ping[sel] = -1, INF, -1
            done |= by_exp
        fl = self._flight
        if fl is not None and fl.open_count:
            blk = np.nonzero(live & blocked & ~done)[0]
            if len(blk):
                bids = slot_msg[blk]
                m = fl.sampled_mask(bids)
                if m.any():
                    fl.on_blocked(bids[m], t_now)
        cols = np.nonzero(done)[0]
        self._record_and_free(cols, by_exp[cols], red, t_now)
        return len(cols)

    def advance(self) -> int:
        """Run one segment (activate -> span -> retire); returns the new
        current round.  May raise :class:`WindowOverflowError` from
        ``activate`` with the engine state untouched since the previous
        segment boundary."""
        t = self.t
        if t >= self.rounds:
            return t
        t_end = min(t + self.seg_len, self.rounds)
        if self.snapshot_round is not None and t <= self.snapshot_round:
            t_end = min(t_end, self.snapshot_round + 1)
        # Activate events due before t_end while free columns last.
        b0 = self.cw.next_bc
        t_end = self.cw.activate(t, t_end)
        fl = self._flight
        if fl is not None and self.cw.next_bc > b0:
            b1 = self.cw.next_bc
            fl.on_activate(np.arange(b0, b1), self.cw.bc_origin[b0:b1],
                           self.cw.bc_round[b0:b1])
        self._rec.begin(self._sid["dispatch"])
        self._run_segment(t, t_end)
        self._rec.end()
        if (self.snapshot_round is not None
                and t_end - 1 == self.snapshot_round):
            self.snapshot = {key: v.copy() for key, v in self.st.items()}
            self.snapshot["is_app"] = self.cw.slot_app.copy()
            self.snapshot["slot_msg"] = self.cw.slot_msg.copy()
        self._rec.begin(self._sid["retire"])
        self._retire(t_end)
        self._rec.end()
        if self.obs is not None:
            seg = self.series[t:t_end]
            self.obs.gauge("piggyback_bytes",
                           16 * int(seg[:, 1].sum() + seg[:, 3].sum())
                           + 24 * int(seg[:, 2].sum()))
            self.obs.gauge("window_occupancy",
                           int((self.cw.slot_msg >= 0).sum()))
        self.t = t_end
        return t_end

    def finish(self) -> WindowedRunResult:
        """Drain still-live columns and build the run result.  Whatever
        is still live keeps its end-of-run values, exactly like the
        monolithic matrices at ``t == rounds``."""
        live_cols = np.nonzero(self.cw.slot_msg >= 0)[0]
        self._record_and_free(live_cols, np.zeros(len(live_cols), bool))
        stats = stats_from_series(self.series, self.first_receipts)
        return WindowedRunResult(
            scenario=self.scn, window=self.w, backend=self.backend,
            stats=stats, series=self.series, delivered=self.delivered_full,
            deliv_count=self.deliv_count, bcast_done=self.bcast_done,
            expired=self.expired, state=self.st, snapshot=self.snapshot,
            peak_live=self.cw.peak_live, lat_sum=self.lat_sum,
            lat_cnt=self.lat_cnt, deliv_round_sum=self.deliv_round_sum)


def execute_windowed(scn: VecScenario, window: int, backend: str = "auto",
                     horizon: Optional[int] = None, seg_len: int = 32,
                     snapshot_round: Optional[int] = None,
                     collect: str = "auto",
                     obs=None) -> WindowedRunResult:
    """Run ``scn`` through a ``window``-column streaming buffer.

    ``horizon`` — force-retire columns older than this many rounds
    (default: never; exactness preserved).  ``seg_len`` — rounds per
    jitted segment between retirement sweeps (also bounds how long a
    finished column lingers before its slot recycles).  ``collect`` —
    ``"full"`` keeps the (N, M_total) delivered matrix, ``"aggregate"``
    keeps only per-message counters, ``"auto"`` picks by size.

    This is the engine implementation behind ``repro.api.run``; prefer
    the front door (``repro.api.run(RunSpec(...))``) in new code."""
    stepper = WindowedStepper(scn, window, backend=backend, horizon=horizon,
                              seg_len=seg_len, snapshot_round=snapshot_round,
                              collect=collect, obs=obs)
    while not stepper.done:
        stepper.advance()
    return stepper.finish()


def run_vec_windowed(scn: VecScenario, window: int, backend: str = "auto",
                     horizon: Optional[int] = None, seg_len: int = 32,
                     snapshot_round: Optional[int] = None,
                     collect: str = "auto") -> WindowedRunResult:
    """Legacy entry point — identical signature and behavior to
    :func:`execute_windowed`, which it delegates to after emitting a
    :class:`~repro.core.types.LegacyEntryPointWarning`.  New code goes
    through the one front door: ``repro.api.run(RunSpec(...))``."""
    warnings.warn(
        "run_vec_windowed is a legacy entry point; use "
        "repro.api.run(RunSpec(...)) (see DESIGN.md §3)",
        LegacyEntryPointWarning, stacklevel=2)
    return execute_windowed(scn, window, backend=backend, horizon=horizon,
                            seg_len=seg_len, snapshot_round=snapshot_round,
                            collect=collect)
