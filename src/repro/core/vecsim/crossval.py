"""Cross-validation harness: one scenario, two engines, same deliveries.

``run_exact`` replays a :class:`VecScenario` event-by-event on the exact
discrete-event simulator (``repro.core.events.Network`` driving the
paper-faithful ``PCBroadcast``/``RBroadcast`` processes), mapping

  * one lockstep round              -> one unit of simulated time,
  * a slot's integer delay          -> a constant link delay,
  * the scenario's add/rm/crash/broadcast schedule -> ``call_later``
    callbacks registered in phase order (removals, additions, crashes,
    broadcasts) so same-timestamp events fire in the lockstep engine's
    phase order (setup-registered callbacks outrank in-flight arrivals
    in the event heap's tie-break).

``cross_validate`` then runs both engines to quiescence and compares the
(pid, origin, counter) delivered-message multisets byte-for-byte, plus
happens-before oracle reports on both traces.  Equality of the multisets
is a strong end-to-end check: it requires both engines to agree on which
broadcasts happened, which processes were reachable, and that neither
lost or duplicated a delivery — while leaving the engines free to differ
in sub-round timing, which the lockstep model deliberately does not
reproduce (DESIGN.md §2.4).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..events import Network
from ..oracle import OracleReport, check_trace
from ..pcbroadcast import PCBroadcast
from ..rbroadcast import RBroadcast
from ..vector_clock import VCBroadcast
from .metrics import build_trace, delivered_multiset
from .scenario import VecScenario
from .sim import VecRunResult, execute_vec
from .vc import run_vec_vc

__all__ = ["run_exact", "delivered_multiset_exact", "final_clocks_exact",
           "cross_validate"]


def run_exact(scn: VecScenario, seed: int = 0,
              protocol: Optional[str] = None,
              snapshot_round: Optional[int] = None) -> Network:
    """Replay ``scn`` on the exact event simulator and run to quiescence.

    ``protocol`` — ``"pc"``/``"r"``/``"vc"``; defaults to ``scn.mode``.
    ``"vc"`` runs the vector-clock baseline (``core.vector_clock``), for
    which the link-safety schedule fields are plain topology changes.

    ``snapshot_round`` — capture the Fig. 7 graph metrics right after
    that round (at sim time ``snapshot_round + 0.5``, i.e. after every
    integer-time event of the round) onto ``net.snapshot_graphs``:
    ``{"safe": .., "full": .., "unsafe": unsafe_link_stats tuple}`` —
    the exact-engine twin of the vec engines' state snapshot."""
    protocol = scn.mode if protocol is None else protocol
    net = Network(seed=seed, default_delay=1.0,
                  oob_delay=float(scn.pong_delay))
    for pid in range(scn.n):
        if protocol == "pc":
            proc = PCBroadcast(pid, ping_mode="flood",
                               always_gate=scn.always_gate)
        elif protocol == "vc":
            proc = VCBroadcast(pid)
        elif protocol == "r":
            proc = RBroadcast(pid)
        else:
            raise ValueError(f"unknown protocol {protocol!r}")
        net.add_process(proc)
    for p in range(scn.n):
        for kk in range(scn.k):
            q = int(scn.adj0[p, kk])
            if q >= 0:
                net.connect(p, q, delay=float(scn.delay0[p, kk]))

    # Replay slot occupancy so each vec slot removal maps to the one
    # (p, q) link it deactivates at that point in time.
    slot_target = scn.adj0.astype(np.int64).copy()
    slot_active = scn.adj0 >= 0

    def do_broadcast(o: int) -> None:
        proc = net.procs[o]
        if not proc.crashed:
            proc.broadcast()

    events = sorted(
        [(int(t), 0, e) for e, t in enumerate(scn.rm_round)]
        + [(int(t), 1, e) for e, t in enumerate(scn.add_round)]
        + [(int(t), 2, e) for e, t in enumerate(scn.crash_round)]
        + [(int(t), 3, i) for i, t in enumerate(scn.bcast_round)],
        key=lambda ev: (ev[0], ev[1], ev[2]))
    for t, phase, e in events:
        if phase == 0:
            p, kk = int(scn.rm_p[e]), int(scn.rm_k[e])
            if slot_active[p, kk]:
                q = int(slot_target[p, kk])
                slot_active[p, kk] = False
                net.call_later(float(t), lambda p=p, q=q: net.disconnect(p, q))
        elif phase == 1:
            p, kk, q = (int(scn.add_p[e]), int(scn.add_k[e]),
                        int(scn.add_q[e]))
            d = float(scn.add_delay[e])
            slot_target[p, kk] = q
            slot_active[p, kk] = True
            net.call_later(float(t),
                           lambda p=p, q=q, d=d: net.connect(p, q, delay=d))
        elif phase == 2:
            pid = int(scn.crash_pid[e])
            net.call_later(float(t), lambda pid=pid: net.crash(pid))
        else:
            net.call_later(float(t), lambda o=int(scn.bcast_origin[e]):
                           do_broadcast(o))
    if snapshot_round is not None:
        from ..metrics import full_graph, safe_graph, unsafe_link_stats

        def capture():
            net.snapshot_graphs = dict(safe=safe_graph(net),
                                       full=full_graph(net),
                                       unsafe=unsafe_link_stats(net))
        net.call_later(float(snapshot_round) + 0.5, capture)
    net.run()
    assert net.idle(), "exact replay did not quiesce"
    return net


def delivered_multiset_exact(net: Network) -> List[Tuple[int, int, int]]:
    """Sorted (pid, origin, counter) triples from the exact engine's logs."""
    out = [(pid, m.origin, m.counter)
           for pid, proc in net.procs.items()
           for m in proc.delivered_log]
    out.sort()
    return out


def final_clocks_exact(net: Network) -> List[Dict[int, int]]:
    """Per-process ``VCBroadcast.vc`` dicts (pid order) from an exact
    vector-clock replay, for byte-level clock cross-validation."""
    return [dict(net.procs[pid].vc) for pid in sorted(net.procs)]


def cross_validate(scn: VecScenario, seed: int = 0,
                   backend: str = "numpy",
                   window: Optional[int] = None,
                   protocol: Optional[str] = None,
                   vec_result=None) -> Dict[str, object]:
    """Run both engines on ``scn``; return multisets + oracle reports.
    ``window`` routes the vec run through the streaming windowed engine
    (with the full delivered matrix collected), so windowed execution is
    cross-validated against the exact simulator the same way.
    ``protocol`` defaults to ``scn.mode``; ``"vc"`` cross-validates the
    vectorized vector-clock baseline (``vecsim.vc``) against
    ``core.vector_clock`` — the result then additionally carries
    ``vec_clocks``/``exact_clocks`` (per-process final clock dicts),
    which must be byte-identical.

    ``vec_result`` — a vec-engine result of the *same scenario* already
    in hand (it must carry the full delivered matrix); skips the vec
    re-execution, leaving only the exact replay to run."""
    protocol = scn.mode if protocol is None else protocol
    if vec_result is not None and vec_result.delivered is not None:
        res = vec_result
    elif protocol == "vc":
        if window is not None:
            raise ValueError("the vector-clock vec engine has no windowed "
                             "mode (its buffers are O(N·m_app) already)")
        res = run_vec_vc(scn)
    else:
        res = execute_vec(scn, backend=backend, window=window,
                          collect=None if window is None else "full")
    net = run_exact(scn, seed=seed, protocol=protocol)
    crashed: Set[int] = set(np.nonzero(res.state["crashed"])[0].tolist())
    vec_rep = check_trace(build_trace(res), crashed=crashed,
                          all_pids=set(range(scn.n)))
    exact_rep = check_trace(net.trace, crashed=crashed,
                            all_pids=set(range(scn.n)))
    out = dict(
        vec=res,
        exact=net,
        vec_multiset=delivered_multiset(res),
        exact_multiset=delivered_multiset_exact(net),
        vec_report=vec_rep,
        exact_report=exact_rep,
    )
    if protocol == "vc":
        out["vec_clocks"] = res.final_clocks()
        out["exact_clocks"] = final_clocks_exact(net)
    return out
