"""Cross-validation harness: one scenario, two engines, same deliveries.

``run_exact`` replays a :class:`VecScenario` event-by-event on the exact
discrete-event simulator (``repro.core.events.Network`` driving the
paper-faithful ``PCBroadcast``/``RBroadcast`` processes), mapping

  * one lockstep round              -> one unit of simulated time,
  * a slot's integer delay          -> a constant link delay,
  * the scenario's add/rm/crash/broadcast schedule -> ``call_later``
    callbacks registered in phase order (removals, additions, crashes,
    broadcasts) so same-timestamp events fire in the lockstep engine's
    phase order (setup-registered callbacks outrank in-flight arrivals
    in the event heap's tie-break).

``cross_validate`` then runs both engines to quiescence and compares the
(pid, origin, counter) delivered-message multisets byte-for-byte, plus
happens-before oracle reports on both traces.  Equality of the multisets
is a strong end-to-end check: it requires both engines to agree on which
broadcasts happened, which processes were reachable, and that neither
lost or duplicated a delivery — while leaving the engines free to differ
in sub-round timing, which the lockstep model deliberately does not
reproduce (DESIGN.md §2.4).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..events import Network
from ..oracle import OracleReport, check_trace
from ..pcbroadcast import PCBroadcast
from ..rbroadcast import RBroadcast
from .metrics import build_trace, delivered_multiset
from .scenario import VecScenario
from .sim import VecRunResult, run_vec

__all__ = ["run_exact", "delivered_multiset_exact", "cross_validate"]


def run_exact(scn: VecScenario, seed: int = 0) -> Network:
    """Replay ``scn`` on the exact event simulator and run to quiescence."""
    net = Network(seed=seed, default_delay=1.0,
                  oob_delay=float(scn.pong_delay))
    for pid in range(scn.n):
        if scn.mode == "pc":
            proc = PCBroadcast(pid, ping_mode="flood",
                               always_gate=scn.always_gate)
        else:
            proc = RBroadcast(pid)
        net.add_process(proc)
    for p in range(scn.n):
        for kk in range(scn.k):
            q = int(scn.adj0[p, kk])
            if q >= 0:
                net.connect(p, q, delay=float(scn.delay0[p, kk]))

    # Replay slot occupancy so each vec slot removal maps to the one
    # (p, q) link it deactivates at that point in time.
    slot_target = scn.adj0.astype(np.int64).copy()
    slot_active = scn.adj0 >= 0

    def do_broadcast(o: int) -> None:
        proc = net.procs[o]
        if not proc.crashed:
            proc.broadcast()

    events = sorted(
        [(int(t), 0, e) for e, t in enumerate(scn.rm_round)]
        + [(int(t), 1, e) for e, t in enumerate(scn.add_round)]
        + [(int(t), 2, e) for e, t in enumerate(scn.crash_round)]
        + [(int(t), 3, i) for i, t in enumerate(scn.bcast_round)],
        key=lambda ev: (ev[0], ev[1], ev[2]))
    for t, phase, e in events:
        if phase == 0:
            p, kk = int(scn.rm_p[e]), int(scn.rm_k[e])
            if slot_active[p, kk]:
                q = int(slot_target[p, kk])
                slot_active[p, kk] = False
                net.call_later(float(t), lambda p=p, q=q: net.disconnect(p, q))
        elif phase == 1:
            p, kk, q = (int(scn.add_p[e]), int(scn.add_k[e]),
                        int(scn.add_q[e]))
            d = float(scn.add_delay[e])
            slot_target[p, kk] = q
            slot_active[p, kk] = True
            net.call_later(float(t),
                           lambda p=p, q=q, d=d: net.connect(p, q, delay=d))
        elif phase == 2:
            pid = int(scn.crash_pid[e])
            net.call_later(float(t), lambda pid=pid: net.crash(pid))
        else:
            net.call_later(float(t), lambda o=int(scn.bcast_origin[e]):
                           do_broadcast(o))
    net.run()
    assert net.idle(), "exact replay did not quiesce"
    return net


def delivered_multiset_exact(net: Network) -> List[Tuple[int, int, int]]:
    """Sorted (pid, origin, counter) triples from the exact engine's logs."""
    out = [(pid, m.origin, m.counter)
           for pid, proc in net.procs.items()
           for m in proc.delivered_log]
    out.sort()
    return out


def cross_validate(scn: VecScenario, seed: int = 0,
                   backend: str = "numpy",
                   window: Optional[int] = None) -> Dict[str, object]:
    """Run both engines on ``scn``; return multisets + oracle reports.
    ``window`` routes the vec run through the streaming windowed engine
    (with the full delivered matrix collected), so windowed execution is
    cross-validated against the exact simulator the same way."""
    res = run_vec(scn, backend=backend, window=window,
                  collect=None if window is None else "full")
    net = run_exact(scn, seed=seed)
    crashed: Set[int] = set(np.nonzero(res.state["crashed"])[0].tolist())
    vec_rep = check_trace(build_trace(res), crashed=crashed,
                          all_pids=set(range(scn.n)))
    exact_rep = check_trace(net.trace, crashed=crashed,
                            all_pids=set(range(scn.n)))
    return dict(
        vec=res,
        exact=net,
        vec_multiset=delivered_multiset(res),
        exact_multiset=delivered_multiset_exact(net),
        vec_report=vec_rep,
        exact_report=exact_rep,
    )
