"""repro.core.vecsim — vectorized large-N protocol simulation.

The exact event simulator (``repro.core.events``) keeps one Python object
and one heap event per process/message and tops out around a few thousand
processes.  This package represents the *whole network* as dense arrays —
per-process delivery rounds, ``(N, K)`` link-slot tables, ping-phase state
— and advances all processes in lockstep rounds with ``jax.numpy`` (jitted
``lax.scan``) or a NumPy fallback, reaching N = 50k-100k processes on one
CPU: the population sizes at which the paper's constant-size control
information actually separates from the O(N) vector-clock baseline.

Modules:
  scenario  — preplanned runs (topology + broadcast/churn/crash schedules)
  sim       — the lockstep engine, both backends, NetStats emission
  metrics   — Fig. 7 metrics, oracle-compatible traces, multisets
  crossval  — replay the same scenario on the exact engine and compare

Semantics and fidelity limits vs. the exact simulator: DESIGN.md §2.4.
"""

from .crossval import cross_validate, delivered_multiset_exact, run_exact
from .metrics import (build_trace, delivered_multiset, full_out_mask,
                      mean_shortest_path_vec, safe_out_mask,
                      unsafe_link_stats_vec, vc_overhead_model)
from .scenario import (INF, VecScenario, churn_scenario, crash_scenario,
                       link_add_scenario, ring_topology, settle_rounds,
                       static_scenario)
from .sim import SERIES_FIELDS, VecRunResult, run_vec

__all__ = [
    "INF", "VecScenario", "ring_topology", "settle_rounds",
    "static_scenario", "link_add_scenario", "churn_scenario",
    "crash_scenario",
    "SERIES_FIELDS", "VecRunResult", "run_vec",
    "safe_out_mask", "full_out_mask", "mean_shortest_path_vec",
    "unsafe_link_stats_vec", "build_trace", "delivered_multiset",
    "vc_overhead_model",
    "run_exact", "delivered_multiset_exact", "cross_validate",
]
