"""repro.core.vecsim — vectorized large-N protocol simulation.

The exact event simulator (``repro.core.events``) keeps one Python object
and one heap event per process/message and tops out around a few thousand
processes.  This package represents the *whole network* as dense arrays —
per-process delivery rounds, ``(N, K)`` link-slot tables, ping-phase state
— and advances all processes in lockstep rounds with ``jax.numpy`` (jitted
``lax.scan``) or a NumPy fallback, reaching N = 50k-100k processes on one
CPU: the population sizes at which the paper's constant-size control
information actually separates from the O(N) vector-clock baseline.

For *sustained* traffic the monolithic ``(N, M)`` matrices are replaced by
the streaming windowed engine (``stream``): messages flow through a fixed
O(N·W) live-column buffer and retire into online aggregates once nothing
can touch them, so one host sustains millions of broadcasts at N ≥ 10k.

Modules:
  scenario  — preplanned runs (topologies + broadcast/churn/crash/traffic
              schedules: ring/k-regular/small-world, Poisson/bursty load,
              partition-heal, churn waves, sustained streams)
  sim       — the lockstep engine, numpy/jax/pallas backends, NetStats
              emission
  stream    — streaming windowed execution in O(N·window) memory
  live      — the open-loop serving front door over the windowed and
              sharded engines: bounded ingest queue, arrival processes,
              admission policies, backpressure via the window-occupancy
              signal, rounds-to-delivery latency SLOs (DESIGN.md §2.9)
  kernels   — fused Pallas delivery-sweep kernels behind
              ``backend="pallas"`` (kernel/ops/ref layout, interpret
              mode on CPU; DESIGN.md §2.6)
  shard     — the windowed engine partitioned over a JAX device mesh
              (shard_map row-blocks + per-round frontier exchange): the
              process axis stops being single-host, N reaches 10^6+
              (DESIGN.md §2.5; benchmarks/bench_scale.py)
  vc        — the vector-clock baseline, vectorized and measured
              (Table 1's O(N)/O(W·N) columns; DESIGN.md §3.4)
  metrics   — Fig. 7 metrics, oracle-compatible traces, multisets
  crossval  — replay the same scenario on the exact engine and compare

The spec-driven front door over all of this is ``repro.api``
(DESIGN.md §3); ``run_vec``/``run_vec_windowed`` remain as deprecation
shims over the engine impls (``execute_vec``/``execute_windowed``).
Semantics and fidelity limits vs. the exact simulator: DESIGN.md §2.4.
"""

from .crossval import (cross_validate, delivered_multiset_exact,
                       final_clocks_exact, run_exact)
from .metrics import (build_trace, delivered_multiset, full_out_mask,
                      mean_shortest_path_vec, safe_out_mask,
                      unsafe_link_stats_vec, vc_overhead_model)
from .scenario import (INF, TrafficModel, VecScenario, bursty_traffic,
                       churn_scenario, churn_wave_scenario, crash_scenario,
                       diameter_bound, kregular_topology, link_add_scenario,
                       partition_heal_scenario, poisson_traffic,
                       ring_topology, settle_rounds, smallworld_topology,
                       static_scenario, sustained_scenario)
from .live import (AdmissionPolicy, ArrivalProcess, LiveColumnWindow,
                   LiveLoop, LiveReport, build_arrivals)
from .sim import (SERIES_FIELDS, SlotSchedule, VecRunResult, execute_vec,
                  run_vec)
from .shard import ShardedRunResult, ShardedStepper, execute_sharded
from .stream import (ColumnWindow, WindowedRunResult, WindowedStepper,
                     WindowOverflowError, execute_windowed,
                     run_vec_windowed)
from .vc import VCVecRunResult, run_vec_vc

__all__ = [
    "INF", "VecScenario", "ring_topology", "kregular_topology",
    "smallworld_topology", "settle_rounds", "diameter_bound",
    "poisson_traffic", "bursty_traffic", "TrafficModel",
    "static_scenario", "link_add_scenario", "churn_scenario",
    "crash_scenario", "partition_heal_scenario", "churn_wave_scenario",
    "sustained_scenario",
    "SERIES_FIELDS", "SlotSchedule", "VecRunResult", "run_vec",
    "execute_vec",
    "WindowedRunResult", "WindowOverflowError", "ColumnWindow",
    "WindowedStepper", "run_vec_windowed", "execute_windowed",
    "ShardedRunResult", "ShardedStepper", "execute_sharded",
    "LiveLoop", "LiveReport", "LiveColumnWindow", "ArrivalProcess",
    "AdmissionPolicy", "build_arrivals",
    "VCVecRunResult", "run_vec_vc",
    "safe_out_mask", "full_out_mask", "mean_shortest_path_vec",
    "unsafe_link_stats_vec", "build_trace", "delivered_multiset",
    "vc_overhead_model",
    "run_exact", "delivered_multiset_exact", "final_clocks_exact",
    "cross_validate",
]
