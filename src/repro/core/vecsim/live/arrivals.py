"""Open-loop arrival processes for the live serving front door.

Each process generates a pre-drawn submission trace — one ``(round,
origin)`` per offered message, round-sorted — that the serving loop
feeds into its bounded ingest queue as simulated time passes.  The load
is *open-loop*: clients submit on their own clock regardless of how the
system keeps up, which is what makes queueing delay and shed rate real
observables instead of artifacts of a closed feedback loop.

Origins are drawn uniformly (with replacement — independent clients);
the admission planner enforces the engine's per-(origin, round)
uniqueness when it schedules submissions into rounds.

Registered processes (``repro.api`` exposes these as the ``arrivals``
registry):

* ``poisson`` — constant-rate Poisson arrivals, the steady-state
  capacity workload.
* ``bursty``  — low-rate Poisson baseline with periodic spike windows
  at the full rate (one spike when the period exceeds the span): the
  backpressure workload.
* ``diurnal`` — sinusoidal day-curve ramp (peak 2x the mean rate): the
  slow load-swing workload.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import numpy as np

__all__ = ["ArrivalProcess", "build_arrivals", "_ARRIVALS"]


@dataclass(frozen=True)
class ArrivalProcess:
    """A named open-loop arrival generator.

    ``build(rng, n, rate, messages, params)`` returns ``(rounds,
    origins)`` — ``messages`` submissions, round-sorted int32 — where
    ``rate`` is the *mean* offered submissions per round and ``params``
    carries the process knobs (``rate_lo``, ``period``, ``duty``)."""

    name: str
    description: str
    build: Callable[..., Tuple[np.ndarray, np.ndarray]]


def _from_lambda(rng: np.random.Generator, n: int, messages: int,
                 lam_fn) -> Tuple[np.ndarray, np.ndarray]:
    """Draw Poisson per-round counts under the intensity ``lam_fn(t)``
    until ``messages`` submissions exist, then trim."""
    chunks = []
    t0, total = 0, 0
    while total < messages:
        span = 1024
        lam = np.maximum(0.0, np.asarray(
            lam_fn(np.arange(t0, t0 + span)), float))
        if total == 0 and t0 > (1 << 22):
            raise ValueError("arrival intensity never produced traffic")
        cnt = rng.poisson(lam)
        chunks.append(cnt)
        total += int(cnt.sum())
        t0 += span
    counts = np.concatenate(chunks)
    rounds = np.repeat(np.arange(len(counts)),
                       counts)[:messages].astype(np.int32)
    origins = rng.integers(0, n, messages).astype(np.int32)
    return rounds, origins


def _poisson(rng, n, rate, messages, params):
    """Constant-rate Poisson: ``rate`` mean submissions per round."""
    return _from_lambda(rng, n, messages, lambda t: np.full(len(t), rate))


def _bursty(rng, n, rate, messages, params):
    """Poisson baseline at ``rate_lo`` with spike windows at ``rate``:
    the first ``duty`` fraction of every ``period`` rounds burns at the
    full rate.  With ``period`` at or beyond the run span this is
    "Poisson plus one spike"."""
    period = max(1, int(params.get("period", 256)))
    duty = float(params.get("duty", 0.25))
    rate_lo = params.get("rate_lo")
    if rate_lo is None:
        rate_lo = rate / 8.0
    on = max(1, int(round(duty * period)))
    return _from_lambda(
        rng, n, messages,
        lambda t: np.where((t % period) < on, rate, rate_lo))


def _diurnal(rng, n, rate, messages, params):
    """Sinusoidal day curve: intensity ``rate * (1 - cos(2*pi*t /
    period))`` — mean ``rate``, peak ``2*rate``, troughs near zero."""
    period = max(2, int(params.get("period", 256)))
    return _from_lambda(
        rng, n, messages,
        lambda t: rate * (1.0 - np.cos(2.0 * np.pi * t / period)))


_ARRIVALS: Dict[str, ArrivalProcess] = {
    "poisson": ArrivalProcess(
        "poisson",
        "constant-rate Poisson submissions (steady-state capacity load)",
        _poisson),
    "bursty": ArrivalProcess(
        "bursty",
        "low-rate Poisson with periodic full-rate spike windows "
        "(backpressure load; one spike when period >= span)",
        _bursty),
    "diurnal": ArrivalProcess(
        "diurnal",
        "sinusoidal day-curve ramp, mean rate with 2x peaks "
        "(slow load-swing load)",
        _diurnal),
}


def build_arrivals(kind: str, seed: int, n: int, rate: float,
                   messages: int, **params) -> Tuple[np.ndarray, np.ndarray]:
    """Generate the submission trace for a registered process."""
    try:
        proc = _ARRIVALS[kind]
    except KeyError:
        raise KeyError(
            f"unknown arrival process {kind!r}; known: "
            f"{sorted(_ARRIVALS)}") from None
    if messages < 1:
        raise ValueError("messages must be >= 1")
    if rate <= 0:
        raise ValueError("rate must be > 0")
    rng = np.random.default_rng(seed)
    return proc.build(rng, n, float(rate), int(messages), params)
