"""Live-traffic serving front door for the vecsim engines.

Open-loop causal-broadcast ingest: an :class:`ArrivalProcess` drives
submissions into a bounded queue, an :class:`AdmissionPolicy` plans
them into each segment's rounds, a :class:`LiveColumnWindow` grows the
engine's broadcast schedule between segments, and :class:`LiveLoop`
ties it together with backpressure (the window-occupancy signal and the
state-clean ``WindowOverflowError`` catch-and-defer path) plus
rounds-to-delivery latency SLOs.  See ``DESIGN.md`` §2.9.
"""

from .admission import _ADMISSION, AdmissionPolicy
from .arrivals import _ARRIVALS, ArrivalProcess, build_arrivals
from .loop import (LiveLoop, LiveReport, default_per_round_cap,
                   serving_bound)
from .window import LiveColumnWindow

__all__ = [
    "AdmissionPolicy", "_ADMISSION",
    "ArrivalProcess", "_ARRIVALS", "build_arrivals",
    "LiveColumnWindow",
    "LiveLoop", "LiveReport", "default_per_round_cap", "serving_bound",
]
