"""Growable :class:`ColumnWindow` for live (open-loop) ingest.

The pre-scripted engines know the whole broadcast schedule up front, so
the base :class:`~repro.core.vecsim.stream.ColumnWindow` views the
scenario arrays directly.  The live serving loop admits traffic
*between* segments instead: this subclass owns a fixed-capacity
append-only broadcast buffer (``bc_round``/``bc_origin`` with fill
pointer ``m_bc``) that the admission policy extends each tick, plus a
``withdraw_unactivated`` rollback that un-admits everything the engine
has not yet activated — the recovery half of the catch-and-defer
backpressure path (an overflow raise leaves the window untouched, the
loop withdraws, requeues and retries with less).

The global message-id space is pre-split at ``capacity``
(``m_app_cap``), so link-addition pings keep stable ids no matter how
many broadcasts end up admitted; withdrawn buffer positions are reused
by later admissions, keeping admitted ids dense in ``[0, m_bc)``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..scenario import INF, VecScenario
from ..stream import ColumnWindow

__all__ = ["LiveColumnWindow"]


class LiveColumnWindow(ColumnWindow):
    """A :class:`ColumnWindow` whose broadcast stream grows at runtime.

    ``capacity`` bounds the total broadcasts ever admitted (it sizes the
    id space and the per-message aggregate arrays); ``per_round_cap``
    bounds admissions per simulated round — it is the constant the
    padded/stacked schedule caps are derived from, so every segment of a
    live run reuses one jitted trace exactly like a pre-scripted run.
    """

    mutable_schedule = True

    def __init__(self, scn: VecScenario, window: int, capacity: int,
                 per_round_cap: int, horizon: Optional[int] = None):
        if scn.m_app:
            raise ValueError(
                "live window needs a broadcast-free base scenario "
                f"(got m_app={scn.m_app}); pre-scripted traffic belongs "
                "in batch mode")
        super().__init__(scn, window, horizon=horizon)
        cap = int(capacity)
        if cap < 1:
            raise ValueError("capacity must be >= 1")
        self.per_round_cap = int(per_round_cap)
        if self.per_round_cap < 1:
            raise ValueError("per_round_cap must be >= 1")
        self.m_app_cap = cap
        self.bc_round = np.full(cap, INF, np.int32)
        self.bc_origin = np.full(cap, -1, np.int32)
        self.bc_live_slot = np.full(cap, -1, np.int32)
        self.m_bc = 0

    def segment_caps(self, total_rounds: int, seg_len: int):
        """Schedule caps for a live run: the broadcast cap comes from
        the admission-side ``per_round_cap`` invariant (the schedule is
        not known yet when the engine jits its first segment); the
        add/rm/crash caps are pre-scripted and come from the base."""
        base = super().segment_caps(total_rounds, seg_len)
        bc_cap = min(self.per_round_cap * seg_len, self.m_app_cap)
        return (max(bc_cap, base[0]),) + base[1:]

    def append_broadcasts(self, rounds: np.ndarray,
                          origins: np.ndarray) -> np.ndarray:
        """Admit a round-sorted batch of broadcasts; returns their
        global message ids.  The batch must start at or after the last
        admitted round (the activation stream stays sorted) and respect
        capacity; per-(origin, round) uniqueness is the admission
        planner's contract, checked when the admitted schedule is
        exported as a :class:`VecScenario`."""
        k = len(rounds)
        if not k:
            return np.empty(0, np.int64)
        if self.m_bc + k > self.m_app_cap:
            raise ValueError(
                f"admitted broadcasts would exceed capacity "
                f"{self.m_app_cap} ({self.m_bc} + {k})")
        rounds = np.asarray(rounds, np.int32)
        if k > 1 and (np.diff(rounds) < 0).any():
            raise ValueError("admitted batch must be round-sorted")
        if self.m_bc and rounds[0] < self.bc_round[self.m_bc - 1]:
            raise ValueError(
                f"admitted batch starts at round {int(rounds[0])}, "
                f"before the last admitted round "
                f"{int(self.bc_round[self.m_bc - 1])}")
        ids = np.arange(self.m_bc, self.m_bc + k)
        self.bc_round[ids] = rounds
        self.bc_origin[ids] = np.asarray(origins, np.int32)
        self.m_bc += k
        return ids

    def withdraw_unactivated(self) -> Tuple[np.ndarray, np.ndarray]:
        """Un-admit every broadcast the engine has not activated yet;
        returns their ``(rounds, origins)``.  Their buffer positions
        (ids) are recycled by later admissions.  This is a no-op when
        everything admitted is already live."""
        lo = self.next_bc
        n = self.m_bc - lo
        if n <= 0:
            return np.empty(0, np.int32), np.empty(0, np.int32)
        rounds = self.bc_round[lo: self.m_bc].copy()
        origins = self.bc_origin[lo: self.m_bc].copy()
        self.bc_round[lo: self.m_bc] = INF
        self.bc_origin[lo: self.m_bc] = -1
        self.m_bc = lo
        return rounds, origins
