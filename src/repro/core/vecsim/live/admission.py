"""Admission policies for the live serving front door.

Each tick the serving loop plans which queued submissions enter the
next segment's traffic plane.  The policy decides how the plan relates
to the engine's *capacity signal* — free live columns minus the
pre-scripted activations (link pings) due in the segment, i.e. exactly
the occupancy :class:`~repro.core.vecsim.stream.ColumnWindow` tracks:

* ``defer`` (capacity-aware, keep) — admit up to capacity; the excess
  waits in the queue and its queueing delay lands in the latency
  percentiles.  The default: lossless backpressure.
* ``shed``  (capacity-aware, drop) — admit up to capacity, drop the
  rest; queueing delay stays near zero at the cost of a shed rate.
* ``admit`` (capacity-blind)       — admit everything up to the
  per-round schedule cap regardless of window occupancy.  Overfills on
  purpose: it exercises the ``WindowOverflowError`` catch-and-defer
  path (the raise is state-clean, the loop withdraws the unactivated
  admissions, requeues them and retries the segment).

All three respect ``per_round_cap`` — the constant the live schedule
caps are jitted against — and per-(origin, round) uniqueness.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

__all__ = ["AdmissionPolicy", "_ADMISSION"]


@dataclass(frozen=True)
class AdmissionPolicy:
    """A named admission behavior: whether the tick plan is clamped to
    the window's free-column capacity, and whether the un-admitted
    excess is dropped (shed) or kept queued (deferred)."""

    name: str
    description: str
    capacity_aware: bool
    drop_excess: bool


_ADMISSION: Dict[str, AdmissionPolicy] = {
    "defer": AdmissionPolicy(
        "defer",
        "clamp admissions to free window capacity; excess waits in the "
        "queue (lossless backpressure, queueing delay in latency)",
        capacity_aware=True, drop_excess=False),
    "shed": AdmissionPolicy(
        "shed",
        "clamp admissions to free window capacity; excess is dropped "
        "(bounded latency at the cost of a shed rate)",
        capacity_aware=True, drop_excess=True),
    "admit": AdmissionPolicy(
        "admit",
        "admit up to the per-round cap regardless of occupancy; relies "
        "on the state-clean WindowOverflowError catch-and-defer path",
        capacity_aware=False, drop_excess=False),
}
