"""Dispatch layer for the delivery-sweep Pallas kernels.

Each op pads the live column window to a multiple of the column-tile
width, launches the kernel over a 1-D grid of column tiles, reduces the
per-tile stat partials, and slices the planes back — callers (the span
runners in ``sim.py`` / ``shard/spanner.py`` and the windowed driver's
retirement sweep in ``stream.py``) see exact ``(N, W)`` semantics.

``interpret=None`` resolves via :func:`default_interpret`: compiled
kernels on a real TPU, the Pallas interpreter everywhere else.  The
interpreter lowers to ordinary jitted XLA ops, so interpret-mode
backends are byte-identical to (and test against) the jax backend on
CPU; the padding columns are inert (``arr=INF``, ``delivered=-1``,
``is_app=False``) and can never deliver, flush or count.

Availability is probed lazily (:func:`pallas_available`) so the numpy
backend keeps working on hosts without jax; ``repro.api`` surfaces the
probe's note in ``--list`` and turns a failed probe into a
``SpecError`` when ``backend="pallas"`` is requested explicitly.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

from ..scenario import INF

__all__ = ["PallasUnavailableError", "pallas_available", "require_pallas",
           "default_interpret", "deliver_sweep", "fused_sweep",
           "frontier_sweep", "retire_scan", "retire_scan_jit",
           "retire_reduce", "retire_reduce_jit", "latency_hist",
           "latency_hist_jit", "slot_frontier", "ring_apply",
           "pack_columns", "unpack_columns", "popcount_bytes"]

_INF = np.int32(INF)


class PallasUnavailableError(RuntimeError):
    """``backend="pallas"`` was requested but Pallas cannot initialize."""


@functools.lru_cache(maxsize=1)
def pallas_available() -> Tuple[bool, str]:
    """(ok, note): can the Pallas kernels run here, and how."""
    try:
        import jax
        from jax.experimental import pallas  # noqa: F401
    except Exception as exc:  # pragma: no cover - environment-dependent
        return False, f"jax/pallas import failed: {exc}"
    try:
        platform = jax.default_backend()
    except Exception as exc:  # pragma: no cover - environment-dependent
        return False, f"jax backend init failed: {exc}"
    if platform == "tpu":
        return True, "compiled TPU kernels"
    return True, (f"interpret mode on {platform} (byte-identical to the "
                  "jax backend; compiled speed needs a TPU)")


def require_pallas() -> None:
    ok, note = pallas_available()
    if not ok:
        raise PallasUnavailableError(
            f"backend='pallas' requested but Pallas cannot initialize "
            f"({note}); use backend='jax' or 'auto'")


def default_interpret() -> bool:
    """Interpret unless an actual TPU can compile the kernels."""
    ok, note = pallas_available()
    return not (ok and note == "compiled TPU kernels")


def _resolve(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return default_interpret()
    return bool(interpret)


def _tiles(w: int, block_w: Optional[int]) -> Tuple[int, int, int]:
    """(padded width, tile width, tile count) for a ``w``-column window."""
    bw = int(block_w) if block_w else max(w, 1)
    bw = max(1, min(bw, max(w, 1)))
    wp = -(-max(w, 1) // bw) * bw
    return wp, bw, wp // bw


def _pad_cols(x, wp: int, fill):
    import jax.numpy as jnp
    w = x.shape[-1]
    if w == wp:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, wp - w)]
    return jnp.pad(x, widths, constant_values=fill)


def _t_arr(t):
    import jax.numpy as jnp
    return jnp.asarray(t, jnp.int32).reshape(1)


# --------------------------------------------------------------------- #
# Frontier bit-plane helpers (scan-compatible, plain lax)
#
# The scanned sharded fast body (shard/spanner.py) moves the per-round
# delivery frontier around the ring as a bit-packed uint8 plane — 8
# columns per byte — so the all-gather ships W/8 bytes per row and the
# stats come from byte popcounts instead of full-width boolean
# reductions.  These are ordinary jittable jnp ops (usable inside
# lax.scan and shard_map on any backend, no Pallas required) and use
# numpy packbits(bitorder="little") bit order, so hosts and kernels
# agree on the layout.
# --------------------------------------------------------------------- #
def _bit_shifts():
    import jax.numpy as jnp
    return jnp.left_shift(jnp.uint8(1), jnp.arange(8, dtype=jnp.uint8))


def pack_columns(b):
    """Bit-pack an ``(N, W)`` bool plane into ``(N, ceil(W/8))`` uint8
    (little-endian bit order; ragged tail bits are zero)."""
    import jax.numpy as jnp
    n, w = b.shape
    wp = -(-max(w, 1) // 8)
    if wp * 8 != w:
        b = jnp.concatenate(
            [b, jnp.zeros((n, wp * 8 - w), bool)], axis=1)
    sh = _bit_shifts()
    return jnp.sum(jnp.where(b.reshape(n, wp, 8), sh[None, None, :],
                             jnp.uint8(0)), axis=2, dtype=jnp.uint8)


def unpack_columns(p, w: int):
    """Inverse of :func:`pack_columns`: ``(N, Wp)`` uint8 back to the
    ``(N, w)`` bool plane."""
    import jax.numpy as jnp
    n, wp = p.shape
    sh = _bit_shifts()
    b = (p[:, :, None] & sh[None, None, :]) > 0
    b = b.reshape(n, wp * 8)
    return b[:, :w] if w != wp * 8 else b


def popcount_bytes(x):
    """Per-byte SWAR popcount of a uint8 array (branch-free, three
    shift/mask rounds — the classic Hacker's Delight reduction)."""
    import jax.numpy as jnp
    x = x - ((x >> 1) & jnp.uint8(0x55))
    x = (x & jnp.uint8(0x33)) + ((x >> 2) & jnp.uint8(0x33))
    return (x + (x >> 4)) & jnp.uint8(0x0F)


def deliver_sweep(arr, delivered, crashed, is_app, t, *,
                  block_w: Optional[int] = None,
                  interpret: Optional[bool] = None):
    """Phase 5 over the live window: ``(delivered', napp, nping)``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from .kernel import deliver_sweep_kernel
    n, w = arr.shape
    wp, bw, nt = _tiles(w, block_w)
    out_del, napp, nping = pl.pallas_call(
        deliver_sweep_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, wp), jnp.int32),
            jax.ShapeDtypeStruct((nt, n), jnp.int32),
            jax.ShapeDtypeStruct((nt, n), jnp.int32),
        ],
        interpret=_resolve(interpret),
    )(_t_arr(t), crashed, _pad_cols(is_app, wp, False),
      _pad_cols(arr, wp, _INF), _pad_cols(delivered, wp, -1))
    return (out_del[:, :w], napp.sum(axis=0).astype(jnp.int32),
            nping.sum(axis=0).astype(jnp.int32))


def fused_sweep(arr, delivered, crashed, adj, delay, fwd_ok, is_app, t, *,
                block_w: Optional[int] = None,
                interpret: Optional[bool] = None):
    """Gating-free fused sweep: ``(arr', delivered', napp, nping)``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from .kernel import fused_sweep_kernel
    n, w = arr.shape
    k = adj.shape[1]
    wp, bw, nt = _tiles(w, block_w)
    out_arr, out_del, napp, nping = pl.pallas_call(
        functools.partial(fused_sweep_kernel, k=k, n=n),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, wp), jnp.int32),
            jax.ShapeDtypeStruct((n, wp), jnp.int32),
            jax.ShapeDtypeStruct((nt, n), jnp.int32),
            jax.ShapeDtypeStruct((nt, n), jnp.int32),
        ],
        interpret=_resolve(interpret),
    )(_t_arr(t), crashed, _pad_cols(is_app, wp, False), adj, delay, fwd_ok,
      _pad_cols(arr, wp, _INF), _pad_cols(delivered, wp, -1))
    return (out_arr[:, :w], out_del[:, :w],
            napp.sum(axis=0).astype(jnp.int32),
            nping.sum(axis=0).astype(jnp.int32))


def frontier_sweep(arr, delivered, adj, delay, gate, do, fwd_ok, is_app,
                   t, *, block_w: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """Gated fused sweep (flush + forward): ``(arr', flush_sent)``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from .kernel import frontier_sweep_kernel
    n, w = arr.shape
    k = adj.shape[1]
    wp, bw, nt = _tiles(w, block_w)
    out_arr, flush = pl.pallas_call(
        functools.partial(frontier_sweep_kernel, k=k, n=n),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, wp), jnp.int32),
            jax.ShapeDtypeStruct((nt,), jnp.int32),
        ],
        interpret=_resolve(interpret),
    )(_t_arr(t), adj, delay, gate, do, fwd_ok,
      _pad_cols(is_app, wp, False), _pad_cols(delivered, wp, -1),
      _pad_cols(arr, wp, _INF))
    return out_arr[:, :w], flush.sum().astype(jnp.int32)


def retire_scan(delivered, crashed, min_gate, *,
                block_w: Optional[int] = None,
                interpret: Optional[bool] = None):
    """Per-column retirement reductions: ``(cnt, alivedel, blocked)``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from .kernel import retire_scan_kernel
    n, w = delivered.shape
    wp, bw, nt = _tiles(w, block_w)
    cnt, alivedel, blocked = pl.pallas_call(
        retire_scan_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bw), lambda i: (0, i)),
            pl.BlockSpec((1, bw), lambda i: (0, i)),
            pl.BlockSpec((1, bw), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, wp), jnp.int32),
            jax.ShapeDtypeStruct((1, wp), jnp.int32),
            jax.ShapeDtypeStruct((1, wp), jnp.int32),
        ],
        interpret=_resolve(interpret),
    )(crashed, jnp.asarray(min_gate, jnp.int32),
      _pad_cols(jnp.asarray(delivered, jnp.int32), wp, -1))
    return cnt[0, :w], alivedel[0, :w], blocked[0, :w]


def retire_reduce(arr, delivered, crashed, min_gate, rounds, *,
                  block_w: Optional[int] = None,
                  interpret: Optional[bool] = None):
    """Per-column retirement *and* record reductions:
    ``(cnt, alivedel, blocked, arrcnt, sumdel)`` — the
    :func:`retire_scan` triple plus the first-receipt count and the
    delivered-round sum, so the windowed driver's pallas retirement
    path records a retired column from five scalars instead of
    re-reading its ``(N,)`` plane slices."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from .kernel import retire_reduce_kernel
    n, w = delivered.shape
    wp, bw, nt = _tiles(w, block_w)
    out = pl.pallas_call(
        retire_reduce_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
        ],
        out_specs=[pl.BlockSpec((1, bw), lambda i: (0, i))] * 5,
        out_shape=[jax.ShapeDtypeStruct((1, wp), jnp.int32)] * 5,
        interpret=_resolve(interpret),
    )(crashed, jnp.asarray(min_gate, jnp.int32), _t_arr(rounds),
      _pad_cols(jnp.asarray(arr, jnp.int32), wp, INF),
      _pad_cols(jnp.asarray(delivered, jnp.int32), wp, -1))
    return tuple(x[0, :w] for x in out)


@functools.lru_cache(maxsize=None)
def retire_reduce_jit(block_w: Optional[int] = None,
                      interpret: Optional[bool] = None):
    """Cached jitted :func:`retire_reduce` (same treatment as
    :func:`retire_scan_jit`)."""
    import jax
    return jax.jit(functools.partial(retire_reduce, block_w=block_w,
                                     interpret=interpret))


def latency_hist(base, delivered, *, block_w: Optional[int] = None,
                 interpret: Optional[bool] = None):
    """Per-column ``(W, NB)`` delivery-latency histogram: row p of
    column m counts in bucket(delivered[p, m] - base[m]) when the row
    delivered and the column carries a latency base (``base >= 0``).
    The bucket layout is the ``repro.obs.hist`` contract."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from ....obs.hist import NB
    from .kernel import latency_hist_kernel
    n, w = delivered.shape
    wp, bw, nt = _tiles(w, block_w)
    out = pl.pallas_call(
        functools.partial(latency_hist_kernel, nb=NB),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bw, NB), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wp, NB), jnp.int32),
        interpret=_resolve(interpret),
    )(_pad_cols(jnp.asarray(base, jnp.int32), wp, -1),
      _pad_cols(jnp.asarray(delivered, jnp.int32), wp, -1))
    return out[:w]


@functools.lru_cache(maxsize=None)
def latency_hist_jit(block_w: Optional[int] = None,
                     interpret: Optional[bool] = None):
    """Cached jitted :func:`latency_hist` (same treatment as
    :func:`retire_reduce_jit`)."""
    import jax
    return jax.jit(functools.partial(latency_hist, block_w=block_w,
                                     interpret=interpret))


@functools.lru_cache(maxsize=None)
def retire_scan_jit(block_w: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """Cached jitted :func:`retire_scan` for eager per-segment host
    calls (the windowed driver's retirement sweep): the span runners
    amortize their traces through ``lru_cache``d jitted scans, and this
    gives the host-side reduction the same treatment — one trace per
    plane shape instead of a fresh interpreter lowering every sweep."""
    import jax
    return jax.jit(functools.partial(retire_scan, block_w=block_w,
                                     interpret=interpret))


def slot_frontier(delivered, gate_k, delay_k, do_k, fwd_k, is_app, t, *,
                  gating: bool, block_w: Optional[int] = None,
                  interpret: Optional[bool] = None):
    """One slot's ring contribution plane: ``(vals, win_cnt)``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from .kernel import slot_frontier_kernel
    n, w = delivered.shape
    wp, bw, nt = _tiles(w, block_w)
    vals, win = pl.pallas_call(
        functools.partial(slot_frontier_kernel, gating=gating),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, wp), jnp.int32),
            jax.ShapeDtypeStruct((nt,), jnp.int32),
        ],
        interpret=_resolve(interpret),
    )(_t_arr(t), gate_k, delay_k, do_k, fwd_k,
      _pad_cols(is_app, wp, False), _pad_cols(delivered, wp, -1))
    return vals[:, :w], win.sum().astype(jnp.int32)


def ring_apply(arr, vals, tgt, off, *, block_w: Optional[int] = None,
               interpret: Optional[bool] = None):
    """Owner-local scatter-min of a visiting ring plane: ``arr'``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from .kernel import ring_apply_kernel
    n, w = arr.shape
    wp, bw, nt = _tiles(w, block_w)
    out = pl.pallas_call(
        functools.partial(ring_apply_kernel, n_loc=n),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
            pl.BlockSpec((n, bw), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, bw), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, wp), jnp.int32),
        interpret=_resolve(interpret),
    )(jnp.asarray(off, jnp.int32).reshape(1), tgt,
      _pad_cols(vals, wp, _INF), _pad_cols(arr, wp, _INF))
    return out[:, :w]
