"""Pallas kernels for the vecsim per-round delivery sweep.

The protocol's per-hop work at scale is a handful of dense passes over
the live column window (DESIGN.md §2.6): the arrival-plane comparison
that turns arrivals into deliveries, the flush-window comparison over
gated links, and the scatter-min flood-forward of this round's
deliveries.  These kernels fuse those passes so each round touches the
``(N, W)`` planes once instead of once per phase:

  * :func:`fused_sweep_kernel` — the gating-free hot path (sustained
    traffic, no link additions): deliver-gate comparison, per-row
    NetStats counts and the K-slot forward scatter-min in ONE pass;
  * :func:`deliver_sweep_kernel` / :func:`frontier_sweep_kernel` — the
    gated split: pong detection (a cross-column gather) must observe
    post-delivery state, so delivery lands first, the pong ring runs in
    lax between, and the flush+forward scatter fuses into the second
    kernel (same fusion the sharded engine applies via ``gk_eff``);
  * :func:`retire_scan_kernel` — the per-column retirement reductions
    (delivery counts, alive-delivery counts, gate-blocked counts) the
    windowed driver decides retirement from;
  * :func:`slot_frontier_kernel` / :func:`ring_apply_kernel` — the
    per-shard decomposition: one slot's combined flush+forward value
    plane, and the owner-local scatter-min applied at each ring hop of
    the sharded frontier exchange.

Layout: the grid tiles the **column** axis only.  Forward/flush writes
for message column ``m`` land in column ``m`` of the target row, so
column tiles are fully independent grid programs; the process axis
stays whole inside each program because the scatter targets arbitrary
rows.  The scatter itself is a ``fori_loop`` over sender rows with a
dynamic-row read-modify-write — the Pallas idiom for a scatter the VPU
has no native primitive for.  Scatter-min over int32 is associative and
commutative, so the sequential in-kernel accumulation is bit-equal to
the backends' global ``np.minimum.at`` / ``.at[].min`` scatters.

Counter outputs are int32: per-tile partials are bounded by N·BW
(rows times tile width), which holds far past the engine's memory
ceiling; the int64 NetStats math happens in lax outside the kernels.

``interpret=True`` runs every kernel through the Pallas interpreter
(plain jitted XLA ops) — that is the CPU testing mode under which the
whole scenario matrix cross-validates byte-identical against the jax
backend.  Compiled TPU execution additionally wants the window padded
to the 128-lane tile (``ops.py`` pads) and N a multiple of 8.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..scenario import INF

__all__ = ["fused_sweep_kernel", "deliver_sweep_kernel",
           "frontier_sweep_kernel", "retire_scan_kernel",
           "retire_reduce_kernel", "latency_hist_kernel",
           "slot_frontier_kernel", "ring_apply_kernel"]

_INF = np.int32(INF)


def _deliver(t, arr, delivered, crashed, app):
    """Phase 5: arrivals -> deliveries, plus this round's app/ping
    per-row delivery counts for the NetStats accumulators."""
    newly = (arr == t) & (delivered < 0) & ~crashed[:, None]
    delivered = jnp.where(newly, t, delivered)
    new_del = delivered == t
    napp = (new_del & app[None, :]).sum(axis=1).astype(jnp.int32)
    nping = (new_del & ~app[None, :]).sum(axis=1).astype(jnp.int32)
    return delivered, napp, nping


def deliver_sweep_kernel(t_ref, crashed_ref, is_app_ref, arr_ref,
                         delivered_ref, out_del_ref, napp_ref, nping_ref):
    """Delivery gating over one column tile: ``arr == t`` arrivals not
    yet delivered (and not crashed) deliver at ``t``; emits the updated
    tile plus per-row app/ping delivery-count partials."""
    t = t_ref[0]
    delivered, napp, nping = _deliver(
        t, arr_ref[...], delivered_ref[...], crashed_ref[...],
        is_app_ref[...])
    out_del_ref[...] = delivered
    napp_ref[0, :] = napp
    nping_ref[0, :] = nping


def _scatter_links(t, out_arr_ref, delivered, app, adj_ref, delay_ref,
                   gate_ref, do_ref, fwd_ref, *, k: int, n: int,
                   gating: bool):
    """The K-slot scatter-min: for every sender row ``p`` and link slot
    ``kk``, min-combine the forward contribution (columns delivered this
    round, link forward-eligible) with the flush contribution (columns
    in the gate window, link flushing this round) and scatter the value
    row into the target's row of ``out_arr_ref``.  Row-sequential
    accumulation == the global scatter-min (min commutes)."""

    def body(p, _):
        row_del = delivered[p, :]
        new_row = row_del == t
        for kk in range(k):
            fwd_p = fwd_ref[p, kk]
            send_p = (fwd_p | do_ref[p, kk]) if gating else fwd_p

            @pl.when(send_p)
            def _send():
                tgt = adj_ref[p, kk]
                dk = (t + delay_ref[p, kk]).astype(jnp.int32)
                vals = jnp.where(new_row & fwd_p, dk, _INF)
                if gating:
                    win = ((row_del >= gate_ref[p, kk]) & (row_del < t)
                           & do_ref[p, kk] & app)
                    vals = jnp.minimum(vals, jnp.where(win, dk, _INF))
                out_arr_ref[tgt, :] = jnp.minimum(out_arr_ref[tgt, :], vals)
        return 0

    jax.lax.fori_loop(0, n, body, 0)


def fused_sweep_kernel(t_ref, crashed_ref, is_app_ref, adj_ref, delay_ref,
                       fwd_ref, arr_ref, delivered_ref, out_arr_ref,
                       out_del_ref, napp_ref, nping_ref, *, k: int, n: int):
    """The gating-free fused round sweep (phases 5 + 8): deliver-gate
    the tile, count, and scatter-min this round's deliveries over every
    forward-eligible link — one pass over the live column window."""
    t = t_ref[0]
    app = is_app_ref[...]
    delivered, napp, nping = _deliver(
        t, arr_ref[...], delivered_ref[...], crashed_ref[...], app)
    out_del_ref[...] = delivered
    napp_ref[0, :] = napp
    nping_ref[0, :] = nping
    out_arr_ref[...] = arr_ref[...]
    _scatter_links(t, out_arr_ref, delivered, app, adj_ref, delay_ref,
                   None, None, fwd_ref, k=k, n=n, gating=False)


def frontier_sweep_kernel(t_ref, adj_ref, delay_ref, gate_ref, do_ref,
                          fwd_ref, is_app_ref, delivered_ref, arr_ref,
                          out_arr_ref, flush_ref, *, k: int, n: int):
    """The gated fused sweep (phases 7 + 8) over one column tile:
    flush-window comparison on flushing links, forward values on safe
    links, one combined scatter-min into the arrival plane, and the
    per-tile flushed-message count.  ``delivered`` is post-phase-5 (the
    pong ring between the two kernels needs it)."""
    t = t_ref[0]
    delivered = delivered_ref[...]
    app = is_app_ref[...]
    out_arr_ref[...] = arr_ref[...]
    flushed = jnp.int32(0)
    for kk in range(k):
        win = ((delivered >= gate_ref[:, kk][:, None]) & (delivered < t)
               & do_ref[:, kk][:, None] & app[None, :])
        flushed += win.sum().astype(jnp.int32)
    flush_ref[0] = flushed
    _scatter_links(t, out_arr_ref, delivered, app, adj_ref, delay_ref,
                   gate_ref, do_ref, fwd_ref, k=k, n=n, gating=True)


def retire_scan_kernel(crashed_ref, min_gate_ref, delivered_ref, cnt_ref,
                       alivedel_ref, blocked_ref):
    """Per-column retirement reductions over one tile: total delivery
    count, alive-row delivery count (the all-alive-delivered rule), and
    the count of deliveries at-or-after the row's earliest open gate
    (the pending-flush blocker)."""
    delivered = delivered_ref[...]
    crashed = crashed_ref[...]
    got = delivered >= 0
    cnt_ref[0, :] = got.sum(axis=0).astype(jnp.int32)
    alivedel_ref[0, :] = (got & ~crashed[:, None]).sum(axis=0).astype(
        jnp.int32)
    blocked_ref[0, :] = (
        got & (delivered >= min_gate_ref[...][:, None])).sum(
        axis=0).astype(jnp.int32)


def retire_reduce_kernel(crashed_ref, min_gate_ref, rounds_ref, arr_ref,
                         delivered_ref, cnt_ref, alivedel_ref, blocked_ref,
                         arrcnt_ref, sumdel_ref):
    """:func:`retire_scan_kernel` plus the record-side reductions —
    first-receipt counts (``arr < rounds``) and the per-column
    delivered-round sum the latency aggregate is derived from
    (``lat = sumdel - cnt·birth``) — so retiring a column needs no
    ``(N, cols)`` host fetch beyond the decision itself.  ``sumdel`` is
    an int32 partial: exact while ``N·rounds < 2^31``, which holds
    through the engine's host-plane memory ceiling."""
    delivered = delivered_ref[...]
    crashed = crashed_ref[...]
    got = delivered >= 0
    cnt_ref[0, :] = got.sum(axis=0).astype(jnp.int32)
    alivedel_ref[0, :] = (got & ~crashed[:, None]).sum(axis=0).astype(
        jnp.int32)
    blocked_ref[0, :] = (
        got & (delivered >= min_gate_ref[...][:, None])).sum(
        axis=0).astype(jnp.int32)
    arrcnt_ref[0, :] = (arr_ref[...] < rounds_ref[0]).sum(axis=0).astype(
        jnp.int32)
    sumdel_ref[0, :] = jnp.where(got, delivered, 0).sum(axis=0).astype(
        jnp.int32)


def latency_hist_kernel(base_ref, delivered_ref, hist_ref, *, nb: int):
    """Per-column log-bucketed delivery-latency counts over one tile.

    Implements the ``repro.obs.hist`` bucket contract (16 exact buckets
    then power-of-two decades) with integer comparisons only, so the
    counts are byte-identical to the numpy/jnp bucketings.  Rows that
    never delivered (``delivered < 0``) and columns with no latency
    base (``base < 0``: ping columns, padding) count nowhere."""
    delivered = delivered_ref[...]
    base = base_ref[...]
    valid = (delivered >= 0) & (base >= 0)[None, :]
    lat = delivered - base[None, :]
    extra = jnp.zeros(lat.shape, jnp.int32)
    for k in range(5, 20):
        extra = extra + (lat >= (1 << k)).astype(jnp.int32)
    bidx = jnp.where(lat < 16, jnp.clip(lat, 0, 15),
                     jnp.minimum(16 + extra, nb - 1))
    for b in range(nb):
        hist_ref[:, b] = ((bidx == b) & valid).sum(axis=0).astype(
            jnp.int32)


def slot_frontier_kernel(t_ref, gate_ref, delay_ref, do_ref, fwd_ref,
                         is_app_ref, delivered_ref, vals_ref, win_ref,
                         *, gating: bool):
    """One link slot's combined flush+forward contribution plane for the
    sharded ring exchange: ``t + delay`` where the (local) sender row
    forwards this round's deliveries or flushes its gate window, INF
    elsewhere.  Also emits the per-tile flushed-message count."""
    t = t_ref[0]
    delivered = delivered_ref[...]
    dk = (t + delay_ref[...])[:, None].astype(jnp.int32)
    vals = jnp.where((delivered == t) & fwd_ref[...][:, None], dk, _INF)
    if gating:
        win = ((delivered >= gate_ref[...][:, None]) & (delivered < t)
               & do_ref[...][:, None] & is_app_ref[...][None, :])
        vals = jnp.minimum(vals, jnp.where(win, dk, _INF))
        win_ref[0] = win.sum().astype(jnp.int32)
    else:
        win_ref[0] = jnp.int32(0)
    vals_ref[...] = vals


def ring_apply_kernel(off_ref, tgt_ref, vals_ref, arr_ref, out_arr_ref,
                      *, n_loc: int):
    """One ring hop's owner-local application: scatter-min the visiting
    value plane's rows into the rows this shard owns (global target row
    in ``[off, off + n_loc)``); everything else passes through."""
    out_arr_ref[...] = arr_ref[...]
    off = off_ref[0]

    def body(p, _):
        tl = tgt_ref[p] - off

        @pl.when((tl >= 0) & (tl < n_loc))
        def _apply():
            out_arr_ref[tl, :] = jnp.minimum(out_arr_ref[tl, :],
                                             vals_ref[p, :])
        return 0

    jax.lax.fori_loop(0, n_loc, body, 0)
