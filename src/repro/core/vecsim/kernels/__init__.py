"""Pallas delivery-sweep kernels for the vecsim hot path (DESIGN.md
§2.6) — kernel/ops/ref layout mirroring ``repro.kernels``:

  * ``kernel.py`` — the Pallas kernels (column-tiled grid, row-loop
    scatter-min);
  * ``ops.py``    — padding/dispatch wrappers, the availability probe,
    interpret-mode resolution (this module's public surface);
  * ``ref.py``    — plain-lax references each kernel unit-tests against.

Importing this package is cheap and jax-free; jax/pallas load on first
op call, and :func:`pallas_available` reports whether (and how) the
kernels can run here.
"""

from .ops import *  # noqa: F401,F403
from .ops import __all__  # noqa: F401
