"""Plain-lax references for every delivery-sweep kernel.

Each function here is the unfused jax.numpy statement of what the
Pallas kernel in ``kernel.py`` computes — same inputs, same outputs,
same dtypes — written with the global gather/scatter primitives the
jax backend uses (``.at[].min`` with ``mode="drop"``).  The kernel unit
tests (``tests/test_vecsim_kernels.py``) assert byte-equality between
kernel and ref on random inputs, including ragged column tiles, the
single-column window and all-retired (empty) segments.

Invariant shared with the engines: an ``active`` link always carries a
valid target (``adj >= 0``), so the flush mask never scatters through a
negative row; the forward mask checks ``adj >= 0`` explicitly.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..scenario import INF

__all__ = ["deliver_sweep_ref", "fused_sweep_ref", "frontier_sweep_ref",
           "retire_scan_ref", "retire_reduce_ref", "slot_frontier_ref",
           "ring_apply_ref"]

_INF = np.int32(INF)


def _scatter_min(arr, rows, vals, valid):
    arr = jnp.asarray(arr)
    n = arr.shape[0]
    rows = jnp.where(valid, rows, n)
    return arr.at[rows, :].min(vals, mode="drop")


def deliver_sweep_ref(arr, delivered, crashed, is_app, t):
    """(delivered', napp, nping) — phase 5 + per-row delivery counts."""
    newly = (arr == t) & (delivered < 0) & ~crashed[:, None]
    delivered = jnp.where(newly, t, delivered)
    new_del = delivered == t
    napp = (new_del & is_app[None, :]).sum(axis=1).astype(jnp.int32)
    nping = (new_del & ~is_app[None, :]).sum(axis=1).astype(jnp.int32)
    return delivered, napp, nping


def fused_sweep_ref(arr, delivered, crashed, adj, delay, fwd_ok, is_app, t):
    """(arr', delivered', napp, nping) — the gating-free fused sweep:
    deliver, count, and forward-scatter in one logical pass."""
    delivered, napp, nping = deliver_sweep_ref(arr, delivered, crashed,
                                               is_app, t)
    new_del = delivered == t
    for kk in range(adj.shape[1]):
        ok = fwd_ok[:, kk]
        vals = jnp.where(new_del & ok[:, None],
                         (t + delay[:, kk])[:, None].astype(jnp.int32),
                         _INF)
        arr = _scatter_min(arr, adj[:, kk], vals, ok)
    return arr, delivered, napp, nping


def frontier_sweep_ref(arr, delivered, adj, delay, gate, do, fwd_ok,
                       is_app, t):
    """(arr', flush_sent) — the gated fused sweep (phases 7 + 8):
    ``delivered`` is post-phase-5; ``do`` marks links flushing this
    round, ``fwd_ok`` links forward-eligible after the flush clears."""
    new_del = delivered == t
    flush_sent = jnp.int32(0)
    for kk in range(adj.shape[1]):
        dk = (t + delay[:, kk])[:, None].astype(jnp.int32)
        win = ((delivered >= gate[:, kk][:, None]) & (delivered < t)
               & do[:, kk][:, None] & is_app[None, :])
        flush_sent += win.sum().astype(jnp.int32)
        ok = fwd_ok[:, kk]
        vals = jnp.minimum(jnp.where(new_del & ok[:, None], dk, _INF),
                           jnp.where(win, dk, _INF))
        arr = _scatter_min(arr, adj[:, kk], vals, ok | do[:, kk])
    return arr, flush_sent


def retire_scan_ref(delivered, crashed, min_gate):
    """(cnt, alivedel, blocked) — per-column retirement reductions."""
    got = delivered >= 0
    cnt = got.sum(axis=0).astype(jnp.int32)
    alivedel = (got & ~crashed[:, None]).sum(axis=0).astype(jnp.int32)
    blocked = (got & (delivered >= min_gate[:, None])).sum(
        axis=0).astype(jnp.int32)
    return cnt, alivedel, blocked


def retire_reduce_ref(arr, delivered, crashed, min_gate, rounds):
    """(cnt, alivedel, blocked, arrcnt, sumdel) — retirement + record
    reductions."""
    cnt, alivedel, blocked = retire_scan_ref(delivered, crashed, min_gate)
    arrcnt = (arr < rounds).sum(axis=0).astype(jnp.int32)
    sumdel = jnp.where(delivered >= 0, delivered, 0).sum(
        axis=0).astype(jnp.int32)
    return cnt, alivedel, blocked, arrcnt, sumdel


def slot_frontier_ref(delivered, gate_k, delay_k, do_k, fwd_k, is_app, t,
                      *, gating: bool):
    """(vals, win_cnt) — one slot's combined flush+forward value plane
    for the sharded ring."""
    dk = (t + delay_k)[:, None].astype(jnp.int32)
    vals = jnp.where((delivered == t) & fwd_k[:, None], dk, _INF)
    if not gating:
        return vals, jnp.int32(0)
    win = ((delivered >= gate_k[:, None]) & (delivered < t)
           & do_k[:, None] & is_app[None, :])
    vals = jnp.minimum(vals, jnp.where(win, dk, _INF))
    return vals, win.sum().astype(jnp.int32)


def ring_apply_ref(arr, vals, tgt, off):
    """arr' — owner-local scatter-min of a visiting value plane: rows
    targeting ``[off, off + n_loc)`` apply, the rest drop."""
    arr = jnp.asarray(arr)
    n_loc = arr.shape[0]
    tl = tgt - off
    local = (tl >= 0) & (tl < n_loc)
    rows = jnp.where(local, tl, n_loc)
    return arr.at[rows, :].min(vals, mode="drop")
