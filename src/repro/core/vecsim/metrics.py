"""Fig. 7-style metrics and oracle bridges for vec-engine runs.

Mirrors ``repro.core.metrics`` (which walks Python ``Network`` objects)
over dense vec state:

  * ``mean_shortest_path_vec``  — BFS hop counts over the safe-link or
    full adjacency, vectorized frontier expansion (50k processes in ms);
  * ``unsafe_link_stats_vec``   — gated links / buffered messages per
    process at a state snapshot, same tuple as ``unsafe_link_stats``;
  * ``build_trace``             — reconstructs an event trace compatible
    with ``repro.core.oracle.check_trace`` from the delivery matrix, so
    the happens-before oracle validates vec runs unchanged;
  * ``delivered_multiset``      — the canonical (pid, origin, counter)
    delivery multiset used for byte-level vec/exact cross-validation.

Within-round delivery order is not modeled by the lockstep engine; the
trace orders same-round deliveries by message slot, which is consistent
with causality because a causal predecessor always occupies an earlier
slot (broadcast schedules are round-sorted and a message cannot depend
on a same-round broadcast of another origin — its origin would have had
to deliver that message in an earlier round).  DESIGN.md §2.4 discusses
this and the other fidelity limits.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..base import AppMsg
from .scenario import INF, VecScenario
from .sim import VecRunResult

__all__ = ["safe_out_mask", "full_out_mask", "mean_shortest_path_vec",
           "unsafe_link_stats_vec", "build_trace", "delivered_multiset",
           "vc_overhead_model"]


def safe_out_mask(state: Dict[str, np.ndarray]) -> np.ndarray:
    """(N, K) bool: slots the protocol will actually disseminate over —
    active, ungated, populated, endpoints alive (cf. ``metrics.safe_graph``)."""
    crashed = state["crashed"]
    adj = state["adj"]
    tgt_alive = ~crashed[np.clip(adj, 0, len(crashed) - 1)]
    return (state["active"] & (state["gate"] < 0) & (adj >= 0)
            & ~crashed[:, None] & tgt_alive)


def full_out_mask(state: Dict[str, np.ndarray]) -> np.ndarray:
    """(N, K) bool: all alive links regardless of gating."""
    crashed = state["crashed"]
    adj = state["adj"]
    tgt_alive = ~crashed[np.clip(adj, 0, len(crashed) - 1)]
    return state["active"] & (adj >= 0) & ~crashed[:, None] & tgt_alive


def mean_shortest_path_vec(adj: np.ndarray, mask: np.ndarray,
                           sources: Sequence[int],
                           unreachable_penalty: Optional[float] = None,
                           exclude: Optional[np.ndarray] = None) -> float:
    """Mean BFS hops from ``sources`` to every other (alive) process.

    Frontier-expansion BFS over the (N, K) slot table: each hop gathers
    the out-targets of the current frontier and keeps the unvisited ones.
    ``exclude`` marks processes (e.g. crashed) that are neither expanded
    nor counted as destinations."""
    n = adj.shape[0]
    alive = np.ones(n, bool) if exclude is None else ~exclude
    total, count = 0.0, 0
    for s in sources:
        s = int(s)
        if not alive[s]:
            continue
        dist = np.full(n, -1, np.int32)
        dist[s] = 0
        frontier = np.zeros(n, bool)
        frontier[s] = True
        d = 0
        while frontier.any():
            rows = np.nonzero(frontier)[0]
            sub = mask[rows]
            targets = adj[rows][sub]
            frontier = np.zeros(n, bool)
            if targets.size:
                cand = np.unique(targets)
                cand = cand[(dist[cand] < 0) & alive[cand]]
                dist[cand] = d + 1
                frontier[cand] = True
            d += 1
        reach = (dist > 0)
        total += float(dist[reach].sum())
        count += int(reach.sum())
        missed = int((alive & (dist < 0)).sum())
        if unreachable_penalty is not None and missed:
            total += unreachable_penalty * missed
            count += missed
    return total / count if count else float("nan")


def unsafe_link_stats_vec(state: Dict[str, np.ndarray], t: int,
                          m_app: int) -> Tuple[float, float, int]:
    """(mean unsafe links/process, mean buffered msgs/process, max buffer)
    at a state snapshot taken right after round ``t`` — the same tuple as
    ``repro.core.metrics.unsafe_link_stats``.  A gated slot's buffer holds
    every app message its owner delivered in ``[gate, t]``.

    Works on monolithic snapshots (app messages are the first ``m_app``
    columns) and on windowed-engine snapshots, which carry an ``is_app``
    mask because live buffer columns interleave app and ping slots; the
    windowed buffer retains every flush-relevant column by construction,
    so the stats are identical."""
    gate, delivered, crashed = state["gate"], state["delivered"], state["crashed"]
    alive = ~crashed
    gated = (gate >= 0) & alive[:, None]
    n_alive = max(1, int(alive.sum()))
    if not gated.any():
        return 0.0, 0.0, 0
    if "is_app" in state:
        d_app = delivered[:, state["is_app"]]
    else:
        d_app = delivered[:, :m_app]
    # buffered[p, kk] = #app msgs delivered by p in [gate, t] on that slot
    win = (d_app >= 0) & (d_app <= t)
    buf = ((d_app[:, None, :] >= gate[:, :, None])
           & win[:, None, :]).sum(axis=2)
    buf = np.where(gated, buf, 0)
    return (float(gated.sum() / n_alive),
            float(buf.sum() / n_alive),
            int(buf.max()))


def _app_msgs(scn: VecScenario) -> List[AppMsg]:
    counters = scn.msg_counters()
    return [AppMsg(int(o), int(c))
            for o, c in zip(scn.bcast_origin, counters)]


def build_trace(res: VecRunResult) -> List[Tuple[float, str, int, AppMsg]]:
    """Oracle-compatible trace: per round, broadcasts first (the lockstep
    broadcast phase precedes the arrival-delivery phase), then deliveries
    ordered by message slot.  Accepts monolithic and windowed results —
    the latter must have collected the full delivered matrix
    (``collect="full"``)."""
    if res.delivered is None:
        raise ValueError("trace reconstruction needs the full delivered "
                         "matrix; rerun the windowed engine with "
                         "collect='full'")
    scn = res.scenario
    msgs = _app_msgs(scn)
    d_app = res.delivered[:, : scn.m_app]
    events: List[Tuple[Tuple[int, int, int, int], str, int, AppMsg]] = []
    for i in range(scn.m_app):
        t = int(scn.bcast_round[i])
        o = int(scn.bcast_origin[i])
        # a broadcast happened iff its origin delivered it (an origin that
        # crashed before its scheduled round never broadcast the message)
        if res.delivered[o, i] >= 0:
            events.append(((t, 0, i, -1), "broadcast", o, msgs[i]))
    pids, slots = np.nonzero(d_app >= 0)
    for p, i in zip(pids.tolist(), slots.tolist()):
        t = int(d_app[p, i])
        events.append(((t, 1, i, p), "deliver", p, msgs[i]))
    events.sort(key=lambda ev: ev[0])
    return [(float(key[0]), kind, pid, m) for key, kind, pid, m in events]


def delivered_multiset(res: VecRunResult) -> List[Tuple[int, int, int]]:
    """Sorted (pid, origin, counter) triples over all app deliveries."""
    if res.delivered is None:
        raise ValueError("delivered multiset needs the full delivered "
                         "matrix; rerun the windowed engine with "
                         "collect='full'")
    scn = res.scenario
    counters = scn.msg_counters()
    d_app = res.delivered[:, : scn.m_app]
    pids, slots = np.nonzero(d_app >= 0)
    out = [(int(p), int(scn.bcast_origin[i]), int(counters[i]))
           for p, i in zip(pids.tolist(), slots.tolist())]
    out.sort()
    return out


def vc_overhead_model(res: VecRunResult) -> Tuple[float, float]:
    """(mean control bytes/message, mean vector comparisons/delivery) a
    vector-clock baseline would have paid on the same causal run.

    This is the *analytic approximation* that predated the measured
    vectorized VC protocol (``vecsim.vc.run_vec_vc``); benchmarks now
    report the measurement and keep this as ``vc_model`` rows for
    contrast (it counts 16 bytes per clock entry where the exact
    engine's ``control_bytes`` charges 8, and weights by delivery
    counts rather than actual sends).

    Derived from the vec delivery matrix rather than simulated: message
    ``i``'s piggybacked clock holds one entry per distinct origin its
    broadcaster had delivered from before broadcasting (plus itself) —
    exactly what ``VCBroadcast`` piggybacks — and every delivery rescans
    that clock once (Table 1's O(N) terms).  DESIGN.md §2.4."""
    if res.delivered is None:
        raise ValueError("the VC overhead model needs the full delivered "
                         "matrix; rerun the windowed engine with "
                         "collect='full'")
    scn = res.scenario
    d_app = res.delivered[:, : scn.m_app]
    origins = scn.bcast_origin
    vc_len = np.zeros(scn.m_app, np.int64)
    for i in range(scn.m_app):
        o, r = int(origins[i]), int(scn.bcast_round[i])
        seen = (d_app[o] >= 0) & (d_app[o] < r)
        vc_len[i] = len({int(origins[j]) for j in np.nonzero(seen)[0]} |
                        {o})
    deliveries = (d_app >= 0).sum(axis=0)
    total_deliv = int(deliveries.sum())
    sent = max(1, res.stats.sent_messages)
    # bytes: id pair + one (pid, counter) pair per clock entry, weighted by
    # how many copies of each message the network actually carried; approx
    # copies proportional to deliveries.
    bytes_per_msg = float(np.average(16 + 16 * vc_len, weights=np.maximum(
        deliveries, 1)))
    comparisons = (float((vc_len * deliveries).sum() / total_deliv)
                   if total_deliv else 0.0)
    return bytes_per_msg, comparisons
