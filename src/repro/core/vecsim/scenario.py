"""Preplanned large-N scenarios for the vectorized lockstep simulator.

A :class:`VecScenario` is the dense-array twin of a scripted run on the
exact event simulator (``repro.core.events``): an initial ``(N, K)``
out-link table plus integer-round schedules for broadcasts, link churn
and crashes.  The same scenario object drives both engines —
``vecsim.sim.run_vec`` executes it in lockstep rounds, while
``vecsim.crossval.run_exact`` replays it event-by-event on ``Network`` —
which is what makes byte-level cross-validation of delivered-message
multisets possible (DESIGN.md §2.4).

Builder invariants (asserted by :meth:`VecScenario.validate`):

  * slot 0 holds a directed ring that is never removed, so the overlay
    stays strongly connected and flooding reaches everyone;
  * a process's active out-targets are distinct at all times, so a vec
    slot removal maps to exactly one ``Network.disconnect``;
  * at most one broadcast per (origin, round), so per-origin message
    counters are identical across engines;
  * same-round link additions touch distinct processes (the lockstep
    engine evaluates all of a round's additions against the same
    pre-round state).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import numpy as np

__all__ = ["INF", "VecScenario", "ring_topology", "kregular_topology",
           "smallworld_topology", "settle_rounds", "diameter_bound",
           "poisson_traffic", "bursty_traffic", "TrafficModel",
           "static_scenario", "link_add_scenario", "churn_scenario",
           "crash_scenario", "partition_heal_scenario",
           "churn_wave_scenario", "sustained_scenario"]

INF = np.int32(2 ** 30)


def _i32(a) -> np.ndarray:
    return np.asarray(a, np.int32)


def _empty() -> np.ndarray:
    return np.zeros((0,), np.int32)


@dataclass(frozen=True, eq=False)
class VecScenario:
    """A fully preplanned run: topology + integer-round schedules."""

    n: int                       # processes
    k: int                       # out-link slots per process
    rounds: int                  # lockstep rounds to simulate
    adj0: np.ndarray             # (N, K) initial out-targets, -1 = empty
    delay0: np.ndarray           # (N, K) per-link delay in rounds (>= 1)
    bcast_round: np.ndarray      # (M,) sorted broadcast rounds
    bcast_origin: np.ndarray     # (M,)
    add_round: np.ndarray = field(default_factory=_empty)   # (E,)
    add_p: np.ndarray = field(default_factory=_empty)
    add_k: np.ndarray = field(default_factory=_empty)
    add_q: np.ndarray = field(default_factory=_empty)
    add_delay: np.ndarray = field(default_factory=_empty)
    rm_round: np.ndarray = field(default_factory=_empty)    # (R,)
    rm_p: np.ndarray = field(default_factory=_empty)
    rm_k: np.ndarray = field(default_factory=_empty)
    crash_round: np.ndarray = field(default_factory=_empty)  # (C,)
    crash_pid: np.ndarray = field(default_factory=_empty)
    mode: str = "pc"             # "pc" (link-safety gating) | "r" (none)
    pong_delay: int = 1          # rounds for the pong rho to return
    always_gate: bool = False    # paper-faithful unconditional gating

    @property
    def m_app(self) -> int:
        return len(self.bcast_round)

    @property
    def n_adds(self) -> int:
        return len(self.add_round)

    @property
    def m_total(self) -> int:
        """App slots + one ping slot per link addition."""
        return self.m_app + self.n_adds

    def msg_counters(self) -> np.ndarray:
        """Per-origin sequential counter of each app slot (1-based), i.e.
        the ``AppMsg.counter`` the exact engine assigns to that broadcast."""
        counters = np.zeros(self.m_app, np.int32)
        seen: dict = {}
        for i, o in enumerate(self.bcast_origin):
            o = int(o)
            seen[o] = seen.get(o, 0) + 1
            counters[i] = seen[o]
        return counters

    def validate(self) -> "VecScenario":
        """Check every builder invariant, raising :class:`ValueError`
        with an informative message (never a bare ``AssertionError`` —
        the checks must survive ``python -O`` and read well from
        ``repro.api`` spec errors)."""
        def fail(msg: str):
            raise ValueError(f"invalid VecScenario: {msg}")

        if self.mode not in ("pc", "r"):
            fail(f"mode={self.mode!r} must be 'pc' or 'r'")
        if self.n < 1 or self.k < 1:
            fail(f"n={self.n}, k={self.k} must be >= 1")
        for name, a in (("adj0", self.adj0), ("delay0", self.delay0)):
            if a.shape != (self.n, self.k):
                fail(f"{name} shape {a.shape} != (n={self.n}, k={self.k})")
        if (self.adj0 >= self.n).any() or (self.adj0 < -1).any():
            fail("adj0 targets must be -1 (empty) or process ids in "
                 f"[0, {self.n})")
        if ((self.delay0 < 1) & (self.adj0 >= 0)).any():
            fail("populated adj0 slots need delay0 >= 1 (a same-round "
                 "hop has no exact-engine equivalent)")
        # ragged schedules: every schedule is a parallel array group
        groups = {
            "bcast": (self.bcast_round, self.bcast_origin),
            "add": (self.add_round, self.add_p, self.add_k, self.add_q,
                    self.add_delay),
            "rm": (self.rm_round, self.rm_p, self.rm_k),
            "crash": (self.crash_round, self.crash_pid),
        }
        for gname, arrays in groups.items():
            lens = {len(a) for a in arrays}
            if len(lens) > 1:
                fail(f"ragged {gname} schedule: column lengths "
                     f"{sorted(len(a) for a in arrays)} differ")
        if len(self.bcast_round) and (np.diff(self.bcast_round) < 0).any():
            fail("bcast_round is not sorted")
        for gname, ids, hi in (("bcast_origin", self.bcast_origin, self.n),
                               ("add_p", self.add_p, self.n),
                               ("add_q", self.add_q, self.n),
                               ("rm_p", self.rm_p, self.n),
                               ("crash_pid", self.crash_pid, self.n),
                               ("add_k", self.add_k, self.k),
                               ("rm_k", self.rm_k, self.k)):
            if len(ids) and ((ids < 0).any() or (ids >= hi).any()):
                fail(f"{gname} out of range: values must lie in [0, {hi})"
                     f" (got min={int(ids.min())}, max={int(ids.max())})")
        if len(self.add_delay) and (self.add_delay < 1).any():
            fail("add_delay entries must be >= 1")
        pairs = set(zip(self.bcast_origin.tolist(), self.bcast_round.tolist()))
        if len(pairs) != self.m_app:
            fail("duplicate (origin, round) broadcast: per-origin message "
                 "counters would diverge between the engines")
        # same-round adds must touch distinct processes (lockstep batching)
        for t in np.unique(self.add_round):
            ps = self.add_p[self.add_round == t]
            if len(set(ps.tolist())) != len(ps):
                fail(f"two link additions at round {int(t)} share a "
                     "process (same-round adds are batched against the "
                     "same pre-round state)")
        # distinct out-targets per process, so every (p, slot) maps to one
        # (p, q) link in the exact-engine replay
        for p in range(self.n):
            tgt = [int(q) for q in self.adj0[p] if q >= 0]
            if len(set(tgt)) != len(tgt):
                fail(f"bad slot table: duplicate out-target at process {p}"
                     " (a vec slot removal must map to exactly one link)")
            if p in tgt:
                fail(f"bad slot table: self-link at process {p}")
        add_pk = list(zip(self.add_p.tolist(), self.add_k.tolist()))
        if len(set(add_pk)) != len(add_pk):
            fail("slot added twice (reuse of a slot mid-run is not "
                 "modeled)")
        for e in range(self.n_adds):
            p, q = int(self.add_p[e]), int(self.add_q[e])
            if q == p:
                fail(f"addition {e} is a self-link at process {p}")
            init = {int(x) for x in self.adj0[p] if x >= 0}
            if q in init:
                fail(f"addition {e} duplicates an initial target of {p}")
        # removals never touch the connectivity ring (slot 0) or overwrite
        # a scheduled addition's slot
        if len(self.rm_k):
            if (self.rm_k == 0).any():
                fail("a removal targets slot 0 — the never-removed "
                     "connectivity ring")
            add_slots = set(add_pk)
            rm_slots = set(zip(self.rm_p.tolist(), self.rm_k.tolist()))
            both = add_slots & rm_slots
            if both:
                fail(f"removal races an addition on slot(s) "
                     f"{sorted(both)}")
        return self


def settle_rounds(n: int, k: int, max_delay: int, pong_delay: int = 1,
                  diam: Optional[int] = None) -> int:
    """Rounds needed after the last scheduled event for a broadcast to
    flood the overlay and all ping phases to resolve.

    Without ``diam`` this uses the expander heuristic (flooding diameter
    ~ log_{k-1} N hops, each up to ``max_delay``) — fine for ring+random
    and k-regular overlays, NOT sound for low-beta small-world lattices
    whose diameter is Θ(n/k).  Builders that know the actual slot table
    pass ``diam=diameter_bound(adj0)``, which makes the returned window
    a *sound* delivery bound on static overlays: every broadcast
    delivers everywhere within ``settle_rounds(...)`` rounds of its
    broadcast round (property-tested in ``tests/test_vecsim_fuzz.py``)."""
    if diam is None:
        diam = math.ceil(math.log(max(n, 2)) / math.log(max(k - 1, 2))) + 3
    return (diam + 2) * max_delay + 2 * pong_delay + 6


def diameter_bound(adj: np.ndarray) -> int:
    """Sound upper bound on the directed hop diameter of a slot-table
    graph: ``ecc_out(0) + ecc_in(0)`` (every u→w path via node 0 is at
    most that long, and the true diameter never exceeds it).  Two
    vectorized BFS sweeps, O(E) per level."""
    n, k = adj.shape
    mask = adj >= 0
    src = np.repeat(np.arange(n), k)[mask.ravel()]
    dst = adj.ravel()[mask.ravel()].astype(np.int64)

    def ecc(forward: bool) -> int:
        seen = np.zeros(n, bool)
        frontier = np.zeros(n, bool)
        seen[0] = frontier[0] = True
        hops = 0
        while True:
            if forward:
                cand = dst[frontier[src]]
            else:
                cand = src[frontier[dst]]
            frontier = np.zeros(n, bool)
            fresh = cand[~seen[cand]]
            if not len(fresh):
                break
            seen[fresh] = frontier[fresh] = True
            hops += 1
        if not seen.all():
            raise ValueError("slot table is not strongly connected "
                             f"({int((~seen).sum())} unreachable "
                             f"{'from' if forward else 'to'} process 0)")
        return hops

    return ecc(True) + ecc(False)


def ring_topology(seed: int, n: int, k: int, max_delay: int = 3,
                  free_slots: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Directed ring on slot 0 plus random distinct extra out-links on
    slots ``1 .. k-1-free_slots``; the last ``free_slots`` slots stay
    empty for scheduled additions.  Targets are distinct per process so
    every (p, slot) maps to a unique (p, q) link."""
    rng = np.random.default_rng(seed)
    adj0 = np.full((n, k), -1, np.int32)
    adj0[:, 0] = (np.arange(n) + 1) % n
    n_extra = max(0, k - 1 - free_slots)
    for p in range(n):
        used = {p, int(adj0[p, 0])}
        j = 1
        while j <= n_extra and len(used) < n:
            q = int(rng.integers(0, n))
            if q not in used:
                adj0[p, j] = q
                used.add(q)
                j += 1
    delay0 = rng.integers(1, max_delay + 1, size=(n, k)).astype(np.int32)
    return adj0, delay0


def _spread_broadcasts(rng, n: int, m_app: int, lo: int, hi: int):
    """Sorted broadcast schedule over [lo, hi) with unique (origin, round)."""
    seen = set()
    rounds, origins = [], []
    while len(rounds) < m_app:
        t, o = int(rng.integers(lo, hi)), int(rng.integers(0, n))
        if (o, t) not in seen:
            seen.add((o, t))
            rounds.append(t)
            origins.append(o)
    order = np.argsort(np.asarray(rounds), kind="stable")
    return (_i32(np.asarray(rounds)[order]), _i32(np.asarray(origins)[order]))


def static_scenario(seed: int, n: int, k: int = 4, m_app: int = 8,
                    max_delay: int = 3, mode: str = "pc",
                    pong_delay: int = 1, topology: str = "ring",
                    beta: float = 0.2) -> VecScenario:
    """Broadcast-only run on a static overlay (``topology`` picks the
    builder: ring+random, k-regular, or small-world with ``beta``)."""
    adj0, delay0 = _build_topology(topology, seed, n, k, max_delay,
                                   free_slots=1, beta=beta)
    rng = np.random.default_rng(seed + 1)
    window = max(2 * m_app, 8)
    bc_round, bc_origin = _spread_broadcasts(rng, n, m_app, 0, window)
    rounds = window + settle_rounds(n, k, max_delay, pong_delay,
                                    diam=diameter_bound(adj0))
    return VecScenario(n=n, k=k, rounds=rounds, adj0=adj0, delay0=delay0,
                       bcast_round=bc_round, bcast_origin=bc_origin,
                       mode=mode, pong_delay=pong_delay).validate()


def _plan_adds(rng, n: int, k: int, adj0: np.ndarray, n_adds: int,
               lo: int, hi: int, max_delay: int):
    """Schedule link additions on the free slot ``k-1`` of distinct
    processes, each targeting a process not currently in p's out-view."""
    hi = max(hi, lo + 1)
    procs = rng.choice(n, size=min(n_adds, n), replace=False)
    add_round, add_p, add_k, add_q, add_delay = [], [], [], [], []
    for p in procs:
        p = int(p)
        used = {p} | {int(q) for q in adj0[p] if q >= 0}
        if len(used) >= n:
            continue
        while True:
            q = int(rng.integers(0, n))
            if q not in used:
                break
        add_round.append(int(rng.integers(lo, hi)))
        add_p.append(p)
        add_k.append(k - 1)
        add_q.append(q)
        add_delay.append(int(rng.integers(1, max_delay + 1)))
    order = np.argsort(np.asarray(add_round), kind="stable")
    return tuple(_i32(np.asarray(a)[order]) for a in
                 (add_round, add_p, add_k, add_q, add_delay))


def link_add_scenario(seed: int, n: int, k: int = 4, m_app: int = 10,
                      n_adds: Optional[int] = None, max_delay: int = 3,
                      pong_delay: int = 1, topology: str = "ring",
                      beta: float = 0.2) -> VecScenario:
    """Static bootstrap, early broadcasts, then a batch of link additions
    that race with later broadcasts — the Fig. 3 shortcut situation that
    PC-broadcast's ping gating exists to make safe.  Additions happen
    after every process has delivered the early traffic, so the gating
    condition (Algorithm 2 with the delivered-something fast-path)
    engages identically in both engines."""
    n_adds = n_adds if n_adds is not None else max(2, n // 8)
    adj0, delay0 = _build_topology(topology, seed, n, k, max_delay,
                                   free_slots=1, beta=beta)
    rng = np.random.default_rng(seed + 2)
    settle = settle_rounds(n, k, max_delay, pong_delay)
    early = max(2, m_app // 3)
    bc_round_a, bc_origin_a = _spread_broadcasts(rng, n, early, 0, 2 * early)
    t_add_lo = 2 * early + settle          # early traffic fully delivered
    t_add_hi = t_add_lo + max(4, n_adds)
    adds = _plan_adds(rng, n, k, adj0, n_adds, t_add_lo, t_add_hi, max_delay)
    bc_round_b, bc_origin_b = _spread_broadcasts(
        rng, n, m_app - early, t_add_lo, t_add_hi + 4)
    bc_round = np.concatenate([bc_round_a, bc_round_b])
    bc_origin = np.concatenate([bc_origin_a, bc_origin_b])
    rounds = int(t_add_hi) + 4 + settle
    return VecScenario(n=n, k=k, rounds=rounds, adj0=adj0, delay0=delay0,
                       bcast_round=_i32(bc_round), bcast_origin=_i32(bc_origin),
                       add_round=adds[0], add_p=adds[1], add_k=adds[2],
                       add_q=adds[3], add_delay=adds[4],
                       pong_delay=pong_delay).validate()


def churn_scenario(seed: int, n: int, k: int = 5, m_app: int = 12,
                   n_adds: Optional[int] = None, n_rms: Optional[int] = None,
                   max_delay: int = 3, pong_delay: int = 1,
                   churn_window: Optional[int] = None,
                   topology: str = "ring", beta: float = 0.2) -> VecScenario:
    """Broadcasts interleaved with batched link additions *and* removals
    (the ring is never removed, so the overlay stays connected).

    ``churn_window`` — rounds the add/remove batch is spread over; adds
    land on distinct processes, so packing several into one round is
    valid for the lockstep batching rule."""
    n_adds = n_adds if n_adds is not None else max(2, n // 8)
    n_rms = n_rms if n_rms is not None else max(2, n // 8)
    adj0, delay0 = _build_topology(topology, seed, n, k, max_delay,
                                   free_slots=1, beta=beta)
    rng = np.random.default_rng(seed + 3)
    settle = settle_rounds(n, k, max_delay, pong_delay)
    early = max(2, m_app // 3)
    bc_round_a, bc_origin_a = _spread_broadcasts(rng, n, early, 0, 2 * early)
    lo = 2 * early + settle
    hi = lo + (churn_window if churn_window is not None
               else max(6, n_adds, n_rms))
    adds = _plan_adds(rng, n, k, adj0, n_adds, lo, hi, max_delay)
    # removals: random non-ring, non-add slots that are populated initially
    rm_round, rm_p, rm_k = [], [], []
    for _ in range(n_rms):
        p = int(rng.integers(0, n))
        kk = int(rng.integers(1, max(2, k - 1)))
        if adj0[p, kk] >= 0:
            rm_round.append(int(rng.integers(lo, hi)))
            rm_p.append(p)
            rm_k.append(kk)
    if rm_round:
        order = np.argsort(np.asarray(rm_round), kind="stable")
        rm = tuple(_i32(np.asarray(a)[order]) for a in (rm_round, rm_p, rm_k))
    else:
        rm = (_empty(), _empty(), _empty())
    bc_round_b, bc_origin_b = _spread_broadcasts(rng, n, m_app - early,
                                                 lo, hi + 4)
    bc_round = np.concatenate([bc_round_a, bc_round_b])
    bc_origin = np.concatenate([bc_origin_a, bc_origin_b])
    rounds = int(hi) + 4 + settle
    return VecScenario(n=n, k=k, rounds=rounds, adj0=adj0, delay0=delay0,
                       bcast_round=_i32(bc_round), bcast_origin=_i32(bc_origin),
                       add_round=adds[0], add_p=adds[1], add_k=adds[2],
                       add_q=adds[3], add_delay=adds[4],
                       rm_round=rm[0], rm_p=rm[1], rm_k=rm[2],
                       pong_delay=pong_delay).validate()


def crash_scenario(seed: int, n: int, k: int = 6, m_app: int = 10,
                   n_crashes: int = 2, max_delay: int = 2,
                   pong_delay: int = 1, topology: str = "ring",
                   beta: float = 0.2) -> VecScenario:
    """Silent crashes (Fig. 5b) mid-broadcast on a well-connected overlay
    (k large enough that the correct subgraph almost surely stays
    connected).  Crashed processes freeze; correct ones keep delivering."""
    base = static_scenario(seed, n, k=k, m_app=m_app, max_delay=max_delay,
                           pong_delay=pong_delay, topology=topology,
                           beta=beta)
    rng = np.random.default_rng(seed + 4)
    mid = int(base.bcast_round[m_app // 2])
    pids = rng.choice(n, size=n_crashes, replace=False)
    # crashed processes never broadcast afterwards: drop their later slots
    keep = ~(np.isin(base.bcast_origin, pids) & (base.bcast_round >= mid))
    return replace(base,
                   bcast_round=base.bcast_round[keep],
                   bcast_origin=base.bcast_origin[keep],
                   crash_round=_i32(np.full(n_crashes, mid)),
                   crash_pid=_i32(pids)).validate()


# --------------------------------------------------------------------- #
# Topology builders beyond ring+random
# --------------------------------------------------------------------- #
def _perm_avoiding(rng, n: int, forbidden: np.ndarray) -> np.ndarray:
    """Random permutation of ``range(n)`` with ``perm[p] != p`` and
    ``perm[p]`` not in ``forbidden[p]`` (an ``(n, j)`` column stack of
    already-used targets).  Repairs conflicts by reshuffling the
    conflicted positions among themselves, which converges quickly while
    the forbidden sets stay small relative to ``n``."""
    perm = rng.permutation(n).astype(np.int64)
    me = np.arange(n)
    for it in range(1000):
        bad = perm == me
        for c in range(forbidden.shape[1]):
            bad |= perm == forbidden[:, c]
        idx = np.nonzero(bad)[0]
        if not len(idx):
            return perm
        if len(idx) == 1 or it % 7 == 6:
            # a lone conflict (or a cycling set) needs fresh material:
            # swap each conflicted position with a random other one
            others = rng.integers(0, n, size=len(idx))
            for i, j in zip(idx, others):
                perm[i], perm[j] = perm[j], perm[i]
        else:
            perm[idx] = perm[idx[rng.permutation(len(idx))]]
    raise RuntimeError("could not build a conflict-free permutation "
                       f"(n={n}, {forbidden.shape[1]} forbidden/row)")


def kregular_topology(seed: int, n: int, k: int, max_delay: int = 3,
                      free_slots: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Random k-regular digraph: slot 0 is the directed ring (a cyclic
    permutation, kept for the never-removed connectivity invariant) and
    each further populated slot is an independent random permutation, so
    every process has equal out- AND in-degree — the paper's uniform
    peer-sampling ideal, without ring+random's in-degree skew."""
    assert n >= k + 2, "need n >= k + 2 distinct targets per process"
    rng = np.random.default_rng(seed)
    adj0 = np.full((n, k), -1, np.int64)
    adj0[:, 0] = (np.arange(n) + 1) % n
    n_extra = max(0, k - 1 - free_slots)
    for j in range(1, n_extra + 1):
        adj0[:, j] = _perm_avoiding(rng, n, adj0[:, :j])
    delay0 = rng.integers(1, max_delay + 1, size=(n, k)).astype(np.int32)
    return adj0.astype(np.int32), delay0


def smallworld_topology(seed: int, n: int, k: int, beta: float = 0.2,
                        max_delay: int = 3, free_slots: int = 1
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Watts-Strogatz-style overlay: a directed ring lattice (slot ``j``
    points ``j+1`` positions ahead) whose non-ring slots are rewired to a
    uniform random target with probability ``beta``.  ``beta=0`` is a
    pure lattice (long paths), ``beta=1`` approaches ring+random; small
    ``beta`` gives the clustered/short-diameter regime in between."""
    rng = np.random.default_rng(seed)
    adj0 = np.full((n, k), -1, np.int64)
    n_used = max(1, k - free_slots)
    assert n > n_used + 1, "lattice needs n > k - free_slots + 1"
    for j in range(n_used):
        adj0[:, j] = (np.arange(n) + j + 1) % n
    for j in range(1, n_used):            # slot 0 ring is never rewired
        for p in np.nonzero(rng.random(n) < beta)[0]:
            p = int(p)
            used = {p} | {int(q) for q in adj0[p] if q >= 0}
            if len(used) >= n:
                continue
            while True:
                q = int(rng.integers(0, n))
                if q not in used:
                    break
            adj0[p, j] = q
    delay0 = rng.integers(1, max_delay + 1, size=(n, k)).astype(np.int32)
    return adj0.astype(np.int32), delay0


# --------------------------------------------------------------------- #
# Traffic schedules: sustained load instead of a fixed broadcast batch
# --------------------------------------------------------------------- #
def _per_round_origins(rng, n: int, counts: np.ndarray, t0: int):
    rounds, origins = [], []
    for off, c in enumerate(counts):
        c = int(min(c, n))
        if c <= 0:
            continue
        rounds.extend([t0 + off] * c)
        origins.extend(rng.choice(n, size=c, replace=False).tolist())
    return _i32(rounds), _i32(origins)


def poisson_traffic(seed: int, n: int, rate: float, t0: int, t1: int,
                    max_messages: Optional[int] = None):
    """Poisson(rate) broadcasts per round over ``[t0, t1)``; origins are
    drawn without replacement per round, so the (origin, round) pairs
    are unique as the lockstep batching rule requires.  Truncates to
    ``max_messages`` if given."""
    rng = np.random.default_rng(seed)
    counts = rng.poisson(rate, size=max(0, t1 - t0))
    bc_round, bc_origin = _per_round_origins(rng, n, counts, t0)
    if max_messages is not None:
        bc_round, bc_origin = bc_round[:max_messages], bc_origin[:max_messages]
    return bc_round, bc_origin


def bursty_traffic(seed: int, n: int, rate_hi: float, rate_lo: float,
                   period: int, duty: float, t0: int, t1: int,
                   max_messages: Optional[int] = None):
    """On/off traffic: rounds in the first ``duty`` fraction of each
    ``period`` draw Poisson(rate_hi) broadcasts, the rest Poisson(rate_lo)
    — the heavy-tailed load pattern large deployments actually see."""
    rng = np.random.default_rng(seed)
    ts = np.arange(t0, t1)
    hot = (ts % max(1, period)) < duty * period
    counts = rng.poisson(np.where(hot, rate_hi, rate_lo))
    bc_round, bc_origin = _per_round_origins(rng, n, counts, t0)
    if max_messages is not None:
        bc_round, bc_origin = bc_round[:max_messages], bc_origin[:max_messages]
    return bc_round, bc_origin


def _ring_entry(seed, n, k, max_delay, free_slots, beta):
    """directed ring on slot 0 plus random extra out-links"""
    return ring_topology(seed, n, k, max_delay, free_slots)


def _kregular_entry(seed, n, k, max_delay, free_slots, beta):
    """random k-regular digraph (equal out- AND in-degree)"""
    return kregular_topology(seed, n, k, max_delay, free_slots)


def _smallworld_entry(seed, n, k, max_delay, free_slots, beta):
    """Watts-Strogatz ring lattice rewired with probability beta"""
    return smallworld_topology(seed, n, k, beta=beta, max_delay=max_delay,
                               free_slots=free_slots)


#: Topology dispatch table, keyed by the ``topology=`` builder argument.
#: Every entry has the uniform signature
#: ``(seed, n, k, max_delay, free_slots, beta) -> (adj0, delay0)``.
#: ``repro.api.TOPOLOGIES`` is a live view of this dict, so a kind
#: registered there is immediately buildable by every scenario builder.
_TOPOLOGIES = {"ring": _ring_entry, "kregular": _kregular_entry,
               "smallworld": _smallworld_entry}


def _build_topology(topology: str, seed: int, n: int, k: int,
                    max_delay: int, free_slots: int, beta: float):
    try:
        builder = _TOPOLOGIES[topology]
    except KeyError:
        raise ValueError(f"unknown topology {topology!r}; "
                         f"choose from {sorted(_TOPOLOGIES)}") from None
    return builder(seed, n, k, max_delay, free_slots, beta)


@dataclass(frozen=True)
class TrafficModel:
    """A sustained-traffic generator, dispatchable by name.

    ``build(seed, n, t0, t1, max_messages, params)`` returns the sorted
    ``(bcast_round, bcast_origin)`` pair (unique (origin, round), per
    the lockstep batching rule); ``mean_rate(params)`` is the expected
    broadcasts per round, used to size the schedule span.  ``params``
    carries the RunSpec traffic knobs: rate, rate_lo, period, duty.
    ``repro.api.TRAFFIC`` is a live view of the ``_TRAFFIC`` table, so
    a model registered there is immediately usable by
    :func:`sustained_scenario`."""

    build: object
    mean_rate: object
    description: str = ""        # one line for the CLI discovery surface


_TRAFFIC = {
    "poisson": TrafficModel(
        build=lambda seed, n, t0, t1, mm, p:
            poisson_traffic(seed, n, p["rate"], t0, t1, mm),
        mean_rate=lambda p: p["rate"],
        description="Poisson(rate) broadcasts per round, sustained"),
    "bursty": TrafficModel(
        build=lambda seed, n, t0, t1, mm, p:
            bursty_traffic(seed, n, p["rate"], p["rate_lo"], p["period"],
                           p["duty"], t0, t1, mm),
        mean_rate=lambda p: (p["duty"] * p["rate"]
                             + (1 - p["duty"]) * p["rate_lo"]),
        description="on/off load: Poisson(rate) for a duty fraction of "
        "each period, Poisson(rate_lo) otherwise"),
}


# --------------------------------------------------------------------- #
# Partition / heal
# --------------------------------------------------------------------- #
def partition_heal_scenario(seed: int, n: int, k: int = 5, m_app: int = 12,
                            n_cross: Optional[int] = None,
                            n_heal: Optional[int] = None,
                            n_bridge: int = 1,
                            max_delay: int = 2, pong_delay: int = 1,
                            traffic_during_partition: bool = False
                            ) -> VecScenario:
    """Two halves, each internally ringed on slot 0, joined by cross
    links on slot ``k-2``.  The partition removes all but ``n_bridge``
    cross links per direction in one round; after a quiet interval,
    fresh cross links are added on the free slot ``k-1`` (the heal) and
    race the tail of the traffic, so the healed links re-enter through
    the Algorithm 2 ping phase exactly like any other addition.

    The surviving bridge makes this a *brownout* rather than a total
    partition, and deliberately so: pings travel over safe links only,
    so after a total partition a healed link's ping could never reach
    its target — the gate would hang forever in both engines and nothing
    would ever heal.  The thin bridge keeps the ping phase functional
    (and Algorithm 2 exercised end-to-end) while cross-half capacity
    collapses; traffic broadcast during the brownout (opt-in) squeezes
    through the bridge at much higher latency."""
    assert k >= 4 and n >= 8
    assert n_bridge >= 1, "a total partition cannot re-gate (see docstring)"
    half = n // 2
    rng = np.random.default_rng(seed)
    n_cross = n_cross if n_cross is not None else max(2, n // 8)
    n_cross = max(n_cross, n_bridge + 1)
    n_heal = n_heal if n_heal is not None else max(2, n // 8)

    adj0 = np.full((n, k), -1, np.int64)
    sides = (np.arange(half), np.arange(half, n))
    for side in sides:
        m = len(side)
        adj0[side, 0] = side[(np.arange(m) + 1) % m]       # intra-half ring
        for p in side:
            p = int(p)
            used = {p, int(adj0[p, 0])}
            for j in range(1, k - 2):
                if len(used) >= m:
                    break
                while True:
                    q = int(side[rng.integers(0, m)])
                    if q not in used:
                        break
                adj0[p, j] = q
                used.add(q)
    # cross links, slot k-2; the first n_bridge per *direction* survive
    # the partition as the brownout bridge (a direction can contribute
    # fewer than n_cross links when n_cross exceeds the half size, so
    # survivors are tracked per direction, not by modulo)
    cross_p, cross_q, sever = [], [], []
    for a, b in ((sides[0], sides[1]), (sides[1], sides[0])):
        ps = a[rng.permutation(len(a))[:n_cross]]
        qs = b[rng.integers(0, len(b), size=len(ps))]
        assert len(ps) > n_bridge, \
            "half too small to keep a bridge and still partition"
        cross_p.extend(int(x) for x in ps)
        cross_q.extend(int(x) for x in qs)
        sever.extend(int(x) for x in ps[n_bridge:])
    adj0[cross_p, k - 2] = cross_q
    delay0 = rng.integers(1, max_delay + 1, size=(n, k)).astype(np.int32)

    settle = settle_rounds(n, k, max_delay, pong_delay)
    m1 = max(2, m_app // 3)
    m2 = max(1, m_app // 4) if traffic_during_partition else 0
    m3 = m_app - m1 - m2
    bc_r1, bc_o1 = _spread_broadcasts(rng, n, m1, 0, 2 * m1)
    t_part = 2 * m1 + settle
    rm_round = _i32(np.full(len(sever), t_part))
    rm_p = _i32(sever)
    rm_k = _i32(np.full(len(sever), k - 2))
    if m2:
        bc_r2, bc_o2 = _spread_broadcasts(rng, n, m2, t_part + 2,
                                          t_part + 2 + 2 * m2)
    t_heal = t_part + (2 * m2 + 2 if m2 else 0) + settle
    # heal: distinct processes, fresh cross targets on the free slot k-1
    heal_p_pool = np.concatenate([sides[0][rng.permutation(half)[:n_heal]],
                                  sides[1][rng.permutation(n - half)[:n_heal]]])
    add_round, add_p, add_k, add_q, add_delay = [], [], [], [], []
    for p in heal_p_pool:
        p = int(p)
        other = sides[1] if p < half else sides[0]
        used = {p} | {int(q) for q in adj0[p] if q >= 0}
        while True:
            q = int(other[rng.integers(0, len(other))])
            if q not in used:
                break
        add_round.append(t_heal + len(add_round) % 4)
        add_p.append(p)
        add_k.append(k - 1)
        add_q.append(q)
        add_delay.append(int(rng.integers(1, max_delay + 1)))
    order = np.argsort(np.asarray(add_round), kind="stable")
    adds = tuple(_i32(np.asarray(a)[order]) for a in
                 (add_round, add_p, add_k, add_q, add_delay))
    bc_r3, bc_o3 = _spread_broadcasts(rng, n, m3, t_heal, t_heal + 4 + m3)
    parts_r = [bc_r1] + ([bc_r2] if m2 else []) + [bc_r3]
    parts_o = [bc_o1] + ([bc_o2] if m2 else []) + [bc_o3]
    bc_round = _i32(np.concatenate(parts_r))
    bc_origin = _i32(np.concatenate(parts_o))
    rounds = int(t_heal) + 4 + m3 + settle
    return VecScenario(n=n, k=k, rounds=rounds,
                       adj0=adj0.astype(np.int32), delay0=delay0,
                       bcast_round=bc_round, bcast_origin=bc_origin,
                       add_round=adds[0], add_p=adds[1], add_k=adds[2],
                       add_q=adds[3], add_delay=adds[4],
                       rm_round=rm_round, rm_p=rm_p, rm_k=rm_k,
                       pong_delay=pong_delay).validate()


# --------------------------------------------------------------------- #
# Churn waves
# --------------------------------------------------------------------- #
def churn_wave_scenario(seed: int, n: int, k: int = 6, m_app: int = 18,
                        waves: int = 3, adds_per_wave: Optional[int] = None,
                        rms_per_wave: Optional[int] = None,
                        wave_gap: Optional[int] = None, max_delay: int = 2,
                        pong_delay: int = 1, topology: str = "ring",
                        beta: float = 0.2) -> VecScenario:
    """Churn arriving in periodic waves — each wave batches link
    additions (on distinct processes drawn from a shared pool, so no
    slot is reused) and removals, with traffic flowing throughout.  The
    dynamic-membership pattern of diurnal or flash-crowd systems."""
    adds_per_wave = adds_per_wave if adds_per_wave is not None \
        else max(2, n // (8 * waves))
    rms_per_wave = rms_per_wave if rms_per_wave is not None \
        else max(2, n // (8 * waves))
    adj0, delay0 = _build_topology(topology, seed, n, k, max_delay,
                                   free_slots=1, beta=beta)
    rng = np.random.default_rng(seed + 5)
    settle = settle_rounds(n, k, max_delay, pong_delay)
    wave_gap = wave_gap if wave_gap is not None else settle // 2 + 4
    early = max(2, m_app // (waves + 1))
    bc_round, bc_origin = _spread_broadcasts(rng, n, early, 0, 2 * early)
    bc_round, bc_origin = [bc_round], [bc_origin]
    lo = 2 * early + settle

    pool = rng.permutation(n)          # distinct add-processes across ALL waves
    pool_at = 0
    add_round, add_p, add_k, add_q, add_delay = [], [], [], [], []
    rm_round, rm_p, rm_k = [], [], []
    rm_seen = set()
    m_left = m_app - early
    for wv in range(waves):
        w_lo = lo + wv * wave_gap
        w_hi = w_lo + max(3, adds_per_wave)
        for _ in range(adds_per_wave):
            if pool_at >= n:
                break
            p = int(pool[pool_at])
            pool_at += 1
            used = {p} | {int(q) for q in adj0[p] if q >= 0}
            if len(used) >= n:
                continue
            while True:
                q = int(rng.integers(0, n))
                if q not in used:
                    break
            add_round.append(int(rng.integers(w_lo, w_hi)))
            add_p.append(p)
            add_k.append(k - 1)
            add_q.append(q)
            add_delay.append(int(rng.integers(1, max_delay + 1)))
        for _ in range(rms_per_wave):
            p = int(rng.integers(0, n))
            kk = int(rng.integers(1, max(2, k - 1)))
            if adj0[p, kk] >= 0 and (p, kk) not in rm_seen:
                rm_seen.add((p, kk))
                rm_round.append(int(rng.integers(w_lo, w_hi)))
                rm_p.append(p)
                rm_k.append(kk)
        m_wave = m_left // (waves - wv)
        m_left -= m_wave
        if m_wave:
            r, o = _spread_broadcasts(rng, n, m_wave, w_lo, w_hi + 4)
            bc_round.append(r)
            bc_origin.append(o)
    order = np.argsort(np.asarray(add_round), kind="stable")
    adds = tuple(_i32(np.asarray(a)[order]) for a in
                 (add_round, add_p, add_k, add_q, add_delay))
    if rm_round:
        order = np.argsort(np.asarray(rm_round), kind="stable")
        rms = tuple(_i32(np.asarray(a)[order])
                    for a in (rm_round, rm_p, rm_k))
    else:
        rms = (_empty(), _empty(), _empty())
    bc_all = np.concatenate(bc_round)
    bo_all = np.concatenate(bc_origin)
    order = np.argsort(bc_all, kind="stable")
    rounds = lo + waves * wave_gap + adds_per_wave + 8 + settle
    return VecScenario(n=n, k=k, rounds=rounds, adj0=adj0, delay0=delay0,
                       bcast_round=_i32(bc_all[order]),
                       bcast_origin=_i32(bo_all[order]),
                       add_round=adds[0], add_p=adds[1], add_k=adds[2],
                       add_q=adds[3], add_delay=adds[4],
                       rm_round=rms[0], rm_p=rms[1], rm_k=rms[2],
                       pong_delay=pong_delay).validate()


# --------------------------------------------------------------------- #
# Sustained heavy traffic (the streaming engine's home scenario)
# --------------------------------------------------------------------- #
def sustained_scenario(seed: int, n: int, k: int = 8,
                       rate: float = 4.0, messages: int = 1000,
                       topology: str = "kregular",
                       traffic: str = "poisson", beta: float = 0.2,
                       burst_period: int = 64, burst_duty: float = 0.25,
                       rate_lo: Optional[float] = None,
                       max_delay: int = 1, mode: str = "pc",
                       pong_delay: int = 1) -> VecScenario:
    """Open-ended sustained load: ``messages`` broadcasts at ``rate`` per
    round on a static well-connected overlay.  Built for the streaming
    windowed engine — the monolithic engine would need O(N·messages)
    memory — but emits the same ``VecScenario`` schema as every other
    builder, so small instances still cross-validate on the exact
    engine."""
    free_slots = 0
    adj0, delay0 = _build_topology(topology, seed, n, k, max_delay,
                                   free_slots, beta)
    try:
        model = _TRAFFIC[traffic]
    except KeyError:
        raise ValueError(f"unknown traffic model {traffic!r}; "
                         f"choose from {sorted(_TRAFFIC)}") from None
    if not isinstance(model, TrafficModel):
        raise ValueError(f"traffic {traffic!r} is not a sustained-traffic "
                         "model (it only schedules batch broadcasts)")
    params = dict(rate=rate, rate_lo=rate / 8 if rate_lo is None
                  else rate_lo, period=burst_period, duty=burst_duty)
    # size the span by the *effective* mean rate (bursty spends most
    # rounds at rate_lo), then grow it if the random draw fell short
    eff_rate = model.mean_rate(params)
    span = max(8, int(np.ceil(messages / max(eff_rate, 1e-9) * 1.25)))
    for _ in range(16):
        bc_round, bc_origin = model.build(seed + 1, n, 0, span, messages,
                                          params)
        if len(bc_round) == messages:
            break
        span *= 2
    assert len(bc_round) == messages, \
        f"traffic span too short: {len(bc_round)} < {messages}"
    last = int(bc_round[-1]) if len(bc_round) else 0
    rounds = last + 1 + settle_rounds(n, k, max_delay, pong_delay,
                                      diam=diameter_bound(adj0))
    return VecScenario(n=n, k=k, rounds=rounds, adj0=adj0, delay0=delay0,
                       bcast_round=bc_round, bcast_origin=bc_origin,
                       mode=mode, pong_delay=pong_delay).validate()
