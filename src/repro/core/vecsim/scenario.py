"""Preplanned large-N scenarios for the vectorized lockstep simulator.

A :class:`VecScenario` is the dense-array twin of a scripted run on the
exact event simulator (``repro.core.events``): an initial ``(N, K)``
out-link table plus integer-round schedules for broadcasts, link churn
and crashes.  The same scenario object drives both engines —
``vecsim.sim.run_vec`` executes it in lockstep rounds, while
``vecsim.crossval.run_exact`` replays it event-by-event on ``Network`` —
which is what makes byte-level cross-validation of delivered-message
multisets possible (DESIGN.md §2.4).

Builder invariants (asserted by :meth:`VecScenario.validate`):

  * slot 0 holds a directed ring that is never removed, so the overlay
    stays strongly connected and flooding reaches everyone;
  * a process's active out-targets are distinct at all times, so a vec
    slot removal maps to exactly one ``Network.disconnect``;
  * at most one broadcast per (origin, round), so per-origin message
    counters are identical across engines;
  * same-round link additions touch distinct processes (the lockstep
    engine evaluates all of a round's additions against the same
    pre-round state).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import numpy as np

__all__ = ["INF", "VecScenario", "ring_topology", "settle_rounds",
           "static_scenario", "link_add_scenario", "churn_scenario",
           "crash_scenario"]

INF = np.int32(2 ** 30)


def _i32(a) -> np.ndarray:
    return np.asarray(a, np.int32)


def _empty() -> np.ndarray:
    return np.zeros((0,), np.int32)


@dataclass(frozen=True, eq=False)
class VecScenario:
    """A fully preplanned run: topology + integer-round schedules."""

    n: int                       # processes
    k: int                       # out-link slots per process
    rounds: int                  # lockstep rounds to simulate
    adj0: np.ndarray             # (N, K) initial out-targets, -1 = empty
    delay0: np.ndarray           # (N, K) per-link delay in rounds (>= 1)
    bcast_round: np.ndarray      # (M,) sorted broadcast rounds
    bcast_origin: np.ndarray     # (M,)
    add_round: np.ndarray = field(default_factory=_empty)   # (E,)
    add_p: np.ndarray = field(default_factory=_empty)
    add_k: np.ndarray = field(default_factory=_empty)
    add_q: np.ndarray = field(default_factory=_empty)
    add_delay: np.ndarray = field(default_factory=_empty)
    rm_round: np.ndarray = field(default_factory=_empty)    # (R,)
    rm_p: np.ndarray = field(default_factory=_empty)
    rm_k: np.ndarray = field(default_factory=_empty)
    crash_round: np.ndarray = field(default_factory=_empty)  # (C,)
    crash_pid: np.ndarray = field(default_factory=_empty)
    mode: str = "pc"             # "pc" (link-safety gating) | "r" (none)
    pong_delay: int = 1          # rounds for the pong rho to return
    always_gate: bool = False    # paper-faithful unconditional gating

    @property
    def m_app(self) -> int:
        return len(self.bcast_round)

    @property
    def n_adds(self) -> int:
        return len(self.add_round)

    @property
    def m_total(self) -> int:
        """App slots + one ping slot per link addition."""
        return self.m_app + self.n_adds

    def msg_counters(self) -> np.ndarray:
        """Per-origin sequential counter of each app slot (1-based), i.e.
        the ``AppMsg.counter`` the exact engine assigns to that broadcast."""
        counters = np.zeros(self.m_app, np.int32)
        seen: dict = {}
        for i, o in enumerate(self.bcast_origin):
            o = int(o)
            seen[o] = seen.get(o, 0) + 1
            counters[i] = seen[o]
        return counters

    def validate(self) -> "VecScenario":
        assert self.mode in ("pc", "r")
        assert self.adj0.shape == (self.n, self.k)
        assert self.delay0.shape == (self.n, self.k)
        assert (self.delay0[self.adj0 >= 0] >= 1).all()
        assert (np.diff(self.bcast_round) >= 0).all(), "broadcasts unsorted"
        pairs = set(zip(self.bcast_origin.tolist(), self.bcast_round.tolist()))
        assert len(pairs) == self.m_app, "duplicate (origin, round) broadcast"
        # same-round adds must touch distinct processes (lockstep batching)
        for t in np.unique(self.add_round):
            ps = self.add_p[self.add_round == t]
            assert len(set(ps.tolist())) == len(ps)
        # distinct out-targets per process, so every (p, slot) maps to one
        # (p, q) link in the exact-engine replay
        for p in range(self.n):
            tgt = [int(q) for q in self.adj0[p] if q >= 0]
            assert len(set(tgt)) == len(tgt), f"duplicate out-target at {p}"
            assert p not in tgt, f"self-link at {p}"
        add_pk = list(zip(self.add_p.tolist(), self.add_k.tolist()))
        assert len(set(add_pk)) == len(add_pk), "slot added twice (reuse " \
            "of a slot mid-run is not modeled)"
        for e in range(self.n_adds):
            p, q = int(self.add_p[e]), int(self.add_q[e])
            assert q != p, "add self-link"
            init = {int(x) for x in self.adj0[p] if x >= 0}
            assert q not in init, f"add duplicates an initial target of {p}"
        # removals never touch the connectivity ring (slot 0) or overwrite
        # a scheduled addition's slot
        if len(self.rm_k):
            assert (self.rm_k > 0).all(), "removal targets the ring slot"
            add_slots = set(zip(self.add_p.tolist(), self.add_k.tolist()))
            rm_slots = set(zip(self.rm_p.tolist(), self.rm_k.tolist()))
            assert not (add_slots & rm_slots), "removal races an addition"
        return self


def settle_rounds(n: int, k: int, max_delay: int, pong_delay: int = 1) -> int:
    """Rounds needed after the last scheduled event for a broadcast to
    flood the overlay and all ping phases to resolve (generous bound:
    flooding diameter ~ log_{k-1} N hops, each up to ``max_delay``)."""
    diam = math.ceil(math.log(max(n, 2)) / math.log(max(k - 1, 2))) + 3
    return (diam + 2) * max_delay + 2 * pong_delay + 6


def ring_topology(seed: int, n: int, k: int, max_delay: int = 3,
                  free_slots: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Directed ring on slot 0 plus random distinct extra out-links on
    slots ``1 .. k-1-free_slots``; the last ``free_slots`` slots stay
    empty for scheduled additions.  Targets are distinct per process so
    every (p, slot) maps to a unique (p, q) link."""
    rng = np.random.default_rng(seed)
    adj0 = np.full((n, k), -1, np.int32)
    adj0[:, 0] = (np.arange(n) + 1) % n
    n_extra = max(0, k - 1 - free_slots)
    for p in range(n):
        used = {p, int(adj0[p, 0])}
        j = 1
        while j <= n_extra and len(used) < n:
            q = int(rng.integers(0, n))
            if q not in used:
                adj0[p, j] = q
                used.add(q)
                j += 1
    delay0 = rng.integers(1, max_delay + 1, size=(n, k)).astype(np.int32)
    return adj0, delay0


def _spread_broadcasts(rng, n: int, m_app: int, lo: int, hi: int):
    """Sorted broadcast schedule over [lo, hi) with unique (origin, round)."""
    seen = set()
    rounds, origins = [], []
    while len(rounds) < m_app:
        t, o = int(rng.integers(lo, hi)), int(rng.integers(0, n))
        if (o, t) not in seen:
            seen.add((o, t))
            rounds.append(t)
            origins.append(o)
    order = np.argsort(np.asarray(rounds), kind="stable")
    return (_i32(np.asarray(rounds)[order]), _i32(np.asarray(origins)[order]))


def static_scenario(seed: int, n: int, k: int = 4, m_app: int = 8,
                    max_delay: int = 3, mode: str = "pc",
                    pong_delay: int = 1) -> VecScenario:
    """Broadcast-only run on a static ring+random overlay."""
    adj0, delay0 = ring_topology(seed, n, k, max_delay, free_slots=1)
    rng = np.random.default_rng(seed + 1)
    window = max(2 * m_app, 8)
    bc_round, bc_origin = _spread_broadcasts(rng, n, m_app, 0, window)
    rounds = window + settle_rounds(n, k, max_delay, pong_delay)
    return VecScenario(n=n, k=k, rounds=rounds, adj0=adj0, delay0=delay0,
                       bcast_round=bc_round, bcast_origin=bc_origin,
                       mode=mode, pong_delay=pong_delay).validate()


def _plan_adds(rng, n: int, k: int, adj0: np.ndarray, n_adds: int,
               lo: int, hi: int, max_delay: int):
    """Schedule link additions on the free slot ``k-1`` of distinct
    processes, each targeting a process not currently in p's out-view."""
    hi = max(hi, lo + 1)
    procs = rng.choice(n, size=min(n_adds, n), replace=False)
    add_round, add_p, add_k, add_q, add_delay = [], [], [], [], []
    for p in procs:
        p = int(p)
        used = {p} | {int(q) for q in adj0[p] if q >= 0}
        if len(used) >= n:
            continue
        while True:
            q = int(rng.integers(0, n))
            if q not in used:
                break
        add_round.append(int(rng.integers(lo, hi)))
        add_p.append(p)
        add_k.append(k - 1)
        add_q.append(q)
        add_delay.append(int(rng.integers(1, max_delay + 1)))
    order = np.argsort(np.asarray(add_round), kind="stable")
    return tuple(_i32(np.asarray(a)[order]) for a in
                 (add_round, add_p, add_k, add_q, add_delay))


def link_add_scenario(seed: int, n: int, k: int = 4, m_app: int = 10,
                      n_adds: Optional[int] = None, max_delay: int = 3,
                      pong_delay: int = 1) -> VecScenario:
    """Static bootstrap, early broadcasts, then a batch of link additions
    that race with later broadcasts — the Fig. 3 shortcut situation that
    PC-broadcast's ping gating exists to make safe.  Additions happen
    after every process has delivered the early traffic, so the gating
    condition (Algorithm 2 with the delivered-something fast-path)
    engages identically in both engines."""
    n_adds = n_adds if n_adds is not None else max(2, n // 8)
    adj0, delay0 = ring_topology(seed, n, k, max_delay, free_slots=1)
    rng = np.random.default_rng(seed + 2)
    settle = settle_rounds(n, k, max_delay, pong_delay)
    early = max(2, m_app // 3)
    bc_round_a, bc_origin_a = _spread_broadcasts(rng, n, early, 0, 2 * early)
    t_add_lo = 2 * early + settle          # early traffic fully delivered
    t_add_hi = t_add_lo + max(4, n_adds)
    adds = _plan_adds(rng, n, k, adj0, n_adds, t_add_lo, t_add_hi, max_delay)
    bc_round_b, bc_origin_b = _spread_broadcasts(
        rng, n, m_app - early, t_add_lo, t_add_hi + 4)
    bc_round = np.concatenate([bc_round_a, bc_round_b])
    bc_origin = np.concatenate([bc_origin_a, bc_origin_b])
    rounds = int(t_add_hi) + 4 + settle
    return VecScenario(n=n, k=k, rounds=rounds, adj0=adj0, delay0=delay0,
                       bcast_round=_i32(bc_round), bcast_origin=_i32(bc_origin),
                       add_round=adds[0], add_p=adds[1], add_k=adds[2],
                       add_q=adds[3], add_delay=adds[4],
                       pong_delay=pong_delay).validate()


def churn_scenario(seed: int, n: int, k: int = 5, m_app: int = 12,
                   n_adds: Optional[int] = None, n_rms: Optional[int] = None,
                   max_delay: int = 3, pong_delay: int = 1,
                   churn_window: Optional[int] = None) -> VecScenario:
    """Broadcasts interleaved with batched link additions *and* removals
    (the ring is never removed, so the overlay stays connected).

    ``churn_window`` — rounds the add/remove batch is spread over; adds
    land on distinct processes, so packing several into one round is
    valid for the lockstep batching rule."""
    n_adds = n_adds if n_adds is not None else max(2, n // 8)
    n_rms = n_rms if n_rms is not None else max(2, n // 8)
    adj0, delay0 = ring_topology(seed, n, k, max_delay, free_slots=1)
    rng = np.random.default_rng(seed + 3)
    settle = settle_rounds(n, k, max_delay, pong_delay)
    early = max(2, m_app // 3)
    bc_round_a, bc_origin_a = _spread_broadcasts(rng, n, early, 0, 2 * early)
    lo = 2 * early + settle
    hi = lo + (churn_window if churn_window is not None
               else max(6, n_adds, n_rms))
    adds = _plan_adds(rng, n, k, adj0, n_adds, lo, hi, max_delay)
    # removals: random non-ring, non-add slots that are populated initially
    rm_round, rm_p, rm_k = [], [], []
    for _ in range(n_rms):
        p = int(rng.integers(0, n))
        kk = int(rng.integers(1, max(2, k - 1)))
        if adj0[p, kk] >= 0:
            rm_round.append(int(rng.integers(lo, hi)))
            rm_p.append(p)
            rm_k.append(kk)
    if rm_round:
        order = np.argsort(np.asarray(rm_round), kind="stable")
        rm = tuple(_i32(np.asarray(a)[order]) for a in (rm_round, rm_p, rm_k))
    else:
        rm = (_empty(), _empty(), _empty())
    bc_round_b, bc_origin_b = _spread_broadcasts(rng, n, m_app - early,
                                                 lo, hi + 4)
    bc_round = np.concatenate([bc_round_a, bc_round_b])
    bc_origin = np.concatenate([bc_origin_a, bc_origin_b])
    rounds = int(hi) + 4 + settle
    return VecScenario(n=n, k=k, rounds=rounds, adj0=adj0, delay0=delay0,
                       bcast_round=_i32(bc_round), bcast_origin=_i32(bc_origin),
                       add_round=adds[0], add_p=adds[1], add_k=adds[2],
                       add_q=adds[3], add_delay=adds[4],
                       rm_round=rm[0], rm_p=rm[1], rm_k=rm[2],
                       pong_delay=pong_delay).validate()


def crash_scenario(seed: int, n: int, k: int = 6, m_app: int = 10,
                   n_crashes: int = 2, max_delay: int = 2,
                   pong_delay: int = 1) -> VecScenario:
    """Silent crashes (Fig. 5b) mid-broadcast on a well-connected overlay
    (k large enough that the correct subgraph almost surely stays
    connected).  Crashed processes freeze; correct ones keep delivering."""
    base = static_scenario(seed, n, k=k, m_app=m_app, max_delay=max_delay,
                           pong_delay=pong_delay)
    rng = np.random.default_rng(seed + 4)
    mid = int(base.bcast_round[m_app // 2])
    pids = rng.choice(n, size=n_crashes, replace=False)
    # crashed processes never broadcast afterwards: drop their later slots
    keep = ~(np.isin(base.bcast_origin, pids) & (base.bcast_round >= mid))
    return replace(base,
                   bcast_round=base.bcast_round[keep],
                   bcast_origin=base.bcast_origin[keep],
                   crash_round=_i32(np.full(n_crashes, mid)),
                   crash_pid=_i32(pids)).validate()
