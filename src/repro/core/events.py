"""Deterministic discrete-event simulator with FIFO links.

This module is the substrate for the *exact* reproduction of the paper's
algorithms (Algorithms 1-3).  It models:

  * directed FIFO links with (possibly time-varying) transmission delays,
  * an out-of-band channel for pong replies (the paper: "Replies rho travel
    using any communication mean"), optionally lossy,
  * link addition/removal and process crash/departure,
  * per-process timeouts (used by Algorithm 3),
  * a global event trace consumed by the happens-before oracle.

Determinism: the event queue is a heap keyed by (time, seq) where ``seq`` is
a monotone tie-breaker, and all randomness flows from one seeded generator.
"""

from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from .types import DelayFn, NetStats, constant_delay, uniform_delay

__all__ = [
    "Network",
    "Link",
    "NetStats",
    "EPS",
    "DelayFn",
    "constant_delay",
    "uniform_delay",
]

# Minimal spacing between two arrivals on the same FIFO link.  Keeps the
# arrival order on a link identical to the send order even when the delay
# function is time-varying or jittered (FIFO discipline).
EPS = 1e-9


@dataclass
class Link:
    """A directed FIFO communication link ``src -> dst``."""

    src: int
    dst: int
    delay_fn: DelayFn
    # Arrival time of the last message scheduled on this link; successors
    # must arrive strictly after it (FIFO).
    last_arrival: float = -1.0
    # Messages scheduled but not yet received (event ids).  Used to drop
    # in-flight traffic when the link is removed.
    in_flight: int = 0
    alive: bool = True


class Network:
    """Deterministic event-driven network of protocol processes.

    Protocol instances are registered with :meth:`add_process` and must
    implement the callbacks ``on_receive(src, msg)``, ``on_oob(src, msg)``,
    ``on_open(q)``, ``on_close(q)`` and ``on_timeout(payload)`` (see
    ``repro.core.base.Protocol``).
    """

    def __init__(
        self,
        seed: int = 0,
        default_delay: DelayFn | float = 1.0,
        oob_delay: DelayFn | float | None = None,
        oob_loss: float = 0.0,
    ) -> None:
        self.rng = random.Random(seed)
        self.time: float = 0.0
        self._queue: List[Tuple[float, int, Tuple]] = []
        self._seq = itertools.count()
        self.procs: Dict[int, Any] = {}
        self.links: Dict[Tuple[int, int], Link] = {}
        self.out: Dict[int, List[int]] = {}  # src -> [dst] (alive links)
        if not callable(default_delay):
            default_delay = constant_delay(float(default_delay))
        self.default_delay: DelayFn = default_delay
        if oob_delay is None:
            oob_delay = default_delay
        elif not callable(oob_delay):
            oob_delay = constant_delay(float(oob_delay))
        self.oob_delay: DelayFn = oob_delay
        self.oob_loss = float(oob_loss)
        self.stats = NetStats()
        # Event trace for the oracle: list of (time, kind, pid, data).
        self.trace: List[Tuple[float, str, int, Any]] = []
        self.trace_enabled = True

    # ------------------------------------------------------------------ #
    # Topology management
    # ------------------------------------------------------------------ #
    def add_process(self, proc: Any) -> None:
        assert proc.pid not in self.procs, f"duplicate pid {proc.pid}"
        self.procs[proc.pid] = proc
        self.out.setdefault(proc.pid, [])
        proc.net = self

    def has_link(self, a: int, b: int) -> bool:
        lk = self.links.get((a, b))
        return lk is not None and lk.alive

    def connect(self, a: int, b: int, delay: DelayFn | float | None = None,
                bidirectional: bool = False) -> None:
        """Add the directed link ``a -> b`` and notify ``a`` (paper: open(q))."""
        if self.has_link(a, b):
            return
        if delay is None:
            delay_fn = self.default_delay
        elif not callable(delay):
            delay_fn = constant_delay(float(delay))
        else:
            delay_fn = delay
        lk = self.links.get((a, b))
        if lk is None:
            lk = Link(a, b, delay_fn)
            self.links[(a, b)] = lk
        else:  # resurrect a removed link
            lk.alive = True
            lk.delay_fn = delay_fn
            lk.last_arrival = self.time
        self.out[a].append(b)
        self._record("open", a, b)
        self.procs[a].on_open(b)
        if bidirectional:
            self.connect(b, a, delay=delay, bidirectional=False)

    def disconnect(self, a: int, b: int, bidirectional: bool = False) -> None:
        """Remove the link ``a -> b``; in-flight messages on it are dropped."""
        lk = self.links.get((a, b))
        if lk is not None and lk.alive:
            lk.alive = False
            self.out[a].remove(b)
            self._record("close", a, b)
            self.procs[a].on_close(b)
        if bidirectional:
            self.disconnect(b, a, bidirectional=False)

    def crash(self, pid: int) -> None:
        """Crash a process: it stops reacting; its links die silently
        (neighbors are NOT notified — Fig. 5b's silent-departure scenario
        corresponds to crashing without disconnecting)."""
        self.procs[pid].crashed = True
        self._record("crash", pid, None)

    def depart(self, pid: int) -> None:
        """Graceful departure: remove all incident links, then crash."""
        for (a, b), lk in list(self.links.items()):
            if lk.alive and (a == pid or b == pid):
                self.disconnect(a, b)
        self.crash(pid)

    def neighbors(self, pid: int) -> List[int]:
        return list(self.out.get(pid, ()))

    # ------------------------------------------------------------------ #
    # Messaging
    # ------------------------------------------------------------------ #
    def send(self, src: int, dst: int, msg: Any) -> None:
        """Send ``msg`` over the FIFO link ``src -> dst``."""
        lk = self.links.get((src, dst))
        if lk is None or not lk.alive:
            return  # link vanished under the sender; message lost
        delay = max(0.0, lk.delay_fn(self.time, self.rng))
        arrival = max(self.time + delay, lk.last_arrival + EPS)
        lk.last_arrival = arrival
        lk.in_flight += 1
        self.stats.sent_messages += 1
        self._push(arrival, ("recv", src, dst, msg))

    def send_oob(self, src: int, dst: int, msg: Any) -> None:
        """Out-of-band unicast (pong replies): any channel, possibly lossy,
        NOT FIFO with respect to link traffic."""
        self.stats.oob_messages += 1
        if self.oob_loss > 0.0 and self.rng.random() < self.oob_loss:
            return  # lost
        delay = max(0.0, self.oob_delay(self.time, self.rng))
        self._push(self.time + delay, ("oob", src, dst, msg))

    def set_timeout(self, pid: int, delay: float, payload: Any) -> None:
        self._push(self.time + delay, ("timeout", pid, payload))

    def call_later(self, delay: float, fn: Callable[[], None]) -> None:
        self._push(self.time + delay, ("call", fn))

    # ------------------------------------------------------------------ #
    # Event loop
    # ------------------------------------------------------------------ #
    def _push(self, t: float, ev: Tuple) -> None:
        heapq.heappush(self._queue, (t, next(self._seq), ev))

    def _record(self, kind: str, pid: int, data: Any) -> None:
        if self.trace_enabled:
            self.trace.append((self.time, kind, pid, data))

    def record_delivery(self, pid: int, msg: Any) -> None:
        """Called by protocols on app-message delivery (oracle hook)."""
        self.stats.deliveries += 1
        self._record("deliver", pid, msg)

    def record_broadcast(self, pid: int, msg: Any) -> None:
        self._record("broadcast", pid, msg)

    def run(self, until: float = float("inf"), max_events: int = 100_000_000) -> int:
        """Run the simulation until the queue is empty or ``until`` is hit.
        Returns the number of processed events."""
        n = 0
        while self._queue and n < max_events:
            t, _, ev = self._queue[0]
            if t > until:
                break
            heapq.heappop(self._queue)
            self.time = max(self.time, t)
            kind = ev[0]
            if kind == "recv":
                _, src, dst, msg = ev
                lk = self.links.get((src, dst))
                if lk is not None:
                    lk.in_flight -= 1
                    if not lk.alive:
                        n += 1
                        continue  # dropped with the link
                proc = self.procs.get(dst)
                if proc is not None and not getattr(proc, "crashed", False):
                    proc.on_receive(src, msg)
            elif kind == "oob":
                _, src, dst, msg = ev
                proc = self.procs.get(dst)
                if proc is not None and not getattr(proc, "crashed", False):
                    proc.on_oob(src, msg)
            elif kind == "timeout":
                _, pid, payload = ev
                proc = self.procs.get(pid)
                if proc is not None and not getattr(proc, "crashed", False):
                    proc.on_timeout(payload)
            elif kind == "call":
                ev[1]()
            n += 1
        if self._queue and n < max_events:
            self.time = until if until != float("inf") else self.time
        return n

    def idle(self) -> bool:
        return not self._queue
