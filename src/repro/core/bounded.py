"""Algorithm 3 — bounding buffer sizes and handling network failures.

Extends PC-broadcast with:
  * ``maxSize``  — a bound on each unsafe-link buffer; exceeding it resets
    the ping phase with a fresh counter (Fig. 6), discarding stale pongs;
  * ``maxRetry`` — a bound on phase restarts; past it the link is abandoned
    (``close``), trading it for liveness (the overlay replaces links);
  * timeouts   — lost pongs / silent departures (Fig. 5b-c) trigger retries.

State (paper, Algorithm 3):
  ``I`` — ping id  -> link awaiting that ping's pong,
  ``R`` — link     -> number of retries so far.

Method map (paper, Algorithm 3; hooks are invoked by Algorithm 2's
implementation in ``pcbroadcast.py``):

  ``on_ping_sent``    upon ping(from, to, id), lines 5-9: if to not in R:
                      R[to] <- 0; I[id] <- to; arm the retry timeout
  ``on_link_safe``    upon receiveAck(from, to, id), lines 10-12:
                      I <- I \\ id ; R <- R \\ to  (stale pongs never get
                      here — Algorithm 2 drops them on the buffer-counter
                      mismatch, Fig. 6c)
  ``on_pc_deliver``   upon PC-deliver(m), lines 13-16: any buffer with
                      |B[q]| > maxSize resets its phase via retry(q)
  ``retry``           function retry(q), lines 17-25: drop pending ping
                      ids for q; R[q] += 1; re-open the phase (fresh
                      counter + empty buffer) while R[q] <= maxRetry,
                      else close(q) and let the overlay replace the link
  ``on_timeout``      HANDLING FAILURES, lines 26-28: a ping whose id is
                      still in I when the timer fires lost its pong
                      (Fig. 5b-c) -> retry(to)
  ``on_close``        upon close(q): clear B[q] (Alg. 2) plus I/R entries
"""

from __future__ import annotations

from typing import Any, Dict

from .base import AppMsg
from .pcbroadcast import PCBroadcast

__all__ = ["BoundedPCBroadcast"]


class BoundedPCBroadcast(PCBroadcast):
    def __init__(
        self,
        pid: int,
        deliver_cb=None,
        ping_mode: str = "flood",
        always_gate: bool = False,
        direct_ping_fallback: bool = False,
        max_size: float = float("inf"),
        max_retry: float = float("inf"),
        ping_timeout: float = float("inf"),
    ):
        super().__init__(pid, deliver_cb, ping_mode, always_gate,
                         direct_ping_fallback)
        self.max_size = max_size
        self.max_retry = max_retry
        self.ping_timeout = ping_timeout
        self.I: Dict[int, int] = {}   # ping id -> link
        self.R: Dict[int, int] = {}   # link -> retries
        self.gave_up: list[int] = []  # links closed after maxRetry (stats)

    # ------------------------------------------------------------------ #
    # BOUNDING BUFFERS (Algorithm 3)
    # ------------------------------------------------------------------ #
    def on_ping_sent(self, q: int, ping_id: int) -> None:
        """upon ping(from, to, id): register retry state + arm a timeout."""
        if q not in self.R:                        # if q not in R: R[q] <- 0
            self.R[q] = 0
        self.I[ping_id] = q                        # I[id] <- to
        if self.ping_timeout != float("inf"):
            self.net.set_timeout(self.pid, self.ping_timeout,
                                 ("ping", q, ping_id))

    def on_link_safe(self, q: int, ping_id: int) -> None:
        """upon receiveAck(from, to, id): I <- I \\ id ; R <- R \\ to.

        (Stale pongs never reach here: PCBroadcast discards them on the
        buffer-counter mismatch, matching Fig. 6c.)"""
        self.I.pop(ping_id, None)
        self.R.pop(q, None)

    def on_pc_deliver(self, m: AppMsg) -> None:
        """upon PC-deliver(m): reset any buffer past its bound."""
        over = [q for q, ent in self.B.items() if len(ent[1]) > self.max_size]
        for q in over:                             # |B[q]| > maxSize
            self.retry(q)

    def on_close(self, q: int) -> None:
        """upon close(q): drop buffer (Alg. 2) and retry state (Alg. 3)."""
        super().on_close(q)
        for i in [i for i, lk in self.I.items() if lk == q]:
            del self.I[i]                          # I <- I \ i
        self.R.pop(q, None)                        # R <- R \ q

    def retry(self, q: int) -> None:
        """function retry(q)."""
        for i in [i for i, lk in self.I.items() if lk == q]:
            del self.I[i]
        if q in self.R:
            self.R[q] += 1
            if self.R[q] <= self.max_retry:
                # Paper: open(q).  The link is already gated (not in Q), so
                # re-run the ping-phase body directly: fresh counter, fresh
                # (empty) buffer, fresh ping.  Stale pongs are discarded by
                # the counter check.
                self._begin_ping_phase(q)
            else:
                # Give up on the link entirely (paper: close(q)).  The
                # overlay's dynamicity replaces abandoned links over time.
                self.gave_up.append(q)
                self.net.disconnect(self.pid, q)

    # ------------------------------------------------------------------ #
    # HANDLING FAILURES (Algorithm 3, lines 26-28)
    # ------------------------------------------------------------------ #
    def on_timeout(self, payload: Any) -> None:
        kind, q, ping_id = payload
        if kind == "ping" and ping_id in self.I:   # if id in I: retry(to)
            self.retry(q)
