"""repro.core.engine — TPU-native tensorized PC-broadcast simulation.

Event-driven -> bulk-synchronous adaptation of the paper's protocol
(DESIGN.md §2.1): dense per-round state, one lax.scan per run, process
axis shardable across devices (sharded.py).
"""

from .ref import analyze, run_ref
from .state import INF, EngineConfig, Schedule, build_state, random_instance
from .step import make_step, run_engine

__all__ = [
    "INF", "EngineConfig", "Schedule", "build_state", "random_instance",
    "analyze", "run_ref", "make_step", "run_engine",
]
