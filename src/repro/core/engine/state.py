"""Tensorized protocol-engine state and schedules.

The TPU-native adaptation of the paper (DESIGN.md §2.1): the event-driven
simulation becomes a bulk-synchronous round simulation over dense arrays.

  * time      — integer rounds; a message sent on a link with delay ``d`` at
    round ``t`` arrives at round ``t+d``; constant (or non-decreasing)
    per-link delays make FIFO automatic;
  * messages  — global slots ``0..M-1``; slots ``[0, m_app)`` are
    application broadcasts, slots ``[m_app, M)`` are ping messages, one per
    scheduled link addition (pings flood over safe links exactly like app
    messages — the paper's "ping travels using safe links");
  * state     — ``arr[q, m]``: earliest known arrival round of message m at
    process q; ``delivered[q, m]``: delivery round (-1 = not yet);
    per-link-slot arrays over ``(N, K)`` for adjacency, delay, activity and
    the ping-phase machinery (gate round, flush round, ping slot).

Everything is preplanned (schedules are dense arrays) so the whole run jits
into one ``lax.scan`` — no Python in the hot loop, and the process axis is
shard_map-partitionable (see ``sharded.py``).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

__all__ = ["INF", "EngineConfig", "Schedule", "build_state", "random_instance"]

INF = np.int32(2**30)


@dataclass(frozen=True)
class EngineConfig:
    n: int                      # processes
    k: int                      # out-link slots per process
    rounds: int                 # simulated rounds
    mode: str = "pc"            # "pc" (safe links) | "r" (use all links)
    pong_delay: int = 1         # rounds for rho to return (any channel)
    always_gate: bool = False   # paper-faithful unconditional gating

    def __post_init__(self):
        assert self.mode in ("pc", "r")


@dataclass
class Schedule:
    """Preplanned run: broadcasts + link churn, all numpy int32 arrays."""

    # broadcasts: message slot i is broadcast by origin[i] at round[i]
    bcast_round: np.ndarray      # (M_app,)
    bcast_origin: np.ndarray     # (M_app,)
    # link additions: at round, set adj[p, k] = q  (one ping slot each)
    add_round: np.ndarray        # (E,)
    add_p: np.ndarray            # (E,)
    add_k: np.ndarray            # (E,)
    add_q: np.ndarray            # (E,)
    add_delay: np.ndarray        # (E,)
    # link removals: at round, deactivate slot (p, k)
    rm_round: np.ndarray         # (R,)
    rm_p: np.ndarray             # (R,)
    rm_k: np.ndarray             # (R,)

    @property
    def m_app(self) -> int:
        return len(self.bcast_round)

    @property
    def n_adds(self) -> int:
        return len(self.add_round)

    @property
    def m_total(self) -> int:
        return self.m_app + self.n_adds

    @staticmethod
    def empty_churn(bcast_round, bcast_origin) -> "Schedule":
        z = np.zeros((0,), np.int32)
        return Schedule(np.asarray(bcast_round, np.int32),
                        np.asarray(bcast_origin, np.int32),
                        z, z, z, z, z, z, z, z)


def build_state(cfg: EngineConfig, sched: Schedule, adj0: np.ndarray,
                delay0: np.ndarray, active0: Optional[np.ndarray] = None):
    """Initial dense state (numpy; moved to device by the runner)."""
    n, k, m = cfg.n, cfg.k, sched.m_total
    if active0 is None:
        active0 = adj0 >= 0
    return dict(
        arr=np.full((n, m), INF, np.int32),
        delivered=np.full((n, m), -1, np.int32),
        adj=adj0.astype(np.int32),
        delay=delay0.astype(np.int32),
        active=active0.astype(bool),
        gate=np.full((n, k), -1, np.int32),       # -1 = safe
        flush=np.full((n, k), INF, np.int32),
        ping=np.full((n, k), -1, np.int32),       # message slot of the ping
    )


def random_instance(seed: int, n: int, k: int, m_app: int, n_adds: int,
                    n_rms: int, rounds: int, max_delay: int = 3,
                    mode: str = "pc", pong_delay: int = 1,
                    always_gate: bool = False):
    """A random connected instance: ring + random extra links, random
    broadcast/churn schedule.  Used by tests and benchmarks."""
    rng = np.random.default_rng(seed)
    cfg = EngineConfig(n=n, k=k, rounds=rounds, mode=mode,
                       pong_delay=pong_delay, always_gate=always_gate)
    adj0 = np.full((n, k), -1, np.int64)
    adj0[:, 0] = (np.arange(n) + 1) % n          # ring: strong connectivity
    for i in range(n):
        extra = rng.choice(n, size=min(k - 1, max(0, n - 1)), replace=False)
        extra = [int(x) for x in extra if x != i][: k - 2]
        for j, q in enumerate(extra):
            adj0[i, 1 + j] = q                   # leave last slot free
    delay0 = rng.integers(1, max_delay + 1, size=(n, k))

    last_event = max(1, rounds - 3 * max_delay - 6)
    bc_round = np.sort(rng.integers(0, last_event, size=m_app)).astype(np.int32)
    bc_origin = rng.integers(0, n, size=m_app).astype(np.int32)

    # distinct add rounds: the JAX engine evaluates all same-round adds
    # against pre-round state, the numpy ref sequentially — keep them apart
    n_adds = min(n_adds, last_event)
    add_round = np.sort(rng.choice(last_event, size=n_adds,
                                   replace=False)).astype(np.int32)
    add_p = rng.integers(0, n, size=n_adds).astype(np.int32)
    add_k = np.full(n_adds, k - 1, np.int32)     # adds target the free slot
    # distinct p per add so slot reuse cannot collide mid-phase
    if n_adds:
        add_p = np.array(rng.choice(n, size=n_adds, replace=n_adds > n),
                         np.int32)
    add_q = ((add_p + 1 + rng.integers(1, max(2, n - 1), size=n_adds)) % n
             ).astype(np.int32)
    add_delay = rng.integers(1, max_delay + 1, size=n_adds).astype(np.int32)

    rm_round = np.sort(rng.integers(0, last_event, size=n_rms)).astype(np.int32)
    rm_p = rng.integers(0, n, size=n_rms).astype(np.int32)
    rm_k = rng.integers(1, max(2, k - 1), size=n_rms).astype(np.int32)  # never the ring

    sched = Schedule(bc_round, bc_origin, add_round, add_p, add_k, add_q,
                     add_delay, rm_round, rm_p, rm_k)
    return cfg, sched, adj0, delay0
