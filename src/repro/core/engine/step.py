"""JAX tensorized engine: the whole run is one ``lax.scan`` over rounds.

Semantics are pinned to ``ref.py`` (numpy oracle); tests sweep random
instances for exact equality.  All shapes are static; the per-round body is
pure scatter/gather over ``(N, M)`` and ``(N, K)`` arrays, so the process
axis shards cleanly (see ``sharded.py``) and the same body runs unmodified
on TPU.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .state import INF, EngineConfig, Schedule, build_state

__all__ = ["run_engine", "make_step"]


def _scatter_min(arr: jnp.ndarray, rows: jnp.ndarray, vals: jnp.ndarray,
                 valid: jnp.ndarray) -> jnp.ndarray:
    """arr[rows[i], :] = min(arr[rows[i], :], vals[i, :]) where valid[i]."""
    n = arr.shape[0]
    rows = jnp.where(valid, rows, n)          # out-of-bounds -> dropped
    return arr.at[rows, :].min(vals, mode="drop")


def make_step(cfg: EngineConfig, sched: Schedule):
    """Build the per-round body (closure over the static schedule)."""
    m_app = sched.m_app
    bc_round = jnp.asarray(sched.bcast_round)
    bc_origin = jnp.asarray(sched.bcast_origin)
    add_round = jnp.asarray(sched.add_round)
    add_p = jnp.asarray(sched.add_p)
    add_k = jnp.asarray(sched.add_k)
    add_q = jnp.asarray(sched.add_q)
    add_delay = jnp.asarray(sched.add_delay)
    add_slot = jnp.asarray(m_app + np.arange(sched.n_adds, dtype=np.int32))
    rm_round = jnp.asarray(sched.rm_round)
    rm_p = jnp.asarray(sched.rm_p)
    rm_k = jnp.asarray(sched.rm_k)
    K = cfg.k
    pc_mode = cfg.mode == "pc"

    def step(state, t):
        arr, delivered, adj, delay, active, gate, flush, ping = state
        n = arr.shape[0]
        t = t.astype(jnp.int32)

        # -- 1. removals -------------------------------------------------- #
        if rm_round.shape[0]:
            sel = rm_round == t
            p_, k_ = jnp.where(sel, rm_p, n), rm_k
            active = active.at[p_, k_].set(False, mode="drop")
            gate = gate.at[p_, k_].set(-1, mode="drop")
            flush = flush.at[p_, k_].set(INF, mode="drop")
            ping = ping.at[p_, k_].set(-1, mode="drop")

        # -- 2. additions -------------------------------------------------- #
        if add_round.shape[0]:
            sel = add_round == t
            p_ = jnp.where(sel, add_p, n)
            adj = adj.at[p_, add_k].set(add_q, mode="drop")
            delay = delay.at[p_, add_k].set(add_delay, mode="drop")
            active = active.at[p_, add_k].set(True, mode="drop")
            if pc_mode:
                # gate if p has >=1 other safe active link AND (always_gate
                # or p already delivered an app message).
                safe_links = active & (gate < 0)
                safe_cnt = safe_links.sum(axis=1)                 # (N,)
                own_slot_safe = safe_links[
                    jnp.clip(add_p, 0, n - 1), add_k]             # (E,)
                other_safe = (safe_cnt[jnp.clip(add_p, 0, n - 1)]
                              - own_slot_safe.astype(jnp.int32)) >= 1
                if cfg.always_gate:
                    want = other_safe
                else:
                    has_del = (delivered[:, :m_app] >= 0).any(axis=1)
                    want = other_safe & has_del[jnp.clip(add_p, 0, n - 1)]
                gsel = sel & want
                pg = jnp.where(gsel, add_p, n)
                gate = gate.at[pg, add_k].set(t, mode="drop")
                flush = flush.at[pg, add_k].set(INF, mode="drop")
                ping = ping.at[pg, add_k].set(add_slot, mode="drop")
                # own ping is "delivered" by p now -> floods from phase 7
                delivered = delivered.at[pg, add_slot].set(t, mode="drop")
                # non-gated adds must clear any stale slot state
                csel = sel & ~want
                pc_ = jnp.where(csel, add_p, n)
                gate = gate.at[pc_, add_k].set(-1, mode="drop")
                flush = flush.at[pc_, add_k].set(INF, mode="drop")
                ping = ping.at[pc_, add_k].set(-1, mode="drop")

        # -- 3. broadcasts -------------------------------------------------- #
        if bc_round.shape[0]:
            sel = bc_round == t
            o_ = jnp.where(sel, bc_origin, n)
            slots = jnp.arange(m_app, dtype=jnp.int32)
            delivered = delivered.at[o_, slots].max(t, mode="drop")

        # -- 4. arrivals -> deliveries -------------------------------------- #
        newly = (arr == t) & (delivered < 0)
        delivered = jnp.where(newly, t, delivered)

        # -- 5. pong detection ---------------------------------------------- #
        if pc_mode:
            q_ = jnp.clip(adj, 0, n - 1)
            s_ = jnp.clip(ping, 0, delivered.shape[1] - 1)
            tgt_del = delivered[q_, s_]                           # (N, K)
            fire = (gate >= 0) & (flush == INF) & (ping >= 0) & (tgt_del >= 0)
            flush = jnp.where(fire, t + cfg.pong_delay, flush)

        # -- 6. flush buffered app messages over now-safe links ------------- #
        if pc_mode:
            d_app = delivered[:, :m_app]                          # (N, m_app)
            for kk in range(K):
                do = (flush[:, kk] == t) & active[:, kk]          # (N,)
                win = ((d_app >= gate[:, kk][:, None])
                       & (d_app < t) & do[:, None])               # (N, m_app)
                vals = jnp.where(
                    win, (t + delay[:, kk])[:, None].astype(jnp.int32), INF)
                pad = jnp.full((n, delivered.shape[1] - m_app), INF,
                               jnp.int32)
                arr = _scatter_min(arr, adj[:, kk],
                                   jnp.concatenate([vals, pad], axis=1), do)
            cleared = flush == t
            gate = jnp.where(cleared, -1, gate)
            ping = jnp.where(cleared, -1, ping)
            flush = jnp.where(cleared, INF, flush)

        # -- 7. forward this round's deliveries over safe active links ------ #
        new_del = delivered == t                                  # (N, M)
        for kk in range(K):
            ok = active[:, kk] & (gate[:, kk] < 0) & (adj[:, kk] >= 0)
            vals = jnp.where(new_del & ok[:, None],
                             (t + delay[:, kk])[:, None].astype(jnp.int32),
                             INF)
            arr = _scatter_min(arr, adj[:, kk], vals, ok)

        return (arr, delivered, adj, delay, active, gate, flush, ping), None

    return step


def run_engine(cfg: EngineConfig, sched: Schedule, adj0, delay0,
               jit: bool = True):
    """Run the tensorized engine; returns ``delivered`` as numpy (N, M)."""
    st = build_state(cfg, sched, adj0, delay0)
    state = (
        jnp.asarray(st["arr"]), jnp.asarray(st["delivered"]),
        jnp.asarray(st["adj"]), jnp.asarray(st["delay"]),
        jnp.asarray(st["active"]), jnp.asarray(st["gate"]),
        jnp.asarray(st["flush"]), jnp.asarray(st["ping"]),
    )
    step = make_step(cfg, sched)

    def run(state):
        rounds = jnp.arange(cfg.rounds, dtype=jnp.int32)
        final, _ = jax.lax.scan(step, state, rounds)
        return final

    if jit:
        run = jax.jit(run)
    final = run(state)
    return np.asarray(final[1])
