"""Multi-device tensorized engine: process axis sharded over a mesh.

The per-round body from ``step.py`` runs unmodified under ``jax.jit`` with
the ``(N, M)`` / ``(N, K)`` state sharded on the process axis; XLA inserts
the cross-shard collectives for scatters whose target row lives on another
device.  On a TPU pod this is how a 10^6-process fleet simulation runs; on
this box it is exercised with ``--xla_force_host_platform_device_count``
(tests spawn a subprocess so the flag precedes jax initialization).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .state import EngineConfig, Schedule, build_state
from .step import make_step

__all__ = ["run_engine_sharded", "pad_instance"]


def pad_instance(cfg: EngineConfig, adj0: np.ndarray, delay0: np.ndarray,
                 n_devices: int):
    """Pad the process axis to a multiple of the device count with inert,
    link-less processes (they never send or receive)."""
    n = cfg.n
    n_pad = (-n) % n_devices
    if n_pad == 0:
        return cfg, adj0, delay0
    adj0 = np.concatenate([adj0, np.full((n_pad, cfg.k), -1, adj0.dtype)])
    delay0 = np.concatenate(
        [delay0, np.ones((n_pad, cfg.k), delay0.dtype)])
    cfg = EngineConfig(n=n + n_pad, k=cfg.k, rounds=cfg.rounds, mode=cfg.mode,
                       pong_delay=cfg.pong_delay, always_gate=cfg.always_gate)
    return cfg, adj0, delay0


def run_engine_sharded(cfg: EngineConfig, sched: Schedule, adj0, delay0,
                       mesh=None):
    """Same contract as ``run_engine`` but state sharded over 'procs'."""
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), ("procs",))
    n_dev = int(np.prod(mesh.devices.shape))
    cfg, adj0, delay0 = pad_instance(cfg, adj0, delay0, n_dev)

    row = NamedSharding(mesh, P("procs"))
    st = build_state(cfg, sched, adj0, delay0)
    order = ("arr", "delivered", "adj", "delay", "active", "gate", "flush",
             "ping")
    state = tuple(jax.device_put(st[k], row) for k in order)

    step = make_step(cfg, sched)

    def run(state):
        rounds = jnp.arange(cfg.rounds, dtype=jnp.int32)
        final, _ = jax.lax.scan(step, state, rounds)
        return final

    shardings = tuple(row for _ in order)
    run_c = jax.jit(run, in_shardings=(shardings,),
                    out_shardings=shardings)
    final = run_c(state)
    return np.asarray(final[1])
