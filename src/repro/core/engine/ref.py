"""NumPy oracle for the tensorized engine: identical round semantics,
written as plain loops.  The JAX engine (step.py) must match it exactly
(tests/test_engine.py sweeps random instances)."""

from __future__ import annotations

import numpy as np

from .state import INF, EngineConfig, Schedule, build_state

__all__ = ["run_ref", "analyze"]


def run_ref(cfg: EngineConfig, sched: Schedule, adj0, delay0):
    st = build_state(cfg, sched, adj0, delay0)
    arr, delivered = st["arr"], st["delivered"]
    adj, delay, active = st["adj"], st["delay"], st["active"]
    gate, flush, ping = st["gate"], st["flush"], st["ping"]
    n, k, m_app = cfg.n, cfg.k, sched.m_app

    for t in range(cfg.rounds):
        # 1. removals
        for e in np.nonzero(sched.rm_round == t)[0]:
            p, kk = int(sched.rm_p[e]), int(sched.rm_k[e])
            active[p, kk] = False
            gate[p, kk], flush[p, kk], ping[p, kk] = -1, INF, -1
        # 2. additions (one ping slot each)
        for e in np.nonzero(sched.add_round == t)[0]:
            p, kk, q = int(sched.add_p[e]), int(sched.add_k[e]), int(sched.add_q[e])
            adj[p, kk], delay[p, kk], active[p, kk] = q, int(sched.add_delay[e]), True
            gate[p, kk], flush[p, kk], ping[p, kk] = -1, INF, -1
            if cfg.mode == "pc":
                other_safe = any(active[p, j] and gate[p, j] < 0
                                 for j in range(k) if j != kk)
                has_delivered = bool((delivered[p, :m_app] >= 0).any())
                if other_safe and (cfg.always_gate or has_delivered):
                    slot = m_app + e
                    gate[p, kk], ping[p, kk] = t, slot
                    delivered[p, slot] = t      # own ping: flooded below
        # 3. broadcasts
        for i in np.nonzero(sched.bcast_round == t)[0]:
            o = int(sched.bcast_origin[i])
            if delivered[o, i] < 0:
                delivered[o, i] = t
        # 4. arrivals -> deliveries
        newly = (arr == t) & (delivered < 0)
        delivered[newly] = t
        # 5. pong detection (target delivered the ping; rho returns oob)
        for p in range(n):
            for kk in range(k):
                if gate[p, kk] >= 0 and flush[p, kk] == INF:
                    s, q = ping[p, kk], adj[p, kk]
                    if s >= 0 and delivered[q, s] >= 0:
                        flush[p, kk] = t + cfg.pong_delay
        # 6. flush: buffered app messages ride the now-safe link
        for p in range(n):
            for kk in range(k):
                if flush[p, kk] == t and active[p, kk]:
                    q, g, d = adj[p, kk], gate[p, kk], delay[p, kk]
                    win = ((delivered[p, :m_app] >= g)
                           & (delivered[p, :m_app] < t))
                    for mm in np.nonzero(win)[0]:
                        arr[q, mm] = min(arr[q, mm], t + d)
                    gate[p, kk], flush[p, kk], ping[p, kk] = -1, INF, -1
        # 7. forward everything delivered this round over safe active links
        new_del = delivered == t
        for p in range(n):
            if not new_del[p].any():
                continue
            for kk in range(k):
                if active[p, kk] and gate[p, kk] < 0 and adj[p, kk] >= 0:
                    q, d = adj[p, kk], delay[p, kk]
                    for mm in np.nonzero(new_del[p])[0]:
                        arr[q, mm] = min(arr[q, mm], t + d)
    return delivered


def analyze(delivered: np.ndarray, sched: Schedule):
    """Causal-order analysis of an engine run (app messages only).

    Checks each message against its *direct* causal past (everything its
    broadcaster had delivered strictly before broadcasting); respecting the
    direct past at every process implies full causal order by induction."""
    m_app = sched.m_app
    d_app = delivered[:, :m_app]
    n = delivered.shape[0]
    n_viol = 0
    n_missing = 0
    latencies = []
    for i in range(m_app):
        o, r0 = int(sched.bcast_origin[i]), int(sched.bcast_round[i])
        past = np.nonzero((d_app[o] >= 0) & (d_app[o] < d_app[o, i]))[0]
        past = past[past != i]
        di = d_app[:, i]
        got_i = di >= 0
        if past.size:
            dj = d_app[:, past]
            n_viol += int(((dj > di[:, None]) & got_i[:, None]
                           & (dj >= 0)).sum())
            n_missing += int(((dj < 0) & got_i[:, None]).sum())
        latencies.extend((di[got_i] - r0).tolist())
    frac = float((d_app >= 0).mean())
    mean_lat = float(np.mean(latencies)) if latencies else float("nan")
    return dict(violations=n_viol, missing=n_missing,
                delivered_frac=frac, mean_latency=mean_lat)
